"""Roofline table builder (deliverable g).

Reads the dry-run JSONL (launch/dryrun.py --out) and renders the
per-(arch x shape x mesh) roofline table for EXPERIMENTS.md §Roofline:
three terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs
usefulness ratio, and per-device memory fit.

Run the dry-run first (its own process — device-count env var):

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out dryrun_all.jsonl
    PYTHONPATH=src python -m benchmarks.roofline dryrun_all.jsonl
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List

HBM_PER_CHIP = 16e9   # v5e


def load(path: str) -> List[Dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line.startswith("{"):
                rows.append(json.loads(line))
    # Last record wins per cell (re-runs append).
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:6.2f}ms"
    return f"{x * 1e6:6.1f}us"


def table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24} {'shape':12} {'mesh':8} {'compute':>9} "
           f"{'memory':>9} {'collective':>11} {'bound':>10} "
           f"{'useful':>7} {'roofline%':>9} {'peakGB':>7} fit")
    out = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        terms = {k: r[k] for k in ("compute_s", "memory_s", "collective_s")}
        dom = max(terms, key=terms.get)
        # roofline fraction: compute term / dominant term (how close the
        # step is to being compute-bound at peak).
        roof = terms["compute_s"] / max(terms[dom], 1e-30)
        peak = r["per_device_bytes"]["peak"] if isinstance(
            r["per_device_bytes"], dict) else r["per_device_bytes"]
        fit = "OK" if peak <= HBM_PER_CHIP else "OVER"
        uf = r.get("useful_flops_frac")
        out.append(
            f"{r['arch']:24} {r['shape']:12} {r['mesh']:8} "
            f"{fmt_s(terms['compute_s']):>9} {fmt_s(terms['memory_s']):>9} "
            f"{fmt_s(terms['collective_s']):>11} {dom[:-2]:>10} "
            f"{(uf if uf else 0):7.3f} {100 * roof:8.1f}% "
            f"{peak / 1e9:6.2f} {fit}")
    return "\n".join(out)


def main(argv=None):
    path = (argv or sys.argv[1:] or ["dryrun_all.jsonl"])[0]
    rows = load(path)
    print(table(rows))
    n_over = sum(1 for r in rows if (r["per_device_bytes"]["peak"]
                 if isinstance(r["per_device_bytes"], dict)
                 else r["per_device_bytes"]) > HBM_PER_CHIP)
    print(f"\n{len(rows)} cells; {n_over} exceed {HBM_PER_CHIP / 1e9:.0f} GB"
          " HBM/chip")


if __name__ == "__main__":
    main()
