"""Analytic per-chip HBM-traffic model for the roofline memory term.

XLA's ``cost_analysis()['bytes accessed']`` counts scan bodies once (same
defect as its FLOPs — see hlo_analysis.py), and unrolling every cell for
exact byte counts is not affordable at 512 devices, so the memory leg of
the roofline is derived analytically from first principles.  Every term is
a deliberate, documented over/under-approximation; EXPERIMENTS.md §Roofline
cross-checks one small unrolled cell against XLA's numbers.

All quantities are **bytes per chip per step**.
"""

from __future__ import annotations

import math
from typing import Dict

import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


def _mesh_factors(mesh) -> Dict[str, int]:
    tp = mesh.shape.get("model", 1)
    dp = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    return {"tp": tp, "dp": dp, "chips": tp * dp}


def kv_bytes_per_token_layer(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0                              # attention-free: no KV
    if cfg.mla is not None:
        m = cfg.mla
        return (m.kv_lora_rank + m.qk_rope_head_dim
                + m.kv_lora_rank) * BF16      # k payload + v payload
    return 2 * cfg.n_kv_heads * cfg.resolved_head_dim * BF16


def kv_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid.period
    if cfg.family == "encdec":
        return cfg.encdec.dec_layers
    return cfg.n_layers


def memory_bytes(arch: str, shape_name: str, mesh) -> Dict[str, float]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    f = _mesh_factors(mesh)
    tp, dp, chips = f["tp"], f["dp"], f["chips"]
    P = cfg.param_count()
    d = cfg.d_model
    L = cfg.n_layers

    if shape.kind == "train":
        toks_local = shape.global_batch * shape.seq_len / dp
        # Weights: fwd read + remat re-read + bwd read (TP slice each).
        w = 3 * BF16 * P / tp
        # Gradients: written fp32 + read by the optimizer (TP slice).
        g = 2 * F32 * P / tp
        # Optimizer: m, v read+write + fp32 master read+write (ZeRO-1).
        opt = 6 * F32 * P / chips
        # Activations: ~16 d-wide tensors per layer per token (store +
        # remat re-read, flash attention on-chip); MoE adds dispatch
        # buffers ~ top_k routed copies.
        c_act = 16
        if cfg.moe:
            c_act += 4 * cfg.moe.top_k
        act = L * toks_local * d * BF16 * c_act
        # Embedding + logits (vocab TP-sharded), fwd+bwd.
        emb = 2 * toks_local * cfg.vocab_size / tp * BF16
        total = w + g + opt + act + emb
        return {"weights": w, "grads": g, "opt": opt, "act": act,
                "emb": emb, "total": total}

    if shape.kind == "prefill":
        toks_local = shape.global_batch * shape.seq_len / dp
        w = BF16 * P / tp
        c_act = 8 + (2 * cfg.moe.top_k if cfg.moe else 0)
        act = L * toks_local * d * BF16 * c_act
        # KV pool writes: whole cache, page-sharded across all chips.
        kvw = (shape.global_batch * shape.seq_len
               * kv_bytes_per_token_layer(cfg) * kv_layers(cfg) / chips)
        emb = toks_local * cfg.vocab_size / tp * BF16 / shape.seq_len
        total = w + act + kvw + emb
        return {"weights": w, "act": act, "kv_write": kvw, "emb": emb,
                "total": total}

    # decode
    B_local = shape.global_batch / dp if shape.global_batch >= dp else \
        shape.global_batch / chips  # long-context: work spread everywhere
    # Weights: every chip multiplies against its TP slice once per token
    # batch; MoE reads only experts that receive ≥1 token.
    if cfg.moe:
        dense_p = cfg.active_param_count() - (
            (cfg.n_layers - cfg.moe.first_dense)
            * 3 * d * cfg.moe.d_expert * cfg.moe.top_k)
        expert_p = P - dense_p
        B_tok = max(1.0, shape.global_batch / dp)
        frac = min(1.0, B_tok * cfg.moe.top_k / cfg.moe.n_experts)
        w = BF16 * (dense_p + expert_p * frac) / tp
    else:
        w = BF16 * P / tp
    # KV read: context-parallel paged attention — the full cache streams
    # once, split over all chips (the Mosaic pool's page shards).
    kv = (shape.global_batch * (shape.seq_len + 1)
          * kv_bytes_per_token_layer(cfg) * kv_layers(cfg) / chips)
    if cfg.family == "encdec":
        kv += (shape.global_batch * cfg.encdec.source_len
               * 2 * cfg.n_kv_heads * cfg.resolved_head_dim * BF16
               * cfg.encdec.dec_layers / chips)
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        d_in = s.expand * d
        nh = d_in // s.head_dim
        state = cfg.n_layers * shape.global_batch * nh * s.head_dim \
            * s.d_state * F32 * 2 / dp   # read+write recurrent state
        kv += state
    act = L * max(1.0, shape.global_batch / dp) * d * BF16 * 12
    # logits: activations [B_local, V/tp] + unembed weight slice read once.
    emb = max(1.0, shape.global_batch / dp) * cfg.vocab_size / tp * BF16 \
        + cfg.vocab_size * d * BF16 / tp
    total = w + kv + act + emb
    return {"weights": w, "kv": kv, "act": act, "emb": emb, "total": total}
