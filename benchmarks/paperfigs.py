"""Paper-figure reproductions (Figs. 1, 5, 6, 7, 8 of the MICRO'17 paper).

Shared machinery: build multi-application workloads through the *real*
allocators (CoCoA vs the GPU-MMU baseline), translate the traces, and run
the Table-1 TLB/paging timing simulator.  Each ``fig*`` function returns a
list of result-dict rows and asserts the paper's headline claim for that
figure (soft check — prints PASS/FAIL rather than raising, so the full
suite always reports).

Scale knobs: the paper simulates 235 workloads for ~10^9 cycles each; we
default to a representative subset sized for minutes on CPU and keep the
full-scale settings one flag away (--full).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.tlb_sim import AppResult, SimConfig, TranslationSim, \
    weighted_speedup
from repro.core.workloads import (
    APP_NAMES,
    build_workload,
    heterogeneous_names,
    homogeneous_names,
)


def _run(names: Sequence[str], manager_kind: str, *, mode: str,
         ideal: bool = False, paging: bool = True, warm: bool = False,
         seed: int = 0, n_access: int = 4000):
    traces, mgr = build_workload(names, manager_kind, seed=seed,
                                 n_access=n_access)
    sim = TranslationSim(SimConfig(mode=mode, ideal=ideal, paging=paging,
                                   warm=warm), traces)
    res = sim.run()
    return res, sim, mgr


def _alone_ipc_cache() -> Dict[str, float]:
    return {}


_ALONE: Dict[tuple, float] = {}


def alone_ipc(app: str, n_access: int) -> float:
    """IPC_alone: the app running by itself on the baseline manager.

    Steady-state window (warm=True): over the paper's ~1e9-cycle horizon
    cold faults amortize to noise; in our scaled window they would
    dominate and mask the translation effects Figs. 5/6/8 measure.
    The paging axis is measured explicitly by Figs. 1 and 7.
    """
    key = (app, n_access)
    if key not in _ALONE:
        res, _, _ = _run([app], "gpu-mmu", mode="base", warm=True,
                         n_access=n_access)
        _ALONE[key] = res[0].ipc
    return _ALONE[key]


def ws_of(shared: List[AppResult], n_access: int) -> float:
    return float(sum(r.ipc / max(alone_ipc(r.name, n_access), 1e-12)
                     for r in shared))


# ------------------------------------------------------------------ figures


def fig1_translation_overhead(n_access=4000, apps=("bfs", "spmv", "lulesh",
                                                   "kmeans")):
    """Fig. 1: 4KB vs 2MB pages vs ideal TLB (no demand-paging cost)."""
    rows = []
    for app in apps:
        names = homogeneous_names(app, 2)
        perf = {}
        for label, mode, ideal in (("4KB", "base", False),
                                   ("2MB", "large", False),
                                   ("ideal", "base", True)):
            res, _, _ = _run(names, "gpu-mmu", mode=mode, ideal=ideal,
                             paging=False, n_access=n_access)
            perf[label] = float(np.sum([r.ipc for r in res]))
        rows.append({
            "bench": "fig1", "app": app,
            "perf_4k_norm": perf["4KB"] / perf["ideal"],
            "perf_2m_norm": perf["2MB"] / perf["ideal"],
        })
    m4 = np.mean([r["perf_4k_norm"] for r in rows])
    m2 = np.mean([r["perf_2m_norm"] for r in rows])
    # Paper: 4KB loses ~48.1% vs ideal; 2MB comes within ~2%.
    ok = (m4 < 0.75) and (m2 > 0.9)
    rows.append({"bench": "fig1", "app": "MEAN", "perf_4k_norm": m4,
                 "perf_2m_norm": m2, "claim_4k_much_worse": ok})
    return rows


def fig5_homogeneous(n_access=4000, apps=("spmv", "bfs", "kmeans"),
                     counts=(1, 2, 3, 4, 5)):
    """Fig. 5: homogeneous weighted speedup, GPU-MMU vs Mosaic vs Ideal."""
    rows = []
    gains, gaps = [], []
    for app in apps:
        for n in counts:
            names = homogeneous_names(app, n)
            res_b, _, _ = _run(names, "gpu-mmu", mode="base", warm=True,
                               n_access=n_access)
            res_m, _, _ = _run(names, "mosaic", mode="mosaic", warm=True,
                               n_access=n_access)
            res_i, _, _ = _run(names, "gpu-mmu", mode="base", ideal=True,
                               warm=True, n_access=n_access)
            ws_b, ws_m, ws_i = (ws_of(r, n_access)
                                for r in (res_b, res_m, res_i))
            rows.append({"bench": "fig5", "app": app, "napps": n,
                         "ws_gpummu": ws_b, "ws_mosaic": ws_m,
                         "ws_ideal": ws_i})
            if n > 1:
                gains.append(ws_m / ws_b - 1)
                gaps.append(1 - ws_m / ws_i)
    rows.append({"bench": "fig5", "app": "MEAN", "napps": 0,
                 "mosaic_gain_over_gpummu": float(np.mean(gains)),
                 "gap_to_ideal": float(np.mean(gaps)),
                 # Paper: +55.5% avg gain, within 6.8% of ideal.
                 "claim_large_gain": bool(np.mean(gains) > 0.2),
                 "claim_near_ideal": bool(np.mean(gaps) < 0.2)})
    return rows


def fig6_heterogeneous(n_access=4000, n_workloads=6, counts=(2, 3, 4, 5)):
    """Fig. 6: heterogeneous weighted speedup (random app mixes)."""
    rows = []
    gains, gaps = [], []
    w = 0
    for k in counts:
        for rep in range(max(1, n_workloads // len(counts))):
            names = heterogeneous_names(k, seed=w)
            w += 1
            res_b, _, _ = _run(names, "gpu-mmu", mode="base", warm=True,
                               n_access=n_access, seed=w)
            res_m, _, _ = _run(names, "mosaic", mode="mosaic", warm=True,
                               n_access=n_access, seed=w)
            res_i, _, _ = _run(names, "gpu-mmu", mode="base", ideal=True,
                               warm=True, n_access=n_access, seed=w)
            ws_b, ws_m, ws_i = (ws_of(r, n_access)
                                for r in (res_b, res_m, res_i))
            rows.append({"bench": "fig6", "apps": "+".join(names),
                         "napps": k, "ws_gpummu": ws_b, "ws_mosaic": ws_m,
                         "ws_ideal": ws_i})
            gains.append(ws_m / ws_b - 1)
            gaps.append(1 - ws_m / ws_i)
    rows.append({"bench": "fig6", "apps": "MEAN", "napps": 0,
                 "mosaic_gain_over_gpummu": float(np.mean(gains)),
                 "gap_to_ideal": float(np.mean(gaps)),
                 # Paper: +29.7% avg, within 15.4% of ideal.
                 "claim_gain": bool(np.mean(gains) > 0.1)})
    return rows


def fig7_demand_paging(n_access=8000, apps=("dct", "gaussian", "hotspot")):
    """Fig. 7: GPU-MMU / Mosaic vs GPU-MMU *without* demand paging."""
    rows = []
    for app in apps:
        names = homogeneous_names(app, 2)
        res_np, _, _ = _run(names, "gpu-mmu", mode="base", paging=False,
                            n_access=n_access)
        res_b, _, _ = _run(names, "gpu-mmu", mode="base", paging=True,
                           n_access=n_access)
        res_m, _, _ = _run(names, "mosaic", mode="mosaic", paging=True,
                           n_access=n_access)
        base = ws_of(res_np, n_access)
        rows.append({
            "bench": "fig7", "app": app,
            "gpummu_paging_norm": ws_of(res_b, n_access) / base,
            "mosaic_paging_norm": ws_of(res_m, n_access) / base,
        })
    mg = np.mean([r["mosaic_paging_norm"] for r in rows])
    bg = np.mean([r["gpummu_paging_norm"] for r in rows])
    rows.append({"bench": "fig7", "app": "MEAN",
                 "gpummu_paging_norm": float(bg),
                 "mosaic_paging_norm": float(mg),
                 # Paper: Mosaic beats GPU-MMU-no-paging by ~58.5% (homog);
                 # paging overhead itself is small.
                 "claim_mosaic_beats_nopaging": bool(mg > 1.0)})
    return rows


def fig8_tlb_hitrate(n_access=4000, apps=("spmv", "bfs", "shoc-spmv"),
                     counts=(2, 3, 4, 5)):
    """Fig. 8: L1/L2 TLB hit rates and the baseline's interference slide."""
    rows = []
    for app in apps:
        for n in counts:
            names = homogeneous_names(app, n)
            _, sim_b, _ = _run(names, "gpu-mmu", mode="base", warm=True,
                               n_access=n_access)
            _, sim_m, _ = _run(names, "mosaic", mode="mosaic", warm=True,
                               n_access=n_access)
            rows.append({
                "bench": "fig8", "app": app, "napps": n,
                "l1_gpummu": sim_b.l1_hit_rate_micro(),
                "l1_mosaic": sim_m.l1_hit_rate_micro(),
                "l2_gpummu": sim_b.l2_hit_rate(),
                "l2_mosaic": sim_m.l2_hit_rate(),
            })
    l1m = np.mean([r["l1_mosaic"] for r in rows])
    # Baseline degradation with app count (slope over n for each app).
    slide = np.mean([
        rows[i + len(counts) - 1]["l2_gpummu"] - rows[i]["l2_gpummu"]
        for i in range(0, len(rows), len(counts))
    ])
    rows.append({"bench": "fig8", "app": "MEAN", "napps": 0,
                 "l1_mosaic_mean": float(l1m),
                 "l2_gpummu_slide_2to5": float(slide),
                 # Paper: Mosaic miss rate < 1%; baseline slides 81%→62%.
                 "claim_mosaic_sub1pct_miss": bool(l1m > 0.99),
                 "claim_baseline_slides": bool(slide < 0.0)})
    return rows
