"""Multi-tenant serving benchmark: Mosaic vs GPU-MMU manager on the real
engine (the LLM-serving analogue of the paper's Figs. 5/6 setting).

Identical request streams through both managers; reports tokens/s (CPU
wall-clock — relative only), coalesced fraction (the structural quantity
that becomes TLB reach / kernel indirection savings on TPU), compaction
copy counts, and memory bloat.

Host-tier scenarios (DESIGN.md §6):

* ``oversubscribed_compare`` — the pool holds only 1/factor of the
  sized-for-peak KV working set; requests are preempted to host DRAM under
  ``OutOfMemory`` and resumed via base-page demand fault-in.  Reports
  faults, DMA descriptors, bytes_in, transfer_us and swap counts per
  manager; outputs stay identical.  Absolute swap counts are *not*
  comparable across managers here — whole-frame reservation makes Mosaic
  hit pressure at different moments than the page-packed baseline, so the
  two runs preempt different requests at different times.  The same-trace
  contiguity claim lives in ``swap_cycle_compare``.
* ``swap_cycle_compare`` — a *controlled* preempt→churn→resume cycle over
  the exact same trace for both managers, isolating the paper's
  contiguity-helps-transfer claim: Mosaic re-maps the resumed request into
  whole frames, so its fault batch merges into few contiguous DMAs, while
  the GPU-MMU baseline's scattered free list pays per-page setup.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.engine import Request, ServingEngine

GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)


def run_engine(manager_kind: str, n_requests=8, max_new=8, seed=0):
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=4, max_seq=128,
                        manager_kind=manager_kind, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        T = int(rng.integers(24, 64))
        prompt = rng.integers(0, cfg.vocab_size, size=T).astype(np.int32)
        r = Request(rid=i, tenant=i % 3, prompt=prompt, max_new=max_new)
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained(max_steps=500)
    assert all(r.done for r in reqs)
    return eng, reqs


def serving_compare(n_requests=8) -> List[Dict]:
    rows = []
    outs = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs = run_engine(kind, n_requests=n_requests)
        outs[kind] = {r.rid: tuple(r.out) for r in reqs}
        st = eng.cache.stats()
        rows.append({
            "bench": "serving", "manager": kind,
            "tok_per_s_cpu": round(eng.stats.tok_per_s(), 1),
            "coalesced_mean": round(eng.stats.coalesced_mean, 3),
            "compaction_copies": eng.stats.compaction_copies,
            "coalesce_ops": int(st.get("coalesce_ops", 0)),
            "memory_bloat": round(st.get("memory_bloat", 1.0), 3),
        })
    # Application-transparency check: identical outputs.
    identical = outs["mosaic"] == outs["gpu-mmu"]
    rows.append({"bench": "serving", "manager": "CHECK",
                 "outputs_identical": identical})
    assert identical, "manager changed model outputs!"
    return rows


# ------------------------------------------------------------ host tier


def run_oversubscribed(manager_kind: str, *, factor: float = 2.0,
                       n_requests: int = 12, seed: int = 0):
    """2× (by default) oversubscribed multi-tenant run to completion."""
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=6, max_seq=96,
                        manager_kind=manager_kind, seed=0,
                        oversubscription=factor)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        # Decode-heavy: the working set grows well past the pool mid-run,
        # so pressure comes from appends, not just admission.
        T = int(rng.integers(24, 56))
        prompt = rng.integers(0, cfg.vocab_size, size=T).astype(np.int32)
        r = Request(rid=i, tenant=i % 3, prompt=prompt,
                    max_new=int(rng.integers(24, 40)))
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained(max_steps=5000)
    assert all(r.done for r in reqs), "oversubscribed run did not drain"
    eng.cache.check_invariants()
    return eng, reqs


def oversubscribed_compare(factor: float = 2.0,
                           n_requests: int = 12) -> List[Dict]:
    rows = []
    outs = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs = run_oversubscribed(kind, factor=factor,
                                       n_requests=n_requests)
        outs[kind] = {r.rid: tuple(r.out) for r in reqs}
        s = eng.stats
        rows.append({
            "bench": "serving-oversub", "manager": kind, "factor": factor,
            "tok_per_s_cpu": round(s.tok_per_s(), 1),
            "swaps_out": s.swaps_out, "swaps_in": s.swaps_in,
            "faults": s.faults, "fault_dmas": s.fault_dmas,
            "bytes_in": s.bytes_in,
            "transfer_us": round(s.transfer_us, 1),
            "host_peak_pages": eng.host.stats["peak_pages"],
        })
    identical = outs["mosaic"] == outs["gpu-mmu"]
    paged = any(r["swaps_out"] > 0 and r["faults"] > 0
                for r in rows if "swaps_out" in r)
    rows.append({"bench": "serving-oversub", "manager": "CHECK",
                 "outputs_identical": identical,
                 "paging_exercised": paged})
    assert identical, "oversubscription changed model outputs!"
    assert paged, "oversubscribed run never touched the host tier"
    return rows


def run_swap_cycle(manager_kind: str):
    """Controlled preempt→churn→resume cycle (same trace per manager).

    r1/r3/r5 finish early (their frees pepper the pool), r2/r4 keep
    decoding into the holes while r0 is held swapped out; the resume
    fault-in then measures how contiguous the re-mapped pages are.
    """
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=6, max_seq=96,
                        manager_kind=manager_kind, seed=0)
    rng = np.random.default_rng(0)
    spec = [(35, 40), (4, 18), (5, 70), (6, 20), (7, 70), (8, 22)]
    reqs = []
    for i, (T, mn) in enumerate(spec):
        r = Request(rid=i, tenant=i % 3,
                    prompt=rng.integers(0, cfg.vocab_size, T)
                    .astype(np.int32), max_new=mn)
        reqs.append(r)
        eng.submit(r)
    for _ in range(80):
        eng.step()
        if reqs[1].done and reqs[3].done and reqs[5].done:
            break
    assert not reqs[0].done, "preempt target finished too early"
    eng.preempt(0, hold=True)
    eng.cache.check_invariants()
    for _ in range(16):              # churn: live requests fill the holes
        eng.step()
    pre_dmas, pre_faults = eng.stats.fault_dmas, eng.stats.faults
    eng.release(0)
    eng.run_until_drained(max_steps=400)
    assert all(r.done for r in reqs)
    eng.cache.check_invariants()
    return (eng, reqs, eng.stats.fault_dmas - pre_dmas,
            eng.stats.faults - pre_faults)


def swap_cycle_compare() -> List[Dict]:
    rows = []
    outs = {}
    dmas = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs, resume_dmas, resume_faults = run_swap_cycle(kind)
        outs[kind] = {r.rid: tuple(r.out) for r in reqs}
        dmas[kind] = resume_dmas
        rows.append({
            "bench": "swap-cycle", "manager": kind,
            "resume_faults": resume_faults,
            "resume_fault_dmas": resume_dmas,
            "pages_per_dma": round(resume_faults / max(resume_dmas, 1), 2),
            "transfer_us_total": round(eng.stats.transfer_us, 1),
        })
    identical = outs["mosaic"] == outs["gpu-mmu"]
    rows.append({"bench": "swap-cycle", "manager": "CHECK",
                 "outputs_identical": identical,
                 "mosaic_fewer_dmas": dmas["mosaic"] < dmas["gpu-mmu"]})
    assert identical, "swap cycle changed model outputs!"
    # The paper's contiguity-helps-transfer claim, as a measured fact.
    assert dmas["mosaic"] < dmas["gpu-mmu"], \
        f"expected fewer merged DMAs under mosaic: {dmas}"
    return rows
