"""Multi-tenant serving benchmark: Mosaic vs GPU-MMU manager on the real
engine (the LLM-serving analogue of the paper's Figs. 5/6 setting).

Identical request streams through both managers; reports tokens/s (CPU
wall-clock — relative only), coalesced fraction (the structural quantity
that becomes TLB reach / kernel indirection savings on TPU), compaction
copy counts, and memory bloat.

Host-tier scenarios (DESIGN.md §6):

* ``oversubscribed_compare`` — the pool holds only 1/factor of the
  sized-for-peak KV working set; requests are preempted to host DRAM under
  ``OutOfMemory`` and resumed via base-page demand fault-in.  Reports
  faults, DMA descriptors, bytes_in, transfer_us and swap counts per
  manager; outputs stay identical.  Absolute swap counts are *not*
  comparable across managers here — whole-frame reservation makes Mosaic
  hit pressure at different moments than the page-packed baseline, so the
  two runs preempt different requests at different times.  The same-trace
  contiguity claim lives in ``swap_cycle_compare``.
* ``swap_cycle_compare`` — a *controlled* preempt→churn→resume cycle over
  the exact same trace for both managers, isolating the paper's
  contiguity-helps-transfer claim: Mosaic re-maps the resumed request into
  whole frames, so its fault batch merges into few contiguous DMAs, while
  the GPU-MMU baseline's scattered free list pays per-page setup.
* ``overlap_compare`` — the same oversubscribed trace under
  ``fault_mode="sync"`` (PR 1's blocking fault-in) vs ``"async"`` (the
  double-buffered prefetch pipeline, DESIGN.md §7) across
  oversubscription ratios: byte-identical tokens, and the async pipeline
  hides the bulk of the transfer µs behind decode compute.
* ``overlap_link_contention`` — the DMA-channel overlap model transplanted
  into the TLB-timing simulator's multi-app runs: cross-app queueing on
  the shared host↔device link (contention cycles) shrinks as channels are
  added.
* ``prefix_reuse_compare`` — the content-hash prefix cache (DESIGN.md §8):
  requests sharing a system-prompt prefix admitted with the cache on vs
  off.  Byte-identical tokens both ways; with the cache on, the shared
  prefix's KV pages fault in from the host tier at admission (merged
  DMAs through the async pipeline) instead of being re-decoded, so hit
  admissions compute ~suffix/prompt of the cold prefill and complete
  faster.
* ``duplex_compare`` — outbound eviction/parking gathers on vs off the
  DMA timeline (full-duplex "out" lanes): tokens unchanged, outbound
  traffic visible with per-direction hidden/exposed/queue invariants.
* ``duplex_sim_compare`` — the TLB simulator under an HBM capacity cap:
  capacity writebacks ride the link; a full-duplex link keeps them off
  the fault path, half-duplex queues faults behind them.

Cluster tier (DESIGN.md §10):

* ``cluster_prefix_share_compare`` — one process-wide ``PrefixIndex``
  over the shared host tier vs per-engine indexes: a prefix parked by
  replica 0 is a cache hit on replica 1 only when the index is shared,
  so the shared configuration achieves a strictly higher hit rate on a
  shared-prefix workload (tokens byte-identical either way).
* ``cluster_router_compare`` — deadline-aware (slack-ordered) dispatch
  vs FIFO round-robin on an unevenly loaded cluster: SLO attainment is
  higher when the router sends tight-deadline requests to the idle
  replica instead of queueing them behind long best-effort work.
* ``cluster_migration_compare`` — work-stealing migration of a preempted
  request to an idle replica: the destination decodes it with **zero
  re-prefill** (only its host-resident base pages change hands, via
  frame-lease re-assignment + fault-in over the destination's own DMA
  lanes), and tokens are byte-identical across 1-engine, N-engine, and
  N-engine-with-migration runs.
* ``cluster_sim_compare`` — the TLB simulator's cluster model:
  per-engine links remove cross-engine link contention, the shared host
  store re-serializes transfers on its DRAM lanes, and widening
  ``host_lanes`` relieves it.

Spill tier (DESIGN.md §11):

* ``spill_compare`` — the host tier under a hard ``capacity_frames``
  cap, spill on vs off, on a grouped-prefix two-wave workload that
  overflows the cap.  With spill on, LRU prefix frames ride the "out"
  DMA lanes to frame-granular disk files and promote back on wave-2
  touches, so every wave-2 admission is still a prefix hit and pays
  only a modeled promote stall; with spill off the same frames are
  hard-evicted through the prefix index, wave 2 re-prefills the full
  prompt, and p99 admission latency jumps.  Tokens byte-identical
  either way — the spill tier is pure memory management.
* ``spill_backpressure_compare`` — a saturated write-back buffer
  (1-deep queue, slow disk) makes ``park_allowed()`` go False: new
  prefix parks are *refused* (``prefix_park_refused``) instead of
  queueing unboundedly, and the queue never exceeds its bound.
* ``spill_sim_compare`` — the TLB simulator's disk model: capacity
  writebacks stream host→disk after their link transfer; the disk is an
  order of magnitude slower than the link, so one lane queues evictions
  (``disk_contention_cycles``) and a second lane relieves them.

Fused gather-attend decode (DESIGN.md §13):

* ``fused_decode_compare`` — sync vs async vs ``fault_mode="fused"`` on
  the oversubscribed trace: the fused path never blocks on the DMA
  engine before decode — arriving pages are consumed straight from the
  staging buffer by the readiness-masked attention path and only the
  transfer *tail* past the decode window is exposed.  Tokens are
  byte-identical across all three modes, and at the starved 2 µs
  window the fused path's exposed µs sit strictly below the async
  pipeline's (which must stall for every page before launch).
* ``fused_kernel_compare`` — the readiness-masked kernel against the
  gather-then-attend baseline on one synthetic batch: with every page
  resident the fused kernel's output is bitwise identical to the
  baseline paged kernel, and with half the pages staged it matches the
  scatter-then-attend result to float32 round-off.

Fault tolerance (DESIGN.md §12):

* ``faults_crash_compare`` — a seeded engine crash mid-decode vs the
  same crash with no failover, vs fault-free: the router re-homes the
  victim's preempted bundle to a survivor (zero re-prefill) and
  re-dispatches its in-flight/queued requests from the prompt;
  recovered tokens are byte-identical to the fault-free run and the
  deadline-met fraction is strictly above the no-failover baseline.
* ``faults_spill_compare`` — the spill workload under injected disk
  faults: bit-flipped spill frames are caught 100 % by the per-frame
  checksum (quarantine + re-derive, never decoded from); unbounded
  write errors trigger bounded retries with backoff then a graceful
  degrade to the hard-cap path with zero dropped requests; injected
  DMA stalls shift timing only, reproducibly under the same seed.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.engine import Request, ServingEngine

GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)


def run_engine(manager_kind: str, n_requests=8, max_new=8, seed=0):
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=4, max_seq=128,
                        manager_kind=manager_kind, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        T = int(rng.integers(24, 64))
        prompt = rng.integers(0, cfg.vocab_size, size=T).astype(np.int32)
        r = Request(rid=i, tenant=i % 3, prompt=prompt, max_new=max_new)
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained(max_steps=500)
    assert all(r.done for r in reqs)
    return eng, reqs


def serving_compare(n_requests=8) -> List[Dict]:
    rows = []
    outs = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs = run_engine(kind, n_requests=n_requests)
        outs[kind] = {r.rid: tuple(r.out) for r in reqs}
        st = eng.cache.stats()
        rows.append({
            "bench": "serving", "manager": kind,
            "tok_per_s_cpu": round(eng.stats.tok_per_s(), 1),
            "coalesced_mean": round(eng.stats.coalesced_mean, 3),
            "compaction_copies": eng.stats.compaction_copies,
            "coalesce_ops": int(st.get("coalesce_ops", 0)),
            "memory_bloat": round(st.get("memory_bloat", 1.0), 3),
        })
    # Application-transparency check: identical outputs.
    identical = outs["mosaic"] == outs["gpu-mmu"]
    rows.append({"bench": "serving", "manager": "CHECK",
                 "outputs_identical": identical})
    assert identical, "manager changed model outputs!"
    return rows


# ------------------------------------------------------------ host tier


def run_oversubscribed(manager_kind: str, *, factor: float = 2.0,
                       n_requests: int = 12, seed: int = 0,
                       fault_mode: str = "async",
                       decode_window_us=None, duplex: bool = True):
    """2× (by default) oversubscribed multi-tenant run to completion.

    The prefix cache stays OFF here: these prompts share no prefixes,
    so parking on completion would only add gather traffic unrelated to
    what the PR 1/PR 2 suites measure (their BENCH_serving.json
    trajectories must stay comparable across PRs); reuse is measured by
    its own ``prefix-reuse`` suite."""
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=6, max_seq=96,
                        manager_kind=manager_kind, seed=0,
                        oversubscription=factor, fault_mode=fault_mode,
                        decode_window_us=decode_window_us, duplex=duplex,
                        prefix_cache=False)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        # Decode-heavy: the working set grows well past the pool mid-run,
        # so pressure comes from appends, not just admission.
        T = int(rng.integers(24, 56))
        prompt = rng.integers(0, cfg.vocab_size, size=T).astype(np.int32)
        r = Request(rid=i, tenant=i % 3, prompt=prompt,
                    max_new=int(rng.integers(24, 40)))
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained(max_steps=5000)
    assert all(r.done for r in reqs), "oversubscribed run did not drain"
    eng.cache.check_invariants()
    return eng, reqs


def oversubscribed_compare(factor: float = 2.0,
                           n_requests: int = 12) -> List[Dict]:
    rows = []
    outs = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs = run_oversubscribed(kind, factor=factor,
                                       n_requests=n_requests)
        outs[kind] = {r.rid: tuple(r.out) for r in reqs}
        s = eng.stats
        rows.append({
            "bench": "serving-oversub", "manager": kind, "factor": factor,
            "tok_per_s_cpu": round(s.tok_per_s(), 1),
            "swaps_out": s.swaps_out, "swaps_in": s.swaps_in,
            "faults": s.faults, "fault_dmas": s.fault_dmas,
            "bytes_in": s.bytes_in,
            "transfer_us": round(s.transfer_us, 1),
            "exposed_us": round(s.fault_exposed_us, 1),
            "hidden_us": round(s.fault_hidden_us, 1),
            "host_peak_pages": eng.host.stats["peak_pages"],
        })
    identical = outs["mosaic"] == outs["gpu-mmu"]
    paged = any(r["swaps_out"] > 0 and r["faults"] > 0
                for r in rows if "swaps_out" in r)
    rows.append({"bench": "serving-oversub", "manager": "CHECK",
                 "outputs_identical": identical,
                 "paging_exercised": paged})
    assert identical, "oversubscription changed model outputs!"
    assert paged, "oversubscribed run never touched the host tier"
    return rows


def run_swap_cycle(manager_kind: str):
    """Controlled preempt→churn→resume cycle (same trace per manager).

    r1/r3/r5 finish early (their frees pepper the pool), r2/r4 keep
    decoding into the holes while r0 is held swapped out; the resume
    fault-in then measures how contiguous the re-mapped pages are.
    """
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=6, max_seq=96,
                        manager_kind=manager_kind, seed=0)
    rng = np.random.default_rng(0)
    spec = [(35, 40), (4, 18), (5, 70), (6, 20), (7, 70), (8, 22)]
    reqs = []
    for i, (T, mn) in enumerate(spec):
        r = Request(rid=i, tenant=i % 3,
                    prompt=rng.integers(0, cfg.vocab_size, T)
                    .astype(np.int32), max_new=mn)
        reqs.append(r)
        eng.submit(r)
    for _ in range(80):
        eng.step()
        if reqs[1].done and reqs[3].done and reqs[5].done:
            break
    assert not reqs[0].done, "preempt target finished too early"
    eng.preempt(0, hold=True)
    eng.cache.check_invariants()
    for _ in range(16):              # churn: live requests fill the holes
        eng.step()
    pre_dmas, pre_faults = eng.stats.fault_dmas, eng.stats.faults
    eng.release(0)
    eng.run_until_drained(max_steps=400)
    assert all(r.done for r in reqs)
    eng.cache.check_invariants()
    return (eng, reqs, eng.stats.fault_dmas - pre_dmas,
            eng.stats.faults - pre_faults)


def swap_cycle_compare() -> List[Dict]:
    rows = []
    outs = {}
    dmas = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs, resume_dmas, resume_faults = run_swap_cycle(kind)
        outs[kind] = {r.rid: tuple(r.out) for r in reqs}
        dmas[kind] = resume_dmas
        rows.append({
            "bench": "swap-cycle", "manager": kind,
            "resume_faults": resume_faults,
            "resume_fault_dmas": resume_dmas,
            "pages_per_dma": round(resume_faults / max(resume_dmas, 1), 2),
            "transfer_us_total": round(eng.stats.transfer_us, 1),
        })
    identical = outs["mosaic"] == outs["gpu-mmu"]
    rows.append({"bench": "swap-cycle", "manager": "CHECK",
                 "outputs_identical": identical,
                 "mosaic_fewer_dmas": dmas["mosaic"] < dmas["gpu-mmu"]})
    assert identical, "swap cycle changed model outputs!"
    # The paper's contiguity-helps-transfer claim, as a measured fact.
    assert dmas["mosaic"] < dmas["gpu-mmu"], \
        f"expected fewer merged DMAs under mosaic: {dmas}"
    return rows


# ---------------------------------------------------- async fault-in overlap


def overlap_compare(factors=(1.5, 2.0), n_requests: int = 12) -> List[Dict]:
    """Sync vs async fault-in on the same oversubscribed trace.

    The async pipeline must (a) produce byte-identical decode tokens —
    prefetching never alters allocation or scheduling — and (b) hide at
    least half of the transfer µs the blocking path exposes (the claim is
    checked at 2× oversubscription, the ISSUE's acceptance point).

    The DMA timeline uses *modeled* decode windows (deterministic, not
    CPU wall time, which would include seconds of jit compilation): a
    1 ms window models a realistic accelerator decode step, and the
    "async-tight" 2 µs window deliberately starves the overlap so the
    partial-wait path (stall only for the transfer remainder) shows up
    in the measurements.
    """
    configs = (("sync", "sync", None),
               ("async", "async", 1000.0),
               ("async-tight", "async", 2.0))
    rows = []
    hidden_frac_at_2x = None
    all_identical = True
    for factor in factors:
        outs, stats = {}, {}
        for mode, fault_mode, window in configs:
            eng, reqs = run_oversubscribed(
                "mosaic", factor=factor, n_requests=n_requests,
                fault_mode=fault_mode, decode_window_us=window)
            outs[mode] = {r.rid: tuple(r.out) for r in reqs}
            stats[mode] = eng.stats
            s = eng.stats
            rows.append({
                "bench": "serving-overlap", "mode": mode, "factor": factor,
                "tok_per_s_cpu": round(s.tok_per_s(), 1),
                "faults": s.faults, "dma_count": s.fault_dmas,
                "transfer_us": round(s.transfer_us, 1),
                "exposed_us": round(s.fault_exposed_us, 1),
                "hidden_us": round(s.fault_hidden_us, 1),
                "prefetch_hits": s.prefetch_hits,
                "prefetch_misses": s.prefetch_misses,
                "prefetch_wasted": s.prefetch_wasted,
            })
        identical = all(o == outs["sync"] for o in outs.values())
        all_identical = all_identical and identical
        assert identical, f"async fault-in changed tokens at {factor}x!"
        # Fraction of the blocking path's exposed µs the pipeline hides.
        frac = 1.0 - (stats["async"].fault_exposed_us
                      / max(stats["sync"].fault_exposed_us, 1e-9))
        tight = 1.0 - (stats["async-tight"].fault_exposed_us
                       / max(stats["sync"].fault_exposed_us, 1e-9))
        if factor == 2.0:
            hidden_frac_at_2x = frac
        rows.append({"bench": "serving-overlap", "mode": "CHECK",
                     "factor": factor,
                     "hidden_fraction": round(frac, 3),
                     "hidden_fraction_tight": round(tight, 3),
                     "outputs_identical": identical})
    rows.append({"bench": "serving-overlap", "mode": "CLAIM", "factor": 2.0,
                 "claim_outputs_identical": all_identical,
                 "claim_hides_half_transfer":
                     bool(hidden_frac_at_2x is not None
                          and hidden_frac_at_2x >= 0.5)})
    return rows


def overlap_link_contention(n_access: int = 2000) -> List[Dict]:
    """The DMA-channel overlap model in the TLB simulator's multi-app
    setting: cross-app interference on the shared host↔device link
    (queueing cycles a fault pays because the link is busy, almost always
    with another app's transfer) shrinks as channels are added."""
    from repro.core.tlb_sim import SimConfig, TranslationSim
    from repro.core.workloads import build_workload, homogeneous_names

    names = homogeneous_names("dct", 3)
    traces, _ = build_workload(names, "mosaic", seed=0, n_access=n_access)
    rows = []
    contention = {}
    for ch in (1, 2, 4):
        sim = TranslationSim(
            SimConfig(mode="mosaic", paging=True, dma_channels=ch), traces)
        sim.run()
        contention[ch] = sim.link.contention_total()
        rows.append({"bench": "overlap-sim", "dma_channels": ch,
                     "faults": sim.link.faults,
                     "contention_cycles": round(contention[ch], 1),
                     "fault_cycles": round(sim.link.fault_cycles_total, 1)})
    rows.append({"bench": "overlap-sim", "dma_channels": "CHECK",
                 "claim_channels_cut_contention":
                     bool(contention[4] < contention[1]
                          and contention[1] > 0)})
    return rows


# ------------------------------------------------- prefix cache + duplex


def run_prefix_workload(prefix_cache: bool, *, n_requests: int = 8,
                        shared_tokens: int = 40, suffix_tokens: int = 8,
                        max_new: int = 6, seed: int = 0,
                        fault_mode: str = "async"):
    """Shared-system-prompt workload in two waves (DESIGN.md §8).

    Every prompt = one shared ``shared_tokens`` prefix (page-aligned) +
    a distinct suffix.  Wave 1 (two requests) runs to completion and
    parks the prefix; wave 2 (the rest) then admits against a warm
    index — with the cache on, each admission faults the prefix's pages
    in from the host tier and prefills only the suffix.
    """
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=4, max_seq=128,
                        manager_kind="mosaic", seed=0,
                        prefix_cache=prefix_cache, fault_mode=fault_mode,
                        decode_window_us=1000.0)
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size,
                          shared_tokens).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        suf = rng.integers(0, cfg.vocab_size,
                           suffix_tokens).astype(np.int32)
        reqs.append(Request(rid=i, tenant=i % 3,
                            prompt=np.concatenate([shared, suf]),
                            max_new=max_new))
    for r in reqs[:2]:
        eng.submit(r)
    eng.run_until_drained(max_steps=500)
    for r in reqs[2:]:
        eng.submit(r)
    eng.run_until_drained(max_steps=1000)
    assert all(r.done for r in reqs), "prefix workload did not drain"
    eng.cache.check_invariants()
    return eng, reqs


def prefix_reuse_compare(n_requests: int = 8) -> List[Dict]:
    """Cache-hit admission vs cold admission on the same request stream.

    The claims: (a) tokens are byte-identical with the cache on or off
    (application transparency extends to reuse); (b) a cache-hit
    admission is cheaper than re-decoding the shared prefix — it
    computes only the suffix's prefill tokens, and the modeled µs to
    fault the reused pages in is below even the most conservative
    recompute bound (one decode-window of compute per hit admission;
    in reality re-prefilling the prefix costs far more); (c) the reused
    pages really move through the DMA pipeline (admission-time
    fault-in, not recompute): every reused page is a prefetch hit or
    demand fault.  Wall-clock admission latencies are reported
    (hit vs cold) but not gated on — the smoke model is op-dispatch
    bound on CPU, so wall time under-states the compute saved.
    """
    rows = []
    outs, engines = {}, {}
    for mode, on in (("cache-on", True), ("cache-off", False)):
        eng, reqs = run_prefix_workload(on, n_requests=n_requests)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        engines[mode] = eng
        s = eng.stats
        rows.append({
            "bench": "prefix-reuse", "mode": mode,
            "tok_per_s_cpu": round(s.tok_per_s(), 1),
            "prefill_tokens": s.prefill_tokens,
            "prefix_hits": s.prefix_hits,
            "prefix_misses": s.prefix_misses,
            "reused_tokens": s.prefix_reused_tokens,
            "parked_pages": s.prefix_parked_pages,
            "admit_hit_ms": round(s.admit_hit_mean_us() / 1e3, 1),
            "admit_cold_ms": round(s.admit_cold_mean_us() / 1e3, 1),
            "prefix_fault_us": round(s.prefix_fault_us, 1),
            "faults": s.faults, "dma_count": s.fault_dmas,
            "transfer_us": round(s.transfer_us, 1),
            "exposed_us": round(s.fault_exposed_us, 1),
            "hidden_us": round(s.fault_hidden_us, 1),
            "prefetch_hits": s.prefetch_hits,
        })
    on, off = engines["cache-on"].stats, engines["cache-off"].stats
    identical = outs["cache-on"] == outs["cache-off"]
    # Modeled cost: faulting every reused prefix in cost prefix_fault_us;
    # re-decoding it costs ≥ one decode window of compute per hit
    # admission (a deliberately loose lower bound — full-prefix prefill
    # is far more).  Deterministic, unlike CPU wall clock.
    redecode_floor_us = on.prefix_hits * 1000.0
    cheaper = (on.prefill_tokens < off.prefill_tokens
               and on.prefix_hits > 0
               and on.prefix_fault_us < redecode_floor_us)
    via_dma = on.prefix_reused_tokens > 0 and on.faults >= (
        on.prefix_reused_tokens // GEO.page_tokens)
    rows.append({"bench": "prefix-reuse", "mode": "CHECK",
                 "outputs_identical": identical,
                 "saved_prefill_tokens":
                     off.prefill_tokens - on.prefill_tokens,
                 "admit_speedup": round(
                     on.admit_cold_mean_us()
                     / max(on.admit_hit_mean_us(), 1e-9), 2)})
    rows.append({"bench": "prefix-reuse", "mode": "CLAIM",
                 "claim_prefix_tokens_identical": identical,
                 "claim_prefix_hit_cheaper_than_redecode": bool(cheaper),
                 "claim_prefix_faulted_via_dma": bool(via_dma)})
    assert identical, "prefix cache changed model outputs!"
    return rows


def duplex_compare(factor: float = 2.0, n_requests: int = 10) -> List[Dict]:
    """Outbound (eviction/parking) traffic on vs off the DMA timeline.

    ``duplex=True`` puts device→host gathers on the channels' "out"
    lanes; ``duplex=False`` is PR 2's fault-in-only timeline.  Tokens
    must not change — outbound modeling is accounting, not scheduling —
    and the per-direction ``hidden + exposed == transfer`` invariant
    must hold with eviction traffic visible.
    """
    rows = []
    outs, engines = {}, {}
    for mode, duplex in (("duplex", True), ("fault-in-only", False)):
        eng, reqs = run_oversubscribed(
            "mosaic", factor=factor, n_requests=n_requests,
            decode_window_us=1000.0, duplex=duplex)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        engines[mode] = eng
        s, d = eng.stats, eng.dma.stats
        rows.append({
            "bench": "serving-duplex", "mode": mode, "factor": factor,
            "tok_per_s_cpu": round(s.tok_per_s(), 1),
            "evict_pages": s.evict_pages, "evict_dmas": s.evict_dmas,
            "bytes_out": s.bytes_out,
            "evict_us": round(s.evict_us, 1),
            "out_hidden_us": round(d["hidden_us_out"], 1),
            "out_queue_us": round(d["queue_us_out"], 1),
            "exposed_us": round(s.fault_exposed_us, 1),
            "hidden_us": round(s.fault_hidden_us, 1),
            "transfer_us": round(s.transfer_us, 1),
        })
    don = engines["duplex"].dma.stats
    inv_in = abs(don["hidden_us"] + don["exposed_us"]
                 - don["transfer_us"]) < 1e-6
    inv_out = abs(don["hidden_us_out"] + don["exposed_us_out"]
                  - don["transfer_us_out"]) < 1e-6
    identical = outs["duplex"] == outs["fault-in-only"]
    visible = (engines["duplex"].stats.bytes_out > 0
               and engines["fault-in-only"].stats.bytes_out == 0)
    rows.append({"bench": "serving-duplex", "mode": "CLAIM",
                 "claim_duplex_tokens_identical": identical,
                 "claim_duplex_outbound_on_timeline": bool(visible),
                 "claim_duplex_split_invariants":
                     bool(inv_in and inv_out)})
    assert identical, "duplex outbound modeling changed model outputs!"
    return rows


def duplex_sim_compare(n_access: int = 2000,
                       hbm_pages: int = 192) -> List[Dict]:
    """Capacity writebacks in the TLB simulator: full- vs half-duplex.

    With ``hbm_pages_per_app`` capped, every fault past the cap evicts
    an LRU page — outbound link traffic.  Full-duplex keeps writebacks
    on their own lanes (inbound fault contention unchanged-ish);
    half-duplex makes faults queue behind them.
    """
    from repro.core.tlb_sim import SimConfig, TranslationSim
    from repro.core.workloads import build_workload, homogeneous_names

    names = homogeneous_names("dct", 3)
    traces, _ = build_workload(names, "mosaic", seed=0, n_access=n_access)
    rows = []
    contention = {}
    for duplex in (True, False):
        sim = TranslationSim(
            SimConfig(mode="mosaic", paging=True, dma_channels=1,
                      duplex=duplex, hbm_pages_per_app=hbm_pages),
            traces)
        sim.run()
        contention[duplex] = sim.link.contention_total()
        rows.append({
            "bench": "duplex-sim", "duplex": duplex,
            "faults": sim.link.faults,
            "writebacks": sim.link.writebacks,
            "contention_cycles_in": round(sim.link.contention_total(), 1),
            "contention_cycles_out":
                round(sim.link.contention_out_total(), 1),
        })
    writebacks = rows[0]["writebacks"]
    rows.append({"bench": "duplex-sim", "duplex": "CHECK",
                 "claim_duplex_cuts_fault_contention":
                     bool(writebacks > 0
                          and contention[True] < contention[False])})
    return rows


# ------------------------------------------------------------ cluster tier


def _shared_prefix_reqs(cfg, n, shared_tokens=40, suffix_tokens=8,
                        max_new=4, seed=0, **req_kw):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, shared_tokens).astype(np.int32)
    return [Request(rid=i, tenant=i % 3,
                    prompt=np.concatenate(
                        [shared, rng.integers(0, cfg.vocab_size,
                                              suffix_tokens)
                         .astype(np.int32)]),
                    max_new=max_new, **req_kw)
            for i in range(n)]


def run_cluster_prefix(share_prefix: bool, *, n_engines: int = 2,
                       n_requests: int = 8):
    """Two-wave shared-prefix workload over the cluster: wave 1 (one
    request, pinned to replica 0) parks the prefix; wave 2 is
    load-balanced over all replicas — only a *shared* index lets the
    replicas that never saw wave 1 hit."""
    from repro.serving.cluster import ServingCluster

    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=n_engines,
                             max_batch=4, max_seq=128, seed=0,
                             share_prefix=share_prefix,
                             decode_window_us=1000.0)
    reqs = _shared_prefix_reqs(cfg, n_requests)
    cluster.submit(reqs[0], engine=0)
    cluster.run_until_drained(max_steps=500)
    for r in reqs[1:]:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=1000)
    assert all(r.done for r in reqs), "cluster prefix workload not drained"
    cluster.check_invariants()
    return cluster, reqs


def cluster_prefix_share_compare(n_requests: int = 8) -> List[Dict]:
    rows = []
    outs, rates = {}, {}
    for mode, share in (("shared-index", True), ("per-engine", False)):
        cluster, reqs = run_cluster_prefix(share, n_requests=n_requests)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        cs = cluster.stats()
        t = cs.totals
        rates[mode] = cs.prefix_hit_rate()
        rows.append({
            "bench": "cluster-prefix", "mode": mode,
            "engines": len(cluster.engines),
            "tok_per_s_cpu": round(t.tok_per_s(), 1),
            "prefix_hits": t.prefix_hits,
            "prefix_misses": t.prefix_misses,
            "hit_rate": round(rates[mode], 3),
            "reused_tokens": t.prefix_reused_tokens,
            "parked_pages": t.prefix_parked_pages,
            "prefill_tokens": t.prefill_tokens,
            "host_frames_peak": cluster.tier.frames.stats["peak_frames"],
        })
    identical = outs["shared-index"] == outs["per-engine"]
    rows.append({"bench": "cluster-prefix", "mode": "CLAIM",
                 "claim_cluster_shared_index_higher_hit_rate":
                     bool(rates["shared-index"] > rates["per-engine"]),
                 "claim_cluster_prefix_tokens_identical": identical})
    assert identical, "prefix-index sharing changed model outputs!"
    return rows


def run_cluster_slo(policy: str, *, n_engines: int = 2):
    """Unevenly loaded cluster: replica 0 starts busy with long
    best-effort work; a burst of tight-deadline requests then arrives.
    Slack-ordered dispatch sends the burst to the idle replica; FIFO
    round-robin queues half of it behind the long work."""
    from repro.serving.cluster import ServingCluster

    cfg = get_smoke_config("qwen2.5-3b")
    # Queued steal (§14) would re-dispatch FIFO's misrouted burst and
    # erase the policy contrast — off here to isolate the dispatch
    # ordering; the steal is measured on its own in the `router` suite.
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=n_engines,
                             max_batch=2, max_seq=128, seed=0,
                             router_policy=policy, migrate=False,
                             router_steal_queued=False,
                             decode_window_us=1000.0)
    rng = np.random.default_rng(0)
    long_reqs = [Request(rid=i, tenant=0,
                         prompt=rng.integers(0, cfg.vocab_size, 32)
                         .astype(np.int32), max_new=24)
                 for i in range(4)]
    for r in long_reqs:
        cluster.submit(r, engine=0)
    for _ in range(2):
        cluster.step()
    burst = [Request(rid=100 + i, tenant=1,
                     prompt=rng.integers(0, cfg.vocab_size, 24)
                     .astype(np.int32), max_new=6,
                     deadline_us=18_000.0)
             for i in range(4)]
    for r in burst:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=1000)
    assert all(r.done for r in long_reqs + burst)
    cluster.check_invariants()
    return cluster, long_reqs + burst


def cluster_router_compare() -> List[Dict]:
    rows = []
    outs, att = {}, {}
    for policy in ("slack", "fifo"):
        cluster, reqs = run_cluster_slo(policy)
        outs[policy] = {r.rid: tuple(r.out) for r in reqs}
        cs = cluster.stats()
        t = cs.totals
        att[policy] = cs.slo_attainment()
        rows.append({
            "bench": "cluster-router", "mode": policy,
            "engines": len(cluster.engines),
            "tok_per_s_cpu": round(t.tok_per_s(), 1),
            "deadline_hits": sum(t.deadline_hits.values()),
            "deadline_misses": sum(t.deadline_misses.values()),
            "slo_attainment": round(att[policy], 3),
            "dispatched": "/".join(
                str(cluster.router.stats.dispatched.get(i, 0))
                for i in range(len(cluster.engines))),
        })
    identical = outs["slack"] == outs["fifo"]
    rows.append({"bench": "cluster-router", "mode": "CLAIM",
                 "claim_cluster_router_raises_slo_attainment":
                     bool(att["slack"] > att["fifo"]),
                 "claim_cluster_router_tokens_identical": identical})
    assert identical, "router policy changed model outputs!"
    return rows


def run_router_burst(cost_model: str, prestage: bool, *,
                     steal_queued: bool = True,
                     deadline_us: float = 12_000.0):
    """Heterogeneous load where token counting misroutes (DESIGN.md §14).

    Replica 0 carries two *decode-heavy* requests (few prompt pages, many
    windows: cheap in token-units, expensive in modeled µs); replica 1
    carries a queue of *prompt-heavy* requests (many prompt pages, two
    tokens each: expensive in token-units, cheap in µs — prefill is wall
    work hidden inside the decode window).  A burst of tight-deadline
    shared-prefix requests then arrives unpinned: token counting sends it
    behind replica 0's long decodes, the modeled cost to replica 1.

    The shared prefix is parked up front and then deliberately spilled to
    disk by a wave of large parks, so admissions pay a disk promote —
    unless pre-staging already promoted and staged the pages at dispatch
    time.  A final idle-cluster wave (prefix re-spilled first) isolates
    that effect: per-engine ``admit_lat_us`` counts are snapshotted just
    before it so the caller can take a wave-local admit p99.
    """
    from repro.serving.cluster import ServingCluster

    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=2,
                             max_batch=2, max_seq=128, seed=0,
                             capacity_frames=3, spill=True, migrate=False,
                             router_cost_model=cost_model,
                             router_prestage=prestage,
                             router_steal_queued=steal_queued,
                             decode_window_us=1000.0)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)

    def _req(rid, tokens, max_new, *, shared_prefix=False, tenant=0,
             deadline_us=None):
        suf = rng.integers(0, cfg.vocab_size, tokens).astype(np.int32)
        prompt = np.concatenate([shared, suf]) if shared_prefix else suf
        return Request(rid=rid, tenant=tenant, prompt=prompt,
                       max_new=max_new, deadline_us=deadline_us)

    # Park the shared prefix, then spill it with a wave of large parks.
    warm = _req(0, 8, 2, shared_prefix=True)
    spillers = [_req(1 + i, 64, 2) for i in range(3)]
    cluster.submit(warm, engine=0)
    cluster.run_until_drained(max_steps=200)
    for r in spillers:
        cluster.submit(r, engine=0)
    cluster.run_until_drained(max_steps=400)

    # Pre-load: decode-heavy on replica 0, prompt-heavy on replica 1 —
    # queued (not yet stepped) so both cost models see the full backlog.
    heavy = [_req(10, 16, 28, tenant=1), _req(11, 16, 24, tenant=1)]
    wide = [_req(12 + i, 64, 2, tenant=1) for i in range(7)]
    for r in heavy:
        cluster.submit(r, engine=0)
    for r in wide:
        cluster.submit(r, engine=1)
    # One step admits the decode-heavy pair into replica 0's batch slots
    # (equal priority: the burst cannot displace them, only queue).
    cluster.step()

    # The burst: unpinned, tight deadlines, heterogeneous suffixes.
    now = max(e._clock_us for e in cluster.engines)
    burst = [_req(100 + i, suf_tok, 3, shared_prefix=True, tenant=2,
                  deadline_us=now + deadline_us)
             for i, suf_tok in enumerate((8, 16, 8, 16))]
    for r in burst[:2]:
        cluster.submit(r)
    cluster.step()
    for r in burst[2:]:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=1500)

    # Re-spill the prefix, then measure admission cost on an idle
    # cluster: with pre-staging the disk promote happens at dispatch
    # time, so the admit sample is prefill compute alone.
    respill = [_req(20 + i, 64, 2) for i in range(3)]
    for r in respill:
        cluster.submit(r, engine=0)
    cluster.run_until_drained(max_steps=400)
    starts = [len(e.stats.admit_lat_us) for e in cluster.engines]
    probe = [_req(200 + i, 8, 2, shared_prefix=True, tenant=2)
             for i in range(4)]
    for r in probe:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=400)

    reqs = [warm] + spillers + heavy + wide + burst + respill + probe
    assert all(r.done for r in reqs), "router bench not drained"
    cluster.check_invariants()
    probe_lat = [x for e, s in zip(cluster.engines, starts)
                 for x in e.stats.admit_lat_us[s:]]
    return cluster, reqs, probe_lat


def router_cost_compare() -> List[Dict]:
    """Token-count vs modeled-µs routing vs modeled + pre-staging.

    Claims: (a) tokens byte-identical across all three configs (routing
    and pre-staging move *when* bytes arrive, never what decode
    computes); (b) modeled cost beats token counting on SLO attainment
    under the heterogeneous burst; (c) pre-staging cuts the probe-wave
    admit p99 versus the same modeled router without it.
    """
    rows = []
    outs, att, p99s = {}, {}, {}
    # "tokens" is the pre-§14 router verbatim: token-count load, no
    # queued steal, no pre-staging.  The modeled rows are the new router
    # with and without pre-staging.
    configs = (("tokens", "tokens", False, False),
               ("modeled", "modeled", False, True),
               ("modeled+prestage", "modeled", True, True))
    for mode, cost_model, prestage, steal in configs:
        cluster, reqs, probe_lat = run_router_burst(
            cost_model, prestage, steal_queued=steal)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        cs = cluster.stats()
        t = cs.totals
        att[mode] = cs.slo_attainment()
        p99s[mode] = float(np.percentile(probe_lat, 99)) \
            if probe_lat else 0.0
        rs = cluster.router.stats
        rows.append({
            "bench": "router", "mode": mode,
            "engines": len(cluster.engines),
            "tok_per_s_cpu": round(t.tok_per_s(), 1),
            "deadline_hits": sum(t.deadline_hits.values()),
            "deadline_misses": sum(t.deadline_misses.values()),
            "slo_attainment": round(att[mode], 3),
            "dispatched": "/".join(
                str(rs.dispatched.get(i, 0))
                for i in range(len(cluster.engines))),
            "queued_steals": rs.queued_steals,
            "prestaged_requests": rs.prestaged_requests,
            "prestage_hits": t.prestage_hits,
            "prestage_wasted": t.prestage_wasted,
            "prestage_cancelled": t.prestage_cancelled,
            "admit_p99_probe_us": round(p99s[mode], 1),
            "promote_stall_us": round(t.promote_stall_us, 1),
        })
    identical = (outs["tokens"] == outs["modeled"]
                 == outs["modeled+prestage"])
    rows.append({
        "bench": "router", "mode": "CLAIM",
        "claim_router_tokens_identical": identical,
        "claim_router_modeled_cost_raises_slo_attainment":
            bool(att["modeled"] > att["tokens"]),
        "claim_router_prestage_cuts_admit_p99":
            bool(p99s["modeled+prestage"] < p99s["modeled"]),
    })
    assert identical, "router cost model / pre-staging changed tokens!"
    return rows


def run_cluster_migration(n_engines: int, migrate: bool):
    """Controlled steal scenario: a long best-effort request on replica 0
    is displaced by a premium burst; with migration on, the idle replica
    adopts it via host-frame handoff instead of leaving it parked."""
    from repro.serving.cluster import ServingCluster

    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=n_engines,
                             max_batch=2, max_seq=96, seed=0,
                             migrate=migrate, prefix_cache=False,
                             decode_window_us=1000.0)
    rng = np.random.default_rng(2)
    victim = Request(rid=0, tenant=0, priority=0,
                     prompt=rng.integers(0, cfg.vocab_size, 40)
                     .astype(np.int32), max_new=20)
    premium = [Request(rid=i, tenant=1, priority=2,
                       prompt=rng.integers(0, cfg.vocab_size, 48)
                       .astype(np.int32), max_new=12)
               for i in range(1, 3)]
    cluster.submit(victim, engine=0)
    for _ in range(2):
        cluster.step()
    for r in premium:
        cluster.submit(r, engine=0)
    cluster.run_until_drained(max_steps=800)
    assert all(r.done for r in [victim] + premium)
    cluster.check_invariants()
    return cluster, [victim] + premium


def cluster_migration_compare() -> List[Dict]:
    rows = []
    outs = {}
    clusters = {}
    for mode, n_eng, migrate in (("1-engine", 1, False),
                                 ("2-engines", 2, False),
                                 ("2-engines-steal", 2, True)):
        cluster, reqs = run_cluster_migration(n_eng, migrate)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        clusters[mode] = cluster
        t = cluster.stats().totals
        r = cluster.router.stats
        rows.append({
            "bench": "cluster-migration", "mode": mode,
            "engines": n_eng,
            "tok_per_s_cpu": round(t.tok_per_s(), 1),
            "prefill_tokens": t.prefill_tokens,
            "decode_tokens": t.decode_tokens,
            "migrations": r.migrations,
            "migrated_pages": r.migrated_pages,
            "whole_frame_moves":
                cluster.tier.frames.stats["whole_frame_moves"],
            "swaps_out": t.swaps_out, "swaps_in": t.swaps_in,
            "transfer_us": round(t.transfer_us, 1),
        })
    steal = clusters["2-engines-steal"]
    dst = steal.engines[1]
    r = steal.router.stats
    identical = (outs["1-engine"] == outs["2-engines"]
                 == outs["2-engines-steal"])
    # Zero re-prefill: the thief decoded the migrated request without
    # ever prefilling (its pages arrived as host-resident base pages),
    # and cluster-wide prefill compute is unchanged by migration.
    zero_reprefill = (r.migrations >= 1 and r.migrated_pages > 0
                      and dst.stats.prefill_tokens == 0
                      and dst.stats.decode_tokens > 0
                      and dst.stats.faults >= r.migrated_pages
                      and clusters["2-engines-steal"].stats().totals
                          .prefill_tokens
                      == clusters["2-engines"].stats().totals
                          .prefill_tokens)
    # Handoff cost: restoring the migrated pages on the thief is modeled
    # DMA µs; re-prefilling the prompt would cost ≥ one decode window
    # per migration (deliberately loose floor, cf. prefix_reuse_compare).
    cheaper = dst.stats.transfer_us < r.migrations * 1000.0
    rows.append({"bench": "cluster-migration", "mode": "CLAIM",
                 "claim_cluster_migration_zero_reprefill":
                     bool(zero_reprefill),
                 "claim_cluster_tokens_identical_1_vs_n": bool(identical),
                 "claim_cluster_migration_cheaper_than_reprefill":
                     bool(cheaper)})
    assert identical, "cluster scale-out changed model outputs!"
    return rows


def cluster_sim_compare(n_access: int = 2000) -> List[Dict]:
    """The TLB simulator's cluster model: 4 apps across engine counts.

    One engine = one shared link (the pre-cluster model).  Two engines
    with private links remove cross-engine link contention; adding a
    shared host store (1 DRAM lane) re-serializes the transfers there;
    widening the host lanes relieves it."""
    from repro.core.tlb_sim import SimConfig, TranslationSim
    from repro.core.workloads import build_workload, homogeneous_names

    names = homogeneous_names("dct", 4)
    traces, _ = build_workload(names, "mosaic", seed=0, n_access=n_access)
    rows = []
    res = {}
    for label, n_eng, host_lanes in (("1-engine", 1, 0),
                                     ("2-engines", 2, 0),
                                     ("2-engines-shared-host", 2, 1),
                                     ("2-engines-wide-host", 2, 2)):
        sim = TranslationSim(
            SimConfig(mode="mosaic", paging=True, dma_channels=1,
                      n_engines=n_eng, host_lanes=host_lanes), traces)
        sim.run()
        res[label] = (sim.link.contention_total(),
                      sim.link.host_contention_total())
        rows.append({"bench": "cluster-sim", "mode": label,
                     "n_engines": n_eng, "host_lanes": host_lanes,
                     "faults": sim.link.faults,
                     "link_contention": round(res[label][0], 1),
                     "host_contention": round(res[label][1], 1)})
    rows.append({"bench": "cluster-sim", "mode": "CLAIM",
                 "claim_cluster_links_cut_link_contention":
                     bool(res["2-engines"][0] < res["1-engine"][0]
                          and res["1-engine"][0] > 0),
                 "claim_cluster_host_lanes_relieve_shared_store":
                     bool(res["2-engines-shared-host"][1]
                          > res["2-engines-wide-host"][1])})
    return rows


# ------------------------------------------------------------ spill tier


def _grouped_prefix_reqs(cfg, *, n_groups=4, per_group=3, shared_tokens=40,
                         suffix_tokens=8, max_new=4, seed=0):
    """``n_groups`` distinct shared prefixes, ``per_group`` requests
    each.  Returned grouped so callers can wave-split: one request per
    group parks its prefix, the rest readmit against a warm index."""
    rng = np.random.default_rng(seed)
    groups, rid = [], 0
    for _ in range(n_groups):
        shared = rng.integers(0, cfg.vocab_size,
                              shared_tokens).astype(np.int32)
        group = []
        for _ in range(per_group):
            suf = rng.integers(0, cfg.vocab_size,
                               suffix_tokens).astype(np.int32)
            group.append(Request(rid=rid, tenant=rid % 3,
                                 prompt=np.concatenate([shared, suf]),
                                 max_new=max_new))
            rid += 1
        groups.append(group)
    return groups


def run_spill_cluster(spill: bool, *, capacity_frames: int = 3,
                      n_engines: int = 2, n_groups: int = 4,
                      per_group: int = 3, injector=None):
    """Two-wave grouped-prefix workload under a hard host-frame cap.

    Wave 1 (one request per group) parks every group's prefix; with
    4 groups x 5 pages in 4-page frames the parked set overflows
    ``capacity_frames``, so the LRU groups either spill to disk
    (``spill=True``) or are hard-evicted through the prefix index
    (``spill=False``).  Wave 2 readmits every group; per-engine
    ``admit_lat_us`` sample counts are snapshotted between the waves so
    the caller can take a wave-2-only p99.
    """
    from repro.serving.cluster import ServingCluster

    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=n_engines,
                             max_batch=4, max_seq=128, seed=0,
                             capacity_frames=capacity_frames, spill=spill,
                             decode_window_us=1000.0,
                             fault_injector=injector)
    groups = _grouped_prefix_reqs(cfg, n_groups=n_groups,
                                  per_group=per_group)
    wave1 = [g[0] for g in groups]
    wave2 = [r for g in groups for r in g[1:]]
    for r in wave1:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=1000)
    starts = [len(e.stats.admit_lat_us) for e in cluster.engines]
    for r in wave2:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=2000)
    assert all(r.done for r in wave1 + wave2), "spill workload not drained"
    cluster.check_invariants()
    wave2_lat = [x for e, s in zip(cluster.engines, starts)
                 for x in e.stats.admit_lat_us[s:]]
    return cluster, wave1 + wave2, wave2_lat


def spill_compare(n_engines: int = 2) -> List[Dict]:
    """Spill-to-disk vs hard-capped eviction under the same frame cap.

    Claims: (a) tokens byte-identical spill on/off (the disk tier is
    transparent memory management); (b) spill keeps the wave-2 prefix
    hit rate strictly higher — spilled frames promote back instead of
    being dropped; (c) wave-2 p99 admission latency (modeled: prefill
    compute at ``prefill_us_per_token`` + promote stalls) is strictly
    lower with spill — a ~200-600 us disk promote beats re-prefilling a
    48-token prompt.
    """
    rows = []
    outs, rates, p99s, clusters = {}, {}, {}, {}
    for mode, spill in (("spill", True), ("hard-cap", False)):
        cluster, reqs, wave2_lat = run_spill_cluster(
            spill, n_engines=n_engines)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        clusters[mode] = cluster
        cs = cluster.stats()
        t = cs.totals
        rates[mode] = cs.prefix_hit_rate()
        p99s[mode] = float(np.percentile(wave2_lat, 99)) \
            if wave2_lat else 0.0
        tier = cluster.tier
        rows.append({
            "bench": "spill", "mode": mode, "engines": n_engines,
            "tok_per_s_cpu": round(t.tok_per_s(), 1),
            "prefix_hits": t.prefix_hits,
            "prefix_misses": t.prefix_misses,
            "hit_rate": round(rates[mode], 3),
            "prefill_tokens": t.prefill_tokens,
            "spilled_frames": tier.stats["spilled_frames"],
            "promoted_frames": tier.stats["promoted_frames"],
            "hard_evicted_pages": tier.stats["hard_evicted_pages"],
            "promote_stall_us": round(t.promote_stall_us, 1),
            "spill_dma_jobs": (tier.wb_dma.stats["spill_jobs"]
                               if tier.spill_enabled else 0),
            "admit_p99_wave2_us": round(p99s[mode], 1),
            "host_frames_peak": tier.frames.stats["peak_frames"],
        })
    st_on = clusters["spill"].tier.stats
    identical = outs["spill"] == outs["hard-cap"]
    # The comparison is meaningful only if the cap actually bit on both
    # sides: frames went to disk with spill on, pages were dropped with
    # spill off.
    cap_bit = (st_on["spilled_frames"] > 0
               and st_on["promoted_frames"] > 0
               and clusters["hard-cap"].tier.stats["hard_evicted_pages"]
               > 0)
    rows.append({"bench": "spill", "mode": "CLAIM",
                 "claim_spill_tokens_identical": identical,
                 "claim_spill_higher_hit_rate":
                     bool(cap_bit and rates["spill"] > rates["hard-cap"]),
                 "claim_spill_lower_admit_p99":
                     bool(cap_bit and p99s["spill"] < p99s["hard-cap"])})
    assert identical, "disk spill tier changed model outputs!"
    return rows


def spill_backpressure_compare() -> List[Dict]:
    """Write-back saturation → refuse-park back-pressure.

    A 1-deep write-back queue over a deliberately slow disk (2 ms/page:
    one frame takes ~8 decode windows to persist) saturates while the
    first spill is still in flight, so later over-cap prefix parks are
    refused outright — the tier sheds cache-insert load instead of
    queueing unboundedly — and the queue depth never exceeds its bound.
    Refused parks only cost future hits; tokens are unaffected.
    """
    from repro.serving.cluster import ServingCluster

    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=2,
                             max_batch=4, max_seq=128, seed=0,
                             capacity_frames=2, wb_queue_frames=1,
                             disk_write_us_per_page=2000.0,
                             decode_window_us=1000.0)
    groups = _grouped_prefix_reqs(cfg, n_groups=5, per_group=1, seed=3)
    reqs = [g[0] for g in groups]
    for r in reqs:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=2000)
    assert all(r.done for r in reqs), "backpressure workload not drained"
    cluster.tier.flush()        # persist the still-in-flight write-back
    cluster.check_invariants()
    t = cluster.stats().totals
    tier = cluster.tier
    rows = [{
        "bench": "spill-backpressure", "mode": "wb-queue-1",
        "parked_pages": t.prefix_parked_pages,
        "parks_refused": t.prefix_park_refused,
        "spilled_frames": tier.stats["spilled_frames"],
        "wb_peak_depth": tier.stats["wb_peak_depth"],
        "wb_queue_frames": tier.wb_queue_frames,
    }]
    rows.append({"bench": "spill-backpressure", "mode": "CLAIM",
                 "claim_spill_backpressure_refuses_parks":
                     bool(t.prefix_park_refused >= 1
                          and tier.stats["spilled_frames"] >= 1
                          and tier.stats["wb_peak_depth"]
                          <= tier.wb_queue_frames)})
    return rows


def spill_sim_compare(n_access: int = 2000,
                      hbm_pages: int = 192) -> List[Dict]:
    """Capacity writebacks hitting the disk in the TLB simulator.

    Same capped setting as ``duplex_sim_compare``, with the disk
    modeled: each writeback streams host→disk after its link transfer
    at ``disk_cycles_per_page`` (~an order of magnitude over the link's
    per-page cost), so evictions queue at a single disk lane; a second
    lane relieves the backlog.
    """
    from repro.core.tlb_sim import SimConfig, TranslationSim
    from repro.core.workloads import build_workload, homogeneous_names

    names = homogeneous_names("dct", 3)
    traces, _ = build_workload(names, "mosaic", seed=0, n_access=n_access)
    rows = []
    res = {}
    for disk_lanes in (1, 2):
        sim = TranslationSim(
            SimConfig(mode="mosaic", paging=True, dma_channels=1,
                      duplex=True, hbm_pages_per_app=hbm_pages,
                      disk_lanes=disk_lanes), traces)
        sim.run()
        res[disk_lanes] = sim.link.disk_contention_total()
        rows.append({
            "bench": "spill-sim", "disk_lanes": disk_lanes,
            "writebacks": sim.link.writebacks,
            "disk_writebacks": sim.link.disk_writebacks,
            "disk_busy_cycles": round(sim.link.disk_busy_cycles, 1),
            "disk_contention_cycles": round(res[disk_lanes], 1),
        })
    rows.append({"bench": "spill-sim", "disk_lanes": "CLAIM",
                 "claim_spill_disk_lanes_relieve_writeback":
                     bool(res[1] > 0 and res[2] < res[1])})
    return rows


# -------------------------------------------------------- fault tolerance


def _kill_unrecovered(cluster, idx: int) -> None:
    """Model an engine crash with NO failover (the baseline the recovery
    claim is measured against): the engine dies and takes its queued and
    in-flight work with it — those requests never complete.  The dead
    domain's host frames are still reclaimed so tier invariants hold."""
    victim = cluster.engines[idx]
    victim.alive = False
    victim.active.clear()
    victim.queue.clear()
    victim.preempted.clear()
    victim._held.clear()
    victim.states.clear()
    victim._saved_tokens.clear()
    if cluster.tier is not None:
        cluster.tier.reclaim_domain(victim.engine_id)


def run_crash_cluster(mode: str):
    """Deadline workload with replica 0 carrying most of the work.

    Replica 0 decodes two long requests, a premium request preempts one
    of them (leaving a host-side bundle), and a small burst lands on the
    idle replica 1.  ``mode``:

    * ``"fault-free"``  — no failure; reference tokens and SLO.
    * ``"recovery"``    — the injector kills engine 0 at router step 6
      (mid-decode, bundle parked): the router re-homes the preempted
      bundle to replica 1 with zero re-prefill and re-dispatches the
      in-flight/queued victims from the prompt.
    * ``"no-recovery"`` — the same crash point with failover disabled:
      the victim's requests die with the engine.
    """
    from repro.serving.cluster import ServingCluster
    from repro.serving.faults import FaultInjector, FaultPlan

    crash_step = 6
    inj = FaultInjector(FaultPlan(engine_crashes=((crash_step, 0),))) \
        if mode == "recovery" else None
    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=2, max_batch=2,
                             max_seq=128, seed=0, prefix_cache=False,
                             migrate=False, decode_window_us=1000.0,
                             fault_injector=inj)
    rng = np.random.default_rng(5)
    long_reqs = [Request(rid=i, tenant=0, priority=0,
                         prompt=rng.integers(0, cfg.vocab_size, 32)
                         .astype(np.int32), max_new=20,
                         deadline_us=120_000.0)
                 for i in range(2)]
    for r in long_reqs:
        cluster.submit(r, engine=0)
    for _ in range(2):
        cluster.step()
    premium = Request(rid=2, tenant=1, priority=2,
                      prompt=rng.integers(0, cfg.vocab_size, 24)
                      .astype(np.int32), max_new=6, deadline_us=40_000.0)
    cluster.submit(premium, engine=0)
    for _ in range(2):
        cluster.step()
    burst = [Request(rid=3 + i, tenant=2, priority=0,
                     prompt=rng.integers(0, cfg.vocab_size, 24)
                     .astype(np.int32), max_new=6, deadline_us=60_000.0)
             for i in range(2)]
    for r in burst:
        cluster.submit(r)
    reqs = long_reqs + [premium] + burst
    if mode == "no-recovery":
        for _ in range(2):          # reach the same crash point
            cluster.step()
        _kill_unrecovered(cluster, 0)
    cluster.run_until_drained(max_steps=1500)
    if mode != "no-recovery":
        assert all(r.done for r in reqs), f"{mode}: workload not drained"
    cluster.check_invariants()
    return cluster, reqs


def faults_crash_compare() -> List[Dict]:
    """Engine-crash recovery vs a no-failover baseline (DESIGN.md §12).

    Claims: (a) after the crash the recovered run's tokens are
    byte-identical to the fault-free run's for *every* request — the
    preempted bundle resumes on the survivor with zero re-prefill, the
    in-flight/queued victims replay deterministically from the prompt;
    (b) recovery's deadline-met fraction (over all submitted
    deadline-carrying requests; never-completed counts as a miss) is
    strictly above the no-failover baseline's.
    """
    rows = []
    outs, met, clusters = {}, {}, {}
    for mode in ("fault-free", "recovery", "no-recovery"):
        cluster, reqs = run_crash_cluster(mode)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs if r.done}
        clusters[mode] = cluster
        t = cluster.stats().totals
        rs = cluster.router.stats
        n_dl = sum(1 for q in reqs if q.deadline_us is not None)
        met[mode] = sum(t.deadline_hits.values()) / max(n_dl, 1)
        rows.append({
            "bench": "faults-crash", "mode": mode,
            "engines": len(cluster.engines),
            "tok_per_s_cpu": round(t.tok_per_s(), 1),
            "completed": sum(1 for q in reqs if q.done),
            "requests": len(reqs),
            "deadline_met_frac": round(met[mode], 3),
            "crashes": rs.crashes,
            "recovered_bundles": rs.recovered_bundles,
            "recovered_requeued": rs.recovered_requeued,
            "reclaimed_frames": cluster.tier.stats["reclaimed_frames"],
        })
    rec = clusters["recovery"].router.stats
    # The scenario must actually bite: one crash, at least one zero-
    # re-prefill bundle re-homed, at least one victim re-dispatched.
    crash_bit = (rec.crashes == 1 and rec.recovered_bundles >= 1
                 and rec.recovered_requeued >= 1)
    identical = outs["recovery"] == outs["fault-free"]
    rows.append({"bench": "faults-crash", "mode": "CLAIM",
                 "claim_faults_crash_tokens_identical":
                     bool(crash_bit and identical),
                 "claim_faults_recovery_higher_slo":
                     bool(crash_bit
                          and met["recovery"] > met["no-recovery"])})
    assert identical, "crash recovery changed model outputs!"
    return rows


def faults_spill_compare() -> List[Dict]:
    """Spill-store integrity under injected disk faults (DESIGN.md §12).

    The ``spill_compare`` workload (grouped prefixes overflowing the
    frame cap, spill on) re-run under four fault plans against a clean
    reference:

    * ``corrupt``   — every spilled frame gets a seeded bit flip on
      disk.  Claims: the blake2b checksum catches **100 %** of corrupt
      reads (no corrupted frame is ever decoded from: zero successful
      reads), every caught frame is quarantined, and tokens still match
      the clean run — quarantined prefixes are re-derived by a full
      prefill, never served from bad bytes.
    * ``degrade``   — every disk write fails (transient, unbounded).
      Bounded retries with exponential backoff are charged to the
      modeled clock; once the error rate crosses the threshold the tier
      degrades to the hard-cap (spill-off) path.  Claims: the tier
      degraded, retries/backoff were exercised, and **zero requests
      dropped** — tokens identical to the clean run.
    * ``dma-stall`` (x2, same seed) — every 3rd DMA job stalls 500 µs.
      Claims: stalls fired, tokens are unchanged (timing-only fault),
      and two identically-seeded runs produce identical injector stats
      (the fault schedule is reproducible).
    """
    from repro.serving.faults import FaultInjector, FaultPlan

    plans = {
        "clean": None,
        "corrupt": FaultPlan(corrupt_write_rate=1.0),
        "degrade": FaultPlan(disk_write_error_rate=1.0,
                             max_transient_failures=10 ** 6),
        "dma-stall": FaultPlan(dma_stall_every=3, dma_stall_us=500.0),
        "dma-stall-b": FaultPlan(dma_stall_every=3, dma_stall_us=500.0),
    }
    rows, outs, clusters, injs = [], {}, {}, {}
    for mode, plan in plans.items():
        inj = FaultInjector(plan) if plan is not None else None
        cluster, reqs, _ = run_spill_cluster(True, injector=inj)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        clusters[mode], injs[mode] = cluster, inj
        tier = cluster.tier
        ss = tier.spill_store.stats if tier.spill_store is not None else {}
        rows.append({
            "bench": "faults-spill", "mode": mode,
            "spilled_frames": tier.stats["spilled_frames"],
            "promoted_frames": tier.stats["promoted_frames"],
            "frames_quarantined": tier.stats["frames_quarantined"],
            "checksum_failures": ss.get("checksum_failures", 0),
            "frames_read_ok": ss.get("frames_read", 0),
            "disk_errors": tier.stats["disk_errors"],
            "disk_retries": tier.stats["disk_retries"],
            "retry_backoff_us": round(tier.stats["retry_backoff_us"], 1),
            "degraded": tier.stats["degraded"],
            "lost_restarts": cluster.stats().totals.lost_restarts,
            "dma_stalls": inj.stats["dma_stalls"] if inj else 0,
            "injected_stall_us":
                round(inj.stats["dma_stall_us"], 1) if inj else 0.0,
        })
    # Clean reference must exercise the disk at all for the injected
    # plans to mean anything.
    clean_bit = (clusters["clean"].tier.stats["spilled_frames"] > 0
                 and clusters["clean"].tier.stats["promoted_frames"] > 0)
    ssc = clusters["corrupt"].tier.spill_store.stats
    tc = clusters["corrupt"].tier.stats
    detected = (clean_bit and ssc["checksum_failures"] >= 1
                and ssc["frames_read"] == 0          # 100%: none decoded
                and tc["frames_quarantined"] >= 1
                and injs["corrupt"].stats["corrupted_frames"] >= 1)
    corrupt_identical = outs["corrupt"] == outs["clean"]
    td = clusters["degrade"].tier
    degrade_ok = (clean_bit and bool(td.degraded)
                  and td.stats["disk_retries"] >= 1
                  and td.stats["retry_backoff_us"] > 0.0
                  and outs["degrade"] == outs["clean"])
    ia, ib = injs["dma-stall"], injs["dma-stall-b"]
    dma_ok = (ia.stats["dma_stalls"] >= 1
              and outs["dma-stall"] == outs["clean"]
              and outs["dma-stall"] == outs["dma-stall-b"]
              and ia.stats == ib.stats)
    rows.append({"bench": "faults-spill", "mode": "CLAIM",
                 "claim_faults_corruption_detected": bool(detected),
                 "claim_faults_corruption_tokens_identical":
                     bool(clean_bit and corrupt_identical),
                 "claim_faults_degrade_zero_drops": bool(degrade_ok),
                 "claim_faults_dma_stall_timing_only": bool(dma_ok)})
    assert corrupt_identical, "spill corruption leaked into outputs!"
    assert outs["degrade"] == outs["clean"], \
        "degraded tier changed model outputs!"
    return rows


# ------------------------------------------- fused gather-attend decode


def fused_decode_compare(factor: float = 2.0,
                         n_requests: int = 8) -> List[Dict]:
    """Sync vs async vs fused fault-in on the same oversubscribed trace.

    The fused path (``fault_mode="fused"``) removes the step-granularity
    DMA barrier: instead of waiting for every missing page before the
    decode launches, it hands the attention kernel a per-page readiness
    mask plus staging-buffer slots and lets the kernel consume arriving
    pages in place.  Only the transfer tail past the decode window is
    exposed, so at the starved 2 µs window its exposed µs must sit
    strictly below the async pipeline's (which stalls per page before
    launch).  Tokens stay byte-identical across all three modes — the
    staged bytes are exactly what the scatter would have written.

    The hidden-fraction claim is calibrated at the default 8-request
    trace: bigger traces shift exposure into single resume transfers
    many times the window (a 20 µs DMA exposes ≥18 µs under *any*
    2 µs-window scheme), so async and fused converge toward the same
    floor and the fraction measures trace shape, not the mechanism.
    The strictly-below-async claim holds at every size.
    """
    configs = (("sync", "sync", None),
               ("async", "async", 1000.0),
               ("async-tight", "async", 2.0),
               ("fused", "fused", 1000.0),
               ("fused-tight", "fused", 2.0))
    rows = []
    outs, stats = {}, {}
    for mode, fault_mode, window in configs:
        eng, reqs = run_oversubscribed(
            "mosaic", factor=factor, n_requests=n_requests,
            fault_mode=fault_mode, decode_window_us=window)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        stats[mode] = eng.stats
        s = eng.stats
        rows.append({
            "bench": "fused-decode", "mode": mode, "factor": factor,
            "tok_per_s_cpu": round(s.tok_per_s(), 1),
            "faults": s.faults, "dma_count": s.fault_dmas,
            "transfer_us": round(s.transfer_us, 1),
            "exposed_us": round(s.fault_exposed_us, 1),
            "hidden_us": round(s.fault_hidden_us, 1),
            "fused_ready_pages": s.fused_ready_pages,
            "fused_drained_pages": s.fused_drained_pages,
            "fused_tail_us": round(s.fused_tail_us, 1),
        })
    identical = all(o == outs["sync"] for o in outs.values())
    assert identical, "fused fault-in changed tokens!"
    sync_exp = max(stats["sync"].fault_exposed_us, 1e-9)
    frac_tight = 1.0 - stats["fused-tight"].fault_exposed_us / sync_exp
    below_async = (stats["fused-tight"].fault_exposed_us
                   < stats["async-tight"].fault_exposed_us)
    drained = (stats["fused"].fused_drained_pages
               + stats["fused-tight"].fused_drained_pages)
    rows.append({"bench": "fused-decode", "mode": "CHECK", "factor": factor,
                 "hidden_fraction_fused_tight": round(frac_tight, 3),
                 "fused_tight_exposed_us":
                     round(stats["fused-tight"].fault_exposed_us, 1),
                 "async_tight_exposed_us":
                     round(stats["async-tight"].fault_exposed_us, 1),
                 "outputs_identical": identical})
    rows.append({"bench": "fused-decode", "mode": "CLAIM", "factor": factor,
                 "claim_fused_tokens_identical": bool(identical),
                 "claim_fused_tight_exposed_below_async": bool(below_async),
                 "claim_fused_hides_over_089": bool(frac_tight > 0.89),
                 "claim_fused_drains_in_kernel": bool(drained > 0),
                 "hidden_fraction_fused_tight": round(frac_tight, 3)})
    assert below_async, (
        f"fused tight exposed {stats['fused-tight'].fault_exposed_us:.1f}us "
        f"not below async {stats['async-tight'].fault_exposed_us:.1f}us")
    return rows


def fused_kernel_compare(B: int = 4, nblk: int = 8, reps: int = 3) -> List[Dict]:
    """Readiness-masked kernel vs gather-then-attend on one synthetic batch.

    All-resident (every slot -1) the fused kernel must be *bitwise*
    identical to the baseline page-granularity kernel — the masked loads
    all select the pool and the late accumulator never initializes, so
    the flush emits the ready scratch untouched.  With half the pages
    staged it must match scatter-then-attend to float32 round-off (the
    two-accumulator combine is a fixed-order reassociation, and pallas
    interpret mode jits the kernel while the scatter path runs the same
    ops under a separate trace).  Tokens/s rows are CPU wall-clock on
    the interpret-mode kernel — relative only.
    """
    import time

    import jax.numpy as jnp

    from repro.kernels.paged_attention import (fused_paged_attention_kernel,
                                               paged_attention_kernel)

    n_kv, g, dh, ptok = 2, 2, 16, 8
    NP = B * nblk + 4
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, n_kv * g, dh), np.float32))
    pool_k = jnp.asarray(rng.standard_normal((NP, ptok, n_kv, dh), np.float32))
    pool_v = jnp.asarray(rng.standard_normal((NP, ptok, n_kv, dh), np.float32))
    tables = jnp.asarray(
        rng.permutation(NP)[:B * nblk].reshape(B, nblk).astype(np.int32))
    ntok = jnp.full((B, nblk), ptok, jnp.int32)
    scale = 1.0 / float(np.sqrt(dh))

    base = paged_attention_kernel(q, pool_k, pool_v, tables, ntok,
                                  granularity="page", scale=scale)

    # All resident: every slot -1, stage pools untouched.
    no_slots = jnp.full((B, nblk), -1, jnp.int32)
    stage_k = pool_k[:4]
    stage_v = pool_v[:4]
    allready = fused_paged_attention_kernel(
        q, pool_k, pool_v, stage_k, stage_v, tables, no_slots, ntok,
        scale=scale)
    bitwise = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(allready, base))

    # Half the pages staged: their pool bytes are garbage, the staging
    # buffer holds the truth.  Gather-then-attend scatters first.
    late = np.zeros((B, nblk), bool)
    late[:, 1::2] = True
    NS = int(late.sum())
    slots_np = np.full((B, nblk), -1, np.int32)
    slots_np[late] = np.arange(NS, dtype=np.int32)
    tbl_np = np.asarray(tables)
    sk = np.asarray(pool_k)[tbl_np[late]]
    sv = np.asarray(pool_v)[tbl_np[late]]
    dirty_k = np.asarray(pool_k).copy()
    dirty_v = np.asarray(pool_v).copy()
    dirty_k[tbl_np[late]] = rng.standard_normal(sk.shape).astype(np.float32)
    dirty_v[tbl_np[late]] = rng.standard_normal(sv.shape).astype(np.float32)
    slots = jnp.asarray(slots_np)
    fused = fused_paged_attention_kernel(
        q, jnp.asarray(dirty_k), jnp.asarray(dirty_v),
        jnp.asarray(sk), jnp.asarray(sv), tables, slots, ntok, scale=scale)
    partial_ok = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
        for a, b in zip(fused, base))

    def _time(fn):
        fn()                        # warm the jit/trace caches
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn()
            [np.asarray(x) for x in r]
        return (time.perf_counter() - t0) / reps

    def _gather_then_attend():
        gk = np.asarray(dirty_k).copy()
        gv = np.asarray(dirty_v).copy()
        gk[tbl_np[late]] = sk
        gv[tbl_np[late]] = sv
        return paged_attention_kernel(q, jnp.asarray(gk), jnp.asarray(gv),
                                      tables, ntok,
                                      granularity="page", scale=scale)

    t_fused = _time(lambda: fused_paged_attention_kernel(
        q, jnp.asarray(dirty_k), jnp.asarray(dirty_v),
        jnp.asarray(sk), jnp.asarray(sv), tables, slots, ntok, scale=scale))
    t_gather = _time(_gather_then_attend)
    toks = B * nblk * ptok
    rows = [
        {"bench": "fused-kernel", "mode": "fused", "batch": B,
         "blocks": nblk, "staged_pages": NS,
         "tok_per_s_cpu": round(toks / max(t_fused, 1e-9), 1)},
        {"bench": "fused-kernel", "mode": "gather-then-attend", "batch": B,
         "blocks": nblk, "staged_pages": NS,
         "tok_per_s_cpu": round(toks / max(t_gather, 1e-9), 1)},
        {"bench": "fused-kernel", "mode": "CLAIM",
         "claim_fused_allready_bitwise": bool(bitwise),
         "claim_fused_partial_matches_gather": bool(partial_ok)},
    ]
    assert bitwise, "all-resident fused kernel is not bitwise identical"
    assert partial_ok, "partially-staged fused kernel diverged from gather"
    return rows


# ------------------------------------------- translation (radix walker)


def translation_radix_compare(n_access: int = 2000) -> List[Dict]:
    """Contiguity ⇒ cheap translation, measured (DESIGN.md §15).

    The same trace geometry is allocated by the mosaic manager
    (contiguity-preserving CoCoA) and the gpu-mmu baseline (interleaved
    per-buffer allocation), then run under the radix walker with
    subregion-coalesced TLB entries whose coverage is derived from each
    allocator's *actual* frame map.  Claims:

    * mosaic pays fewer walks and fewer total walk cycles than the
      scattered baseline (one coalesced entry covers a contiguous run);
    * coalescing itself is the mechanism: span-1 entries (per-page)
      erase mosaic's advantage walk-for-walk;
    * per-level walk caches cut DRAM accesses per walk;
    * ``translation="flat"`` and radix with PWCs off + span 1 agree
      bitwise (per-app cycles), so every pre-§15 claim is preserved.
    """
    from repro.core.tlb_sim import SimConfig, TranslationSim
    from repro.core.workloads import build_workload, homogeneous_names

    names = homogeneous_names("bfs", 2)
    rows = []
    st = {}
    for label, kind, cfg_kw in (
            ("mosaic-radix", "mosaic", {}),
            ("gpu-mmu-radix", "gpu-mmu", {}),
            ("mosaic-span1", "mosaic", {"coalesce_span": 1}),
            ("gpu-mmu-nopwc", "gpu-mmu", {"pwc_entries": 0})):
        traces, _ = build_workload(names, kind, seed=0, n_access=n_access)
        sim = TranslationSim(
            SimConfig(translation="radix", paging=False, **cfg_kw), traces)
        res = sim.run()
        st[label] = {
            "walks": sim.total_walks(),
            "walk_cycles": sim.total_walk_cycles(),
            "dram": sim.walk_dram_accesses(),
            "queue": sim.walker_queue_cycles(),
            "pwc": sim.pwc_hit_rate(),
            "ipc": float(sum(r.ipc for r in res)),
        }
        rows.append({
            "bench": "translation", "mode": label,
            "walks": st[label]["walks"],
            "walk_cycles": round(st[label]["walk_cycles"], 1),
            "dram_accesses": st[label]["dram"],
            "walker_queue_cycles": round(st[label]["queue"], 1),
            "pwc_hit": round(st[label]["pwc"], 3),
            "l1_hit": round(sim.l1_hit_rate(), 3),
            "ipc_sum": round(st[label]["ipc"], 4),
        })

    # Flat/radix parity: the degenerate radix config must reproduce the
    # flat walker's timings bitwise (mode="base" exercises the flat
    # base-page path; large arrays zeroed so entry budgets match).
    parity_kw = dict(mode="base", paging=False,
                     l1_large_entries=0, l2_large_entries=0)
    tf, _ = build_workload(names, "gpu-mmu", seed=0, n_access=n_access)
    sim_f = TranslationSim(SimConfig(translation="flat", **parity_kw), tf)
    tr, _ = build_workload(names, "gpu-mmu", seed=0, n_access=n_access)
    sim_r = TranslationSim(
        SimConfig(translation="radix", pwc_entries=0, coalesce_span=1,
                  **parity_kw), tr)
    rf, rr = sim_f.run(), sim_r.run()
    parity = (all(f.cycles == r.cycles and f.retired == r.retired
                  for f, r in zip(rf, rr))
              and sim_f.walker.walks == sim_r.total_walks())
    rows.append({
        "bench": "translation", "mode": "flat-parity",
        "flat_walks": sim_f.walker.walks,
        "radix_walks": sim_r.total_walks(),
        "flat_cycles": round(float(sum(f.cycles for f in rf)), 1),
        "radix_cycles": round(float(sum(r.cycles for r in rr)), 1),
    })

    rows.append({
        "bench": "translation", "mode": "CLAIM",
        "claim_translation_mosaic_fewer_walks":
            bool(st["mosaic-radix"]["walks"]
                 < st["gpu-mmu-radix"]["walks"]),
        "claim_translation_mosaic_cheaper_walk_cycles":
            bool(st["mosaic-radix"]["walk_cycles"]
                 < st["gpu-mmu-radix"]["walk_cycles"]),
        "claim_translation_coalescing_cuts_walks":
            bool(st["mosaic-radix"]["walks"]
                 < st["mosaic-span1"]["walks"]),
        "claim_translation_pwc_cuts_dram_accesses":
            bool(st["gpu-mmu-radix"]["dram"]
                 < st["gpu-mmu-nopwc"]["dram"]),
        "claim_translation_flat_radix_parity": bool(parity),
    })
    assert parity, "flat/radix parity broke — pre-§15 claims at risk"
    return rows


def run_translation_cluster(mode: str):
    """Walker-contention routing scenario (DESIGN.md §15).

    Engine 0 is pinned four long-context requests, engine 1 four
    short-context ones with *identical* decode footprints (same
    ``max_new``, same arrival).  The meter runs with a deliberately
    small TLB (4/8 coalesced entries) so engine 0's big KV tables
    capacity-thrash its radix walker every step — sustained walker
    queueing — while engine 1's tables fit.  An unpinned probe wave
    then arrives with ``max_new=1``: ``ceil(remaining/max_batch)``
    stays under the critical path, so every pre-§15 cost term ties
    exactly, and the dispatch is decided purely by the tie-break.
    Without translation awareness that is the engine index (probes
    pile onto the walker-saturated engine 0); with it, the walker
    backlog term routes them to engine 1.  ``mode``: "aware",
    "unaware", or "off" (meters off — the pre-§15 router verbatim).
    """
    from repro.serving.cluster import ServingCluster

    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(
        cfg, geometry=GEO, n_engines=2, max_batch=8, max_seq=192,
        manager_kind="gpu-mmu", seed=0, prefix_cache=False,
        migrate=False, router_steal_queued=False,
        decode_window_us=1000.0,
        translation="off" if mode == "off" else "radix",
        router_translation_aware=(mode != "unaware"),
        translation_kw={"l1_entries": 4, "l2_entries": 8})
    rng = np.random.default_rng(11)

    def _req(rid, tokens, max_new, tenant=0):
        return Request(rid=rid, tenant=tenant,
                       prompt=rng.integers(0, cfg.vocab_size, tokens)
                       .astype(np.int32), max_new=max_new)

    heavy = [_req(i, 160, 8, tenant=0) for i in range(4)]
    light = [_req(10 + i, 8, 8, tenant=1) for i in range(4)]
    for r in heavy:
        cluster.submit(r, engine=0)
    for r in light:
        cluster.submit(r, engine=1)
    for _ in range(2):       # book walker time before the probes arrive
        cluster.step()
    probes = [_req(100 + i, 16, 1, tenant=2) for i in range(4)]
    for r in probes:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=600)
    reqs = heavy + light + probes
    assert all(r.done for r in reqs), "translation bench not drained"
    cluster.check_invariants()
    return cluster, reqs, probes


def translation_router_compare() -> List[Dict]:
    """Translation-aware routing A/B (DESIGN.md §15).

    Claims: (a) tokens byte-identical across aware / unaware / off —
    the walker term moves *placement*, never what decode computes;
    (b) awareness routes the probe wave away from the walker-saturated
    engine; (c) cluster-wide walker-queue interference drops.
    """
    rows = []
    outs, probe_split, queue_cycles = {}, {}, {}
    for mode in ("off", "unaware", "aware"):
        cluster, reqs, probes = run_translation_cluster(mode)
        outs[mode] = {r.rid: tuple(r.out) for r in reqs}
        t = cluster.stats().totals
        on1 = sum(1 for r in probes
                  if cluster.router._owner.get(r.rid) == 1)
        probe_split[mode] = on1
        queue_cycles[mode] = t.translation_queue_cycles
        rows.append({
            "bench": "translation-router", "mode": mode,
            "tok_per_s_cpu": round(t.tok_per_s(), 1),
            "probes_to_engine1": on1,
            "translation_lookups": t.translation_lookups,
            "translation_walks": t.translation_walks,
            "translation_queue_cycles":
                round(t.translation_queue_cycles, 1),
            "translation_us": round(t.translation_us, 1),
            "dispatched": "/".join(
                str(cluster.router.stats.dispatched.get(i, 0))
                for i in range(2)),
        })
    identical = outs["off"] == outs["unaware"] == outs["aware"]
    rows.append({
        "bench": "translation-router", "mode": "CLAIM",
        "claim_translation_tokens_identical": bool(identical),
        "claim_translation_aware_routes_off_hot_walker":
            bool(probe_split["aware"] > probe_split["unaware"]),
        "claim_translation_aware_cuts_queue_cycles":
            bool(queue_cycles["aware"] < queue_cycles["unaware"]),
    })
    assert identical, "translation metering changed model outputs!"
    return rows
