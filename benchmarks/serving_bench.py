"""Multi-tenant serving benchmark: Mosaic vs GPU-MMU manager on the real
engine (the LLM-serving analogue of the paper's Figs. 5/6 setting).

Identical request streams through both managers; reports tokens/s (CPU
wall-clock — relative only), coalesced fraction (the structural quantity
that becomes TLB reach / kernel indirection savings on TPU), compaction
copy counts, and memory bloat.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.engine import Request, ServingEngine

GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)


def run_engine(manager_kind: str, n_requests=8, max_new=8, seed=0):
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=4, max_seq=128,
                        manager_kind=manager_kind, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        T = int(rng.integers(24, 64))
        prompt = rng.integers(0, cfg.vocab_size, size=T).astype(np.int32)
        r = Request(rid=i, tenant=i % 3, prompt=prompt, max_new=max_new)
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained(max_steps=500)
    assert all(r.done for r in reqs)
    return eng, reqs


def serving_compare(n_requests=8) -> List[Dict]:
    rows = []
    outs = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs = run_engine(kind, n_requests=n_requests)
        outs[kind] = {r.rid: tuple(r.out) for r in reqs}
        st = eng.cache.stats()
        rows.append({
            "bench": "serving", "manager": kind,
            "tok_per_s_cpu": round(eng.stats.tok_per_s(), 1),
            "coalesced_mean": round(eng.stats.coalesced_mean, 3),
            "compaction_copies": eng.stats.compaction_copies,
            "coalesce_ops": int(st.get("coalesce_ops", 0)),
            "memory_bloat": round(st.get("memory_bloat", 1.0), 3),
        })
    # Application-transparency check: identical outputs.
    identical = outs["mosaic"] == outs["gpu-mmu"]
    rows.append({"bench": "serving", "manager": "CHECK",
                 "outputs_identical": identical})
    assert identical, "manager changed model outputs!"
    return rows
