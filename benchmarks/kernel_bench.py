"""Kernel micro-benchmarks (beyond-paper deliverable).

Times the pure-JAX oracle paths on CPU (wall-clock, jitted, steady-state)
and derives the *structural* cost of the Pallas kernels for TPU: per-call
indirection counts and DMA contiguity at both page granularities — the
quantity Mosaic's coalescing improves (the kernel-level analogue of TLB
reach).  Wall-clock on CPU is NOT a TPU number; the structural columns are
hardware-independent.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import paged


def _time(fn, *args, iters=20, warmup=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def paged_attention_granularity(B=4, H=8, n_kv=4, dh=64, ptok=64, fp=16,
                                ctx_tokens=16384) -> List[Dict]:
    """Oracle decode attention: coalesced-frame path vs splintered pages.

    Structural columns: indirections (scalar-prefetched table reads) and
    contiguous DMA run length — 16x better when frames are coalesced.
    """
    rng = np.random.default_rng(0)
    pages_per_seq = ctx_tokens // ptok
    NP = B * pages_per_seq
    k_pool = jnp.asarray(rng.normal(size=(NP, ptok, n_kv, dh)),
                         jnp.bfloat16)
    v_pool = jnp.asarray(rng.normal(size=(NP, ptok, n_kv, dh)),
                         jnp.bfloat16)
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.bfloat16)

    # Splintered: every page its own table entry (random placement).
    pt = rng.permutation(NP).reshape(B, pages_per_seq).astype(np.int32)
    pn = np.full((B, pages_per_seq), ptok, np.int32)

    # Coalesced: same pages but frame-contiguous (CoCoA layout): entries
    # ascend in runs of fp (the structural property the kernel exploits).
    ct = np.arange(NP).reshape(B, pages_per_seq).astype(np.int32)

    f = jax.jit(lambda q, k, v, t, n: paged.combine_partials(
        *paged.paged_attention_local(q, k, v, t, n, scale=dh ** -0.5), ()))
    us_split = _time(f, q, k_pool, v_pool, jnp.asarray(pt), jnp.asarray(pn))
    us_coal = _time(f, q, k_pool, v_pool, jnp.asarray(ct), jnp.asarray(pn))

    return [{
        "bench": "kernel_paged_attention",
        "ctx_tokens": ctx_tokens,
        "us_splintered": us_split,
        "us_coalesced_layout": us_coal,
        # structural: table indirections per (seq, layer) lookup
        "indirections_splintered": pages_per_seq,
        "indirections_coalesced": pages_per_seq // fp,
        "dma_run_tokens_splintered": ptok,
        "dma_run_tokens_coalesced": ptok * fp,
    }]


def page_compact_cost(NP=4096, ptok=64, n_kv=8, dh=128,
                      batch_sizes=(16, 64, 256)) -> List[Dict]:
    """CAC copy cost per compaction batch (bytes moved, µs on CPU oracle)."""
    from repro.kernels.page_compact import page_compact
    rng = np.random.default_rng(1)
    pool = jnp.asarray(rng.normal(size=(NP, ptok, n_kv, dh)), jnp.bfloat16)
    rows = []
    f = jax.jit(lambda p, s, d: page_compact(p, s, d))
    for n in batch_sizes:
        perm = rng.permutation(NP)
        src = jnp.asarray(perm[:n].astype(np.int32))
        dst = jnp.asarray(perm[n:2 * n].astype(np.int32))
        us = _time(f, pool, src, dst, iters=5)
        page_bytes = ptok * n_kv * dh * 2
        rows.append({
            "bench": "kernel_page_compact", "copies": n,
            "bytes_moved": n * page_bytes,
            "us_per_batch_cpu": us,
            # TPU structural estimate: HBM rd+wr at 819 GB/s
            "tpu_est_us": 2 * n * page_bytes / 819e9 * 1e6,
        })
    return rows


def pagesize_sweep(ctx_tokens=16384, B=2, H=8, n_kv=4, dh=64) -> List[Dict]:
    """TPU-native page-size trade-off (paper Fig. 1 + §1, re-tiled).

    Sweeps page_tokens: smaller pages = finer transfer granularity (less
    over-fetch on faults) but more indirections per attention call;
    frame coalescing recovers the indirection cost — which is the paper's
    whole point, in one table.
    """
    from repro.core.demand_paging import LinkModel
    link = LinkModel()
    rows = []
    kv_bytes_tok = 2 * n_kv * dh * 2
    for ptok in (16, 32, 64, 128, 256):
        pages = ctx_tokens // ptok
        page_bytes = ptok * kv_bytes_tok
        # Demand-paging term: one token's fault over-fetches page_bytes.
        fault_us = link.transfer_us(page_bytes)
        rows.append({
            "bench": "pagesize_sweep", "page_tokens": ptok,
            "indirections_base": pages,
            "indirections_coalesced": max(1, pages // 16),
            "fault_transfer_us": fault_us,
            "fault_overfetch_bytes": page_bytes,
        })
    return rows
