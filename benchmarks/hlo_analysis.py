"""Trip-count-aware HLO analysis: FLOPs + collective bytes from compiled HLO.

XLA's ``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE,
which silently under-reports a scanned-transformer's work by ~n_layers×.
This module parses the compiled HLO text instead:

  * splits it into computations and builds an op→shape symbol table;
  * walks the call graph (ENTRY → fusions/calls/while bodies), carrying a
    multiplier = product of enclosing ``known_trip_count``s;
  * FLOPs: every ``dot`` op contributes 2·|out|·K·multiplier (K = product
    of the LHS contracting dim sizes); convolutions are counted as dots of
    their im2col shape (none of our models use them);
  * collective bytes: ring *wire* cost per op, × multiplier.  Operand
    bytes alone undercount: a ring all-gather of an s-byte shard over n
    devices moves (n-1)·s per link; an all-reduce of a b-byte tensor
    moves 2·b·(n-1)/n.  We parse each op's replica_groups to get n and
    apply the standard ring-collective cost model:

        all-reduce          2·(n-1)/n · operand
        all-gather          (n-1)     · operand   (operand = shard)
        reduce-scatter      (n-1)/n   · operand   (operand = full)
        all-to-all          (n-1)/n   · operand
        collective-permute  1         · operand

The result is the per-*program* total (one SPMD partition — i.e. per chip).
"""

from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"^([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLSITE_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_GROUPS_ARR = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    """Devices per replica group of a collective op (1 if unparseable)."""
    m = _GROUPS_ARR.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_factor(kind: str, n: int) -> float:
    """Ring-collective wire bytes per link, as a multiple of operand bytes."""
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-gather":
        return float(n - 1)
    if kind in ("reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def _parse_shape(s: str) -> Tuple[Optional[str], int]:
    """'bf16[8,128]{...}' -> ('bf16', 1024). Tuples handled by caller."""
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return None, 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return dt, n


def _shape_bytes(s: str) -> int:
    dt, n = _parse_shape(s)
    return n * _DTYPE_BYTES.get(dt, 0)


def _split_operands(s: str) -> List[str]:
    """Split an operand list on top-level commas.

    Commas inside shape brackets (``f32[8,16]``) and layout braces
    (``{1,0}``) are not separators — old XLA prints operands inline-typed
    with both.
    """
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _operand_parts(arg: str) -> Tuple[Optional[str], Optional[str]]:
    """Split one operand of an op into (inline shape, name).

    Newer XLA prints bare names (``%op.1``); older XLA prints the operand
    inline-typed (``f32[8,16]{1,0} %op.1``).  Returns whichever parts are
    present.
    """
    arg = arg.strip()
    shape = None
    if _SHAPE_RE.match(arg):
        shape, _, arg = arg.rpartition(" ")
        if not shape:           # shape only, no name
            shape, arg = arg, ""
    return shape or None, arg.lstrip("%") or None


class HloProgram:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(text)
        self.shapes: Dict[str, str] = {}
        self._build_symbols()

    def _split(self, text: str) -> None:
        cur = None
        for line in text.splitlines():
            m = _COMP_HDR.match(line)
            if m and ("{" in line):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                self.computations[cur].append(line)

    def _build_symbols(self) -> None:
        self.defs: Dict[str, str] = {}
        for comp, lines in self.computations.items():
            for line in lines:
                m = _OP_RE.match(line)
                if not m:
                    continue
                name, rhs = m.groups()
                # rhs starts with the output shape (maybe a tuple).
                self.shapes[name] = rhs.split(" ", 1)[0]
                self.defs[name] = line

    # CPU-backend correction: XLA:CPU's float-normalization pass upcasts
    # every bf16 collective to f32 with a convert round-trip
    # (f32 -> bf16 -> f32) because the CPU dot emitter has no native
    # bf16.  On the TPU target the collective stays bf16, so wire bytes
    # for such ops are counted at bf16 width.  The round-trip is the
    # fingerprint: a fusion feeding the collective whose computation
    # converts to bf16 and immediately back to f32.
    _RT_BF16 = re.compile(r"=\s*bf16\[[^\]]*\]\{?[^}]*\}?\s*convert\(")
    _RT_F32 = re.compile(r"=\s*f32\[[^\]]*\]\{?[^}]*\}?\s*convert\(%?convert")

    def _bf16_payload(self, line: str) -> bool:
        ops = re.search(r"(?:%s)[\w\-]*\(([^)]*)\)" %
                        "|".join(COLLECTIVES), line)
        if not ops:
            return False
        for a in _split_operands(ops.group(1)):
            _, name = _operand_parts(a)
            d = self.defs.get(name, "")
            cm = re.search(r"calls=%?([\w.\-]+)", d)
            if not cm:
                return False
            body = self.computations.get(cm.group(1), [])
            has_rt = (any(self._RT_BF16.search(x) for x in body)
                      and any(self._RT_F32.search(x) for x in body))
            if not has_rt:
                return False
        return True

    # ------------------------------------------------------------ walker

    def multipliers(self) -> Dict[str, float]:
        """computation -> product of enclosing trip counts (from ENTRY)."""
        mult: Dict[str, float] = {}
        if self.entry is None:
            # fall back: treat every computation as top-level
            return {c: 1.0 for c in self.computations}

        def visit(comp: str, m: float):
            if m <= mult.get(comp, 0.0):
                return
            mult[comp] = m
            for line in self.computations.get(comp, []):
                trip = 1.0
                tm = _TRIP_RE.search(line)
                is_while = " while(" in line or "= while(" in line
                if tm and is_while:
                    trip = float(tm.group(1))
                for callee in _CALLSITE_RE.findall(line):
                    if callee in self.computations:
                        visit(callee, m * (trip if is_while else 1.0))

        visit(self.entry, 1.0)
        return mult

    # ------------------------------------------------------------ flops

    def _dot_flops(self, line: str, comp: str) -> float:
        m = _OP_RE.match(line)
        if m is None:
            return 0.0
        out_shape = m.group(2).split(" ", 1)[0]
        _, out_n = _parse_shape(out_shape)
        # operands
        ops = re.search(r"dot\((.*)\)", line)
        if not ops:
            return 0.0
        # Operands may be inline-typed (older XLA); commas inside shape
        # brackets are not separators.
        args = _split_operands(ops.group(1))
        lhs_shape, lhs = _operand_parts(args[0]) if args else (None, None)
        if lhs_shape is None:
            lhs_shape = self.shapes.get(lhs, "")
        mm = _SHAPE_RE.match(lhs_shape)
        if not mm:
            return 0.0
        lhs_dims = [int(d) for d in mm.group(2).split(",") if d]
        c = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        k = 1
        if c and c.group(1):
            for idx in c.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
        return 2.0 * out_n * k

    def totals(self, pod_group_sizes=()) -> Dict[str, float]:
        """``pod_group_sizes``: replica-group sizes whose groups span the
        pod (DCN) boundary on the current mesh — their wire bytes are
        additionally accumulated in ``dcn_bytes`` (DCN links are an order
        of magnitude slower than ICI; EXPERIMENTS.md reports the split
        for the multi-pod cells)."""
        mult = self.multipliers()
        flops = 0.0
        dcn = 0.0
        coll: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
        for comp, lines in self.computations.items():
            m = mult.get(comp, 0.0)
            if m == 0.0:
                continue
            for line in lines:
                if " dot(" in line:
                    flops += m * self._dot_flops(line, comp)
                else:
                    for kind in COLLECTIVES:
                        if f" {kind}(" in line or f"{kind}-start(" in line:
                            nbytes = self._collective_bytes(line)
                            n = _group_size(line)
                            w = _wire_factor(kind, n)
                            if self._bf16_payload(line):
                                w *= 0.5   # TPU keeps this collective bf16
                            coll[kind] += m * nbytes * w
                            if n in pod_group_sizes:
                                dcn += m * nbytes * w
                            break
        coll_total = sum(coll.values())
        return {"flops": flops, "collective_bytes": coll_total,
                "collectives": coll, "dcn_bytes": dcn}

    def _collective_bytes(self, line: str) -> int:
        m = _OP_RE.match(line)
        if not m:
            return 0
        # Prefer operand bytes (payload moved); fall back to output shape.
        ops = re.search(r"(?:%s)[\w\-]*\(([^)]*)\)" %
                        "|".join(COLLECTIVES), line)
        total = 0
        if ops:
            for a in _split_operands(ops.group(1)):
                shape, name = _operand_parts(a)
                if shape is None and name in self.shapes:
                    shape = self.shapes[name]
                if shape:
                    total += _shape_bytes(shape)
        if total == 0:
            out = m.group(2).split(" ", 1)[0]
            if out.startswith("("):
                for part in re.findall(r"[a-z0-9]+\[[\d,]*\]", out):
                    total += _shape_bytes(part)
            else:
                total = _shape_bytes(out)
        return total


def analyze_hlo(text: str, pod_group_sizes=()) -> Dict[str, float]:
    return HloProgram(text).totals(pod_group_sizes)
