"""Benchmark driver: one suite per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig1,...]

Suites:
  fig1     4KB vs 2MB vs ideal-TLB translation overhead   (paper Fig. 1)
  fig5     homogeneous weighted speedup                   (paper Fig. 5)
  fig6     heterogeneous weighted speedup                 (paper Fig. 6)
  fig7     demand-paging on/off                           (paper Fig. 7)
  fig8     L1/L2 TLB hit rates + interference             (paper Fig. 8)
  kernels  paged-attention granularity + CAC copy cost    (beyond paper)
  pagesize TPU-native page-size trade-off                 (paper §1)
  serving  Mosaic vs GPU-MMU on the serving engine        (Figs. 5/6 analogue)
  oversub  2x-oversubscribed host-tier paging + swap cycle (paper §1/§4.2)
  overlap  sync vs async double-buffered fault-in + link contention (§7)
  prefix-reuse  content-hash prefix cache + full-duplex DMA (§8)
  cluster  shared host tier + deadline router + migration (§10)
  router   modeled-µs cost routing + queued steal + pre-staging (§14)
  spill    disk spill tier + write-back back-pressure     (§11)
  faults   crash recovery + spill integrity + degrade     (§12)
  fused-decode  fused gather-attend decode vs sync/async  (§13)
  translation  radix walker + coalesced TLB: mosaic vs scattered,
           walker-contention routing                      (§15)
  roofline dry-run roofline table, if dryrun_all.jsonl exists (deliv. g)

Output: CSV-ish `key=value` rows per suite + a PASS/FAIL claim summary,
plus a machine-readable ``BENCH_serving.json`` artifact (suite/config →
tok/s, exposed_us, hidden_us, dma_count) so the perf trajectory is
tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _json_safe(v):
    """numpy scalars / bools → plain JSON types."""
    import numpy as np
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    return v


def write_bench_artifact(results, path="BENCH_serving.json"):
    """suite/config → {tok_per_s, exposed_us, hidden_us, dma_count, ...}.

    Every serving-side row (anything reporting a tok/s) gets an entry
    keyed ``<suite>/<bench>/<manager-or-mode>[@factor]``; claim rows are
    collected verbatim so CI can diff trajectories across PRs.
    """
    suites = {}
    claims = {}
    for suite, rows in results.items():
        for r in rows:
            cfg = r.get("manager", r.get("mode", ""))
            if "tok_per_s_cpu" in r:
                label = f"{suite}/{r.get('bench', suite)}/{cfg}"
                if "factor" in r:
                    label += f"@{r['factor']}"
                suites[label] = {
                    "tok_per_s": _json_safe(r["tok_per_s_cpu"]),
                    "exposed_us": _json_safe(r.get("exposed_us", 0.0)),
                    "hidden_us": _json_safe(r.get("hidden_us", 0.0)),
                    "dma_count": _json_safe(
                        r.get("dma_count", r.get("fault_dmas", 0))),
                    "faults": _json_safe(r.get("faults", 0)),
                    "transfer_us": _json_safe(r.get("transfer_us", 0.0)),
                }
            for k, v in r.items():
                if k.startswith("claim_") or k.startswith("hidden_fraction"):
                    label = f"{suite}/{k}"
                    if "factor" in r:       # keep per-factor datapoints
                        label += f"@{r['factor']}"
                    claims[label] = _json_safe(v)
    if not suites:
        # A figure-only run has no serving rows; don't clobber a
        # previously-written trajectory artifact with an empty one.
        return
    # Merge with an existing artifact so partial --only runs refresh
    # their own entries without deleting other suites' datapoints.
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            suites = {**prev.get("suites", {}), **suites}
            claims = {**prev.get("claims", {}), **claims}
        except (json.JSONDecodeError, OSError):
            pass                        # corrupt artifact: rewrite fresh
    with open(path, "w") as f:
        json.dump({"schema": 1, "suites": suites, "claims": claims}, f,
                  indent=2, sort_keys=True)
    print(f"\nwrote {path} ({len(suites)} configs, {len(claims)} claims)",
          flush=True)


def _emit(rows):
    for r in rows:
        print("  " + ",".join(f"{k}={v}" for k, v in r.items()), flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller traces (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--engines", type=int, default=2,
                    help="cluster width for the spill suite")
    args = ap.parse_args(argv)
    n = 2000 if args.fast else 4000

    from benchmarks import kernel_bench, paperfigs, serving_bench

    suites = {
        "fig1": lambda: paperfigs.fig1_translation_overhead(n_access=n),
        "fig5": lambda: paperfigs.fig5_homogeneous(n_access=n),
        "fig6": lambda: paperfigs.fig6_heterogeneous(n_access=n),
        "fig7": lambda: paperfigs.fig7_demand_paging(n_access=2 * n),
        "fig8": lambda: paperfigs.fig8_tlb_hitrate(n_access=n),
        "kernels": lambda: (kernel_bench.paged_attention_granularity()
                            + kernel_bench.page_compact_cost()),
        "pagesize": kernel_bench.pagesize_sweep,
        "serving": serving_bench.serving_compare,
        "oversub": lambda: (serving_bench.oversubscribed_compare()
                            + serving_bench.swap_cycle_compare()),
        "overlap": lambda: (serving_bench.overlap_compare(
                                factors=(2.0,) if args.fast else (1.5, 2.0),
                                n_requests=8 if args.fast else 12)
                            + serving_bench.overlap_link_contention(
                                n_access=n // 2)),
        "prefix-reuse": lambda: (
            serving_bench.prefix_reuse_compare(
                n_requests=6 if args.fast else 8)
            + serving_bench.duplex_compare(
                n_requests=8 if args.fast else 10)
            + serving_bench.duplex_sim_compare(n_access=n // 2)),
        "cluster": lambda: (
            serving_bench.cluster_prefix_share_compare(
                n_requests=6 if args.fast else 8)
            + serving_bench.cluster_router_compare()
            + serving_bench.cluster_migration_compare()
            + serving_bench.cluster_sim_compare(n_access=n // 2)),
        "router": serving_bench.router_cost_compare,
        "spill": lambda: (
            serving_bench.spill_compare(n_engines=args.engines)
            + serving_bench.spill_backpressure_compare()
            + serving_bench.spill_sim_compare(n_access=n // 2)),
        "faults": lambda: (
            serving_bench.faults_crash_compare()
            + serving_bench.faults_spill_compare()),
        "fused-decode": lambda: (
            serving_bench.fused_decode_compare()
            + serving_bench.fused_kernel_compare()),
        "translation": lambda: (
            serving_bench.translation_radix_compare(n_access=n // 2)
            + serving_bench.translation_router_compare()),
    }
    picked = (args.only.split(",") if args.only else list(suites))
    unknown = [p for p in picked if p not in suites and p != "roofline"]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choose from "
                 f"{sorted(suites) + ['roofline']}")

    claims = []
    results = {}
    for name in [p for p in picked if p in suites]:
        t0 = time.time()
        print(f"=== {name}", flush=True)
        rows = _emit(suites[name]())
        results[name] = rows
        for r in rows:
            for k, v in r.items():
                if k.startswith("claim_"):
                    claims.append((name, k, bool(v)))
        print(f"  ({time.time() - t0:.1f}s)", flush=True)

    write_bench_artifact(results)

    if os.path.exists("dryrun_all.jsonl") and (args.only is None
                                               or "roofline" in picked):
        print("=== roofline (from dryrun_all.jsonl)", flush=True)
        from benchmarks import roofline
        roofline.main(["dryrun_all.jsonl"])

    print("\n=== claim summary")
    ok = True
    for suite, claim, passed in claims:
        print(f"  {suite:8} {claim:32} {'PASS' if passed else 'FAIL'}")
        ok = ok and passed
    print("ALL CLAIMS PASS" if ok else "SOME CLAIMS FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
