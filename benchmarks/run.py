"""Benchmark driver: one suite per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig1,...]

Suites:
  fig1     4KB vs 2MB vs ideal-TLB translation overhead   (paper Fig. 1)
  fig5     homogeneous weighted speedup                   (paper Fig. 5)
  fig6     heterogeneous weighted speedup                 (paper Fig. 6)
  fig7     demand-paging on/off                           (paper Fig. 7)
  fig8     L1/L2 TLB hit rates + interference             (paper Fig. 8)
  kernels  paged-attention granularity + CAC copy cost    (beyond paper)
  pagesize TPU-native page-size trade-off                 (paper §1)
  serving  Mosaic vs GPU-MMU on the serving engine        (Figs. 5/6 analogue)
  oversub  2x-oversubscribed host-tier paging + swap cycle (paper §1/§4.2)
  roofline dry-run roofline table, if dryrun_all.jsonl exists (deliv. g)

Output: CSV-ish `key=value` rows per suite + a PASS/FAIL claim summary.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _emit(rows):
    for r in rows:
        print("  " + ",".join(f"{k}={v}" for k, v in r.items()), flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller traces (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args(argv)
    n = 2000 if args.fast else 4000

    from benchmarks import kernel_bench, paperfigs, serving_bench

    suites = {
        "fig1": lambda: paperfigs.fig1_translation_overhead(n_access=n),
        "fig5": lambda: paperfigs.fig5_homogeneous(n_access=n),
        "fig6": lambda: paperfigs.fig6_heterogeneous(n_access=n),
        "fig7": lambda: paperfigs.fig7_demand_paging(n_access=2 * n),
        "fig8": lambda: paperfigs.fig8_tlb_hitrate(n_access=n),
        "kernels": lambda: (kernel_bench.paged_attention_granularity()
                            + kernel_bench.page_compact_cost()),
        "pagesize": kernel_bench.pagesize_sweep,
        "serving": serving_bench.serving_compare,
        "oversub": lambda: (serving_bench.oversubscribed_compare()
                            + serving_bench.swap_cycle_compare()),
    }
    picked = (args.only.split(",") if args.only else list(suites))

    claims = []
    for name in picked:
        t0 = time.time()
        print(f"=== {name}", flush=True)
        rows = _emit(suites[name]())
        for r in rows:
            for k, v in r.items():
                if k.startswith("claim_"):
                    claims.append((name, k, bool(v)))
        print(f"  ({time.time() - t0:.1f}s)", flush=True)

    if os.path.exists("dryrun_all.jsonl") and (args.only is None
                                               or "roofline" in picked):
        print("=== roofline (from dryrun_all.jsonl)", flush=True)
        from benchmarks import roofline
        roofline.main(["dryrun_all.jsonl"])

    print("\n=== claim summary")
    ok = True
    for suite, claim, passed in claims:
        print(f"  {suite:8} {claim:32} {'PASS' if passed else 'FAIL'}")
        ok = ok and passed
    print("ALL CLAIMS PASS" if ok else "SOME CLAIMS FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
