"""Radix page-table walker + coalesced-TLB tests (DESIGN.md §15).

Covers the §15 acceptance properties:

* flat/radix bitwise parity: ``translation="radix"`` with PWCs disabled
  and span-1 entries reproduces ``translation="flat"`` timings exactly
  (cycles, retired, faults, walker walks);
* coalesced-entry coverage monotonically reduces walk count as the
  subregion span grows over a contiguity-preserving allocation;
* MSHR merging: in-flight walks never exceed ``walker_slots`` and
  duplicate concurrent misses merge instead of re-walking;
* splintering invalidates only the touched subregion;
* the serving-side :class:`TranslationMeter` is observational (tokens
  byte-identical with the meter off/flat/radix) and mosaic allocation
  pays fewer walks than the scattered baseline;
* the ``LRU.rate`` never-touched regression reports nan, not a perfect
  1.0.
"""

import math

import numpy as np
import pytest

from repro.core.ptw import (CoalescedTLB, RadixWalker, TranslationMeter,
                            subregion_entry)
from repro.core.tlb_sim import LRU, AppTrace, SimConfig, TranslationSim
from repro.core.workloads import build_workload, homogeneous_names

pytestmark = pytest.mark.ptw

N_ACCESS = 2000


def scattered_trace(seed: int, pages: int = 600, n: int = N_ACCESS,
                    contiguous: bool = False) -> AppTrace:
    """Synthetic trace over ``pages`` base pages: contiguous maps every
    vpn to vpn + const (perfect CoCoA contiguity); scattered permutes
    frames (the interleaved baseline of the paper's Fig. 2)."""
    r = np.random.default_rng(seed)
    vpn = r.integers(0, pages, n).astype(np.int32)
    if contiguous:
        ppn = (vpn + 4 * pages).astype(np.int32)
    else:
        perm = r.permutation(pages).astype(np.int32)
        ppn = perm[vpn]
    return AppTrace(vpn=vpn, ppn=ppn, frame=(ppn // 8).astype(np.int32),
                    coalesced=np.zeros(n, np.int8), gap_cycles=100,
                    name=f"app{seed}")


# ---------------------------------------------------------------- parity


def test_flat_radix_bitwise_parity_synthetic():
    """PWCs off + span 1 ⇒ the radix walker is the flat walker: every
    walk is full depth at ``walk_levels × dram_latency`` with identical
    slot-queue and MSHR mechanics, so per-app timings match bitwise."""
    base = dict(mode="base", l1_large_entries=0, l2_large_entries=0)
    flat = SimConfig(translation="flat", **base)
    radix = SimConfig(translation="radix", pwc_entries=0, coalesce_span=1,
                      **base)
    sf = TranslationSim(flat, [scattered_trace(s) for s in (1, 2)])
    sr = TranslationSim(radix, [scattered_trace(s) for s in (1, 2)])
    rf, rr = sf.run(), sr.run()
    for f, r in zip(rf, rr):
        assert f.cycles == r.cycles          # bitwise, not approx
        assert f.retired == r.retired
        assert f.faults == r.faults
        assert f.l1_hit == r.l1_hit
    assert sf.walker.walks == sr.total_walks()
    assert sf.link.faults == sr.link.faults


def test_flat_radix_bitwise_parity_real_allocator():
    """Same parity through the real manager-built workload (gpu-mmu
    allocation, the scattered end of the spectrum)."""
    names = homogeneous_names("bfs", 2)
    base = dict(mode="base", l1_large_entries=0, l2_large_entries=0,
                paging=False)
    traces, _ = build_workload(names, "gpu-mmu", seed=0, n_access=1500)
    sf = TranslationSim(SimConfig(translation="flat", **base), traces)
    traces2, _ = build_workload(names, "gpu-mmu", seed=0, n_access=1500)
    sr = TranslationSim(
        SimConfig(translation="radix", pwc_entries=0, coalesce_span=1,
                  **base), traces2)
    rf, rr = sf.run(), sr.run()
    for f, r in zip(rf, rr):
        assert f.cycles == r.cycles
        assert f.retired == r.retired
    assert sf.walker.walks == sr.total_walks()


# ---------------------------------------------------- coalesced coverage


def test_span_monotonically_reduces_walks_on_contiguous_maps():
    """Over a contiguity-preserving allocation, doubling the subregion
    span can only widen every entry's reach: walk count is monotonically
    non-increasing in span (and strictly falls from 1 to 32)."""
    walks = []
    for span in (1, 2, 4, 8, 16, 32):
        cfg = SimConfig(translation="radix", coalesce_span=span,
                        paging=False)
        sim = TranslationSim(
            cfg, [scattered_trace(s, contiguous=True) for s in (1, 2)])
        sim.run()
        walks.append(sim.total_walks())
    assert all(a >= b for a, b in zip(walks, walks[1:])), walks
    assert walks[-1] < walks[0]


def test_contiguous_allocation_pays_fewer_walk_cycles_than_scattered():
    """The tentpole claim at sim level: same trace geometry, same radix
    walker — the contiguous map needs fewer walks *and* fewer total
    translation cycles, because one coalesced entry covers a whole run."""
    cfg = SimConfig(translation="radix", paging=False)
    sim_c = TranslationSim(
        cfg, [scattered_trace(s, contiguous=True) for s in (1, 2)])
    sim_s = TranslationSim(
        cfg, [scattered_trace(s, contiguous=False) for s in (1, 2)])
    sim_c.run(), sim_s.run()
    assert sim_c.total_walks() < sim_s.total_walks()
    assert sim_c.total_walk_cycles() < sim_s.total_walk_cycles()
    assert sim_c.walk_dram_accesses() < sim_s.walk_dram_accesses()


def test_subregion_entry_coverage_from_frame_map():
    # vpn 0..3 contiguous at delta 10; vpn 4 splintered to a different
    # delta; vpn 5 unmapped; vpn 6 at another delta; vpn 7 back at 10.
    ppn_map = [10, 11, 12, 13, 99, -1, 20, 17]
    delta, mask = subregion_entry(ppn_map, 1, span=8)
    assert delta == 10
    assert mask & 0b1111 == 0b1111       # the contiguous run
    assert not (mask >> 4) & 1           # splintered page not covered
    assert not (mask >> 5) & 1           # unmapped hole not covered
    assert not (mask >> 6) & 1           # different delta not covered
    assert (mask >> 7) & 1               # same delta: covered


def test_pwc_skips_upper_levels():
    """A second walk under the same upper-level subtree only fetches the
    uncached tail: per-level DRAM accesses drop for levels 1..L-1."""
    w = RadixWalker(slots=8, levels=4, dram_latency=160, pwc_entries=64,
                    pwc_latency=2)
    d1 = w.walk(0.0, 0.0, 0, 0x1234, ("a", 1))
    assert d1 == 4 * 160                  # cold: full depth
    # Neighbouring page, same upper levels (tags >> 9 match): 1 access.
    d2 = w.walk(d1 + 1, d1 + 1, 0, 0x1235, ("a", 2))
    assert d2 - (d1 + 1) == 160 + 2       # leaf access + PWC probe
    assert w.level_accesses[0] == 1       # root touched once
    assert w.dram_accesses() == 5


# ----------------------------------------------------------------- MSHR


def test_mshr_merges_and_inflight_bounded_by_slots():
    slots = 4
    w = RadixWalker(slots=slots, levels=4, dram_latency=160,
                    pwc_entries=0)
    # 32 concurrent misses on 8 distinct keys at t=0: duplicates merge,
    # distinct walks queue on the slot heap.
    done = [w.walk(0.0, 0.0, 0, k, ("k", k % 8)) for k in range(32)]
    assert w.walks == 8                   # one real walk per distinct key
    assert w.merged == 24                 # the duplicates merged
    assert w.peak_inflight <= slots
    # Each batch of `slots` walks serializes behind the previous batch.
    assert max(done) == (8 // slots) * 4 * 160


def test_mshr_reuses_only_inflight_walks():
    w = RadixWalker(slots=8, levels=4, dram_latency=160, pwc_entries=0)
    d1 = w.walk(0.0, 0.0, 0, 7, ("k", 7))
    # After d1 resolved, the same key misses again → a new walk.
    d2 = w.walk(d1 + 1, d1 + 1, 0, 7, ("k", 7))
    assert w.walks == 2 and w.merged == 0
    assert d2 > d1


# ------------------------------------------------------------ splintering


def test_splinter_invalidates_only_touched_subregion():
    cfg = SimConfig(translation="radix", coalesce_span=8, paging=False)
    tr = scattered_trace(1, contiguous=True)
    sim = TranslationSim(cfg, [tr])
    sim.run()
    # Warm state: pick two subregions resident in L1.
    l1 = sim.l1_co[0]
    tags = list(l1.d)
    assert len(tags) >= 2
    victim, sibling = tags[0], tags[1]
    walks_before = sim.total_walks()
    sim.splinter(0, victim * 8 + 3, new_ppn=999_999)
    assert victim not in l1.d             # touched subregion dropped
    assert sibling in l1.d                # sibling untouched
    assert (0, sibling) not in sim.l2_co.d or True
    # A lookup in the sibling subregion still hits without a walk.
    h0 = l1.hits
    assert l1.lookup(sibling, 0) is not None
    assert l1.hits == h0 + 1
    assert sim.total_walks() == walks_before  # no re-walk for siblings
    # Re-walking inside the splintered subregion builds a fresh entry
    # whose coverage excludes the remapped page (delta mismatch).
    entry = sim._mk_entry(sim.ppn_maps[0], victim * 8, 8)
    assert not (entry[1] >> 3) & 1


def test_meter_splinter_only_affected_subregion():
    m = TranslationMeter("radix", span=4)
    ppn_map = list(range(100, 116))       # 16 pages, fully contiguous
    m.step_access(0.0, [(("s", 0), "tenant", ppn_map)])
    assert len(m.l1.d) >= 2
    m.splinter(("s", 0), 5)               # subregion 1
    assert (("s", 0), 1) not in m.l1.d
    assert (("s", 0), 0) in m.l1.d


# ------------------------------------------------------- serving meter


def _run_engine(translation, seed=0):
    from repro.configs import get_smoke_config
    from repro.configs.base import PoolGeometry
    from repro.serving.engine import Request, ServingEngine
    cfg = get_smoke_config("qwen2.5-3b")
    geo = PoolGeometry(page_tokens=8, frame_pages=4)
    eng = ServingEngine(cfg, geometry=geo, max_batch=2, max_seq=64,
                        decode_window_us=100.0, seed=seed,
                        translation=translation)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, tenant=i % 2,
                    prompt=rng.integers(0, cfg.vocab_size, 12 + 4 * i)
                    .astype(np.int32),
                    max_new=5) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, {r.rid: tuple(r.out) for r in reqs}


def test_meter_is_observational_and_radix_beats_flat():
    eng_off, out_off = _run_engine("off")
    eng_flat, out_flat = _run_engine("flat")
    eng_radix, out_radix = _run_engine("radix")
    assert out_off == out_flat == out_radix   # byte-identical tokens
    assert eng_off.stats.translation_lookups == 0
    assert eng_flat.stats.translation_lookups \
        == eng_radix.stats.translation_lookups > 0
    # Coalesced entries + PWCs: radix never walks more than flat.
    assert eng_radix.stats.translation_walks \
        <= eng_flat.stats.translation_walks
    assert eng_radix.translation_meter.summary()


def test_engine_validates_translation_mode():
    from repro.configs import get_smoke_config
    from repro.configs.base import PoolGeometry
    from repro.serving.engine import ServingEngine
    with pytest.raises(ValueError, match="translation"):
        ServingEngine(get_smoke_config("qwen2.5-3b"),
                      geometry=PoolGeometry(page_tokens=8, frame_pages=4),
                      max_batch=2, max_seq=64, translation="bogus")


# --------------------------------------------------------- LRU.rate fix


def test_lru_rate_nan_when_untouched():
    """Regression: a never-touched cache must not report a perfect 1.0
    hit rate in bench tables."""
    assert math.isnan(LRU(16).rate)
    assert math.isnan(CoalescedTLB(16, 4).rate)
    lru = LRU(16)
    lru.insert("a")
    assert lru.lookup("a") and lru.rate == 1.0
    assert not lru.lookup("b") and lru.rate == 0.5


def test_sim_config_validates_translation():
    with pytest.raises(ValueError, match="translation"):
        TranslationSim(SimConfig(translation="bogus"),
                       [scattered_trace(1)])
