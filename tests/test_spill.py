"""Disk spill tier tests (DESIGN.md §11).

Covers the SpillStore's byte-exact whole-frame files, the host tier's
capacity-bound spill/promote state machine (LRU victim choice,
promote-on-touch, write-back cancellation), the bounded write-back
buffer's refuse-park back-pressure, the hard-capped (no-spill) baseline
that evicts prefix frames *through* the index, migration over spilled
sequences, the modeled promote stall, and end-to-end token identity of
a capped cluster vs an unbounded one.
"""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.cluster import (FRAME_HOST, FRAME_PENDING_WB,
                                   FRAME_SPILLED, ServingCluster,
                                   SharedHostTier)
from repro.serving.engine import Request
from repro.serving.host_tier import SpillStore

GEO = PoolGeometry(page_tokens=8, frame_pages=2, compact_threshold=0.4)


def _payload(tag: float):
    return (np.full((2, 3), tag, np.float32),
            np.full((2, 3), -tag, np.float32))


def _tier(**kw):
    kw.setdefault("capacity_frames", 2)
    return SharedHostTier(GEO, n_engines=1, **kw)


def _fill(view, seq, n, tag0=0.0):
    for i in range(n):
        view.put(seq, 0, i, *_payload(tag0 + i))


# ------------------------------------------------------------ SpillStore


def test_spillstore_roundtrip_byte_exact():
    store = SpillStore()
    kp = np.arange(12, dtype=np.float32).reshape(3, 4)
    vp = -kp
    pages = [((5, 0, 0), (kp, vp)), ((5, 0, 1), (kp + 1, vp - 1))]
    nbytes = store.write_frame(7, "dom", pages)
    assert nbytes == sum(k.nbytes + v.nbytes for _, (k, v) in pages)
    assert store.has_frame(7) and len(store) == 1
    assert store.frame_keys(7) == ((5, 0, 0), (5, 0, 1))
    back = store.read_frame(7, expect_domain="dom")
    for (k0, (a0, b0)), (k1, (a1, b1)) in zip(pages, back):
        assert k0 == k1
        assert a0.tobytes() == a1.tobytes() and a0.dtype == a1.dtype
        assert b0.tobytes() == b1.tobytes() and b0.shape == b1.shape
    with pytest.raises(AssertionError):
        store.read_frame(7, expect_domain="other")
    store.delete_frame(7)
    assert not store.has_frame(7) and store.stats["frames_deleted"] == 1
    store.close()


def test_spillstore_roundtrip_bfloat16():
    # KV payloads are bfloat16 in the real engine; npz has no native
    # bfloat16, so the store must round-trip raw bytes + dtype exactly.
    import ml_dtypes
    store = SpillStore()
    kp = np.arange(8).reshape(2, 4).astype(ml_dtypes.bfloat16)
    vp = (kp * 2).astype(ml_dtypes.bfloat16)
    store.write_frame(0, None, [((1, 0, 0), (kp, vp))])
    (_, (k2, v2)), = store.read_frame(0)
    assert k2.dtype == kp.dtype and k2.tobytes() == kp.tobytes()
    assert v2.dtype == vp.dtype and v2.tobytes() == vp.tobytes()
    store.close()


# --------------------------------------------------- spill state machine


def test_tier_spills_lru_frames_over_capacity_and_promotes():
    tier = _tier()
    v = tier.view(9)
    _fill(v, 9, 8)                  # 4 frames, capacity 2 → 2 must go
    assert len(tier._pending_wb) == 2
    tier.check_invariants()
    tier.flush()
    tier.check_invariants()
    assert len(tier._pending_wb) == 0
    assert tier.stats["spilled_frames"] == 2
    states = [tier.frames.state_of(f)
              for f in sorted(tier.frames._frame_owner)]
    # LRU: the two oldest frames went; the two youngest stayed.
    assert states == [FRAME_SPILLED, FRAME_SPILLED,
                      FRAME_HOST, FRAME_HOST]
    assert tier.frames.resident_frames() == 2
    # Promote-on-touch: reading a spilled page brings its whole frame
    # back byte-exact (and may re-spill another to hold capacity).
    key = next(iter(tier._spilled))
    kp, _vp = v.peek(*key)
    assert np.array_equal(kp, _payload(float(key[2]))[0])
    assert tier.stats["promoted_frames"] == 1
    assert tier.frames.state_of(tier.frames.frame_of(key)) == FRAME_HOST
    tier.check_invariants()
    # Every page is still reachable through the view, spilled or not.
    assert sorted(v.seq_pages(9)) == [(9, 0, i) for i in range(8)]
    for i in range(8):
        assert np.array_equal(v.peek(9, 0, i)[0], _payload(float(i))[0])
    tier.check_invariants()
    tier.spill_store.close()


def test_spill_rides_outbound_dma_as_one_job_per_frame():
    tier = _tier()
    _fill(tier.view(9), 9, 8)
    tier.flush()
    d = tier.wb_dma.stats
    # Whole frame = contiguous pages = exactly one outbound descriptor.
    assert d["spill_jobs"] == tier.spill_store.stats["frames_written"]
    assert d["spill_jobs"] >= 2
    assert tier.stats["spill_write_us"] > 0.0
    tier.spill_store.close()


def test_touch_before_persist_cancels_writeback():
    tier = _tier(disk_write_us_per_page=1e6)    # never ready on its own
    v = tier.view(9)
    _fill(v, 9, 5)                  # 3 frames (last holds 1 page)
    assert len(tier._pending_wb) == 1
    pending_frame = next(iter(tier._pending_wb))
    # Pop the *only* key that would leave the pending frame empty after
    # removal ⇒ the write-back is cancelled, never persisted.
    keys = sorted(tier.frames.keys_of(pending_frame))
    for k in keys[:-1]:
        v.pop(*k)
    assert tier.frames.state_of(pending_frame) == FRAME_PENDING_WB
    v.pop(*keys[-1])
    assert tier.frames.stats["spill_cancels"] == 1
    assert len(tier._pending_wb) == 0
    assert tier.stats["spilled_frames"] == 0
    tier.check_invariants()
    tier.spill_store.close()


def test_pop_of_spilled_page_promotes_first():
    tier = _tier()
    v = tier.view(9)
    _fill(v, 9, 8)
    tier.flush()
    key = next(iter(tier._spilled))
    kp, vp = v.pop(*key)
    assert np.array_equal(kp, _payload(float(key[2]))[0])
    assert not v.has(*key)
    assert key not in tier._spilled
    assert tier.stats["promoted_frames"] >= 1
    tier.check_invariants()
    tier.spill_store.close()


def test_ensure_resident_charges_seek_plus_per_page_read():
    tier = _tier(disk_seek_us=100.0, disk_read_us_per_page=25.0)
    v = tier.view(9)
    _fill(v, 9, 8)
    tier.flush()
    frame = sorted(f for f, s in tier.frames._state.items()
                   if s == FRAME_SPILLED)[0]
    keys = sorted(tier.spill_store.frame_keys(frame))
    stall = v.ensure_resident(keys, now_us=0.0)
    assert stall == pytest.approx(100.0 + 25.0 * len(keys))
    assert v.ensure_resident(keys) == 0.0       # already resident
    tier.check_invariants()
    tier.spill_store.close()


def test_drop_seq_over_spilled_frames_releases_every_slot():
    tier = _tier()
    v = tier.view(9)
    _fill(v, 9, 8)
    tier.flush()
    assert len(tier._spilled) > 0
    assert v.drop_seq(9) == 8
    assert len(tier.frames) == 0
    assert len(tier._spilled) == 0 and len(tier.spill_store) == 0
    tier.check_invariants()
    tier.spill_store.close()


def test_migrate_seq_promotes_and_cancels_before_release():
    tier = _tier(wb_queue_frames=4, disk_write_us_per_page=1e6)
    v = tier.view(9)
    _fill(v, 9, 4)                  # 2 frames at capacity
    for i in range(4, 6):           # push over → 1 pending write-back
        v.put(9, 0, i, *_payload(float(i)))
    assert len(tier._pending_wb) == 1
    moved = tier.migrate_seq(9, 3)
    assert moved == 6               # every page of seq 9 re-leased
    assert all(tier.frames.owner_of((9, 0, i)) == 3 for i in range(6))
    # Nothing of seq 9 is left pending or on disk mid-migration.
    for f in tier._pending_wb:
        assert all(k[0] != 9 for k in tier.frames.keys_of(f))
    assert all(k[0] != 9 for k in tier._spilled)
    tier.check_invariants()
    tier.spill_store.close()


# ------------------------------------------------------- back-pressure


def test_park_allowed_goes_false_when_wb_queue_full():
    tier = _tier(wb_queue_frames=1, disk_write_us_per_page=1e6)
    v = tier.view(9)
    assert tier.park_allowed()
    _fill(v, 9, 8)                  # over capacity; queue bound = 1
    assert len(tier._pending_wb) == 1
    assert tier.stats["wb_peak_depth"] == 1
    assert not tier.park_allowed()
    assert not v.park_allowed()     # the view engines hold agrees
    # Resident count stays over capacity rather than queueing more.
    assert tier.frames.resident_frames() > tier.capacity_frames
    tier.flush()                    # disk catches up → pressure clears
    assert tier.park_allowed()
    tier.check_invariants()
    tier.spill_store.close()


# ------------------------------------------------------ hard-cap baseline


def test_hard_cap_evicts_prefix_frames_through_index():
    tier = _tier(capacity_frames=2, spill=False)
    assert tier.spill_store is None
    idx = tier.prefix
    rng = np.random.default_rng(0)
    vpn = 0
    for _chain in range(4):             # 4 × 2-page chains > cap 2 frames
        toks = rng.integers(0, 1000, 2 * GEO.page_tokens)
        parent = None
        for i, h in enumerate(idx.chain_hashes(toks)):
            idx.park(h, parent, i, 0, vpn, *_payload(float(vpn)))
            parent = h
            vpn += 1
    assert tier.stats["hard_evicted_pages"] > 0
    assert tier.frames.resident_frames() <= tier.capacity_frames
    # Index ↔ store never disagree: every cached page has its payload,
    # and evicted payloads are gone from the store too.
    for page in idx._pages.values():
        assert tier.store.has(page.owner, page.shard, page.vpn)
    live = {(p.owner, p.shard, p.vpn) for p in idx._pages.values()}
    for key in tier.store._pages:
        if key[0] < 0:
            assert key in live
    tier.check_invariants()


def test_hard_cap_never_drops_request_frames():
    tier = _tier(capacity_frames=1, spill=False)
    v = tier.view(9)
    _fill(v, 9, 6)                  # request pages: not reconstructible
    # Over capacity with nothing evictable: the cap goes soft instead
    # of dropping data.
    assert tier.frames.resident_frames() == 3
    assert tier.stats["hard_evicted_pages"] == 0
    assert sorted(v.seq_pages(9)) == [(9, 0, i) for i in range(6)]
    tier.check_invariants()


# ------------------------------------------------------------ end-to-end


def _run_capped_cluster(capacity_frames, spill):
    cfg = get_smoke_config("qwen2.5-3b")
    geo = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)
    cluster = ServingCluster(cfg, geometry=geo, n_engines=2, max_batch=4,
                             max_seq=128, seed=0, decode_window_us=1000.0,
                             capacity_frames=capacity_frames, spill=spill)
    rng = np.random.default_rng(1)
    shared = [rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
              for _ in range(3)]
    reqs = [Request(rid=i, tenant=i % 3,
                    prompt=np.concatenate(
                        [shared[i % 3],
                         rng.integers(0, cfg.vocab_size, 8)
                         .astype(np.int32)]),
                    max_new=4)
            for i in range(6)]
    for r in reqs[:3]:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=1000)
    for r in reqs[3:]:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=2000)
    assert all(r.done for r in reqs)
    cluster.check_invariants()
    return cluster, {r.rid: tuple(r.out) for r in reqs}


def test_cluster_tokens_identical_capped_spill_vs_unbounded():
    _, out_unbounded = _run_capped_cluster(None, True)
    spilled, out_spill = _run_capped_cluster(3, True)
    _, out_hard = _run_capped_cluster(3, False)
    assert out_spill == out_unbounded == out_hard
    # The capped run really exercised the disk tier.
    assert spilled.tier.stats["spilled_frames"] > 0
    assert spilled.tier.stats["promoted_frames"] > 0
    t = spilled.stats().totals
    assert t.promotions > 0 and t.promote_stall_us > 0.0
