"""Modeled-cost routing, queued steal, and proactive pre-staging tests
(DESIGN.md §14).

Randomized cases are seeded through ``ROUTER_TEST_SEED`` (CI runs seeds
0/1/2): for a fixed seed every test is deterministic.  Covers the cost
model's monotonicity and its divergence from the token-count heuristic,
dispatch determinism under arrival-order shuffles, DMA job cancellation
with lane-time refunds, the read-only prefix probe, pre-stage
lifecycle accounting (hit / wasted / cancelled), queued-steal rules
(pinned requests, hysteresis), the crash → exactly-once re-dispatch
regression, and the sim-side ``Link.engine_occupancy`` mirror.
"""

import os

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.core.tlb_sim import Link, SimConfig
from repro.serving.cluster import ServingCluster
from repro.serving.dma import AsyncDMAEngine
from repro.serving.engine import Request
from repro.serving.router import RequestRouter

pytestmark = pytest.mark.router

SEED = int(os.environ.get("ROUTER_TEST_SEED", "0"))
GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)
CFG = get_smoke_config("qwen2.5-3b")
PTOK = GEO.page_tokens


def _rng(k: int = 0):
    return np.random.default_rng(SEED * 1000 + k)


def _prompt(rng, n: int):
    return rng.integers(0, CFG.vocab_size, n).astype(np.int32)


def _cluster(n_engines: int = 1, **kw) -> ServingCluster:
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 128)
    kw.setdefault("seed", 0)
    kw.setdefault("migrate", False)
    kw.setdefault("decode_window_us", 1000.0)
    return ServingCluster(CFG, geometry=GEO, n_engines=n_engines, **kw)


def _warm_prefix(cluster, shared, *, rid=0, engine=0):
    """Park ``shared`` into the prefix index by running one request."""
    rng = _rng(99)
    req = Request(rid=rid, tenant=0,
                  prompt=np.concatenate([shared, _prompt(rng, PTOK)]),
                  max_new=2)
    cluster.submit(req, engine=engine)
    cluster.run_until_drained(max_steps=300)
    return req


def _payload():
    return (np.zeros((1, PTOK, 1, 4), np.float32),
            np.zeros((1, PTOK, 1, 4), np.float32))


def _enqueue(dma, n_pages, now_us, seq=1):
    keys = [(seq, 0, i) for i in range(n_pages)]
    return dma.enqueue(keys, list(range(n_pages)), 4096,
                       [_payload()] * n_pages, now_us)


# ------------------------------------------------------------ cost model


def test_invalid_cost_model_rejected():
    cluster = _cluster(1)
    with pytest.raises(AssertionError):
        RequestRouter(cluster.engines, cost_model="bogus")


def test_cost_monotone_in_queued_load():
    """Adding queued requests never lowers the modeled cost (seeded)."""
    cluster = _cluster(1)
    router, eng = cluster.router, cluster.engines[0]
    rng = _rng(1)
    prev = router.engine_cost_us(eng)
    assert prev == 0.0
    for i in range(8):
        cluster.submit(Request(
            rid=i, tenant=0, prompt=_prompt(rng, int(rng.integers(8, 64))),
            max_new=int(rng.integers(1, 12))), engine=0)
        cost = router.engine_cost_us(eng)
        assert cost >= prev
        prev = cost
    assert prev > 0.0


def test_cost_monotone_in_dma_backlog():
    cluster = _cluster(1)
    router, eng = cluster.router, cluster.engines[0]
    rng = _rng(2)
    c0 = router.engine_cost_us(eng)
    job = _enqueue(eng.dma, int(rng.integers(2, 8)), eng._clock_us)
    c1 = router.engine_cost_us(eng)
    assert c1 - c0 == pytest.approx(job.transfer_us)
    _enqueue(eng.dma, int(rng.integers(2, 8)), eng._clock_us, seq=2)
    assert router.engine_cost_us(eng) >= c1


def test_cost_includes_writeback_backlog():
    cluster = _cluster(1, capacity_frames=8, spill=True)
    router, eng = cluster.router, cluster.engines[0]
    c0 = router.engine_cost_us(eng)
    cluster.tier.wb_dma.channel_free["out"][0] = eng._clock_us + 777.0
    assert router.engine_cost_us(eng) - c0 == pytest.approx(777.0)


def test_cost_monotone_in_spilled_resume_debt():
    """A preempted request whose saved pages spilled owes disk time."""
    cluster = _cluster(1, capacity_frames=2, spill=True)
    router, eng, tier = cluster.router, cluster.engines[0], cluster.tier
    view = tier.view(0)
    for vpn in range(8):                    # rid 5: two full frames
        view.put(5, 0, vpn, *_payload())
    for vpn in range(8):                    # rid 6 pushes rid 5 to disk
        view.put(6, 0, vpn, *_payload())
    tier.flush()
    assert tier.spilled_keys_of(5)
    c0 = router.engine_cost_us(eng)
    rng = _rng(3)
    eng.preempted.append(Request(rid=5, tenant=0,
                                 prompt=_prompt(rng, 8), max_new=4))
    c1 = router.engine_cost_us(eng)
    n_spilled = len(tier.spilled_keys_of(5))
    assert c1 - c0 >= tier.disk_seek_us \
        + n_spilled * tier.disk_read_us_per_page
    eng.preempted.append(Request(rid=6, tenant=0,
                                 prompt=_prompt(rng, 8), max_new=6))
    assert router.engine_cost_us(eng) > c1


def test_modeled_cost_diverges_from_token_count():
    """The misroute scenario: one long decode is cheap in token units
    but expensive in modeled µs (critical path); many prompt-heavy
    two-token requests are the reverse."""
    cluster = _cluster(2)
    router = cluster.router
    rng = _rng(4)
    e_long, e_wide = cluster.engines
    cluster.submit(Request(rid=0, tenant=0, prompt=_prompt(rng, 16),
                           max_new=20), engine=0)
    for i in range(8):
        cluster.submit(Request(rid=1 + i, tenant=0,
                               prompt=_prompt(rng, 24), max_new=2),
                       engine=1)
    assert router.engine_load(e_long) < router.engine_load(e_wide)
    assert router.engine_cost_us(e_long) > router.engine_cost_us(e_wide)


def test_request_cost_units_match_model():
    cluster = _cluster(1)
    router, eng = cluster.router, cluster.engines[0]
    rng = _rng(5)
    r = Request(rid=9, tenant=0, prompt=_prompt(rng, 24), max_new=5)
    assert router._request_cost(r, eng) \
        == pytest.approx(1000.0 * -(-5 // eng.max_batch))
    router.cost_model = "tokens"
    assert router._request_cost(r, eng) == pytest.approx(24 // PTOK + 5)
    router.cost_model = "modeled"


# ---------------------------------------------------------- determinism


def test_dispatch_deterministic_under_arrival_shuffles():
    """Equal-slack requests land on the same engines regardless of the
    order they were submitted in (seeded shuffles)."""
    rng = _rng(6)
    prompts = [_prompt(rng, int(rng.integers(8, 40))) for _ in range(6)]
    owners = []
    for trial in range(3):
        order = list(range(6))
        if trial:
            rng.shuffle(order)
        cluster = _cluster(2)
        for i in order:
            cluster.submit(Request(rid=i, tenant=0, prompt=prompts[i],
                                   max_new=4, deadline_us=9000.0))
        cluster.router.dispatch()
        owners.append(dict(cluster.router._owner))
    assert owners[0] == owners[1] == owners[2]


def test_rank_breaks_equal_slack_ties_by_rid():
    cluster = _cluster(1)
    rng = _rng(7)
    reqs = [Request(rid=i, tenant=0, prompt=_prompt(rng, 8), max_new=2,
                    deadline_us=5000.0) for i in range(5)]
    perm = list(range(5))
    rng.shuffle(perm)
    shuffled = [(arrival, reqs[i]) for arrival, i in enumerate(perm)]
    order = [r.rid for _, r in sorted(shuffled, key=cluster.router._rank)]
    assert order == [0, 1, 2, 3, 4]


# --------------------------------------------------------- DMA cancel


def test_dma_cancel_midflight_refunds_remainder():
    dma = AsyncDMAEngine(n_channels=1)
    job = _enqueue(dma, 4, 0.0)
    T = job.transfer_us
    refund = dma.cancel(job, T / 2)
    assert refund == pytest.approx(T / 2)
    assert dma.channel_free["in"][0] == pytest.approx(T / 2)
    assert dma.stats["hidden_us"] == pytest.approx(T / 2)
    assert dma.stats["transfer_us"] == pytest.approx(T / 2)
    assert dma.stats["refunded_us"] == pytest.approx(T / 2)
    assert dma.stats["cancelled_jobs"] == 1
    assert job.settled and job.job_id not in dma.in_flight


def test_dma_cancel_with_job_queued_behind_refunds_nothing():
    """Cancelling a job another transfer already queued behind cannot
    reclaim the lane time — the elapsed transfer is written off as
    hidden and the channel timeline is untouched."""
    dma = AsyncDMAEngine(n_channels=1)
    j1 = _enqueue(dma, 4, 0.0)
    j2 = _enqueue(dma, 2, 0.0, seq=2)
    free_before = dma.channel_free["in"][0]
    assert free_before == pytest.approx(j2.done_us)
    refund = dma.cancel(j1, 10.0)
    assert refund == 0.0
    assert dma.stats["refunded_us"] == 0.0
    assert dma.stats["hidden_us"] == pytest.approx(j1.transfer_us)
    assert dma.channel_free["in"][0] == pytest.approx(free_before)
    assert j2.job_id in dma.in_flight


def test_dma_cancel_settled_job_is_noop():
    dma = AsyncDMAEngine(n_channels=1)
    job = _enqueue(dma, 3, 0.0)
    dma.wait(job, 0.0)
    before = dict(dma.stats)
    assert dma.cancel(job, job.done_us) == 0.0
    assert dma.stats == before


def test_dma_cancel_preserves_direction_invariant():
    """hidden + exposed == Σ transfer_us (post-refund) over any seeded
    mix of waited and cancelled jobs."""
    rng = _rng(8)
    dma = AsyncDMAEngine(n_channels=2)
    now = 0.0
    for i in range(12):
        job = _enqueue(dma, int(rng.integers(1, 6)), now, seq=i)
        if rng.random() < 0.5:
            now = dma.wait(job, now)
        else:
            dma.cancel(job, now + float(rng.uniform(0, job.transfer_us)))
        now += float(rng.uniform(0, 50))
    assert dma.stats["hidden_us"] + dma.stats["exposed_us"] \
        == pytest.approx(dma.stats["transfer_us"])


# ----------------------------------------------------- read-only probe


def test_peek_match_is_readonly_and_agrees_with_match():
    cluster = _cluster(1)
    rng = _rng(9)
    shared = _prompt(rng, 5 * PTOK)
    _warm_prefix(cluster, shared)
    idx = cluster.engines[0].prefix
    probe = np.concatenate([shared, _prompt(rng, PTOK)])
    tick0, stats0 = idx._tick, dict(idx.stats)
    pages0 = {h: (p.tick, p.hits) for h, p in idx._pages.items()}
    n_peek, peeked = idx.peek_match(probe)
    assert idx._tick == tick0 and dict(idx.stats) == stats0
    assert {h: (p.tick, p.hits) for h, p in idx._pages.items()} == pages0
    n_match, matched = idx.match(probe)
    assert n_peek == n_match > 0
    assert [(p.owner, p.shard, p.vpn) for p in peeked] \
        == [(p.owner, p.shard, p.vpn) for p in matched]
    assert idx.stats["lookups"] == stats0["lookups"] + 1


# -------------------------------------------------------- pre-staging


def test_prestage_queued_stages_prefix_pages():
    cluster = _cluster(1)
    rng = _rng(10)
    shared = _prompt(rng, 5 * PTOK)
    _warm_prefix(cluster, shared)
    eng = cluster.engines[0]
    req = Request(rid=7, tenant=0,
                  prompt=np.concatenate([shared, _prompt(rng, PTOK)]),
                  max_new=2)
    n = eng.prestage_queued(req)
    assert n == 5
    assert len(eng._prestage_keys) == 5
    assert all(k[0] == 7 for k in eng._prestage_keys)
    assert all(owner < 0 for owner in eng._prestage_keys.values())
    assert all(k in eng.prefetch.in_flight for k in eng._prestage_keys)
    assert eng.stats.prestaged_pages == 5
    # Re-probing while the transfer is in flight issues nothing new.
    assert eng.prestage_queued(req) == 0


def test_cancel_prestage_refunds_and_clears():
    cluster = _cluster(1)
    rng = _rng(11)
    shared = _prompt(rng, 5 * PTOK)
    _warm_prefix(cluster, shared)
    eng = cluster.engines[0]
    req = Request(rid=7, tenant=0,
                  prompt=np.concatenate([shared, _prompt(rng, PTOK)]),
                  max_new=2)
    eng.prestage_queued(req)
    transfer_before = eng.stats.transfer_us
    refund = eng.cancel_prestage(7)
    assert refund > 0.0
    assert eng.stats.prestage_cancelled == 5
    assert eng.stats.prestage_refund_us == pytest.approx(refund)
    assert eng.stats.transfer_us \
        == pytest.approx(transfer_before - refund)
    assert not eng._prestage_keys and not eng.prefetch.in_flight
    assert eng.dma.stats["cancelled_jobs"] == 1
    assert eng.cancel_prestage(7) == 0.0    # idempotent


def test_prestage_waste_counter_and_summary():
    cluster = _cluster(1)
    rng = _rng(12)
    shared = _prompt(rng, 5 * PTOK)
    _warm_prefix(cluster, shared)
    eng = cluster.engines[0]
    req = Request(rid=7, tenant=0,
                  prompt=np.concatenate([shared, _prompt(rng, PTOK)]),
                  max_new=2)
    eng.prestage_queued(req)
    eng._note_prestage_waste(7)
    assert eng.stats.prestage_wasted == 5
    assert not eng._prestage_keys
    assert "prestage 5 pages (0/5/0 hit/wasted/cancelled)" \
        in eng.stats.summary()


def test_prestage_tokens_identical_and_hits():
    """Pre-staging changes when bytes arrive, never what decode
    computes: byte-identical tokens, with staged pages counted as hits
    at admission."""
    rng = _rng(13)
    shared = _prompt(rng, 5 * PTOK)
    suffixes = [_prompt(rng, PTOK * (1 + i % 2)) for i in range(3)]
    cold = _prompt(rng, 24)
    outs = {}
    for prestage in (False, True):
        cluster = _cluster(1, router_prestage=prestage)
        _warm_prefix(cluster, shared)
        reqs = [Request(rid=10 + i, tenant=0,
                        prompt=np.concatenate([shared, suf]), max_new=4)
                for i, suf in enumerate(suffixes)]
        reqs.append(Request(rid=20, tenant=0, prompt=cold, max_new=4))
        for r in reqs:
            cluster.submit(r)
        cluster.run_until_drained(max_steps=500)
        assert all(r.done for r in reqs)
        cluster.check_invariants()
        outs[prestage] = {r.rid: tuple(r.out) for r in reqs}
        if prestage:
            assert cluster.router.stats.prestaged_requests >= 1
            assert cluster.engines[0].stats.prestage_hits > 0
    assert outs[False] == outs[True]


def test_prestage_then_steal_matches_cold_dispatch():
    """A request pre-staged at one engine and then queue-stolen to
    another produces byte-identical tokens to dispatching it cold at
    the thief, and the source's pre-stage is cancelled with a refund."""
    rng = _rng(14)
    shared = _prompt(rng, 5 * PTOK)
    heavy_prompts = [_prompt(rng, 16) for _ in range(2)]
    r_prompt = np.concatenate([shared, _prompt(rng, PTOK)])

    def heavies(cluster):
        hs = [Request(rid=1 + i, tenant=0, prompt=p, max_new=8)
              for i, p in enumerate(heavy_prompts)]
        for h in hs:
            cluster.submit(h, engine=0)
        return hs

    # Stolen path: pre-stage toward busy engine 0, steal to idle 1.
    cluster = _cluster(2, router_prestage=True)
    _warm_prefix(cluster, shared)
    hs = heavies(cluster)
    cluster.step()                          # heavies become active on e0
    router = cluster.router
    r = Request(rid=50, tenant=1, prompt=r_prompt.copy(), max_new=4)
    router._owner[r.rid] = 0                # white-box: queue r at the
    cluster.engines[0].submit(r)            # busy engine, pre-staged
    router.stats.dispatched[0] = router.stats.dispatched.get(0, 0) + 1
    router._prestage_to(r, 0)
    assert router._prestaged[r.rid] == 0
    assert cluster.engines[0]._prestage_keys
    router._steal_queued()
    assert router.stats.queued_steals == 1
    assert router._owner[r.rid] == 1
    assert r in cluster.engines[1].queue
    assert cluster.engines[0].stats.prestage_cancelled > 0
    assert router.stats.prestage_cancels == 1
    assert not cluster.engines[0]._prestage_keys
    cluster.run_until_drained(max_steps=500)
    assert r.done and all(h.done for h in hs)
    cluster.check_invariants()

    # Cold reference: same requests, r dispatched straight to engine 1
    # with pre-staging off.
    cold = _cluster(2, router_prestage=False)
    _warm_prefix(cold, shared)
    hs2 = heavies(cold)
    cold.step()
    r2 = Request(rid=50, tenant=1, prompt=r_prompt.copy(), max_new=4)
    cold.submit(r2, engine=1)
    cold.run_until_drained(max_steps=500)
    assert r2.done and all(h.done for h in hs2)
    assert tuple(r.out) == tuple(r2.out)
    for h, h2 in zip(hs, hs2):
        assert tuple(h.out) == tuple(h2.out)


# -------------------------------------------------------- queued steal


def test_queued_steal_skips_pinned_requests():
    cluster = _cluster(2)
    rng = _rng(15)
    router = cluster.router
    for i in range(2):
        cluster.submit(Request(rid=1 + i, tenant=0,
                               prompt=_prompt(rng, 16), max_new=10),
                       engine=0)
    cluster.step()                          # both active on engine 0
    r_pin = Request(rid=40, tenant=0, prompt=_prompt(rng, 16), max_new=4)
    cluster.submit(r_pin, engine=0)         # pinned: never stolen
    r_free = Request(rid=41, tenant=0, prompt=_prompt(rng, 16), max_new=4)
    router._owner[r_free.rid] = 0           # white-box unpinned insert
    cluster.engines[0].submit(r_free)
    router.stats.dispatched[0] = router.stats.dispatched.get(0, 0) + 1
    router._steal_queued()
    assert router.stats.queued_steals == 1
    assert r_free in cluster.engines[1].queue
    assert r_pin in cluster.engines[0].queue
    router._steal_queued()                  # only the pinned one is left
    assert router.stats.queued_steals == 1


def test_queued_steal_hysteresis_prevents_pingpong():
    """Symmetric load: neither side is strictly costlier than the other
    plus the candidate's own cost, so nothing moves — repeatedly."""
    cluster = _cluster(2)
    rng = _rng(16)
    router = cluster.router
    for idx in (0, 1):
        cluster.submit(Request(rid=1 + idx, tenant=0,
                               prompt=_prompt(rng, 16), max_new=10),
                       engine=idx)
    cluster.step()
    for idx, rid in ((0, 40), (1, 41)):
        r = Request(rid=rid, tenant=0, prompt=_prompt(rng, 16), max_new=4)
        router._owner[rid] = idx
        cluster.engines[idx].submit(r)
        router.stats.dispatched[idx] = \
            router.stats.dispatched.get(idx, 0) + 1
    for _ in range(3):
        router._steal_queued()
    assert router.stats.queued_steals == 0
    assert any(r.rid == 40 for r in cluster.engines[0].queue)
    assert any(r.rid == 41 for r in cluster.engines[1].queue)


# --------------------------------------------- crash re-dispatch (§14)


def test_crash_redispatches_prestaged_request_exactly_once():
    """Regression: a request pre-staged toward a crashed engine is
    re-dispatched exactly once, its victim-side pre-stage written off
    without crediting any live DMA lane."""
    rng = _rng(17)
    shared = _prompt(rng, 5 * PTOK)
    cluster = _cluster(2, router_prestage=True)
    _warm_prefix(cluster, shared, engine=1)
    router = cluster.router
    for i in range(2):                      # engine 0: cheaper backlog
        cluster.submit(Request(rid=1 + i, tenant=0,
                               prompt=_prompt(rng, 16), max_new=6),
                       engine=0)
    for i in range(2):                      # engine 1: longer backlog
        cluster.submit(Request(rid=3 + i, tenant=0,
                               prompt=_prompt(rng, 16), max_new=10),
                       engine=1)
    cluster.step()
    r = Request(rid=60, tenant=1,
                prompt=np.concatenate([shared, _prompt(rng, PTOK)]),
                max_new=4, deadline_us=60_000.0)
    cluster.submit(r)
    router.dispatch()
    assert router._owner[r.rid] == 0        # modeled cost picks engine 0
    assert r in cluster.engines[0].queue
    assert router._prestaged[r.rid] == 0
    assert cluster.engines[0]._prestage_keys
    dispatched_before = sum(router.stats.dispatched.values())
    prestaged_before = router.stats.prestaged_requests
    router._crash(0)
    assert r.rid not in router._prestaged
    assert r.rid not in router._owner
    assert any(req.rid == r.rid for _, req in router.pending)
    assert cluster.engines[0].stats.prestage_cancelled > 0
    assert router.stats.prestage_cancels == 1
    cluster.run_until_drained(max_steps=800)
    assert r.done
    cluster.check_invariants()
    # Exactly one re-dispatch for every requeued victim (r + the two
    # engine-0 pinned requests), all to the lone survivor; r pre-staged
    # afresh exactly once at the survivor; no refund ever credited to a
    # live lane.
    assert sum(router.stats.dispatched.values()) == dispatched_before + 3
    assert router._owner[r.rid] == 1
    assert router.stats.prestaged_requests == prestaged_before + 1
    assert cluster.engines[1].dma.stats["refunded_us"] == 0.0


# ------------------------------------------------------ sim-side mirror


def test_link_engine_occupancy_mirrors_lane_backlog():
    cfg = SimConfig(n_engines=2, dma_channels=2, host_lanes=1,
                    disk_lanes=1, duplex=True)
    link = Link(cfg)
    link._lanes_in[0][0] = 100.0
    link._lanes_out[0][1] = 50.0
    link._lanes_in[1][0] = 70.0
    link._host_lanes[0] = 30.0
    link._disk_lanes[0] = 20.0
    assert link.engine_occupancy(0.0, engine=0) == pytest.approx(200.0)
    assert link.engine_occupancy(0.0, engine=1) == pytest.approx(120.0)
    assert link.engine_occupancy(60.0, engine=0) == pytest.approx(40.0)
    # Monotone in added backlog.
    link._lanes_in[0][1] = 25.0
    assert link.engine_occupancy(0.0, engine=0) == pytest.approx(225.0)
    # Half-duplex shares lane objects — no double counting.
    half = Link(SimConfig(n_engines=1, dma_channels=1, duplex=False))
    half._lanes_in[0][0] = 100.0
    assert half.engine_occupancy(0.0) == pytest.approx(100.0)
