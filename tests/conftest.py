"""Shared test fixtures/helpers.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches see
the single real CPU device; only launch/dryrun.py requests 512 placeholder
devices (and must be run as its own process).
"""

import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.transformer import PageCtx


def toy_page_ctx(batch: int, seq_len: int, page_tokens: int, mpps: int,
                 *, extra_tokens: int = 0):
    """Identity-ish page tables for a single-shard pool (tests only).

    Sequence b uses pages [b*mpps, b*mpps + pages_needed).  Returns
    (ctx, num_pages_needed).  ``extra_tokens`` reserves the write page for
    decode steps past seq_len.
    """
    total = seq_len + extra_tokens
    pages = (total + page_tokens - 1) // page_tokens
    assert pages <= mpps
    tables = np.full((batch, 1, mpps), -1, np.int32)
    ntok = np.zeros((batch, 1, mpps), np.int32)
    for b in range(batch):
        for i in range(pages):
            tables[b, 0, i] = b * mpps + i
            ntok[b, 0, i] = min(page_tokens, total - i * page_tokens)
    wpage = np.zeros((batch, 1), np.int32)
    wslot = np.zeros((batch,), np.int32)
    if extra_tokens or seq_len:
        pos = total - 1
        for b in range(batch):
            wpage[b, 0] = b * mpps + pos // page_tokens
        wslot[:] = pos % page_tokens
    ctx = PageCtx(tables=jnp.asarray(tables), ntok=jnp.asarray(ntok),
                  wpage=jnp.asarray(wpage), wslot=jnp.asarray(wslot))
    return ctx, batch * mpps


def ctx_at_position(batch: int, mpps: int, page_tokens: int, pos: int):
    """PageCtx for decoding the token at absolute position ``pos``."""
    total = pos + 1
    pages = (total + page_tokens - 1) // page_tokens
    tables = np.full((batch, 1, mpps), -1, np.int32)
    ntok = np.zeros((batch, 1, mpps), np.int32)
    for b in range(batch):
        for i in range(pages):
            tables[b, 0, i] = b * mpps + i
            ntok[b, 0, i] = min(page_tokens, total - i * page_tokens)
    wpage = np.asarray(
        [[b * mpps + pos // page_tokens] for b in range(batch)], np.int32)
    wslot = np.full((batch,), pos % page_tokens, np.int32)
    return PageCtx(tables=jnp.asarray(tables), ntok=jnp.asarray(ntok),
                   wpage=jnp.asarray(wpage), wslot=jnp.asarray(wslot))
