"""Cluster serving tier tests (DESIGN.md §10).

Covers the host-tier frame leases (single-domain-per-frame, whole-frame
recycling, migration owner flips), the shared-store views, cross-engine
prefix sharing, the deadline-aware router's dispatch order, SLO
deadline accounting, work-stealing migration (zero re-prefill, token
identity across engine counts), and the MoE/MLA park fallback.
"""

import os

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.cluster import (HostFrameTable, ServingCluster,
                                   SharedHostTier, aggregate_engine_stats)
from repro.serving.engine import EngineStats, Request, ServingEngine
from repro.serving.host_tier import HostPageStore, PrefixIndex

GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)
PTOK = GEO.page_tokens


def _payload(tag: float = 0.0):
    return (np.full((1, PTOK, 1, 4), tag, np.float32),
            np.full((1, PTOK, 1, 4), -tag, np.float32))


# --------------------------------------------------------- frame leases


def test_frame_table_single_domain_per_frame():
    ft = HostFrameTable(frame_pages=4)
    for vpn in range(5):                       # domain 0: 5 pages, 2 frames
        ft.place(0, (1, 0, vpn))
    f_other = ft.place(1, (2, 0, 0))           # domain 1: own frame,
    assert len(ft) == 3                        # despite free slots above
    assert ft.owner_of((2, 0, 0)) == 1
    assert ft.owner_of((1, 0, 4)) == 0
    assert {ft.owner_of((1, 0, v)) for v in range(5)} == {0}
    assert f_other not in {ft._key_frame[(1, 0, v)] for v in range(5)}
    ft.check_invariants()
    # rid collision across domains is an error, not silent sharing.
    with pytest.raises(AssertionError):
        ft.place(1, (1, 0, 0))


def test_frame_table_whole_frame_recycle():
    ft = HostFrameTable(frame_pages=2)
    ft.place(0, (1, 0, 0))
    ft.place(0, (1, 0, 1))
    assert len(ft) == 1
    ft.release((1, 0, 0))
    ft.release((1, 0, 1))
    assert len(ft) == 0 and ft.stats["frames_recycled"] == 1
    # The recycled frame is reusable by a *different* domain (it was
    # returned whole, so no mixing can occur).
    f = ft.place(7, (9, 0, 0))
    assert f == 0 and ft.owner_of((9, 0, 0)) == 7
    ft.check_invariants()


def test_frame_table_migrate_flips_exclusive_frames():
    ft = HostFrameTable(frame_pages=2)
    a = [(1, 0, 0), (1, 0, 1)]                 # fills one frame exactly
    b = [(2, 0, 0)]                            # shares its frame with c
    c = [(3, 0, 0)]
    for k in a + b + c:
        ft.place(0, k)
    moved = ft.migrate(a + b, dst=1)
    assert moved == 3
    # a's frame flipped owner without re-placement; b was re-placed out
    # of the frame it shared with (non-migrating) c.
    assert ft.stats["whole_frame_moves"] == 1
    assert ft.stats["page_moves"] == 1
    assert {ft.owner_of(k) for k in a + b} == {1}
    assert ft.owner_of(c[0]) == 0
    assert ft._key_frame[b[0]] != ft._key_frame[c[0]]
    ft.check_invariants()


def test_shared_tier_views_share_payloads_not_frames():
    tier = SharedHostTier(GEO, n_engines=2)
    v0, v1 = tier.view(0), tier.view(1)
    v0.put(1, 0, 0, *_payload(1.0))
    v1.put(2, 0, 0, *_payload(2.0))
    # Both engines see both payloads (the shared store)...
    assert v0.has(2, 0, 0) and v1.has(1, 0, 0)
    # ...but the pages sit in frames of their own domains.
    assert tier.frames.owner_of((1, 0, 0)) == 0
    assert tier.frames.owner_of((2, 0, 0)) == 1
    tier.check_invariants()
    # pop / drop_seq release the leases.
    v1.pop(2, 0, 0)
    assert tier.frames.owner_of((2, 0, 0)) is None
    assert v0.drop_seq(1) == 1
    assert len(tier.frames) == 0


def test_per_engine_prefix_indexes_never_collide_owners():
    tier = SharedHostTier(GEO, n_engines=2, share_prefix=False)
    toks = np.arange(2 * PTOK, dtype=np.int32)
    for i in range(2):
        idx = tier.prefix_for(i)
        parent = None
        for j, h in enumerate(idx.chain_hashes(toks)):
            idx.park(h, parent, j, 0, j, *_payload(i))
            parent = h
    owners0 = {p.owner for p in tier.prefix_for(0)._pages.values()}
    owners1 = {p.owner for p in tier.prefix_for(1)._pages.values()}
    assert not owners0 & owners1
    # Same (shard, vpn) pages, two indexes, one store: 4 payloads.
    assert len(tier.store) == 4
    tier.check_invariants()


# ------------------------------------------------------------- cluster


def _shared_prefix_reqs(cfg, n, shared_tokens=24, suffix_tokens=8,
                        max_new=3, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, shared_tokens).astype(np.int32)
    return [Request(rid=i, tenant=i % 2,
                    prompt=np.concatenate(
                        [shared, rng.integers(0, cfg.vocab_size,
                                              suffix_tokens)
                         .astype(np.int32)]),
                    max_new=max_new)
            for i in range(n)]


def _run_cluster(n_engines, *, share_prefix=True, n=5):
    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=n_engines,
                             max_batch=4, max_seq=96, seed=0,
                             share_prefix=share_prefix,
                             decode_window_us=1000.0)
    reqs = _shared_prefix_reqs(cfg, n)
    cluster.submit(reqs[0], engine=0)
    cluster.run_until_drained(max_steps=300)
    for r in reqs[1:]:
        cluster.submit(r)
    cluster.run_until_drained(max_steps=600)
    assert all(r.done for r in reqs)
    cluster.check_invariants()
    return cluster, {r.rid: tuple(r.out) for r in reqs}


@pytest.fixture(scope="module")
def cluster_runs():
    one = _run_cluster(1)
    two = _run_cluster(2)
    return one, two


def test_cluster_tokens_identical_across_engine_counts(cluster_runs):
    (_, outs1), (_, outs2) = cluster_runs
    assert outs1 == outs2


def test_cluster_shared_index_hits_across_engines(cluster_runs):
    _, (cluster, _) = cluster_runs
    # Wave 1 ran (and parked) on replica 0 only; every replica that
    # served wave 2 hit the shared index — including replica 1, which
    # never saw the prefix before.
    by_eng = [e.stats for e in cluster.engines]
    assert by_eng[0].prefix_parked_pages > 0
    assert by_eng[1].prefix_hits > 0
    t = cluster.stats().totals
    assert t.prefix_hits >= len(cluster.engines)
    # Drained cluster holds no request-owned host pages; the index's
    # pages persist under negative owners, leased to the prefix domain.
    assert cluster.tier.store.request_pages() == 0
    for key in cluster.tier.store._pages:
        assert key[0] < 0
        assert cluster.tier.frames.owner_of(key) is not None


def test_cluster_requires_unique_rids():
    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=2, max_batch=2,
                             max_seq=64, seed=0)
    r = Request(rid=0, tenant=0, prompt=np.arange(8, dtype=np.int32),
                max_new=2)
    cluster.submit(r, engine=0)
    dup = Request(rid=0, tenant=1, prompt=np.arange(8, dtype=np.int32),
                  max_new=2)
    with pytest.raises(AssertionError):
        cluster.submit(dup, engine=1)


# ----------------------------------------------------------- migration


def _run_steal(migrate):
    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=2, max_batch=2,
                             max_seq=96, seed=0, migrate=migrate,
                             prefix_cache=False, decode_window_us=1000.0)
    rng = np.random.default_rng(2)
    victim = Request(rid=0, tenant=0, priority=0,
                     prompt=rng.integers(0, cfg.vocab_size, 40)
                     .astype(np.int32), max_new=16)
    premium = [Request(rid=i, tenant=1, priority=2,
                       prompt=rng.integers(0, cfg.vocab_size, 48)
                       .astype(np.int32), max_new=10)
               for i in range(1, 3)]
    cluster.submit(victim, engine=0)
    for _ in range(2):
        cluster.step()
    for r in premium:
        cluster.submit(r, engine=0)
    cluster.run_until_drained(max_steps=600)
    assert all(r.done for r in [victim] + premium)
    cluster.check_invariants()
    return cluster, {r.rid: tuple(r.out) for r in [victim] + premium}


def test_cluster_work_stealing_migrates_with_zero_reprefill():
    steal, outs_steal = _run_steal(True)
    stay, outs_stay = _run_steal(False)
    assert outs_steal == outs_stay          # migration never changes tokens
    r = steal.router.stats
    assert r.migrations >= 1 and r.migrated_pages > 0
    dst = steal.engines[1]
    # The thief decoded the victim without prefilling a single token:
    # only host-resident base pages changed hands (frame-lease moves +
    # fault-in over the thief's own DMA lanes).
    assert dst.stats.prefill_tokens == 0
    assert dst.stats.decode_tokens > 0
    assert dst.stats.migrations_in >= 1
    assert steal.engines[0].stats.migrations_out >= 1
    assert dst.stats.faults >= r.migrated_pages
    fs = steal.tier.frames.stats
    assert fs["whole_frame_moves"] + fs["page_moves"] > 0
    # No stealing without migration enabled.
    assert stay.router.stats.migrations == 0
    assert stay.engines[1].stats.decode_tokens == 0


# ------------------------------------------------------------- routing


def test_router_slack_dispatch_prefers_idle_engine():
    cfg = get_smoke_config("qwen2.5-3b")
    rng = np.random.default_rng(0)

    def burst():
        return [Request(rid=100 + i, tenant=1,
                        prompt=rng.integers(0, cfg.vocab_size, 16)
                        .astype(np.int32),
                        max_new=4, deadline_us=9000.0) for i in range(2)]

    for policy, expect_idle in (("slack", True), ("fifo", False)):
        cluster = ServingCluster(cfg, geometry=GEO, n_engines=2,
                                 max_batch=2, max_seq=96, seed=0,
                                 router_policy=policy, migrate=False)
        for i in range(3):                  # load replica 0's queue
            cluster.submit(Request(rid=i, tenant=0,
                                   prompt=np.arange(16, dtype=np.int32),
                                   max_new=12), engine=0)
        for r in burst():
            cluster.submit(r)
        cluster.router.dispatch()
        on_idle = [r.rid for r in cluster.engines[1].queue]
        if expect_idle:
            assert sorted(on_idle) == [100, 101], \
                "slack dispatch must route the burst to the idle replica"
        else:
            assert len(on_idle) < 2, \
                "fifo round-robin splits the burst regardless of load"


def test_router_rank_orders_priority_then_slack():
    cfg = get_smoke_config("qwen2.5-3b")
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=1, max_batch=2,
                             max_seq=64, seed=0)
    mk = lambda rid, pri, dl: Request(
        rid=rid, tenant=0, prompt=np.arange(8, dtype=np.int32),
        max_new=2, priority=pri, deadline_us=dl)
    items = [(i, r) for i, r in enumerate([
        mk(0, 0, None), mk(1, 0, 1200.0), mk(2, 1, None),
        mk(3, 0, 500.0), mk(4, 1, 800.0)])]
    order = [r.rid for _, r in sorted(items, key=cluster.router._rank)]
    assert order == [4, 2, 3, 1, 0]


# ------------------------------------------------- deadline accounting


def test_engine_stats_deadline_accounting_and_summary():
    s = EngineStats(decode_steps=1, decode_tokens=1, wall_s=1.0)
    assert s.slo_attainment() is None       # no SLOs ≠ all SLOs met
    s.note_deadline(1, True)
    s.note_deadline(1, True)
    s.note_deadline(0, False)
    assert s.slo_attainment() == pytest.approx(2 / 3)
    assert s.slo_attainment(1) == 1.0 and s.slo_attainment(0) == 0.0
    line = s.summary()
    assert "SLO 66.7% (t1 2/2, t0 0/1)" in line
    assert "SLO" not in EngineStats().summary()


def test_engine_records_deadline_hits_and_misses():
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=2, max_seq=64,
                        manager_kind="mosaic", seed=0,
                        decode_window_us=1000.0, prefix_cache=False)
    hit = Request(rid=0, tenant=0, prompt=np.arange(8, dtype=np.int32),
                  max_new=2, priority=1, deadline_us=1e9)
    miss = Request(rid=1, tenant=0, prompt=np.arange(8, dtype=np.int32),
                   max_new=2, priority=0, deadline_us=1e-3)
    for r in (hit, miss):
        eng.submit(r)
    eng.run_until_drained(max_steps=100)
    assert eng.stats.deadline_hits == {1: 1}
    assert eng.stats.deadline_misses == {0: 1}


def test_cluster_stats_aggregation():
    a, b = EngineStats(), EngineStats()
    a.faults, b.faults = 3, 4
    a.prefill_tokens, b.prefill_tokens = 10, 20
    a.note_deadline(0, True)
    b.note_deadline(0, False)
    b.note_deadline(2, True)
    agg = aggregate_engine_stats([a, b])
    assert agg.faults == 7 and agg.prefill_tokens == 30
    assert agg.deadline_hits == {0: 1, 2: 1}
    assert agg.deadline_misses == {0: 1}
    assert agg.slo_attainment() == pytest.approx(2 / 3)


# ------------------------------------------------- MoE/MLA park fallback


def test_moe_engine_skips_park_into_shared_index():
    """Regression (satellite): a non-dense replica attached to a shared
    index must never park its KV (MoE routing is batch-shape-dependent —
    the cached pages would be unreplayable) and must never match."""
    cfg = get_smoke_config("dbrx-132b")
    assert cfg.family == "moe"
    store = HostPageStore()
    idx = PrefixIndex(store, GEO.page_tokens)
    eng = ServingEngine(cfg, geometry=GEO, max_batch=2, max_seq=64,
                        manager_kind="mosaic", seed=0, prefix_index=idx)
    assert not eng.prefix_supported and eng.prefix is idx
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    reqs = [Request(rid=i, tenant=0,
                    prompt=np.concatenate(
                        [shared, rng.integers(0, cfg.vocab_size, 6)
                         .astype(np.int32)]), max_new=2)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=200)
    assert all(r.done for r in reqs)
    assert eng.stats.prefix_park_skipped == 3      # one per completion
    assert eng.stats.prefix_hits == 0 and len(idx) == 0
    assert len(store) == 0                         # nothing unreplayable
    assert "parks skipped 3" in eng.stats.summary()


def test_mla_config_is_prefix_incompatible():
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    assert cfg.mla is not None
    eng = ServingEngine(cfg, geometry=GEO, max_batch=1, max_seq=32,
                        manager_kind="mosaic", seed=0)
    # MLA caches latents, not K/V — no index is built, and a shared one
    # would be skip-counted (prefix_supported gates both paths).
    assert not eng.prefix_supported and eng.prefix is None


# --------------------------------------------- §11 correctness satellites


def test_frame_table_migrate_excludes_stale_keys():
    """Regression: migrate() used to report len(keys) even when some
    keys were never placed (or already released) — the router's
    migrated_pages stat over-counted.  Only re-leased pages count."""
    ft = HostFrameTable(frame_pages=2)
    live = [(1, 0, 0), (1, 0, 1)]
    for k in live:
        ft.place(0, k)
    stale = (1, 0, 99)                         # never placed
    moved = ft.migrate(live + [stale], dst=1)
    assert moved == 2
    assert ft.owner_of(stale) is None
    released = (1, 0, 1)
    ft.release(released)
    assert ft.migrate(live, dst=2) == 1        # released key now stale too
    ft.check_invariants()


def test_frame_table_migrate_partially_shared_frames_invariants():
    """Every migrate shape at once — whole-frame flip, re-placement out
    of a shared frame, stale keys, and a same-owner no-op — with the
    lease invariants checked after."""
    ft = HostFrameTable(frame_pages=2)
    whole = [(1, 0, 0), (1, 0, 1)]             # exclusive, full frame
    shared_mig = [(2, 0, 0)]                   # shares a frame with...
    shared_stay = [(3, 0, 0)]                  # ...a non-migrating page
    for k in whole + shared_mig + shared_stay:
        ft.place(0, k)
    already = [(4, 0, 0)]
    ft.place(1, already[0])                    # already at dst: no-op
    moved = ft.migrate(whole + shared_mig + already + [(9, 9, 9)], dst=1)
    assert moved == 3                          # stale + same-dst excluded
    assert ft.stats["whole_frame_moves"] == 1
    assert ft.stats["page_moves"] == 1
    assert {ft.owner_of(k) for k in whole + shared_mig + already} == {1}
    assert ft.owner_of(shared_stay[0]) == 0
    # The split frame holds one page per domain — in different frames.
    assert ft._key_frame[shared_mig[0]] != ft._key_frame[shared_stay[0]]
    ft.check_invariants()


def test_view_drop_seq_releases_every_frame_slot():
    """drop_seq must release each dropped page's frame slot: a frame
    shared by two sequences survives (slots partially freed), and fully
    freed frames recycle for another domain."""
    tier = SharedHostTier(GEO, n_engines=2)
    v = tier.view(0)
    for i in range(5):                         # 5 pages → 2 frames of 4
        v.put(1, 0, i, *_payload(float(i)))
    v.put(2, 0, 0, *_payload(9.0))             # co-tenant in frame 2
    shared_frame = tier.frames._key_frame[(2, 0, 0)]
    assert shared_frame == tier.frames._key_frame[(1, 0, 4)]
    assert v.drop_seq(1) == 5
    tier.check_invariants()
    # The exclusive frame recycled; the shared one kept only seq 2.
    assert len(tier.frames) == 1
    assert tier.frames.keys_of(shared_frame) == {(2, 0, 0)}
    assert tier.frames.stats["frames_recycled"] == 1
    # Freed slots are reusable by a different domain immediately.
    v1 = tier.view(1)
    for i in range(4):
        v1.put(3, 0, i, *_payload(float(i)))
    assert len(tier.frames) == 2               # reuses the recycled frame
    tier.check_invariants()
    assert v.drop_seq(2) == 1 and v1.drop_seq(3) == 4
    assert len(tier.frames) == 0


def _prefix_index_invariants(idx, store):
    # (a) Prefix-closure: every cached page's parent chain is cached.
    for p in idx._pages.values():
        if p.parent is not None:
            assert p.parent in idx._pages, "orphaned prefix page"
            assert idx._pages[p.parent].page_index == p.page_index - 1
    # (b/c) Index ↔ store payload consistency, both directions.
    index_keys = {(p.owner, p.shard, p.vpn) for p in idx._pages.values()}
    store_keys = set(store._pages)
    assert index_keys == store_keys, "index and store disagree"


def test_prefix_index_prefix_closed_randomized():
    """Property test (seeded): random park/match/evict interleavings
    keep the index prefix-closed and index↔store consistent."""
    rng = np.random.default_rng(42)
    store = HostPageStore()
    idx = PrefixIndex(store, PTOK, capacity_pages=6)
    streams = [rng.integers(0, 997, 4 * PTOK).astype(np.int32)
               for _ in range(5)]
    vpn = 0
    for _ in range(300):
        op = rng.integers(0, 3)
        toks = streams[rng.integers(0, len(streams))]
        n_pages = int(rng.integers(1, 5))
        toks = toks[:n_pages * PTOK]
        if op == 0:                            # park the missing suffix
            hashes = idx.chain_hashes(toks)
            start = idx.missing_from(hashes)
            for i in range(start, len(hashes)):
                parent = hashes[i - 1] if i > 0 else None
                idx.park(hashes[i], parent, i, 0, vpn,
                         *_payload(float(vpn % 50)))
                vpn += 1
        elif op == 1:                          # match touches LRU ticks
            n, pages = idx.match(toks)
            assert n <= n_pages
            for pg in pages:                   # every hit is readable
                idx.payload(pg)
        else:                                  # external owner eviction
            if idx._pages:
                victims = rng.choice(
                    [p.owner for p in idx._pages.values()],
                    size=min(2, len(idx._pages)), replace=False)
                idx.evict_owner_pages(int(o) for o in victims)
        assert len(idx) <= idx.capacity_pages
        _prefix_index_invariants(idx, store)
    assert idx.stats["parked_pages"] > 0 and idx.stats["evicted_pages"] > 0
    assert idx.stats["hit_pages"] > 0


def test_engine_wall_clock_survives_wall_time_jumps(monkeypatch):
    """Regression: engine timing used time.time(), so an NTP step (or a
    frozen clock, as here) corrupted wall_s/tok_per_s.  perf_counter is
    monotonic — a constant time.time() must not zero the throughput."""
    import time as time_mod
    monkeypatch.setattr(time_mod, "time", lambda: 1234.5)
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=2, max_seq=64,
                        manager_kind="mosaic", seed=0, prefix_cache=False)
    r = Request(rid=0, tenant=0, prompt=np.arange(16, dtype=np.int32),
                max_new=2)
    eng.submit(r)
    eng.run_until_drained(max_steps=100)
    assert r.done
    assert eng.stats.wall_s > 0.0
    assert eng.stats.tok_per_s() > 0.0


def test_frame_table_invariants_under_randomized_failure_sequences():
    """Property test (seeded, DESIGN.md §12): random interleavings of
    page placement, write-back pumping, spill/promote, sequence
    migration, whole-sequence drops, and whole-domain crash reclaim
    keep HostFrameTable.check_invariants() (and the tier's stronger
    cross-tier checks) true after every operation."""
    rng = np.random.default_rng(11)
    geo = PoolGeometry(page_tokens=8, frame_pages=2, compact_threshold=0.4)
    tier = SharedHostTier(geo, n_engines=3, capacity_frames=4, spill=True)
    home = {}                                  # seq → owning domain
    next_vpn = {}                              # seq → next fresh page
    for _ in range(250):
        op = int(rng.integers(0, 7))
        if op <= 1 or not home:                # place a fresh page
            seq = int(rng.integers(0, 12))
            d = home.setdefault(seq, int(rng.integers(0, 3)))
            vpn = next_vpn.get(seq, 0)
            tier.view(d).put(seq, 0, vpn, *_payload(float(seq + vpn)))
            next_vpn[seq] = vpn + 1
        elif op == 2:                          # advance the pump
            tier.pump(tier._now_us + float(rng.integers(1, 5000)))
        elif op == 3:                          # settle every write-back
            tier.flush()
        elif op == 4 and tier._spilled:        # promote-on-touch
            key = sorted(tier._spilled)[
                int(rng.integers(0, len(tier._spilled)))]
            tier.ensure_resident([key])
        elif op == 5:                          # migrate a sequence
            seq = sorted(home)[int(rng.integers(0, len(home)))]
            dst = int(rng.integers(0, 3))
            if dst != home[seq]:
                tier.migrate_seq(seq, dst)
                home[seq] = dst
        else:                                  # crash: reclaim a domain
            d = int(rng.integers(0, 3))
            if rng.random() < 0.5:
                tier.reclaim_domain(d)
                for seq in [s for s, dd in home.items() if dd == d]:
                    home.pop(seq)
                    next_vpn.pop(seq, None)
            elif home:                         # or drop one sequence
                seq = sorted(home)[int(rng.integers(0, len(home)))]
                tier.view(home.pop(seq)).drop_seq(seq)
                next_vpn.pop(seq, None)
        tier.frames.check_invariants()
        tier.check_invariants()
        for seq, d in home.items():            # leases track the tracker
            for k in tier.seq_pages(seq):
                assert tier.frames.owner_of(k) == d
    assert tier.stats["spilled_frames"] > 0
    assert tier.stats["promoted_frames"] > 0
    assert tier.stats["reclaimed_frames"] > 0
    tier.flush()
    tier.check_invariants()
    tier.spill_store.close()


# -------------------------------------------------- victim scoring (§11/§13)


def test_spill_victim_cost_scoring_diverges_from_lru():
    """A/B the two policies on the same access trace: frame A is full
    (2 pages) but cold, frame B holds one hot page.  Pure LRU spills B
    (stalest tick once A is touched last); cost scoring spills A — its
    hit-frequency × promote-cost score is lower despite the fresh tick."""
    picks = {}
    for policy in ("lru", "cost"):
        ft = HostFrameTable(frame_pages=2, victim_scoring=policy)
        ft.place(0, (1, 0, 0))                 # frame A …
        ft.place(0, (1, 0, 1))                 # … full at 2 pages
        ft.place(0, (1, 0, 2))                 # frame B, 1 page
        for _ in range(8):
            ft.touch((1, 0, 2))                # B is hot
        ft.touch((1, 0, 0))                    # A touched last (fresh tick)
        picks[policy] = ft.spill_victim()
    assert picks["lru"] == 1                   # stalest tick
    assert picks["cost"] == 0                  # cheapest to re-promote
    assert picks["lru"] != picks["cost"]


def test_spill_victim_cost_ties_break_by_lru_tick():
    ft = HostFrameTable(frame_pages=1, victim_scoring="cost")
    ft.place(0, (1, 0, 0))
    ft.place(0, (1, 0, 1))
    ft.touch((1, 0, 0))                        # equal hits+size, older tick
    assert ft.spill_victim() == 1              # (1,0,1) never re-touched


def test_recycled_frame_does_not_inherit_heat():
    ft = HostFrameTable(frame_pages=1, victim_scoring="cost")
    ft.place(0, (1, 0, 0))
    for _ in range(10):
        ft.touch((1, 0, 0))                    # frame 0 runs hot
    hot = ft._frame_hits[0]
    ft.release((1, 0, 0))                      # frame 0 recycled …
    ft.place(0, (2, 0, 0))                     # … by a fresh lease
    assert ft.frame_of((2, 0, 0)) == 0
    assert ft._frame_hits[0] < hot             # heat wiped, not inherited


def test_victim_scoring_flag_validated():
    with pytest.raises(ValueError, match="victim_scoring"):
        HostFrameTable(frame_pages=2, victim_scoring="mru")


# --------------------------------- cross-feature stress (§11/§12/§14)


@pytest.mark.router
@pytest.mark.faults
def test_cluster_crashes_spill_steal_prestage_randomized():
    """Property test (seeded via ROUTER_TEST_SEED): a randomized
    schedule mixing engine crashes (FaultPlan), spill back-pressure
    under a tight frame cap, queued-steal, and pre-staging drains
    completely with no leaked host-frame leases and no orphaned
    staging slots or pre-stage entries on the survivors."""
    from repro.serving.faults import FaultInjector, FaultPlan

    seed = int(os.environ.get("ROUTER_TEST_SEED", "0"))
    rng = np.random.default_rng(seed)
    cfg = get_smoke_config("qwen2.5-3b")
    dead = (0, 2)
    inj = FaultInjector(FaultPlan(seed=seed,
                                  engine_crashes=((4, dead[0]),
                                                  (9, dead[1]))))
    cluster = ServingCluster(cfg, geometry=GEO, n_engines=3, max_batch=2,
                             max_seq=128, seed=0, capacity_frames=4,
                             spill=True, wb_queue_frames=2,
                             router_prestage=True,
                             decode_window_us=1000.0,
                             fault_injector=inj)
    shared = [rng.integers(0, cfg.vocab_size,
                           PTOK * int(rng.integers(3, 6))).astype(np.int32)
              for _ in range(2)]
    reqs, rid = [], 0
    for _ in range(12):
        for _ in range(int(rng.integers(0, 3))):
            if rng.random() < 0.6:          # shared-prefix request
                base = shared[int(rng.integers(0, 2))]
                prompt = np.concatenate([base, rng.integers(
                    0, cfg.vocab_size,
                    int(rng.integers(8, 25))).astype(np.int32)])
            else:                           # cold request
                prompt = rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(8, 49))
                                      ).astype(np.int32)
            req = Request(
                rid=rid, tenant=rid % 3,
                priority=int(rng.integers(0, 3)), prompt=prompt,
                max_new=int(rng.integers(2, 9)),
                deadline_us=(None if rng.random() < 0.5 else
                             float(rng.integers(5_000, 40_000))))
            reqs.append(req)
            cluster.submit(req)
            rid += 1
        cluster.step()
    cluster.run_until_drained(max_steps=3000)
    assert all(r.done for r in reqs), \
        [r.rid for r in reqs if not r.done]
    cluster.check_invariants()
    tier = cluster.tier
    # Crashed domains were reclaimed whole: no lease survives them.
    leaked = [k for k in tier.frames._key_frame
              if tier.frames.owner_of(k) in dead]
    assert not leaked, leaked
    # Survivors hold no orphaned staging slots or pre-stage entries.
    for e in cluster.engines:
        if e.alive:
            assert len(e.staging) == 0, (e.engine_id, len(e.staging))
            assert not e._prestage_keys
            assert not e.prefetch.in_flight
    # The schedule actually exercised every feature under test.
    assert cluster.router.stats.crashes == 2
    assert cluster.router.stats.prestaged_requests > 0
    assert tier.stats["spilled_frames"] > 0
