"""Dry-run lowering smoke test (deliverable e, one cell per kind).

The full 80-cell matrix runs via ``python -m repro.launch.dryrun --all
--mesh both`` (captured in dryrun_all.log / dryrun_all.jsonl); here we
keep one train and one decode cell compiling against the production
16x16 mesh in CI.  Must run in a subprocess: the 512-device override has
to precede any jax import.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cell(arch, shape):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "pod"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=540)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    recs = [json.loads(l) for l in out.stdout.splitlines()
            if l.startswith("{")]
    assert len(recs) == 1
    return recs[0]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [("qwen2.5-3b", "train_4k"),
                                        ("qwen2.5-3b", "decode_32k")])
def test_cell_lowers_and_fits(arch, shape):
    rec = run_cell(arch, shape)
    assert rec["chips"] == 256
    if not rec["per_device_bytes"].get("peak_is_estimate"):
        # Older jax reports no true buffer-assignment peak; the estimate
        # has no liveness analysis, so the HBM bound only holds for the
        # real stat.
        assert rec["per_device_bytes"]["peak"] < 16e9, "exceeds v5e HBM"
    assert rec["hlo_flops_per_chip"] > 0
    assert rec["bottleneck"] in ("compute_s", "memory_s", "collective_s")
    assert rec["collective_bytes_per_chip"]["total"] > 0
