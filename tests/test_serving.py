"""Serving-engine integration tests.

The central semantic claim: the memory manager must be invisible to the
model.  Greedy outputs must be identical whether the pool is managed by
Mosaic (with pressure-induced CAC compaction mid-stream) or by a pressure-
free pool — because coalescing is metadata-only and compaction moves
payloads coherently with the table updates.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import ShardedKVCache

GEO = PoolGeometry(page_tokens=8, frame_pages=4, headroom=1.25,
                   compact_threshold=0.4)


def make_engine(arch="qwen2.5-3b", manager="mosaic", max_batch=3,
                max_seq=96, seed=0, **kw):
    cfg = get_smoke_config(arch)
    return ServingEngine(cfg, geometry=GEO, max_batch=max_batch,
                         max_seq=max_seq, manager_kind=manager, seed=seed,
                         **kw)


def run_workload(eng, prompts, max_new=6):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tenant=i % 2, prompt=p, max_new=max_new))
    eng.run_until_drained(max_steps=200)
    return eng


PROMPTS = [np.array(p, np.int32) for p in
           ([5, 6, 7, 8, 9, 10, 11, 12],
            [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8],
            [2, 7, 1, 8],
            [9, 9, 8, 2, 1, 0, 4, 5, 6, 7, 1, 2, 3],
            [11, 3, 5])]


def test_engine_outputs_independent_of_manager():
    """Mosaic vs GPU-MMU pools: same greedy continuations."""
    results = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng = make_engine(manager=kind)
        reqs = [Request(rid=i, tenant=i % 2, prompt=p, max_new=6)
                for i, p in enumerate(PROMPTS)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=200)
        assert all(r.done for r in reqs)
        results[kind] = {r.rid: list(r.out) for r in reqs}
    assert results["mosaic"] == results["gpu-mmu"]


def test_engine_compaction_preserves_outputs_under_pressure():
    """A tight pool forces mid-stream CAC compaction; outputs must match a
    pressure-free run token-for-token."""
    cfg = get_smoke_config("qwen2.5-3b")

    def run(max_batch, max_seq):
        eng = ServingEngine(cfg, geometry=GEO, max_batch=max_batch,
                            max_seq=max_seq, manager_kind="mosaic", seed=0)
        reqs = [Request(rid=i, tenant=0, prompt=p, max_new=8)
                for i, p in enumerate(PROMPTS)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=300)
        assert all(r.done for r in reqs)
        return {r.rid: list(r.out) for r in reqs}, eng

    # Loose run: big pool, no pressure.
    loose, eng_loose = run(max_batch=5, max_seq=192)
    # Tight run: small batch → churn (alloc/dealloc interleave) → frames
    # fragment → CAC fires.
    tight, eng_tight = run(max_batch=2, max_seq=96)
    assert loose == tight
    eng_tight.cache.check_invariants()


def test_engine_multi_tenant_isolation():
    """Concurrent tenants share the pool; the soft guarantee keeps every
    frame single-owner throughout."""
    eng = make_engine(max_batch=4)
    reqs = [Request(rid=i, tenant=i, prompt=PROMPTS[i % len(PROMPTS)],
                    max_new=5) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
        for mgr in eng.cache.mgrs:
            mgr.check_invariants()   # includes the soft-guarantee assert
    eng.run_until_drained(max_steps=200)
    # Full teardown: every frame returns to the free pool.
    for mgr in eng.cache.mgrs:
        assert mgr.pool.occupancy() == 0.0


def test_engine_tracks_coalescing_stats():
    # Prompts longer than one frame (32 tokens) so en-masse prefill
    # allocation produces fully-covered frames to coalesce.
    ftok = GEO.frame_pages * GEO.page_tokens
    long_prompts = [np.arange(2 * ftok + 3 * i, dtype=np.int32) % 17
                    for i in range(3)]
    eng = run_workload(make_engine(max_seq=160), long_prompts, max_new=4)
    assert eng.stats.decode_steps > 0
    assert eng.stats.prefill_tokens == sum(len(p) for p in long_prompts)
    assert 0.0 <= eng.stats.coalesced_mean <= 1.0
    # En-masse prefill allocation ⇒ a healthy share of pages coalesced.
    assert eng.stats.coalesced_mean > 0.3


# ----------------------------------------------------- host tier / paging


def _oversub_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        # Decode-heavy so the working set outgrows the pool mid-run.
        T = int(rng.integers(24, 56))
        reqs.append(Request(
            rid=i, tenant=i % 3,
            prompt=rng.integers(0, cfg.vocab_size, T).astype(np.int32),
            max_new=int(rng.integers(24, 40))))
    return reqs


def test_engine_oversubscribed_completes_under_both_managers():
    """A 2x oversubscribed multi-tenant run drains under both managers,
    with identical greedy outputs and clean invariants."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2.5-3b")
    results = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng = ServingEngine(cfg, geometry=GEO, max_batch=6, max_seq=96,
                            manager_kind=kind, seed=0, oversubscription=2.0)
        reqs = _oversub_requests(cfg, 10)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=2000)
        assert all(r.done for r in reqs)
        eng.cache.check_invariants()
        assert eng.host.request_pages() == 0, \
            "drained engine must not hold request-owned host pages " \
            "(cached prefixes under negative owners may persist)"
        results[kind] = {r.rid: list(r.out) for r in reqs}
    assert results["mosaic"] == results["gpu-mmu"]


def test_engine_preempted_request_resumes_token_identical():
    """A preempted-then-resumed request must produce exactly the tokens of
    an un-preempted run, and every swap cycle must leave the pool's
    invariants intact."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2.5-3b")

    def run(with_preempt):
        eng = ServingEngine(cfg, geometry=GEO, max_batch=4, max_seq=96,
                            manager_kind="mosaic", seed=0)
        rng = np.random.default_rng(3)
        spec = [(20, 24), (5, 30), (7, 30)]
        reqs = [Request(rid=i, tenant=i,
                        prompt=rng.integers(0, cfg.vocab_size, T)
                        .astype(np.int32), max_new=mn)
                for i, (T, mn) in enumerate(spec)]
        for r in reqs:
            eng.submit(r)
        for step in range(60):
            eng.step()
            if with_preempt and step in (3, 9):
                # Two full swap cycles: hold across a few steps so other
                # requests decode (and may compact) in between.
                assert eng.preempt(0, hold=True)
                eng.cache.check_invariants()
                for _ in range(2):
                    eng.step()
                    eng.cache.check_invariants()
                assert eng.release(0)
                eng.step()                    # resume + first fault-in
                eng.cache.check_invariants()
            if all(r.done for r in reqs):
                break
        eng.run_until_drained(max_steps=200)
        assert all(r.done for r in reqs)
        return eng, {r.rid: list(r.out) for r in reqs}

    eng_plain, plain = run(with_preempt=False)
    eng_swap, swapped = run(with_preempt=True)
    assert eng_swap.stats.swaps_out >= 2 and eng_swap.stats.faults > 0
    assert eng_plain.stats.swaps_out == 0
    assert plain == swapped
    eng_swap.cache.check_invariants()
    assert eng_swap.host.request_pages() == 0


def test_engine_priority_preemption_under_admission_pressure():
    """A high-priority arrival preempts the lowest-priority active request
    instead of waiting, and everyone still finishes with correct state."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=3, max_seq=96,
                        manager_kind="mosaic", seed=0, oversubscription=1.6)
    rng = np.random.default_rng(4)
    low = [Request(rid=i, tenant=0, priority=0,
                   prompt=rng.integers(0, cfg.vocab_size, 64)
                   .astype(np.int32), max_new=16) for i in range(3)]
    for r in low:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    hi = Request(rid=99, tenant=1, priority=5,
                 prompt=rng.integers(0, cfg.vocab_size, 64)
                 .astype(np.int32), max_new=8)
    eng.submit(hi)
    for _ in range(4):
        eng.step()
        eng.cache.check_invariants()
    assert hi in eng.active or hi.done, \
        "high-priority request should displace a low-priority one"
    assert eng.stats.swaps_out >= 1
    eng.run_until_drained(max_steps=500)
    assert all(r.done for r in low + [hi])
    eng.cache.check_invariants()


# ------------------------------------------------------------- kv cache


def test_sharded_cache_frames_never_straddle_shards():
    cache = ShardedKVCache(GEO, pages_per_shard=64, n_shards=4,
                           manager_kind="mosaic")
    cache.allocate(0, 10 * GEO.frame_pages * GEO.page_tokens)
    ftok = GEO.frame_pages * GEO.page_tokens
    # Global frame f must live wholly in sub-pool f % S.
    for s, mgr in enumerate(cache.mgrs):
        if 0 not in mgr.tables:
            continue
        n_local = len(mgr.tables[0].ppn)
        assert n_local % GEO.frame_pages == 0 or s == (10 - 1) % 4
    ctx = cache.pack_ctx([0], mpps=64)
    tb = np.asarray(ctx.tables)[0]            # [S, mpps]
    # Each shard's table only references its local pool.
    assert tb.max() < 64
    total_pages = (tb >= 0).sum()
    assert total_pages == 10 * GEO.frame_pages
    cache.check_invariants()


def test_sharded_cache_pack_dual_splits_by_granularity():
    cache = ShardedKVCache(GEO, pages_per_shard=64, n_shards=1)
    fp, ptok = GEO.frame_pages, GEO.page_tokens
    cache.allocate(0, fp * ptok)        # one full frame -> coalesced
    cache.allocate(1, 2 * ptok)         # partial -> splintered
    ft, fn, pt, pn = cache.pack_dual([0, 1], shard=0, max_frames=4,
                                     max_pages=4 * fp)
    ft, fn, pt, pn = map(np.asarray, (ft, fn, pt, pn))
    assert (ft[0] >= 0).sum() == 1 and fn[0, 0] == fp * ptok
    assert (pt[0] >= 0).sum() == 0      # fully coalesced: no page entries
    assert (ft[1] >= 0).sum() == 0
    assert (pt[1] >= 0).sum() == 2 and pn[1, :2].tolist() == [ptok, ptok]


def test_sharded_cache_random_ops_property():
    """Hypothesis-style invariant sweep: arbitrary allocate/append/free
    interleavings keep every sub-pool's invariants and the striping
    contract (global frame f of a sequence lives in sub-pool f % S)."""
    pytest.importorskip("hypothesis",
                        reason="property tests need hypothesis")
    from hypothesis import given, settings, HealthCheck
    from hypothesis import strategies as st

    ops_st = st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, 3),
                      st.integers(1, 3 * GEO.frame_pages * GEO.page_tokens)),
            st.tuples(st.just("append"), st.integers(0, 3),
                      st.integers(1, 24)),
            st.tuples(st.just("free"), st.integers(0, 3), st.just(0)),
        ),
        min_size=1, max_size=25,
    )

    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=ops_st)
    def run(ops):
        cache = ShardedKVCache(GEO, pages_per_shard=256, n_shards=4,
                               manager_kind="mosaic")
        ftok = GEO.frame_pages * GEO.page_tokens
        for op, seq, n in ops:
            if op == "alloc":
                cache.allocate(seq, n)
            elif op == "append":
                cache.append(seq, n)
            elif op == "free":
                cache.free(seq)
            cache.check_invariants()
            # Striping contract: per-shard local page count implies the
            # shard holds exactly the frames striped to it.
            for s, mgr in enumerate(cache.mgrs):
                for owner, tok in mgr.seq_tokens.items():
                    total = cache.seq_tokens.get(owner, 0)
                    frames = (total + ftok - 1) // ftok
                    mine = sum(1 for f in range(frames) if f % 4 == s)
                    local_frames = (len(mgr.tables[owner].ppn)
                                    + GEO.frame_pages - 1) // GEO.frame_pages
                    assert local_frames <= mine, (owner, s)
        for seq in list(cache.seq_tokens):
            cache.free(seq)
        for mgr in cache.mgrs:
            assert mgr.pool.occupancy() == 0.0

    run()


def test_summary_pins_prestage_counters():
    """Satellite: summary() must expose the pre-stage hit/wasted/
    cancelled split (DESIGN.md §14) — and omit it entirely when no
    pages were ever pre-staged, keeping older engines' lines stable."""
    from repro.serving.engine import EngineStats

    s = EngineStats(prestaged_pages=4, prestage_hits=2,
                    prestage_wasted=1, prestage_cancelled=1)
    assert "prestage 4 pages (2/1/1 hit/wasted/cancelled)" in s.summary()
    assert "prestage" not in EngineStats().summary()
