"""Failure injection & recovery tests (DESIGN.md §12).

Covers the seeded FaultPlan/FaultInjector, spill-store checksum
integrity (bit flips caught before any payload is returned), transient
disk-error retry with modeled backoff, permanent-error / corruption
quarantine (prefix re-derive, request sequences marked lost), graceful
degradation to the hard-cap path on a rising error rate, whole-domain
crash reclaim (prefix frames survive), orphan sweep + context-manager
cleanup of the spill directory, the router's livelock RuntimeError, and
end-to-end engine-crash recovery with byte-identical tokens.
"""

import os

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.cluster import (FRAME_HOST, FRAME_SPILLED,
                                   PREFIX_DOMAIN, ServingCluster,
                                   SharedHostTier)
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import (FaultInjector, FaultPlan,
                                  SpillCorruptionError, SpillIOError)
from repro.serving.host_tier import SpillStore

GEO = PoolGeometry(page_tokens=8, frame_pages=2, compact_threshold=0.4)


def _payload(tag: float):
    return (np.full((2, 3), tag, np.float32),
            np.full((2, 3), -tag, np.float32))


def _tier(**kw):
    kw.setdefault("capacity_frames", 2)
    return SharedHostTier(GEO, n_engines=1, **kw)


def _fill(view, seq, n, tag0=0.0):
    for i in range(n):
        view.put(seq, 0, i, *_payload(tag0 + i))


# ----------------------------------------------------------- fault plan


def test_injector_is_deterministic_per_seed():
    plan = FaultPlan(disk_write_error_rate=0.5, corrupt_write_rate=0.5,
                     max_transient_failures=100)
    logs = []
    for _ in range(2):
        inj = FaultInjector(plan)
        for f in range(20):
            try:
                inj.disk_write_fault(f)
            except SpillIOError:
                pass
            inj.corrupt_written(f, b"payload-bytes")
        logs.append(list(inj.log))
    assert logs[0] == logs[1] and logs[0]     # same seed ⇒ same faults


def test_injector_crashes_fire_once():
    inj = FaultInjector(FaultPlan(engine_crashes=((3, 0), (3, 1), (5, 0))))
    assert inj.crashes_due(2) == []
    assert inj.crashes_due(3) == [0, 1]
    assert inj.crashes_due(4) == []           # already fired
    assert inj.crashes_due(9) == [0]          # late check still fires 5
    assert inj.stats["engine_crashes"] == 3


def test_transient_failures_bounded_per_frame_and_op():
    inj = FaultInjector(FaultPlan(disk_read_error_rate=1.0,
                                  max_transient_failures=2))
    fails = 0
    for _ in range(5):
        try:
            inj.disk_read_fault(7)
        except SpillIOError as e:
            assert e.transient and e.frame == 7
            fails += 1
    assert fails == 2                         # then reads succeed


# ---------------------------------------------------- spill-store integrity


def test_spillstore_checksum_catches_bit_flip():
    inj = FaultInjector(FaultPlan(corrupt_frames=(7,)))
    store = SpillStore(injector=inj)
    kp = np.arange(12, dtype=np.float32).reshape(3, 4)
    store.write_frame(7, "dom", [((5, 0, 0), (kp, -kp))])
    store.write_frame(8, "dom", [((5, 0, 1), (kp + 1, kp - 1))])
    with pytest.raises(SpillCorruptionError):
        store.read_frame(7)
    assert store.stats["checksum_failures"] == 1
    assert store.stats["frames_read"] == 0    # nothing returned
    back = store.read_frame(8)                # healthy frame unaffected
    assert np.array_equal(back[0][1][0], kp + 1)
    store.quarantine_frame(7)
    assert not store.has_frame(7)
    assert store.stats["frames_quarantined"] == 1
    store.close()


def test_spillstore_write_fault_leaves_store_unchanged():
    inj = FaultInjector(FaultPlan(disk_write_error_rate=1.0,
                                  max_transient_failures=1))
    store = SpillStore(injector=inj)
    pages = [((5, 0, 0), _payload(1.0))]
    with pytest.raises(SpillIOError):
        store.write_frame(3, None, pages)
    assert len(store) == 0 and store.stats["frames_written"] == 0
    store.write_frame(3, None, pages)         # transient budget spent
    assert store.has_frame(3)
    store.close()


def test_spillstore_sweeps_orphans_and_cleans_up_as_context_manager(
        tmp_path):
    root = str(tmp_path / "spill")
    os.makedirs(root)
    orphan = os.path.join(root, "frame_00000042.npz")
    with open(orphan, "wb") as f:
        f.write(b"stale bytes from a crashed run")
    store = SpillStore(root)
    assert store.stats["orphans_swept"] == 1 and not os.path.exists(orphan)
    store.close()
    with SpillStore() as owned:
        owned.write_frame(0, None, [((1, 0, 0), _payload(2.0))])
        d = owned._dir
        assert d is not None and os.path.isdir(d)
    assert not os.path.isdir(d)               # owned temp dir removed


# ------------------------------------------------------- tier failure paths


def test_tier_retries_transient_read_errors_with_backoff():
    inj = FaultInjector(FaultPlan(disk_read_error_rate=1.0,
                                  max_transient_failures=2))
    tier = _tier(injector=inj, disk_retries=3, retry_backoff_us=50.0,
                 disk_error_rate_threshold=2.0)   # isolate the retry path
    v = tier.view(0)
    _fill(v, 9, 8)                  # 4 frames over capacity 2
    tier.flush()
    key = sorted(tier._spilled)[0]
    kp, _ = v.peek(*key)            # promote: fails twice, then succeeds
    assert np.array_equal(kp, _payload(float(key[2]))[0])
    assert tier.stats["disk_retries"] == 2
    assert tier.stats["retry_backoff_us"] == 50.0 + 100.0  # exponential
    assert tier.stats["promoted_frames"] == 1
    assert tier.stats["frames_quarantined"] == 0
    tier.check_invariants()
    tier.spill_store.close()


def test_tier_quarantines_permanently_unreadable_request_frame():
    inj = FaultInjector(FaultPlan(permanent_read_frames=(0, 1, 2, 3)))
    tier = _tier(injector=inj, disk_error_rate_threshold=2.0)
    v = tier.view(0)
    _fill(v, 9, 8)
    tier.flush()
    key = sorted(tier._spilled)[0]
    stall = tier.ensure_resident([key])
    assert stall == tier.disk_seek_us         # the discovering seek
    assert not v.has(*key)                    # payload gone, not decoded
    assert tier.stats["frames_quarantined"] == 1
    assert 9 in tier.lost_seqs
    assert tier.take_lost(9) and not tier.take_lost(9)   # exactly once
    tier.check_invariants()
    tier.spill_store.close()


def test_tier_quarantines_corrupt_frame_before_any_decode():
    inj = FaultInjector(FaultPlan(corrupt_write_rate=1.0))
    tier = _tier(injector=inj, disk_error_rate_threshold=2.0)
    v = tier.view(0)
    _fill(v, 9, 8)
    tier.flush()
    n_spilled = len({f for f in tier._spilled.values()})
    tier.ensure_resident(sorted(tier._spilled))
    ss = tier.spill_store.stats
    assert ss["checksum_failures"] == n_spilled       # 100 % detection
    assert ss["frames_read"] == 0                     # never decoded from
    assert tier.stats["frames_quarantined"] == n_spilled
    assert tier.lost_seqs == {9}
    tier.check_invariants()
    tier.spill_store.close()


def test_tier_degrades_to_hard_cap_on_disk_error_rate():
    inj = FaultInjector(FaultPlan(disk_write_error_rate=1.0,
                                  max_transient_failures=10 ** 6))
    tier = _tier(injector=inj, disk_retries=3)
    v = tier.view(0)
    _fill(v, 9, 8)
    tier.flush()
    assert tier.degraded and tier.stats["degraded"] == 1
    assert not tier.spill_enabled             # dropped to hard-cap path
    assert tier.stats["disk_retries"] >= 1    # backoff was exercised
    assert tier.stats["spilled_frames"] == 0  # nothing ever left DRAM
    assert len(tier._pending_wb) == 0         # queue cancelled, not stuck
    assert tier.park_allowed()                # hard cap sheds, not refuses
    for i in range(8):                        # zero data loss
        assert np.array_equal(v.peek(9, 0, i)[0], _payload(float(i))[0])
    tier.check_invariants()
    tier.spill_store.close()


def test_reclaim_domain_recycles_whole_frames_and_spares_prefix():
    tier = _tier()
    pv = tier.view(PREFIX_DOMAIN)
    pv.put(-1, 0, 0, *_payload(50.0))
    v = tier.view(0)
    _fill(v, 9, 8)
    tier.flush()
    assert tier.stats["spilled_frames"] >= 1
    n = tier.reclaim_domain(0)
    assert n >= 1
    assert tier.seq_pages(9) == []            # DRAM *and* disk cleared
    assert all(d == PREFIX_DOMAIN
               for d in tier.frames._frame_owner.values())
    assert (-1, 0, 0) in tier.seq_pages(-1)   # parked KV outlives domain 0
    assert tier.stats["reclaimed_frames"] == n
    tier.check_invariants()
    tier.spill_store.close()


def test_tier_undegrades_after_probe_successes():
    """Transient-bounded write errors: the tier degrades while the disk
    misbehaves, periodic probes observe recovery, and after the success
    streak the spill path is re-enabled (counters track every cycle)."""
    inj = FaultInjector(FaultPlan(seed=1, disk_write_error_rate=1.0,
                                  max_transient_failures=2))
    tier = _tier(injector=inj, undegrade_probe_interval_us=100.0,
                 undegrade_probe_successes=3)
    v = tier.view(0)
    for i in range(12):                       # overflow the 2-frame cap
        v.put(1, 0, i, *_payload(float(i)))
    t = 0.0
    for _ in range(20):
        t += 1000.0
        tier.pump(t)
    assert not tier.degraded and tier.stats["degraded"] == 0
    assert tier.spill_enabled                 # spill path back in service
    assert tier.stats["degrades"] >= 1        # it did fall over first
    assert tier.stats["undegrades"] >= 1
    assert tier.stats["probes"] >= tier.stats["undegrades"] * 3
    for i in range(12):                       # zero data loss throughout
        assert np.array_equal(v.peek(1, 0, i)[0], _payload(float(i))[0])
    tier.check_invariants()
    tier.spill_store.close()


def test_tier_stays_degraded_while_probes_fail():
    """Unbounded write errors (the faults-bench 'degrade' plan): every
    probe write fails too, the success streak never builds, and the
    tier remains on the hard-cap path forever — the committed
    ``claim_faults_degrade_zero_drops`` depends on this."""
    inj = FaultInjector(FaultPlan(disk_write_error_rate=1.0,
                                  max_transient_failures=10 ** 6))
    tier = _tier(injector=inj, undegrade_probe_interval_us=100.0)
    v = tier.view(0)
    _fill(v, 9, 8)
    tier.flush()
    assert tier.degraded
    t = tier._now_us
    for _ in range(10):
        t += 1000.0
        tier.pump(t)
    assert tier.degraded and tier.stats["undegrades"] == 0
    assert tier.stats["probes"] >= 1
    assert tier.stats["probe_failures"] == tier.stats["probes"]
    tier.check_invariants()
    tier.spill_store.close()


def test_tier_probing_disabled_never_probes():
    inj = FaultInjector(FaultPlan(disk_write_error_rate=1.0,
                                  max_transient_failures=10 ** 6))
    tier = _tier(injector=inj, undegrade_probe_interval_us=None)
    v = tier.view(0)
    _fill(v, 9, 8)
    tier.flush()
    assert tier.degraded
    t = tier._now_us
    for _ in range(10):
        t += 100_000.0
        tier.pump(t)
    assert tier.degraded and tier.stats["probes"] == 0
    tier.spill_store.close()


# ------------------------------------------------------------ router & engine


def test_run_until_drained_raises_on_livelock():
    from repro.serving.router import RequestRouter

    class Eng:
        alive = True
        engine_id = 0
        queue: list = []
        active: list = []
        preempted: list = []

    router = RequestRouter([Eng()], tier=None, migrate=False)
    router.submit(Request(rid=1, tenant=0,
                          prompt=np.zeros(4, np.int32), max_new=1))
    with pytest.raises(RuntimeError, match="still outstanding"):
        router.run_until_drained(max_steps=0)


def test_engine_rejects_bad_modes_with_value_error():
    cfg = get_smoke_config("qwen2.5-3b")
    with pytest.raises(ValueError, match="fault_mode"):
        ServingEngine(cfg, geometry=GEO, max_batch=2, max_seq=64,
                      fault_mode="magic")
    with pytest.raises(ValueError, match="victim_policy"):
        ServingEngine(cfg, geometry=GEO, max_batch=2, max_seq=64,
                      victim_policy="random")


@pytest.mark.faults
def test_cluster_crash_recovery_tokens_identical():
    """An engine crash mid-decode: the survivors re-run the victim's
    work and every request finishes with byte-identical tokens."""
    def run(plan):
        cfg = get_smoke_config("qwen2.5-3b")
        inj = FaultInjector(plan) if plan is not None else None
        cluster = ServingCluster(cfg, geometry=GEO, n_engines=2,
                                 max_batch=2, max_seq=64, seed=0,
                                 prefix_cache=False, migrate=False,
                                 decode_window_us=1000.0,
                                 fault_injector=inj)
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i, tenant=0, priority=(2 if i == 2 else 0),
                        prompt=rng.integers(0, cfg.vocab_size, 16)
                        .astype(np.int32), max_new=6)
                for i in range(4)]
        for r in reqs[:3]:
            cluster.submit(r, engine=0)       # overload replica 0
        cluster.submit(reqs[3], engine=1)
        cluster.run_until_drained(max_steps=500)
        assert all(r.done for r in reqs)
        cluster.check_invariants()
        return cluster, {r.rid: tuple(r.out) for r in reqs}

    _, base = run(None)
    cluster, rec = run(FaultPlan(engine_crashes=((2, 0),)))
    assert rec == base, "crash recovery changed model outputs"
    rs = cluster.router.stats
    assert rs.crashes == 1 and rs.recovered_requeued >= 1
    assert not cluster.engines[0].alive
    assert cluster.engines[1].alive
