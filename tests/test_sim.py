"""TLB/paging simulator tests: the paper's evaluation apparatus must
reproduce the paper's qualitative claims on small workloads.

Full-scale figure reproductions (235 workloads) live in benchmarks/; these
tests assert the *trends* on scaled-down runs so CI stays fast:

  Fig.1  large pages ≈ ideal-TLB performance >> base pages
  Fig.5  weighted speedup: Mosaic > GPU-MMU; Mosaic ≈ Ideal
  Fig.8  L1/L2 hit rates: Mosaic ≈ 1 > GPU-MMU; GPU-MMU degrades with apps
  Fig.7  demand paging on/off changes Mosaic's relative win only mildly
"""

import numpy as np
import pytest

from repro.core.tlb_sim import SimConfig, TranslationSim, weighted_speedup
from repro.core.workloads import (
    APP_NAMES,
    build_workload,
    heterogeneous_names,
    homogeneous_names,
)

N_ACCESS = 3000   # scaled-down traces (full scale in benchmarks/)


def run_sim(names, manager_kind, *, mode="mosaic", ideal=False,
            paging=True, seed=0, n_access=N_ACCESS):
    traces, mgr = build_workload(names, manager_kind, seed=seed,
                                 n_access=n_access)
    cfg = SimConfig(mode=mode, ideal=ideal, paging=paging)
    sim = TranslationSim(cfg, traces)
    res = sim.run()
    return res, sim, mgr


def ipcs(res):
    return np.array([r.ipc for r in res])


# ----------------------------------------------------------------- fig 1


def test_large_pages_beat_base_pages_and_near_ideal():
    names = homogeneous_names("bfs", 2)     # TLB-thrashing profile
    res_base, _, _ = run_sim(names, "gpu-mmu", mode="base", paging=False)
    res_large, _, _ = run_sim(names, "gpu-mmu", mode="large", paging=False)
    res_ideal, _, _ = run_sim(names, "gpu-mmu", ideal=True, paging=False)
    perf_base = ipcs(res_base).sum()
    perf_large = ipcs(res_large).sum()
    perf_ideal = ipcs(res_ideal).sum()
    # Paper Fig. 1: 4KB loses ~48% vs ideal; 2MB comes within ~2%.
    assert perf_base < 0.8 * perf_ideal
    assert perf_large > 0.9 * perf_ideal
    assert perf_large > 1.2 * perf_base


# ----------------------------------------------------------------- fig 5


@pytest.mark.parametrize("napps", [2, 4])
def test_mosaic_beats_gpummu_homogeneous(napps):
    names = homogeneous_names("spmv", napps)
    alone, _, _ = run_sim(names[:1], "gpu-mmu", mode="base")
    shared_m, sim_m, mgr_m = run_sim(names, "mosaic", mode="mosaic")
    shared_b, sim_b, mgr_b = run_sim(names, "gpu-mmu", mode="base")
    shared_i, _, _ = run_sim(names, "gpu-mmu", ideal=True)
    alone_n = alone * napps
    ws_m = weighted_speedup(shared_m, alone_n)
    ws_b = weighted_speedup(shared_b, alone_n)
    ws_i = weighted_speedup(shared_i, alone_n)
    assert ws_m > ws_b, "Mosaic must outperform GPU-MMU"
    assert ws_m > 0.75 * ws_i, "Mosaic should approach the ideal TLB"
    # Mechanism check: the win comes from coalescing (Fig. 8's cause).
    # The baseline "virtually always" interleaves owners in frames (paper
    # Fig. 2); a handful of early allocations may land lucky, so assert
    # the opportunity *rate* is negligible rather than exactly zero.
    assert mgr_m.pool.coalesced_fraction() > 0.9
    opp_rate = (mgr_b.stats()["coalesce_opportunities"]
                / max(mgr_b.pool.stats["pages_allocated"], 1))
    assert opp_rate < 0.01


def test_mosaic_beats_gpummu_heterogeneous():
    names = heterogeneous_names(3, seed=1)
    alone = [run_sim([n], "gpu-mmu", mode="base")[0][0] for n in names]
    shared_m, _, _ = run_sim(names, "mosaic", mode="mosaic")
    shared_b, _, _ = run_sim(names, "gpu-mmu", mode="base")
    ws_m = weighted_speedup(shared_m, alone)
    ws_b = weighted_speedup(shared_b, alone)
    assert ws_m > ws_b


# ----------------------------------------------------------------- fig 8


def test_tlb_hit_rates_mosaic_vs_baseline():
    names = homogeneous_names("shoc-spmv", 3)
    _, sim_m, _ = run_sim(names, "mosaic", mode="mosaic")
    _, sim_b, _ = run_sim(names, "gpu-mmu", mode="base")
    # Paper: Mosaic's miss rate falls below ~1% instruction-level in both
    # TLB levels; the baseline thrashes.
    assert sim_m.l1_hit_rate_micro() > 0.99
    assert sim_m.l1_hit_rate_micro() > sim_b.l1_hit_rate_micro()
    assert sim_m.l2_hit_rate() >= sim_b.l2_hit_rate() * 0.95


def test_baseline_l2_degrades_with_more_apps():
    """Fig. 8's second observation: GPU-MMU interference grows with app
    count while Mosaic is immune (large-page entries cover the pool)."""
    h2 = run_sim(homogeneous_names("kmeans", 2), "gpu-mmu", mode="base")[1]
    h5 = run_sim(homogeneous_names("kmeans", 5), "gpu-mmu", mode="base")[1]
    m2 = run_sim(homogeneous_names("kmeans", 2), "mosaic", mode="mosaic")[1]
    m5 = run_sim(homogeneous_names("kmeans", 5), "mosaic", mode="mosaic")[1]
    assert h5.l2_hit_rate() < h2.l2_hit_rate()          # baseline degrades
    drop_m = m2.l1_hit_rate_micro() - m5.l1_hit_rate_micro()
    assert drop_m < 0.01                                 # Mosaic does not


# ----------------------------------------------------------------- fig 7


def test_demand_paging_changes_little():
    """Fig. 7: the transfer cost exists either way (paging on or off), so
    weighted speedup barely moves.

    Holds in the paper's steady-state regime (reuse >> cold faults); our
    scaled traces need a reuse-heavy profile + longer run to be in it.
    """
    names = homogeneous_names("dct", 2)     # small ws, high reuse
    on, _, _ = run_sim(names, "mosaic", mode="mosaic", paging=True,
                       n_access=8000)
    off, _, _ = run_sim(names, "mosaic", mode="mosaic", paging=False,
                        n_access=8000)
    ratio = ipcs(on).sum() / ipcs(off).sum()
    assert 0.7 < ratio <= 1.001


# ----------------------------------------------------------------- misc


def test_mshr_merges_duplicate_walks():
    """Two warps missing on the same page must share one walk."""
    from repro.core.tlb_sim import AppTrace

    vpn = np.zeros(64, np.int32)            # everyone hammers page 0
    tr = AppTrace(vpn=vpn, ppn=vpn, frame=vpn // 512,
                  coalesced=np.zeros(64, np.int8), gap_cycles=0)
    cfg = SimConfig(mode="base", paging=False, warps_per_app=32)
    sim = TranslationSim(cfg, [tr])
    sim.run()
    assert sim.walker.walks == 1            # merged by the MSHR


def test_workload_registry_covers_27_apps():
    assert len(APP_NAMES) == 27
