"""Fused gather-attend decode over partially-resident KV (DESIGN.md §13).

Covers the readiness-masked attention paths at every layer: the pallas
kernel's two-accumulator flush (all-resident → bitwise-identical to the
baseline paged kernel; partial/all-late → matches the eager reference
and gather-then-attend to float32 round-off), the pure-JNP local path's
slot-select (bitwise-identical to the slot-free call when the staged
bytes equal the pool's), per-page DMA completion timestamps, staging
slot addressing, and the serving engine's three-mode token identity
with zero-resident resume steps and mid-run preemption.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.core.demand_paging import LinkModel
from repro.kernels.paged_attention import (fused_paged_attention_kernel,
                                           paged_attention_kernel,
                                           readiness_meta)
from repro.kernels.ref import fused_gather_attend_ref
from repro.models.paged import paged_attention_local
from repro.serving.dma import AsyncDMAEngine, StagingBuffer
from repro.serving.engine import Request, ServingEngine

GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)

# Kernel-vs-anything comparisons are allclose, not bitwise: pallas
# interpret mode jits the whole kernel (XLA fuses elementwise chains)
# while the eager reference runs op-by-op, so identical math can differ
# in the last bits.  Bitwise asserts are reserved for same-trace pairs
# (fused kernel all-ready vs baseline kernel; engine tokens).
TOL = dict(rtol=1e-4, atol=1e-5)


def _case(B=3, nblk=4, n_kv=2, g=2, dh=8, ptok=8, seed=0):
    rng = np.random.default_rng(seed)
    NP = B * nblk + 3
    q = jnp.asarray(rng.standard_normal((B, n_kv * g, dh), np.float32))
    pk = jnp.asarray(rng.standard_normal((NP, ptok, n_kv, dh), np.float32))
    pv = jnp.asarray(rng.standard_normal((NP, ptok, n_kv, dh), np.float32))
    tables = jnp.asarray(
        rng.permutation(NP)[:B * nblk].reshape(B, nblk).astype(np.int32))
    ntok = jnp.asarray(
        rng.integers(1, ptok + 1, (B, nblk)).astype(np.int32))
    return q, pk, pv, tables, ntok, 1.0 / float(np.sqrt(dh))


def _stage_from_pool(pk, pv, tables, late):
    """Stage the `late` pages' true bytes; garbage their pool copies."""
    rng = np.random.default_rng(99)
    tbl = np.asarray(tables)
    sk = np.asarray(pk)[tbl[late]]
    sv = np.asarray(pv)[tbl[late]]
    dk, dv = np.asarray(pk).copy(), np.asarray(pv).copy()
    dk[tbl[late]] = rng.standard_normal(sk.shape).astype(np.float32)
    dv[tbl[late]] = rng.standard_normal(sv.shape).astype(np.float32)
    slots = np.full(tbl.shape, -1, np.int32)
    slots[late] = np.arange(int(late.sum()), dtype=np.int32)
    return (jnp.asarray(sk), jnp.asarray(sv), jnp.asarray(dk),
            jnp.asarray(dv), jnp.asarray(slots))


# ------------------------------------------------------------ kernel layer


def test_fused_kernel_all_ready_bitwise_vs_baseline():
    """Every slot -1: the late accumulator never initializes and the
    flush emits the ready scratch untouched — bitwise-identical to the
    baseline page-granularity kernel."""
    q, pk, pv, tables, ntok, scale = _case()
    base = paged_attention_kernel(q, pk, pv, tables, ntok,
                                  granularity="page", scale=scale)
    slots = jnp.full(tables.shape, -1, jnp.int32)
    fused = fused_paged_attention_kernel(
        q, pk, pv, pk[:2], pv[:2], tables, slots, ntok, scale=scale)
    for a, b in zip(fused, base):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_fused_kernel_partial_matches_ref_and_gather():
    """Mask flips mid-accumulation: alternating ready/late blocks must
    match both the eager reference and scatter-then-attend."""
    q, pk, pv, tables, ntok, scale = _case(seed=1)
    late = np.zeros(tables.shape, bool)
    late[:, 1::2] = True
    late[0, 0] = True                      # first block late on row 0
    sk, sv, dk, dv, slots = _stage_from_pool(pk, pv, tables, late)
    fused = fused_paged_attention_kernel(
        q, dk, dv, sk, sv, tables, slots, ntok, scale=scale)
    ref = fused_gather_attend_ref(q, dk, dv, sk, sv, tables, slots, ntok,
                                  scale=scale)
    base = paged_attention_kernel(q, pk, pv, tables, ntok,
                                  granularity="page", scale=scale)
    for f, r, b in zip(fused, ref, base):
        np.testing.assert_allclose(np.asarray(f), np.asarray(r), **TOL)
        np.testing.assert_allclose(np.asarray(f), np.asarray(b), **TOL)


def test_fused_kernel_zero_resident_row():
    """A row whose pages are ALL late (zero-resident decode step): only
    the late accumulator runs and the flush emits its scratch."""
    q, pk, pv, tables, ntok, scale = _case(seed=2)
    late = np.zeros(tables.shape, bool)
    late[0, :] = True                       # row 0 fully late
    late[2, -1] = True                      # row 2 a single straggler
    sk, sv, dk, dv, slots = _stage_from_pool(pk, pv, tables, late)
    fused = fused_paged_attention_kernel(
        q, dk, dv, sk, sv, tables, slots, ntok, scale=scale)
    base = paged_attention_kernel(q, pk, pv, tables, ntok,
                                  granularity="page", scale=scale)
    for f, b in zip(fused, base):
        np.testing.assert_allclose(np.asarray(f), np.asarray(b), **TOL)


def test_readiness_meta_edges():
    slots = jnp.asarray(np.array([[-1, -1, -1],     # all ready
                                  [0, 1, 2],        # all late
                                  [-1, 3, -1]],     # mixed
                                 np.int32))
    meta = np.asarray(readiness_meta(slots))
    np.testing.assert_array_equal(meta[0], [0, 0, -1])
    np.testing.assert_array_equal(meta[1], [3, -1, 0])
    np.testing.assert_array_equal(meta[2], [1, 0, 1])


# ------------------------------------------------------- local (JNP) layer


def test_local_slot_select_bitwise_when_staged_equals_pool():
    """The local path only swaps the load source per page; with staged
    bytes equal to the pool's, partial-resident and slot-free calls are
    byte-for-byte identical (this is what makes engine tokens identical
    across modes by construction)."""
    q, pk, pv, tables, ntok, scale = _case(seed=3)
    base = paged_attention_local(q, pk, pv, tables, ntok, scale=scale)

    late = np.zeros(tables.shape, bool)
    late[:, ::2] = True
    tbl = np.asarray(tables)
    sk = jnp.asarray(np.asarray(pk)[tbl[late]])
    sv = jnp.asarray(np.asarray(pv)[tbl[late]])
    slots = np.full(tbl.shape, -1, np.int32)
    slots[late] = np.arange(int(late.sum()), dtype=np.int32)
    fused = paged_attention_local(q, pk, pv, tables, ntok, scale=scale,
                                  stage_k=sk, stage_v=sv,
                                  slots=jnp.asarray(slots))
    for a, b in zip(fused, base):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # All-(-1) slots with stage pools attached: classic path, bitwise.
    allready = paged_attention_local(
        q, pk, pv, tables, ntok, scale=scale, stage_k=sk, stage_v=sv,
        slots=jnp.full(tbl.shape, -1, jnp.int32))
    for a, b in zip(allready, base):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- DMA/staging


def _payload():
    return (np.zeros((1, 8, 1, 4), np.float32),
            np.zeros((1, 8, 1, 4), np.float32))


def test_dma_page_done_us_monotone_and_bounded():
    link = LinkModel(setup_us=10.0, bandwidth_GBps=10.0)
    dma = AsyncDMAEngine(link, n_channels=1)
    keys = [(0, 0, i) for i in range(4)]
    job = dma.enqueue(keys, list(range(4)), 1000,
                      [_payload()] * 4, now_us=50.0)
    times = [job.page_done_us(i) for i in range(4)]
    assert all(b > a for a, b in zip(times, times[1:]))
    assert times[0] > job.start_us
    assert times[-1] == pytest.approx(job.done_us)


def test_staging_slot_addressing():
    st = StagingBuffer()
    p = _payload()
    st.stage((1, 0, 0), p)
    st.stage((1, 0, 1), p)
    s0, s1 = st.slot_of((1, 0, 0)), st.slot_of((1, 0, 1))
    assert s0 is not None and s1 is not None and s0 != s1
    assert st.slot_of((9, 9, 9)) is None
    # Slot survives the double-buffer swap while the entry is retained.
    st.swap()
    assert st.slot_of((1, 0, 0)) == s0
    # Consume frees the slot; invalidation frees the rest.
    st.consume((1, 0, 0))
    assert st.slot_of((1, 0, 0)) is None
    st.invalidate_seq(1)
    assert st.slot_of((1, 0, 1)) is None


# ------------------------------------------------------------ engine layer


def _engine(mode, *, window=None, max_batch=6, seed=0, **kw):
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, ServingEngine(cfg, geometry=GEO, max_batch=max_batch,
                              max_seq=96, manager_kind="mosaic", seed=seed,
                              oversubscription=2.0, fault_mode=mode,
                              decode_window_us=window, **kw)


def _requests(cfg, n=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, tenant=i % 3,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(24, 56)))
                    .astype(np.int32),
                    max_new=int(rng.integers(24, 40))) for i in range(n)]


def test_fused_tokens_identical_and_tail_only_exposed():
    """2× oversubscribed, starved 2 µs window: fused tokens byte-equal
    sync and async, exposed µs at or below async's per-page stalls, and
    pages actually ride both fused buckets (ready + drained)."""
    outs, engines = {}, {}
    for mode, window in (("sync", None), ("async", 2.0), ("fused", 2.0)):
        cfg, eng = _engine(mode, window=window)
        reqs = _requests(cfg)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=5000)
        assert all(r.done for r in reqs)
        eng.cache.check_invariants()
        outs[mode] = {r.rid: list(r.out) for r in reqs}
        engines[mode] = eng
    assert outs["fused"] == outs["sync"]
    assert outs["fused"] == outs["async"]
    f, a = engines["fused"].stats, engines["async"].stats
    assert f.faults > 0, "workload never faulted: test is vacuous"
    assert f.fault_exposed_us <= a.fault_exposed_us
    assert f.fault_exposed_us == pytest.approx(f.fused_tail_us)
    assert f.fused_ready_pages + f.fused_drained_pages > 0
    assert "fused" in f.summary()


def test_fused_zero_resident_resume_step():
    """Hold a request swapped out, churn until its pages are cold, then
    release: its first fused decode step starts with every page missing
    (all faulted in-kernel), and tokens still match the sync run."""
    outs, drained = {}, {}
    for mode, window in (("sync", None), ("fused", 2.0)):
        cfg, eng = _engine(mode, window=window, max_batch=3, seed=0)
        rng = np.random.default_rng(3)
        spec = [(64, 16), (40, 28), (40, 28)]
        reqs = [Request(rid=i, tenant=i,
                        prompt=rng.integers(0, cfg.vocab_size, T)
                        .astype(np.int32), max_new=mn)
                for i, (T, mn) in enumerate(spec)]
        for r in reqs:
            eng.submit(r)
        for _ in range(2):
            eng.step()
        assert eng.preempt(0, hold=True)
        for _ in range(6):
            eng.step()
        eng.release(0)
        eng.run_until_drained(max_steps=2000)
        assert all(r.done for r in reqs)
        eng.cache.check_invariants()
        outs[mode] = {r.rid: list(r.out) for r in reqs}
        drained[mode] = eng.stats
    assert outs["fused"] == outs["sync"]
    s = drained["fused"]
    assert s.faults > 0
    assert s.fused_ready_pages + s.fused_drained_pages > 0


def test_fused_midrun_preemption_keeps_tokens():
    """Preempt a live request mid-run under fused mode (its in-flight
    staged pages must settle without corrupting anyone) and resume:
    tokens match the sync run of the same trace."""
    outs = {}
    for mode, window in (("sync", None), ("fused", 2.0)):
        cfg, eng = _engine(mode, window=window)
        reqs = _requests(cfg, n=6, seed=4)
        for r in reqs:
            eng.submit(r)
        for _ in range(4):
            eng.step()
        victim = next(r.rid for r in reqs if not r.done)
        eng.preempt(victim)                 # straight to resume queue
        eng.run_until_drained(max_steps=5000)
        assert all(r.done for r in reqs)
        eng.cache.check_invariants()
        assert eng.host.request_pages() == 0
        outs[mode] = {r.rid: list(r.out) for r in reqs}
    assert outs["fused"] == outs["sync"]


def test_fused_rejects_mla_families():
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    with pytest.raises(ValueError, match="dense-attention"):
        ServingEngine(cfg, geometry=GEO, max_batch=2, max_seq=64,
                      manager_kind="mosaic", seed=0, fault_mode="fused")
