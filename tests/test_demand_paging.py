"""Unit tests for the demand-paging layer (link model, residency tracker,
contiguity-aware fault batching) and a regression pinning the TLB-timing
simulator and the engine-side residency accounting to the same fault cost.
"""

import numpy as np
import pytest

from repro.core.demand_paging import (
    FaultBatch,
    LinkModel,
    ResidencyTracker,
    contiguous_runs,
)


# ------------------------------------------------------------- link model


def test_link_model_arithmetic():
    link = LinkModel(setup_us=10.0, bandwidth_GBps=12.0)
    # 0 bytes: pure setup.
    assert link.transfer_us(0) == pytest.approx(10.0)
    # bandwidth term: bytes / (GB/s * 1e3) = bytes/1e3/GBps microseconds.
    assert link.transfer_us(12_000) == pytest.approx(10.0 + 1.0)
    assert link.transfer_us(120_000) == pytest.approx(10.0 + 10.0)
    # Linear in bytes beyond the fixed cost.
    a, b = link.transfer_us(4096), link.transfer_us(8192)
    assert (b - 10.0) == pytest.approx(2 * (a - 10.0))


def test_contiguous_runs():
    assert contiguous_runs([]) == []
    assert contiguous_runs([5]) == [(5, 1)]
    assert contiguous_runs([3, 4, 5]) == [(3, 3)]
    # Order-independent, duplicate-tolerant.
    assert contiguous_runs([5, 3, 4, 4]) == [(3, 3)]
    assert contiguous_runs([0, 2, 3, 7]) == [(0, 1), (2, 2), (7, 1)]


def test_fault_batch_merges_contiguous_dmas():
    link = LinkModel(setup_us=10.0, bandwidth_GBps=10.0)
    pb = 1000
    merged = FaultBatch([4, 5, 6, 7], pb, link)
    scattered = FaultBatch([0, 2, 4, 6], pb, link)
    assert merged.nbytes == scattered.nbytes == 4 * pb
    assert merged.dma_count == 1
    assert scattered.dma_count == 4
    # One setup for the merged run vs four for the scattered pages; the
    # per-byte term is identical.  This is the paper's contiguity-helps-
    # transfer claim in one assert.
    assert merged.transfer_us == pytest.approx(10.0 + 4 * pb / 10e3)
    assert scattered.transfer_us == pytest.approx(4 * (10.0 + pb / 10e3))
    assert merged.transfer_us < scattered.transfer_us


# ------------------------------------------------------- residency tracker


def make_tracker(n=64, pb=2048):
    return ResidencyTracker(n, pb, LinkModel(setup_us=5.0,
                                             bandwidth_GBps=8.0))


def test_tracker_touch_fault_evict_release_accounting():
    tr = make_tracker()
    assert tr.touch([1, 2, 3]) == [1, 2, 3]        # nothing resident yet
    batch = tr.fault_in([1, 2, 3])
    assert batch.ppns == [1, 2, 3] and batch.dma_count == 1
    assert tr.stats["faults"] == 3
    assert tr.stats["fault_batches"] == 1
    assert tr.stats["dma_transfers"] == 1
    assert tr.stats["bytes_in"] == 3 * tr.page_bytes
    assert tr.stats["transfer_us"] == pytest.approx(batch.transfer_us)
    assert tr.touch([1, 2, 3]) == []

    # Fresh pages are resident with zero transfer.
    tr.mark_resident([10])
    assert tr.touch([10]) == []
    assert tr.stats["bytes_in"] == 3 * tr.page_bytes

    # Eviction accounts outbound bytes and drops residency.
    n = tr.evict([1, 2, 10, 20])                   # 20 was never resident
    assert n == 3
    assert tr.stats["evictions"] == 3
    assert tr.stats["bytes_out"] == 3 * tr.page_bytes
    assert tr.touch([1, 2, 3]) == [1, 2]

    # Release/demote drop residency without transfer accounting.
    tr.release([3])
    before = dict(tr.stats)
    tr.demote([3])
    assert tr.stats == before
    assert tr.touch([3]) == [3]


def test_tracker_fault_in_idempotent_on_resident_pages():
    """Property-style: re-faulting any already-resident subset is free."""
    rng = np.random.default_rng(0)
    tr = make_tracker(n=128)
    universe = rng.permutation(128)[:60]
    tr.fault_in(list(universe))
    snapshot = dict(tr.stats)
    for _ in range(25):
        subset = rng.choice(universe, size=rng.integers(1, 20),
                            replace=True)
        batch = tr.fault_in(list(subset))
        assert batch.ppns == [] and batch.transfer_us == 0.0
        assert tr.stats == snapshot, "resident fault-in must be free"


def test_tracker_transfer_us_monotone_nondecreasing():
    rng = np.random.default_rng(1)
    tr = make_tracker(n=256)
    last = 0.0
    for _ in range(40):
        ppns = rng.integers(0, 256, size=rng.integers(1, 12))
        if rng.random() < 0.3:
            tr.evict(list(ppns))
        else:
            tr.fault_in(list(ppns))
        assert tr.stats["transfer_us"] >= last
        last = tr.stats["transfer_us"]


def test_on_copy_carries_residency():
    tr = make_tracker()
    tr.mark_resident([4])
    tr.on_copy(4, 9)                               # resident payload moved
    assert tr.touch([9]) == [] and tr.touch([4]) == [4]
    tr.demote([9])
    tr.on_copy(9, 12)                              # host-backed page moved
    assert tr.touch([12]) == [12]


# ----------------------------------------------- tlb_sim ↔ engine parity


def test_tlb_sim_and_residency_tracker_agree_on_fault_cost():
    """Same trace + same LinkModel + same page_bytes ⇒ the TLB-timing
    simulator's paging cycles match the engine-side residency accounting
    (converted at the shader clock) within float tolerance.

    Serialized issue (one warp, fault_amortize=1) keeps the simulator's
    bus free of queueing, which is the regime the per-page accounting
    models: each first touch pays setup + page_bytes/bandwidth.
    """
    from repro.core.tlb_sim import AppTrace, SimConfig, TranslationSim

    link = LinkModel(setup_us=10.0, bandwidth_GBps=12.0)
    cfg = SimConfig(paging=True, warm=False, fault_amortize=1,
                    warps_per_app=1, link=link, page_bytes=4096)
    rng = np.random.default_rng(2)
    # Scattered distinct pages (stride 2): every access faults one page and
    # no two pages merge into one DMA on the engine side either.
    ppn = (np.arange(48, dtype=np.int32) * 2)
    ppn = ppn[rng.permutation(len(ppn))]
    trace = AppTrace(vpn=ppn.copy(), ppn=ppn,
                     frame=ppn // 8,
                     coalesced=np.zeros(len(ppn), np.int8),
                     gap_cycles=100, name="parity")
    sim = TranslationSim(cfg, [trace])
    res = sim.run()
    assert res[0].faults == len(ppn)

    tracker = ResidencyTracker(int(ppn.max()) + 1, cfg.page_bytes, link)
    for p in ppn:                       # one touch per access, same order
        missing = tracker.touch([int(p)])
        tracker.fault_in(missing)
    assert tracker.stats["faults"] == len(ppn)

    engine_cycles = tracker.stats["transfer_us"] * cfg.clock_ghz * 1e3
    sim_cycles = sim.link.fault_cycles_total
    assert sim_cycles == pytest.approx(engine_cycles, rel=1e-6)
    # Cross-check against the closed form both sides claim to implement.
    per_fault = cfg.fault_cycles(cfg.page_bytes)
    assert sim_cycles == pytest.approx(per_fault * len(ppn), rel=1e-6)
