"""Training-substrate tests: trainer loop, checkpoint fault tolerance,
data-pipeline determinism, optimizer, gradient compression.
"""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import TrainHParams
from repro.data.pipeline import MemmapCorpus, Prefetcher, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train.grad_compress import BLOCK, pad_to_block, _quant, _dequant
from repro.train.optimizer import adamw_init, adamw_update, lr_schedule
from repro.train.trainer import Trainer


HP = TrainHParams(lr=1e-3, warmup_steps=2, total_steps=50, microbatch=0,
                  remat="none", grad_compress=False)


def make_trainer(tmp, **kw):
    cfg = get_smoke_config("qwen2.5-3b")
    mesh = make_host_mesh()
    return Trainer(cfg, kw.pop("hp", HP), mesh, batch_per_step=4,
                   seq_len=32, ckpt_dir=str(tmp), ckpt_every=kw.pop(
                       "ckpt_every", 3), **kw)


# ---------------------------------------------------------------- loop


def test_trainer_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path, ckpt_every=0)
    hist = tr.run(12, log_every=4)
    assert len(hist) >= 2
    first, last = hist[0][1], hist[-1][1]
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, f"loss did not fall: {first} -> {last}"


def test_trainer_checkpoint_restart_bit_identical(tmp_path):
    """Fault tolerance: kill after step 6, restart, final params match an
    uninterrupted 9-step run exactly (data pipeline is stateless)."""
    tr1 = make_trainer(tmp_path / "a", ckpt_every=3)
    tr1.run(9, log_every=100)
    p_full = jax.device_get(tr1.params)

    tr2 = make_trainer(tmp_path / "b", ckpt_every=3)
    tr2.run(6, log_every=100)
    del tr2
    tr3 = make_trainer(tmp_path / "b", resume=True)
    assert tr3.start_step == 6
    tr3.run(3, log_every=100)
    p_resumed = jax.device_get(tr3.params)

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_microbatch_matches_full_batch(tmp_path):
    """Gradient accumulation must not change the math (mean of micro-grads
    == full-batch grad for a mean loss)."""
    hp_full = TrainHParams(lr=1e-3, warmup_steps=1, total_steps=10,
                           microbatch=0, remat="none")
    hp_micro = TrainHParams(lr=1e-3, warmup_steps=1, total_steps=10,
                            microbatch=2, remat="none")
    t_full = make_trainer(tmp_path / "f", hp=hp_full, ckpt_every=0)
    t_micro = make_trainer(tmp_path / "m", hp=hp_micro, ckpt_every=0)
    t_full.run(2, log_every=100)
    t_micro.run(2, log_every=100)
    for a, b in zip(jax.tree.leaves(jax.device_get(t_full.params)),
                    jax.tree.leaves(jax.device_get(t_micro.params))):
        # bf16 grads differ in the last bit between the two paths; Adam's
        # normalization amplifies that near zero — tolerance is absolute.
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=5e-3)


# ---------------------------------------------------------------- ckpt


def test_checkpoint_atomicity(tmp_path):
    tree = {"w": jnp.arange(8.0), "b": {"x": jnp.ones((2, 2))}}
    d = str(tmp_path)
    ckpt.save(d, 1, tree)
    # A torn write (no _COMPLETE marker) must be invisible to readers.
    os.makedirs(os.path.join(d, "step_00000002", "arrays"))
    with open(os.path.join(d, "step_00000002", "meta.json"), "w") as f:
        f.write("{}")
    assert ckpt.latest_step(d) == 1
    restored, _ = ckpt.restore(d, 1, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(d, 1, {"w": jnp.zeros((5,))})
    with pytest.raises(ValueError, match="missing"):
        ckpt.restore(d, 1, {"other": jnp.zeros((4,))})


def test_checkpoint_prune_keeps_newest(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {"w": jnp.zeros((2,))})
    ckpt.prune(d, keep=2)
    assert ckpt.latest_step(d) == 5
    left = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(left) == 2


# ---------------------------------------------------------------- data


def test_pipeline_determinism_and_rank_disjointness():
    a = SyntheticLM(vocab=97, seq_len=16, batch_per_rank=4, seed=1, rank=0)
    b = SyntheticLM(vocab=97, seq_len=16, batch_per_rank=4, seed=1, rank=0)
    r1 = SyntheticLM(vocab=97, seq_len=16, batch_per_rank=4, seed=1, rank=1)
    np.testing.assert_array_equal(a.batch_at(7)["tokens"],
                                  b.batch_at(7)["tokens"])
    assert not np.array_equal(a.batch_at(7)["tokens"],
                              r1.batch_at(7)["tokens"])
    assert not np.array_equal(a.batch_at(7)["tokens"],
                              a.batch_at(8)["tokens"])
    assert a.batch_at(0)["tokens"].shape == (4, 16)


def test_prefetcher_order_and_restart():
    src = SyntheticLM(vocab=31, seq_len=8, batch_per_rank=2, seed=0)
    pf = Prefetcher(src, start_step=5, depth=2)
    try:
        s0, b0 = next(pf)
        s1, b1 = next(pf)
    finally:
        pf.stop()
    assert (s0, s1) == (5, 6)
    np.testing.assert_array_equal(b0["tokens"], src.batch_at(5)["tokens"])


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "toks.bin")
    np.arange(1000, dtype=np.int32).tofile(path)
    c = MemmapCorpus(path, seq_len=10, batch_per_rank=3)
    b = c.batch_at(0)["tokens"]
    assert b.shape == (3, 10)
    np.testing.assert_array_equal(b[0], np.arange(10))


# ---------------------------------------------------------------- optim


def test_adamw_descends_quadratic():
    hp = TrainHParams(lr=0.05, warmup_steps=0, total_steps=500,
                      grad_clip=10.0, weight_decay=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, m = adamw_update(params, grads, state, hp)
    assert np.abs(np.asarray(params["x"])).max() < 0.5
    assert m["grad_norm"] > 0


def test_lr_schedule_shape():
    hp = TrainHParams(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(hp, 0)) == 0.0
    assert float(lr_schedule(hp, 10)) == pytest.approx(1.0)
    assert float(lr_schedule(hp, 100)) == pytest.approx(0.1)
    assert float(lr_schedule(hp, 55)) > float(lr_schedule(hp, 90))


# ---------------------------------------------------------------- compress


def test_quantization_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4 * BLOCK,)).astype(np.float32))
    q, s = _quant(x)
    err = np.asarray(_dequant(q, s) - x)
    # |err| per element <= scale/2 = max|block|/254
    bound = np.repeat(np.asarray(s), BLOCK) / 2 + 1e-6
    assert (np.abs(err) <= bound).all()


def test_pad_to_block():
    x = jnp.ones((BLOCK + 3,))
    padded, n = pad_to_block(x)
    assert padded.shape[0] % BLOCK == 0 and n == BLOCK + 3


def test_compressed_allreduce_single_device_exact():
    """On a 1-device axis the compressed all-reduce must be exact identity
    (and error feedback zero): the wire path is skipped."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.train.grad_compress import compressed_allreduce_flat

    mesh = jax.make_mesh((1,), ("data",))
    g = jnp.asarray(np.linspace(-1, 1, BLOCK), jnp.float32)
    e = jnp.zeros_like(g)
    fn = shard_map(lambda a, b: compressed_allreduce_flat(a, b, "data"),
                   mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                   check_vma=False)
    red, err = fn(g, e)
    # 1 device: ring skipped, result = dequant(quant(g)), err = g - that.
    np.testing.assert_allclose(np.asarray(red + err), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
