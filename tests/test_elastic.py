"""Fleet-supervision tests: heartbeats, stragglers, elastic restart.

The decision engine is transport-agnostic (we drive time directly), so
these tests cover exactly the logic that must be right when a pod dies
mid-run.
"""

import numpy as np
import pytest

from repro.launch.elastic import (
    FleetDecision,
    FleetMonitor,
    elastic_restart_plan,
)
from repro.launch.mesh import make_elastic_mesh


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def make(n=8, **kw):
    clk = Clock()
    mon = FleetMonitor(n, now=clk, dead_after_s=60.0, straggle_factor=2.0,
                       straggle_patience=3, devices_per_worker=8, **kw)
    return mon, clk


def beat_all(mon, n, step, dt=1.0, skip=()):
    for i in range(n):
        if i not in skip:
            mon.heartbeat(i, step, dt)


def test_healthy_fleet_is_ok():
    mon, clk = make()
    for s in range(5):
        clk.t += 10
        beat_all(mon, 8, s)
        assert mon.assess().kind == "ok"


def test_missed_heartbeats_trigger_restart():
    mon, clk = make()
    beat_all(mon, 8, 0)
    clk.t += 61
    beat_all(mon, 8, 1, skip=(3, 5))
    d = mon.assess()
    assert d.kind == "restart"
    assert set(d.dead) == {3, 5}
    assert d.new_world_size == 6 * 8
    assert sorted(mon.alive_workers()) == [0, 1, 2, 4, 6, 7]
    # Dead workers stay dead on later assessments.
    clk.t += 1
    beat_all(mon, 8, 2, skip=(3, 5))
    assert mon.assess().kind == "ok"


def test_straggler_mitigated_then_evicted():
    mon, clk = make()
    kinds = []
    for s in range(4):
        clk.t += 5
        for i in range(8):
            mon.heartbeat(i, s, 10.0 if i == 2 else 1.0)
        d = mon.assess()
        kinds.append(d.kind)
        if d.kind == "mitigate":
            assert d.stragglers == (2,)
        if d.kind == "restart":
            assert 2 in d.dead
    # two soft strikes, then eviction; afterwards the fleet is healthy.
    assert kinds == ["mitigate", "mitigate", "restart", "ok"]


def test_straggler_strikes_reset_on_recovery():
    mon, clk = make()
    clk.t += 5
    for i in range(8):
        mon.heartbeat(i, 0, 10.0 if i == 2 else 1.0)
    assert mon.assess().kind == "mitigate"
    clk.t += 5
    beat_all(mon, 8, 1)          # worker 2 recovers
    assert mon.assess().kind == "ok"
    assert mon.workers[2].straggle_strikes == 0


@pytest.mark.parametrize("n,expect", [
    (256, ((16, 16), ("data", "model"))),
    (192, ((12, 16), ("data", "model"))),  # 192 % 16 == 0 -> model stays 16
    (100, ((25, 4), ("data", "model"))),
    (7, ((7, 1), ("data", "model"))),      # prime: pure DP
])
def test_elastic_restart_plan(n, expect):
    assert elastic_restart_plan(n) == expect


def test_elastic_mesh_matches_plan():
    mesh = make_elastic_mesh(1, model=1)
    assert mesh.shape == {"data": 1, "model": 1}
