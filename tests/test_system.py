"""System tests for the Mosaic core: allocator invariants, coalescing,
compaction, demand paging, and the kernel-facing packed views.

Property tests (hypothesis) drive random alloc/append/free/dealloc
interleavings through both managers and assert the module-documented
invariants after every operation:

  I1..I5  physical pool invariants (pagepool.check_invariants)
  I6      soft guarantee: a frame only ever holds one owner's pages
  I7      coalesced bit => vframe is full + physically contiguous + aligned
  I8      rmap is exactly the set of mapped pages
  I9      CAC plans never move a page across protection domains and the
          copy batch is hole-free from the kernel's perspective
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.baseline_mmu import BaselineMMU
from repro.core.cocoa import OutOfMemory
from repro.core.manager import MosaicManager
from repro.core.pagepool import PoolConfig
from repro.core.demand_paging import LinkModel, ResidencyTracker

FP = 4          # frame_pages (small so property tests hit edge cases fast)
PTOK = 8        # tokens per page


def make_mgr(kind="mosaic", num_pages=16 * FP, compact_threshold=0.5):
    cfg = PoolConfig(num_pages=num_pages, frame_pages=FP, page_tokens=PTOK,
                     compact_threshold=compact_threshold)
    return MosaicManager(cfg) if kind == "mosaic" else BaselineMMU(cfg)


# ---------------------------------------------------------------- property


ops_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(0, 3),
                  st.integers(1, 6 * PTOK)),
        st.tuples(st.just("append"), st.integers(0, 3), st.integers(1, 12)),
        st.tuples(st.just("free_tail"), st.integers(0, 3),
                  st.integers(1, 4)),
        st.tuples(st.just("dealloc"), st.integers(0, 3), st.just(0)),
        st.tuples(st.just("compact"), st.integers(0, 3), st.just(0)),
    ),
    min_size=1, max_size=40,
)


def _apply_ops(mgr, ops):
    """Drive a manager through an op sequence; returns #completed ops."""
    done = 0
    for op, owner, n in ops:
        try:
            if op == "alloc":
                mgr.allocate_tokens(owner, n)
            elif op == "append":
                mgr.append_tokens(owner, n)
            elif op == "free_tail":
                if owner in mgr.tables:
                    mapped = mgr.tables[owner].mapped_vpns()
                    if mapped:
                        mgr.free_pages(owner, mapped[-min(n, len(mapped)):])
            elif op == "dealloc":
                if owner in mgr.tables:
                    mgr.deallocate(owner)
            elif op == "compact":
                mgr.compact(owner)
        except OutOfMemory:
            pass  # pool pressure is a legal outcome, not a bug
        mgr.check_invariants()
        done += 1
    return done


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy)
def test_mosaic_invariants_under_random_ops(ops):
    mgr = make_mgr("mosaic")
    _apply_ops(mgr, ops)
    # Teardown returns every page: pool must drain to empty.
    for owner in list(mgr.owners()):
        mgr.deallocate(owner)
    mgr.check_invariants()
    assert mgr.pool.occupancy() == 0.0
    assert mgr.pool.num_free_frames == mgr.config.num_frames
    assert not mgr.rmap


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy)
def test_baseline_invariants_under_random_ops(ops):
    mgr = make_mgr("gpu-mmu")
    _apply_ops(mgr, ops)
    for owner in list(mgr.owners()):
        mgr.deallocate(owner)
    mgr.check_invariants()
    assert mgr.pool.occupancy() == 0.0


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy)
def test_cac_plans_stay_in_domain_and_disjoint(ops):
    """I9: every CAC copy batch has src∩dst=∅ and stays within one owner.

    Disjointness is what lets the page_compact kernel execute the whole
    batch as one launch with no ordering hazards (see kernels/page_compact).
    """
    mgr = make_mgr("mosaic", num_pages=8 * FP)
    for op, owner, n in ops:
        try:
            if op == "alloc":
                mgr.allocate_tokens(owner, n)
            elif op == "append":
                mgr.append_tokens(owner, n)
            elif op == "free_tail" and owner in mgr.tables:
                mapped = mgr.tables[owner].mapped_vpns()
                if mapped:
                    mgr.free_pages(owner, mapped[-min(n, len(mapped)):])
            elif op == "dealloc" and owner in mgr.tables:
                mgr.deallocate(owner)
            elif op == "compact":
                mgr.compact(owner)
        except OutOfMemory:
            pass
        batch = mgr.drain_copy_ops()
        srcs = [c.src_ppn for c in batch]
        dsts = [c.dst_ppn for c in batch]
        assert len(set(srcs)) == len(srcs), "page copied out twice"
        assert len(set(dsts)) == len(dsts), "two copies into one slot"
        assert not set(srcs) & set(dsts), "chained copy in one batch"
        mgr.check_invariants()


# ---------------------------------------------------------------- CoCoA


def test_en_masse_allocation_coalesces_immediately():
    """Paper's key observation: en-masse allocation => whole frames =>
    immediate zero-copy coalescing (steps 5-6 of Fig. 4)."""
    mgr = make_mgr()
    mgr.allocate_tokens(0, 3 * FP * PTOK)   # exactly 3 frames of tokens
    t = mgr.table(0)
    assert t.num_pages == 3 * FP
    assert all(t.coalesced[:3])
    assert mgr.pool.coalesced_fraction() == 1.0
    assert mgr.pool.stats["coalesce_ops"] == 3
    # and the migrations required for it: zero.
    assert mgr.pool.stats["compaction_copies"] == 0


def test_soft_guarantee_across_owners():
    mgr = make_mgr()
    for owner in range(4):
        mgr.allocate_tokens(owner, int(2.5 * FP * PTOK))
    pool = mgr.pool
    for owner, table in mgr.tables.items():
        frames = {pool.frame_of(p) for p in table.ppn if p >= 0}
        for f in frames:
            assert pool.frame_owner[f] == owner


def test_append_growth_coalesces_at_frame_boundary():
    """Decode growth fills the active frame slot-by-slot; the frame is
    promoted exactly when its last slot fills (in-place, no copies)."""
    mgr = make_mgr()
    for _ in range((FP - 1) * PTOK):          # fills pages 0..FP-2
        mgr.append_tokens(0, 1)
    assert not mgr.table(0).coalesced[0]
    mgr.append_tokens(0, 1)   # first token of the frame's last page
    assert mgr.table(0).coalesced[0]
    assert mgr.pool.stats["compaction_copies"] == 0


def test_baseline_interleaving_denies_coalescing():
    """Fig. 2: round-robin en-masse allocation through the frame-blind
    baseline interleaves owners within frames -> ~no coalescing chances."""
    mosaic, base = make_mgr("mosaic"), make_mgr("gpu-mmu")
    # Interleave odd-sized buffers (not frame multiples) across 3 owners.
    for rep in range(3):
        for owner in range(3):
            for m in (mosaic, base):
                m.allocate_tokens(owner, 3 * PTOK + owner)
    assert base.multi_owner_frames() > 0
    assert base.coalesce_opportunities == 0
    # Mosaic, same workload: most pages sit in coalesced frames.
    assert mosaic.pool.coalesced_fraction() > 0.5
    packed = mosaic.pack(mosaic.owners(), max_pages=4 * FP)
    assert (packed["coalesced"] == 1).any()


def test_oom_triggers_compaction_then_succeeds():
    """Paper steps 9-10: compaction frees frames for future allocations."""
    mgr = make_mgr(num_pages=4 * FP, compact_threshold=0.4)
    # Two owners, each holding a sliver of two frames (fragmented).
    for owner in (0, 1):
        mgr.allocate_tokens(owner, FP * PTOK + PTOK)    # frame + 1 page
    for owner in (0, 1):
        mapped = mgr.tables[owner].mapped_vpns()
        mgr.free_pages(owner, mapped[1:FP])             # fragment frame 0
    # All 4 frames are owned; a 1-frame en-masse alloc must compact first.
    vpns = mgr.allocate_tokens(2, FP * PTOK)
    assert len(vpns) == FP
    mgr.check_invariants()


# ---------------------------------------------------------------- CAC + kernel


def test_compaction_preserves_payload_through_kernel():
    """End-to-end CAC: plan on host, execute with the page_compact kernel,
    then verify every owner's virtual view of the data is unchanged."""
    import jax.numpy as jnp
    from repro.kernels.page_compact import page_compact

    mgr = make_mgr(num_pages=8 * FP, compact_threshold=0.4)
    rng = np.random.default_rng(3)
    pool_arr = rng.normal(size=(8 * FP, PTOK, 2, 4)).astype(np.float32)

    mgr.allocate_tokens(0, 4 * FP * PTOK)
    # Virtual content: page payload == pool content at its ppn at t0.
    view0 = {v: pool_arr[p].copy()
             for v, p in enumerate(mgr.table(0).ppn)}
    # Fragment: free most of vframes 1 and 2.
    dropped = list(range(FP + 1, 3 * FP - 1))
    mgr.free_pages(0, dropped)
    for v in dropped:
        del view0[v]
    plan_ops = mgr.drain_copy_ops()
    if not plan_ops:   # fragmentation below threshold — force it
        mgr.compact(0)
        plan_ops = mgr.drain_copy_ops()
    assert plan_ops, "expected a compaction plan"
    src = jnp.asarray([c.src_ppn for c in plan_ops], jnp.int32)
    dst = jnp.asarray([c.dst_ppn for c in plan_ops], jnp.int32)
    out = np.asarray(page_compact(jnp.asarray(pool_arr), src, dst))
    # The virtual view through the updated table must be unchanged.
    t = mgr.table(0)
    for v, payload in view0.items():
        np.testing.assert_array_equal(out[t.ppn[v]], payload)
    mgr.check_invariants()


def test_compaction_frees_frames():
    mgr = make_mgr(num_pages=6 * FP, compact_threshold=0.4)
    mgr.allocate_tokens(0, 4 * FP * PTOK)
    free_before = mgr.pool.num_free_frames
    # Leave one live page in each of vframes 0..3 -> 4 fragmented frames.
    drop = [v for v in range(4 * FP) if v % FP != 0]
    mgr.free_pages(0, drop)
    assert mgr.pool.num_free_frames >= free_before + 3


# ---------------------------------------------------------------- packing


def test_pack_batch_tables_layout():
    mgr = make_mgr()
    mgr.allocate_tokens(0, FP * PTOK)        # coalesced frame
    mgr.allocate_tokens(1, 2 * PTOK)         # partial frame (splintered)
    packed = mgr.pack([0, 1], max_pages=2 * FP)
    assert packed["page_tables"].shape == (2, 2 * FP)
    assert packed["frame_tables"].shape == (2, 2)
    assert packed["coalesced"][0, 0] == 1
    assert packed["coalesced"][1, 0] == 0
    assert packed["seq_pages"][0] == FP
    assert packed["seq_pages"][1] == 2
    assert packed["seq_tokens"][0] == FP * PTOK
    # Frame table entry must point at the physical frame of the vframe.
    pf = packed["frame_tables"][0, 0]
    assert pf >= 0
    base = mgr.table(0).ppn[0]
    assert pf == base // FP


# ---------------------------------------------------------------- paging


def test_residency_tracker_accounting():
    link = LinkModel(setup_us=10.0, bandwidth_GBps=10.0)
    tr = ResidencyTracker(num_pages=64, page_bytes=4096, link=link)
    batch = tr.fault_in([1, 2, 3])
    assert batch.nbytes == 3 * 4096
    assert tr.stats["faults"] == 3 and tr.stats["fault_batches"] == 1
    # Second touch: resident, no fault.
    batch = tr.fault_in([1, 2, 3])
    assert not batch.ppns and tr.stats["faults"] == 3
    assert tr.touch([3, 4]) == [4]
    assert tr.evict([2]) == 1
    assert tr.touch([2]) == [2]
    # transfer model: setup + bytes/bw
    assert link.transfer_us(10_000) == pytest.approx(10.0 + 1.0)


def test_memory_bloat_metric():
    """Large-page-only designs bloat; filling the frame removes the bloat."""
    mgr = make_mgr(num_pages=16 * FP)
    mgr.allocate_tokens(0, 1)                 # 1 page in a FP-page frame
    assert mgr.pool.memory_bloat() == FP      # worst case: whole frame held
    mgr.allocate_tokens(0, (FP - 1) * PTOK)   # fill the frame
    assert mgr.pool.memory_bloat() == 1.0
