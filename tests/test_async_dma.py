"""Async double-buffered fault-in pipeline (DESIGN.md §7).

Covers the DMA timeline invariants (hidden + exposed == total transfer
µs; no job completes before it starts), the double-buffer ownership
rules, prefetch hit/miss accounting, async-vs-sync token identity on 2×
oversubscribed runs under both managers, cost-aware victim selection,
and the shared-link contention model in the TLB simulator.
"""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.core.demand_paging import LinkModel
from repro.serving.dma import AsyncDMAEngine, Prefetcher, StagingBuffer
from repro.serving.engine import EngineStats, Request, ServingEngine

GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)


def _payload():
    return (np.zeros((1, 8, 1, 4), np.float32),
            np.zeros((1, 8, 1, 4), np.float32))


# ------------------------------------------------------------ DMA timeline


def test_dma_job_timeline_basics():
    link = LinkModel(setup_us=10.0, bandwidth_GBps=10.0)
    dma = AsyncDMAEngine(link, n_channels=1)
    job = dma.enqueue([(0, 0, 0), (0, 0, 1)], [4, 5], 1000,
                      [_payload(), _payload()], now_us=100.0)
    # One contiguous run: one DMA descriptor, start at enqueue time.
    assert job.dma_count == 1
    assert job.start_us == 100.0
    assert job.done_us == pytest.approx(100.0 + job.transfer_us)
    assert job.done_us >= job.start_us          # never completes early

    # A second job on the same (busy) channel queues behind the first.
    job2 = dma.enqueue([(1, 0, 0)], [9], 1000, [_payload()], now_us=100.0)
    assert job2.start_us == pytest.approx(job.done_us)

    # Waiting at a later time: only the remainder is exposed.
    mid = job.start_us + job.transfer_us / 2
    dma.wait(job, mid)
    assert dma.stats["exposed_us"] == pytest.approx(job.transfer_us / 2)
    assert dma.stats["hidden_us"] == pytest.approx(job.transfer_us / 2)
    # Waiting on the queued job pays its queueing delay separately.
    dma.wait(job2, mid)
    assert dma.stats["queue_us"] > 0.0


def test_dma_timeline_invariants_random():
    """Property-style: random enqueue/wait/drain interleavings keep
    hidden + exposed == Σ transfer µs over settled jobs, and every job's
    completion is ≥ its start ≥ its enqueue time."""
    rng = np.random.default_rng(0)
    link = LinkModel(setup_us=5.0, bandwidth_GBps=8.0)
    dma = AsyncDMAEngine(link, n_channels=2)
    now = 0.0
    settled_transfer = 0.0
    jobs = []
    for i in range(60):
        now += float(rng.uniform(0, 30))
        n = int(rng.integers(1, 6))
        ppns = sorted(rng.choice(100, size=n, replace=False).tolist())
        job = dma.enqueue([(i, 0, v) for v in range(n)], ppns, 2048,
                          [_payload()] * n, now)
        assert job.start_us >= now
        assert job.done_us == pytest.approx(job.start_us + job.transfer_us)
        jobs.append(job)
        act = rng.random()
        if act < 0.4 and jobs:
            j = jobs.pop(int(rng.integers(len(jobs))))
            if not j.settled:
                settled_transfer += j.transfer_us
            now = dma.wait(j, now)
            assert now >= j.done_us - 1e-9
        elif act < 0.7:
            for j in dma.drain(now):
                jobs.remove(j)
                settled_transfer += j.transfer_us
    # Settle everything left in flight.
    for j in dma.drain(float("inf")):
        settled_transfer += j.transfer_us
    assert dma.stats["hidden_us"] + dma.stats["exposed_us"] == \
        pytest.approx(settled_transfer)
    assert dma.stats["queue_us"] >= 0.0
    assert not dma.in_flight


# ------------------------------------------------------------ staging


def test_staging_double_buffer_ownership():
    st = StagingBuffer()
    p = _payload()
    st.stage((0, 0, 0), p)
    # Back-buffer entries are invisible to the consumer until swap.
    assert not st.has((0, 0, 0))
    assert st.contains((0, 0, 0))               # but dedup sees them
    assert st.consume((0, 0, 0)) is None
    st.swap()
    assert st.has((0, 0, 0))
    assert st.consume((0, 0, 0)) is p
    assert st.consume((0, 0, 0)) is None        # consumed exactly once

    # Unconsumed front entries are retained across swaps.
    st.stage((1, 0, 0), p)
    st.swap()
    st.swap()
    assert st.has((1, 0, 0))

    # Invalidation drops a sequence's pages from both buffers.
    st.stage((2, 0, 0), p)
    assert st.invalidate_seq(2) == 1
    assert st.invalidate_seq(1) == 1
    assert len(st) == 0


# --------------------------------------------------- engine: async vs sync


def _oversub_engine(kind, mode, factor=2.0, **kw):
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, ServingEngine(cfg, geometry=GEO, max_batch=6, max_seq=96,
                              manager_kind=kind, seed=0,
                              oversubscription=factor, fault_mode=mode,
                              **kw)


def _oversub_requests(cfg, n=10, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, tenant=i % 3,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(24, 56)))
                    .astype(np.int32),
                    max_new=int(rng.integers(24, 40))) for i in range(n)]


def test_async_token_identical_to_sync_under_both_managers():
    """2× oversubscribed: the async pipeline must produce byte-identical
    greedy tokens to the blocking path, under both managers."""
    for kind in ("mosaic", "gpu-mmu"):
        outs = {}
        for mode in ("sync", "async"):
            cfg, eng = _oversub_engine(kind, mode)
            reqs = _oversub_requests(cfg)
            for r in reqs:
                eng.submit(r)
            eng.run_until_drained(max_steps=5000)
            assert all(r.done for r in reqs)
            eng.cache.check_invariants()
            # Request-owned pages all consumed/dropped; cached prefix
            # pages (negative owners) deliberately persist (DESIGN.md §8).
            assert eng.host.request_pages() == 0
            outs[mode] = {r.rid: list(r.out) for r in reqs}
        assert outs["sync"] == outs["async"], kind


def test_async_prefetch_hit_miss_accounting():
    """Every fault is either a prefetch hit or a demand miss; the sync
    run exposes its full transfer µs while the async run's exposed and
    hidden split covers exactly the transfers it settled."""
    stats = {}
    for mode in ("sync", "async"):
        cfg, eng = _oversub_engine("mosaic", mode)
        reqs = _oversub_requests(cfg)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=5000)
        assert all(r.done for r in reqs)
        stats[mode] = eng
    s, a = stats["sync"].stats, stats["async"].stats
    assert s.faults > 0, "workload never faulted: test is vacuous"
    # Sync: everything exposed, nothing hidden, no prefetch machinery.
    assert s.fault_exposed_us == pytest.approx(s.transfer_us)
    assert s.fault_hidden_us == 0.0 and s.prefetch_hits == 0
    # Async: hit/miss partition of the faulted pages.
    assert a.faults == a.prefetch_hits + a.prefetch_misses
    assert a.prefetch_hits > 0, "prefetcher never hit"
    # Timeline invariant over the settled jobs (settle leftovers first).
    dma = stats["async"].dma
    dma.drain(float("inf"))
    assert dma.stats["hidden_us"] + dma.stats["exposed_us"] == \
        pytest.approx(dma.stats["transfer_us"])
    assert a.fault_hidden_us == pytest.approx(dma.stats["hidden_us"])


def test_async_resume_prefetch_hides_transfer():
    """A predictable preempt→resume cycle: r0 is too big to re-fit until
    a peer completes, so it waits in the resume queue for many steps —
    the prefetcher stages its pages while the others decode, the
    eventual resume faults are all hits, and their transfer µs land
    entirely in the hidden bucket."""
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=3, max_seq=96,
                        manager_kind="mosaic", seed=0,
                        oversubscription=2.0, fault_mode="async")
    rng = np.random.default_rng(3)
    spec = [(64, 16), (40, 28), (40, 28)]
    reqs = [Request(rid=i, tenant=i,
                    prompt=rng.integers(0, cfg.vocab_size, T)
                    .astype(np.int32), max_new=mn)
            for i, (T, mn) in enumerate(spec)]
    for r in reqs:
        eng.submit(r)
    for _ in range(2):
        eng.step()
    assert eng.preempt(0)               # victim parks in the resume queue
    # While preempted, its pages ride the DMA channels behind decode.
    waited = 0
    for _ in range(40):
        eng.step()
        if reqs[0] in eng.active:
            break
        waited += 1
    assert waited > 0, "resume window collapsed: test is vacuous"
    eng.run_until_drained(max_steps=300)
    assert all(r.done for r in reqs)
    assert eng.stats.prefetch_hits > 0
    assert eng.stats.fault_hidden_us > 0.0
    assert eng.stats.prefetch_misses == 0
    assert eng.stats.fault_exposed_us == pytest.approx(0.0)


def test_async_partial_overlap_with_tight_decode_window():
    """A deliberately tiny modeled decode window (2 µs vs ~10 µs
    transfers) starves the overlap: some transfer µs stay exposed, some
    are hidden, tokens are still byte-identical — the partial-wait path
    (stall only for the in-flight remainder), exercised deterministically
    instead of depending on CPU wall time."""
    outs, stats = {}, {}
    for label, mode, window in (("sync", "sync", None),
                                ("tight", "async", 2.0)):
        cfg, eng = _oversub_engine("mosaic", mode, decode_window_us=window)
        reqs = _oversub_requests(cfg, n=12)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=5000)
        assert all(r.done for r in reqs)
        outs[label] = {r.rid: list(r.out) for r in reqs}
        stats[label] = eng.stats
    assert outs["sync"] == outs["tight"]
    t = stats["tight"]
    assert t.prefetch_hits > 0
    assert 0.0 < t.fault_exposed_us < stats["sync"].fault_exposed_us
    assert t.fault_hidden_us > 0.0


# ------------------------------------------------- cost-aware victim pick


def _victim_workload(policy):
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=3, max_seq=128,
                        manager_kind="mosaic", seed=0,
                        victim_policy=policy)
    rng = np.random.default_rng(1)
    # r0 is old, small and nearly done; r1/r2 are big and long-running.
    spec = [(8, 6), (48, 30), (48, 30)]
    reqs = [Request(rid=i, tenant=i,
                    prompt=rng.integers(0, cfg.vocab_size, T)
                    .astype(np.int32), max_new=mn)
            for i, (T, mn) in enumerate(spec)]
    for r in reqs:
        eng.submit(r)
    for _ in range(2):
        eng.step()
    return cfg, eng, reqs, rng


def test_cost_aware_victim_beats_priority_only_on_swap_cycle():
    """Batch-slot displacement by a premium arrival (one forced swap
    cycle): lowest-priority-only evicts the *youngest* request — a big
    long-running one — while the cost score picks the small nearly-done
    one, moving strictly fewer pages out and back in."""
    traffic = {}
    for policy in ("priority", "cost"):
        cfg, eng, reqs, rng = _victim_workload(policy)
        victim = eng._pick_victim()
        if policy == "priority":
            assert victim.rid == 2      # youngest, but big
        else:
            assert victim.rid == 0      # cheapest: small × nearly-done
            scores = {r.rid: eng._victim_score(r) for r in eng.active}
            assert scores[0] < scores[1] and scores[0] < scores[2]
        hi = Request(rid=99, tenant=3, priority=5,
                     prompt=rng.integers(0, cfg.vocab_size, 16)
                     .astype(np.int32), max_new=6)
        eng.submit(hi)
        eng.run_until_drained(max_steps=500)
        assert all(r.done for r in reqs + [hi])
        eng.cache.check_invariants()
        st = eng.cache.stats()
        assert eng.stats.swaps_out >= 1, policy
        traffic[policy] = int(st["bytes_out"])
    assert traffic["cost"] < traffic["priority"], traffic


# ------------------------------------------------------------- sim link


def test_sim_link_channels_cut_cross_app_contention():
    from repro.core.tlb_sim import AppTrace, SimConfig, TranslationSim

    def traces(n_apps):
        out = []
        for a in range(n_apps):
            ppn = np.arange(64, dtype=np.int32) * 2 + a * 1000
            out.append(AppTrace(vpn=ppn.copy(), ppn=ppn, frame=ppn // 8,
                                coalesced=np.zeros(len(ppn), np.int8),
                                gap_cycles=50, name=f"app{a}"))
        return out

    cont = {}
    for ch in (1, 4):
        sim = TranslationSim(
            SimConfig(paging=True, fault_amortize=1, dma_channels=ch),
            traces(3))
        sim.run()
        assert len(sim.link.contention_cycles) == 3
        cont[ch] = sim.link.contention_total()
    assert cont[1] > 0.0
    assert cont[4] < cont[1]

    # Single-channel, single-app serialized issue: no contention at all
    # (seed parity: the Fig. 7 cost model is unchanged by the channels).
    sim = TranslationSim(
        SimConfig(paging=True, fault_amortize=1, warps_per_app=1,
                  dma_channels=1), traces(1))
    sim.run()
    assert sim.link.contention_total() == pytest.approx(0.0)


# ------------------------------------------------------------- stats


def test_engine_stats_guard_and_summary():
    s = EngineStats()
    assert s.tok_per_s() == 0.0         # zero wall_s must not explode
    s.prefill_tokens, s.decode_tokens, s.wall_s = 10, 30, 2.0
    assert s.tok_per_s() == pytest.approx(20.0)
    s.fault_exposed_us, s.fault_hidden_us = 12.5, 37.5
    line = s.summary()
    assert "hidden" in line and "exposed" in line and "38us" in line
