"""Per-architecture smoke tests (deliverable f).

For every assigned arch: instantiate the REDUCED same-family config, run a
train step (loss finite, grads finite) and a prefill + paged-decode step
(shapes correct, no NaNs).  For families with an exact dense reference
(dense/vlm/moe/mla/ssm/hybrid/encdec), decode-after-prefill is additionally
checked against a full forward over the concatenated sequence — this is the
end-to-end correctness proof that the Mosaic paged path preserves model
semantics.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import LM
from repro.models.common import cast

from conftest import ctx_at_position, toy_page_ctx

ARCHS = list_archs()
B, T = 2, 64
PTOK = 8          # page_tokens
MPPS = 16         # max pages per sequence (single shard)


def make_batch(cfg, key):
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            key, (B, cfg.encdec.source_len, cfg.d_model), jnp.float32)
    return batch


def full_forward_last_logits(lm, params, batch, extra_tokens):
    """Reference: run loss-path backbone over concatenated tokens."""
    cfg = lm.cfg
    tokens = jnp.concatenate([batch["tokens"], extra_tokens], axis=1)
    b2 = dict(batch, tokens=tokens)
    # Reuse the training forward to get last-position logits.
    params = cast(params, jnp.dtype(cfg.dtype))
    x = lm._embed(params, tokens)
    n_prefix = 0
    if cfg.family == "vlm":
        pe = b2["patch_embeds"].astype(x.dtype)
        pe = jnp.einsum("bpd,de->bpe", pe,
                        params["frontend_proj"].astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = pe.shape[1]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (x.shape[0], x.shape[1]))
    if cfg.family == "encdec":
        from repro.models import encdec as ed
        src = b2["src_embeds"].astype(x.dtype)
        src = jnp.einsum("bsd,de->bse", src,
                         params["frontend_proj"].astype(x.dtype))
        memory = ed.encoder_apply(cfg, params, src, remat=False)
        x = ed.decoder_stack_train(cfg, params, x, positions, memory,
                                   remat=False)
    else:
        x, _ = lm._backbone_train(params, x, positions, remat=False)
    return lm._logits(params, x[:, -1:, :])[:, 0]


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    batch = make_batch(cfg, key)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss(p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert loss > 0
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn), f"{arch}: grads not finite"
    assert gn > 0, f"{arch}: zero grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = lm.init(key)
    batch = make_batch(cfg, key)

    # VLM prefill prepends the patch-embed prefix: the paged KV holds
    # n_prefix + T tokens and decode positions are offset by n_prefix.
    n_prefix = cfg.frontend_tokens if cfg.family == "vlm" else 0

    shapes = lm.pool_shapes(B * MPPS, PTOK)
    pools = (tuple(jnp.zeros(s.shape, s.dtype) for s in shapes)
             if shapes else None)
    ctx, _ = toy_page_ctx(B, n_prefix + T, PTOK, MPPS)
    logits_p, pools, state = lm.prefill(params, batch, pools, ctx)
    assert logits_p.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits_p.astype(jnp.float32)).all()

    # Greedy-decode two tokens and compare each against the full forward.
    new = jax.random.randint(jax.random.PRNGKey(2), (B, 2), 0,
                             cfg.vocab_size)
    logits_d = None
    for i in range(2):
        pos = jnp.full((B,), n_prefix + T + i, jnp.int32)
        ctx_i = ctx_at_position(B, MPPS, PTOK, n_prefix + T + i)
        logits_d, pools, state = lm.decode_step(
            params, new[:, i], pos, pools, ctx_i, state)
        assert jnp.isfinite(logits_d.astype(jnp.float32)).all()

    ref = full_forward_last_logits(lm, params, batch, new)
    err = jnp.max(jnp.abs(logits_d.astype(jnp.float32)
                          - ref.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(ref.astype(jnp.float32))) + 1e-6
    assert err / scale < 0.05, f"{arch}: decode/full mismatch {err} vs {scale}"
