"""Pallas kernel sweeps: shapes × dtypes vs the pure-JNP oracles.

All kernels run in interpret=True mode on CPU (the kernel body executes in
Python); the same code path compiles for TPU with interpret=False.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.page_compact import page_compact
from repro.kernels.paged_attention import (
    combine_granularities,
    paged_attention_kernel,
)

RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- flash


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,H,Hkv,dh,bq,bk",
    [
        (2, 256, 8, 4, 64, 64, 128),
        (1, 512, 4, 4, 32, 128, 256),
        (2, 128, 8, 2, 16, 64, 64),
        (1, 256, 16, 8, 128, 128, 128),  # MXU-aligned head dim
        (3, 192, 6, 3, 48, 64, 192),     # odd-ish shapes
    ],
)
def test_flash_attention_sweep(B, T, H, Hkv, dh, bq, bk, dtype):
    q = jnp.asarray(RNG.normal(size=(B, T, H, dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, T, Hkv, dh)), dtype)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **_tol(dtype))


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- paged


def _random_tables(B, n_frames_pool, fp, ptok, seq_lens, coalesce_frac,
                   max_frames, max_pages):
    """Random Mosaic-style layout: some frames coalesced, rest splintered."""
    frame_tables = np.full((B, max_frames), -1, np.int32)
    frame_ntok = np.zeros((B, max_frames), np.int32)
    page_tables = np.full((B, max_pages), -1, np.int32)
    page_ntok = np.zeros((B, max_pages), np.int32)
    free_frames = list(RNG.permutation(n_frames_pool))
    for b in range(B):
        toks = seq_lens[b]
        vframes = (toks + fp * ptok - 1) // (fp * ptok)
        fi = pi = 0
        for vf in range(vframes):
            ft = min(fp * ptok, toks - vf * fp * ptok)
            frame = free_frames.pop()
            if RNG.random() < coalesce_frac and ft == fp * ptok:
                frame_tables[b, fi] = frame
                frame_ntok[b, fi] = ft
                fi += 1
            else:
                for s in range(fp):
                    pt = min(ptok, ft - s * ptok)
                    if pt <= 0:
                        break
                    page_tables[b, pi] = frame * fp + s
                    page_ntok[b, pi] = pt
                    pi += 1
    return (jnp.asarray(frame_tables), jnp.asarray(frame_ntok),
            jnp.asarray(page_tables), jnp.asarray(page_ntok))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,n_kv,dh,dhv,ptok,fp,coalesce",
    [
        (2, 8, 4, 32, 32, 16, 4, 0.7),
        (2, 8, 8, 64, 64, 8, 4, 1.0),    # MHA, all coalesced
        (1, 16, 1, 40, 32, 16, 4, 0.5),  # MLA-like: n_kv=1, dh_v != dh
        (4, 4, 2, 128, 128, 32, 2, 0.0), # nothing coalesced (baseline)
    ],
)
def test_paged_attention_dual_sweep(B, H, n_kv, dh, dhv, ptok, fp,
                                    coalesce, dtype):
    n_frames_pool = 16
    NP = n_frames_pool * fp
    pool_k = jnp.asarray(RNG.normal(size=(NP, ptok, n_kv, dh)), dtype)
    pool_v = jnp.asarray(RNG.normal(size=(NP, ptok, n_kv, dhv)), dtype)
    q = jnp.asarray(RNG.normal(size=(B, H, dh)), dtype)
    seq_lens = RNG.integers(1, 3 * fp * ptok, size=B)
    ft, fn, pt, pn = _random_tables(B, n_frames_pool, fp, ptok, seq_lens,
                                    coalesce, max_frames=4,
                                    max_pages=4 * fp)
    scale = dh ** -0.5
    parts = [
        paged_attention_kernel(q, pool_k, pool_v, ft, fn,
                               granularity="frame", frame_pages=fp,
                               scale=scale),
        paged_attention_kernel(q, pool_k, pool_v, pt, pn,
                               granularity="page", scale=scale),
    ]
    o, m, l = combine_granularities(parts)
    out = o / np.maximum(np.asarray(l)[..., None], 1e-30)

    # Oracle over the union of pages.
    fp_pages = (np.asarray(ft)[..., None] * fp + np.arange(fp)).reshape(B, -1)
    fp_pages = np.where(np.repeat(np.asarray(ft), fp, axis=1) >= 0,
                        fp_pages, -1)
    fp_ntok = np.clip(np.repeat(np.asarray(fn), fp, axis=1)
                      - np.tile(np.arange(fp) * ptok, ft.shape[1]), 0, ptok)
    all_t = jnp.asarray(np.concatenate([fp_pages, np.asarray(pt)], axis=1))
    all_n = jnp.asarray(np.concatenate([fp_ntok, np.asarray(pn)], axis=1))
    expect = ref.paged_attention_full_ref(q, pool_k, pool_v, all_t, all_n,
                                          scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


# --------------------------------------------------------------- compact


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("NP,ptok,kv,dh,n", [(32, 8, 2, 16, 4),
                                             (64, 16, 1, 40, 9),
                                             (16, 4, 4, 8, 1)])
def test_page_compact_sweep(NP, ptok, kv, dh, n, dtype):
    pool = jnp.asarray(RNG.normal(size=(NP, ptok, kv, dh)), dtype)
    perm = RNG.permutation(NP)
    src = perm[:n].astype(np.int32)
    dst = perm[n:2 * n].astype(np.int32)
    src[n // 2] = -1
    dst[n // 2] = -1
    out = page_compact(pool, jnp.asarray(src), jnp.asarray(dst))
    expect = ref.page_compact_ref(pool, jnp.asarray(src), jnp.asarray(dst))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


# --------------------------------------------------------------- ssd scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,nh,hd,N,Q,with_h0",
    [
        (2, 128, 3, 16, 32, 32, False),
        (1, 256, 2, 64, 128, 128, True),   # MXU-aligned dims
        (2, 64, 4, 8, 16, 16, True),
        (1, 96, 1, 32, 64, 32, False),     # nc=3, single head
    ],
)
def test_ssd_scan_sweep(B, T, nh, hd, N, Q, with_h0, dtype):
    from repro.kernels.ssd_scan import ssd_scan

    x = jnp.asarray(RNG.normal(size=(B, T, nh, hd)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, T, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(nh,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, T, nh, N)), dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, T, nh, N)), dtype)
    h0 = (jnp.asarray(RNG.normal(size=(B, nh, hd, N)), jnp.float32)
          if with_h0 else None)
    y_k, h_k = ssd_scan(x, dt, A, Bm, Cm, chunk=Q, h0=h0)
    y_r, h_r = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=Q, h0=h0)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **tol)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), **tol)
