"""Content-hash prefix cache + full-duplex DMA + SLO resume (DESIGN.md §8).

Covers the chained-hash PrefixIndex (match/dedup/prefix-closed LRU
eviction), the HostPageStore.drop_seq ↔ cached-prefix interaction, the
bitwise equivalence of suffix-only prefill with full prefill, engine-level
byte-identity with the cache on vs off (both fault modes), the duplex
per-direction timeline invariants, SLO deadline-weighted resume ordering
driving the prefetch depth, and the EngineStats.summary() counters.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.core.demand_paging import LinkModel
from repro.models.lm import LM
from repro.models.transformer import PageCtx
from repro.serving.dma import AsyncDMAEngine, Prefetcher
from repro.serving.engine import EngineStats, Request, ServingEngine
from repro.serving.host_tier import HostPageStore, PrefixIndex

GEO = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)
PTOK = GEO.page_tokens


def _payload(tag: float = 0.0):
    return (np.full((1, PTOK, 1, 4), tag, np.float32),
            np.full((1, PTOK, 1, 4), -tag, np.float32))


# ------------------------------------------------------------ PrefixIndex


def test_chain_hashes_prefix_property():
    idx = PrefixIndex(HostPageStore(), PTOK)
    a = np.arange(4 * PTOK, dtype=np.int32)
    b = a.copy()
    b[2 * PTOK] += 1                        # diverge in page 2
    ha, hb = idx.chain_hashes(a), idx.chain_hashes(b)
    assert len(ha) == 4
    assert ha[:2] == hb[:2]                 # shared prefix, shared hashes
    assert ha[2] != hb[2]
    assert ha[3] != hb[3]                   # chained: divergence propagates
    # Partial tail pages never hash.
    assert len(idx.chain_hashes(a[:3 * PTOK + 2])) == 3


def test_index_match_and_dedup():
    store = HostPageStore()
    idx = PrefixIndex(store, PTOK)
    toks = np.arange(3 * PTOK, dtype=np.int32)
    hs = idx.chain_hashes(toks)
    parent = None
    for i, h in enumerate(hs):
        idx.park(h, parent, i, 0, i, *_payload(i))
        parent = h
    assert len(idx) == 3
    n, pages = idx.match(toks)
    assert n == 3 and [p.page_index for p in pages] == [0, 1, 2]
    # A prompt diverging after page 1 matches exactly 2 pages.
    div = toks.copy()
    div[2 * PTOK] += 7
    n, _ = idx.match(div)
    assert n == 2
    # Re-parking an existing chain is a no-op (dedup by content hash).
    assert idx.missing_from(hs) == 3
    before = store.stats["cached_pages"]
    idx.park(hs[0], None, 0, 0, 0, *_payload())
    assert len(idx) == 3 and store.stats["cached_pages"] == before


def test_index_lru_eviction_is_prefix_closed():
    store = HostPageStore()
    idx = PrefixIndex(store, PTOK, capacity_pages=4)
    a = np.arange(2 * PTOK, dtype=np.int32)
    b = 1000 + np.arange(2 * PTOK, dtype=np.int32)
    for toks in (a, b):
        hs = idx.chain_hashes(toks)
        parent = None
        for i, h in enumerate(hs):
            idx.park(h, parent, i, 0, i, *_payload())
            parent = h
    assert len(idx) == 4
    idx.match(b)                            # a is now the LRU chain
    c = 2000 + np.arange(2 * PTOK, dtype=np.int32)
    hc = idx.chain_hashes(c)
    idx.park(hc[0], None, 0, 0, 0, *_payload())
    # Chain a lost (at least) its tail; chain b is untouched; the index
    # stays prefix-closed: every cached page's parent is cached too.
    assert idx.match(b)[0] == 2
    for page in idx._pages.values():
        assert page.parent is None or page.parent in idx._pages
    assert len(idx) <= 4
    # Evicted payloads left the store.
    assert store.stats["cached_pages"] - idx.stats["evicted_pages"] \
        == len(idx)


def test_drop_seq_never_evicts_cached_prefix_pages():
    """Satellite: finishing (dropping) a request must not evict prefix
    pages still referenced by the index — reuse copies live under the
    request id, the index's originals under negative owner ids."""
    store = HostPageStore()
    idx = PrefixIndex(store, PTOK)
    toks = np.arange(2 * PTOK, dtype=np.int32)
    hs = idx.chain_hashes(toks)
    parent = None
    for i, h in enumerate(hs):
        idx.park(h, parent, i, 0, i, *_payload(i))
        parent = h
    # A request reusing the prefix registers per-request copies.
    n, pages = idx.match(toks)
    for pg in pages:
        k, v = idx.payload(pg)
        store.put(7, pg.shard, pg.vpn, k, v, kind="reuse")
    assert store.has(7, 0, 0) and store.has(7, 0, 1)
    dropped = store.drop_seq(7)
    assert dropped == 2
    # The index's pages survive, payloads intact and still matchable.
    assert len(idx) == 2
    n, pages = idx.match(toks)
    assert n == 2
    k, _v = idx.payload(pages[1])
    assert float(k[0, 0, 0, 0]) == 1.0


# ------------------------------------------------- suffix prefill (model)


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_smoke_config("qwen2.5-3b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _ctx(total_tokens, mpps=16):
    pages = (total_tokens + PTOK - 1) // PTOK
    tables = np.full((1, 1, mpps), -1, np.int32)
    ntok = np.zeros((1, 1, mpps), np.int32)
    for i in range(pages):
        tables[0, 0, i] = i
        ntok[0, 0, i] = min(PTOK, total_tokens - i * PTOK)
    wpage = np.asarray([[(total_tokens - 1) // PTOK]], np.int32)
    wslot = np.asarray([(total_tokens - 1) % PTOK], np.int32)
    return PageCtx(tables=jnp.asarray(tables), ntok=jnp.asarray(ntok),
                   wpage=jnp.asarray(wpage), wslot=jnp.asarray(wslot),
                   frame_pages=GEO.frame_pages)


def test_suffix_prefill_bitwise_matches_full_prefill(lm_setup):
    """The correctness anchor of prefix reuse: prefilling only the suffix
    against cached prefix KV reproduces the full prefill's last-token
    logits AND pool pages bitwise — even when the cached KV came from a
    prompt of a *different* padded length."""
    cfg, lm, params = lm_setup
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, 2 * PTOK).astype(np.int32)
    sufA = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)
    sufB = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)

    def pools():
        shapes = lm.pool_shapes(64, PTOK)
        return tuple(jnp.zeros(s.shape, s.dtype) for s in shapes)

    def full(prompt):
        T = len(prompt)
        Tpad = ((T + PTOK - 1) // PTOK) * PTOK
        toks = np.zeros((1, Tpad), np.int32)
        toks[0, :T] = prompt
        return lm.prefill(params, {"tokens": jnp.asarray(toks)}, pools(),
                          _ctx(T + 1),
                          last_pos=jnp.asarray([T - 1], jnp.int32))

    # Prime: prompt A writes the prefix pages (padded length 40).
    _, poolsA, _ = full(np.concatenate([prefix, sufA]))
    kA, vA = poolsA
    P = 2 * PTOK
    pk = kA[:, :2].reshape(kA.shape[0], 1, P, *kA.shape[3:])
    pv = vA[:, :2].reshape(vA.shape[0], 1, P, *vA.shape[3:])

    # Reference: cold full prefill of prompt B (padded length 32).
    promptB = np.concatenate([prefix, sufB])
    TB = len(promptB)
    logits_ref, pools_ref, _ = full(promptB)

    # Warm: suffix-only prefill; prefix pages pre-scattered (what the
    # host-tier fault-in does), queries attend over the cached KV.
    TpadB = ((TB + PTOK - 1) // PTOK) * PTOK
    toks = np.zeros((1, TpadB - P), np.int32)
    toks[0, :TB - P] = promptB[P:]
    k0, v0 = pools()
    k0 = k0.at[:, :2].set(kA[:, :2])
    v0 = v0.at[:, :2].set(vA[:, :2])
    logits_warm, pools_warm, _ = lm.prefill(
        params, {"tokens": jnp.asarray(toks)}, (k0, v0), _ctx(TB + 1),
        last_pos=jnp.asarray([TB - 1 - P], jnp.int32),
        prefix_kv=(pk, pv), prefix_len=P)

    assert bool(jnp.all(logits_ref == logits_warm))
    npages = (TB + PTOK - 1) // PTOK
    for ref, warm in zip(pools_ref, pools_warm):
        assert bool(jnp.all(ref[:, :npages] == warm[:, :npages]))


# ------------------------------------------------------- engine end-to-end


def _shared_prefix_requests(cfg, n, shared_tokens=40, suffix_tokens=8,
                            max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, shared_tokens).astype(np.int32)
    return [Request(rid=i, tenant=i % 3,
                    prompt=np.concatenate(
                        [shared, rng.integers(0, cfg.vocab_size,
                                              suffix_tokens)
                         .astype(np.int32)]),
                    max_new=max_new)
            for i in range(n)]


def _run_waves(prefix_cache, fault_mode="async", n=5):
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=4, max_seq=128,
                        manager_kind="mosaic", seed=0,
                        prefix_cache=prefix_cache, fault_mode=fault_mode,
                        decode_window_us=1000.0)
    reqs = _shared_prefix_requests(cfg, n)
    for r in reqs[:2]:
        eng.submit(r)
    eng.run_until_drained(max_steps=300)
    for r in reqs[2:]:
        eng.submit(r)
    eng.run_until_drained(max_steps=600)
    assert all(r.done for r in reqs)
    eng.cache.check_invariants()
    return eng, {r.rid: tuple(r.out) for r in reqs}


@pytest.fixture(scope="module")
def warm_runs():
    on_async = _run_waves(True, "async")
    off_async = _run_waves(False, "async")
    return on_async, off_async


def test_prefix_cache_tokens_byte_identical(warm_runs):
    (eng_on, outs_on), (eng_off, outs_off) = warm_runs
    assert outs_on == outs_off
    assert eng_on.stats.prefix_hits >= 3
    assert eng_on.stats.prefix_reused_tokens >= 3 * 40
    assert eng_off.stats.prefix_hits == 0


def test_prefix_cache_skips_prefill_compute(warm_runs):
    (eng_on, _), (eng_off, _) = warm_runs
    s_on, s_off = eng_on.stats, eng_off.stats
    assert s_on.prefill_tokens \
        == s_off.prefill_tokens - s_on.prefix_reused_tokens
    # The reused pages arrived through the DMA pipeline, not recompute:
    # every one was faulted in (admission prefetch → hit, or demand).
    assert s_on.faults >= s_on.prefix_reused_tokens // PTOK
    assert s_on.prefetch_hits + s_on.prefetch_misses >= \
        s_on.prefix_reused_tokens // PTOK


def test_prefix_cache_sync_mode_identical(warm_runs):
    (_, outs_ref), _ = warm_runs
    _, outs_sync_on = _run_waves(True, "sync")
    assert outs_sync_on == outs_ref


def test_drop_seq_engine_keeps_cache_warm(warm_runs):
    """After all requests (including cache-hit ones) finished and were
    dropped, the index still holds the shared prefix and its payloads."""
    (eng_on, _), _ = warm_runs
    assert eng_on.prefix is not None and len(eng_on.prefix) >= 5
    # Index-owned payloads (negative owners) survived every drop_seq.
    owners = {k[0] for k in eng_on.host._pages}
    assert owners and all(o < 0 for o in owners)


def test_parking_rides_outbound_lanes(warm_runs):
    (eng_on, _), _ = warm_runs
    s, d = eng_on.stats, eng_on.dma.stats
    assert s.prefix_parked_pages > 0
    assert s.evict_pages > 0 and s.bytes_out > 0      # park gathers
    assert d["park_jobs"] > 0
    # Per-direction split invariants (settled at run_until_drained).
    assert d["hidden_us"] + d["exposed_us"] \
        == pytest.approx(d["transfer_us"])
    assert d["hidden_us_out"] + d["exposed_us_out"] \
        == pytest.approx(d["transfer_us_out"])
    # Outbound traffic never counts into the fault (inbound) split.
    assert s.fault_hidden_us <= d["transfer_us"] + 1e-9


# ------------------------------------------------------------ duplex DMA


def test_duplex_directions_do_not_contend():
    link = LinkModel(setup_us=10.0, bandwidth_GBps=10.0)
    dma = AsyncDMAEngine(link, n_channels=1, duplex=True)
    jin = dma.enqueue([(0, 0, 0)], [4], 1000, [_payload()], 0.0,
                      kind="demand", direction="in")
    jout = dma.enqueue([(1, 0, 0)], [9], 1000, [_payload()], 0.0,
                       kind="evict", direction="out")
    assert jin.start_us == 0.0 and jout.start_us == 0.0   # full duplex
    dma.wait(jin, 0.0)
    dma.drain(jout.done_us + 1.0)
    assert dma.stats["exposed_us"] == pytest.approx(jin.transfer_us)
    assert dma.stats["hidden_us_out"] == pytest.approx(jout.transfer_us)
    assert dma.stats["evict_jobs"] == 1


def test_half_duplex_serializes_directions():
    link = LinkModel(setup_us=10.0, bandwidth_GBps=10.0)
    dma = AsyncDMAEngine(link, n_channels=1, duplex=False)
    jout = dma.enqueue([(1, 0, 0)], [9], 1000, [_payload()], 0.0,
                       kind="evict", direction="out")
    jin = dma.enqueue([(0, 0, 0)], [4], 1000, [_payload()], 0.0,
                      kind="demand", direction="in")
    # The fault queues behind the eviction on the shared lane.
    assert jin.start_us == pytest.approx(jout.done_us)
    now = dma.wait(jin, 0.0)
    assert now == pytest.approx(jin.done_us)
    assert dma.stats["queue_us"] > 0.0


# ---------------------------------------------------------- SLO schedule


def test_slo_resume_order_and_prefetch_depth():
    cfg = get_smoke_config("qwen2.5-3b")
    eng = ServingEngine(cfg, geometry=GEO, max_batch=2, max_seq=64,
                        manager_kind="mosaic", seed=0,
                        prefetch_depth=1, slo_urgency_us=500.0)
    eng._clock_us = 1000.0
    mk = lambda rid, pri, dl: Request(rid=rid, tenant=0,
                                      prompt=np.zeros(8, np.int32),
                                      max_new=4, priority=pri,
                                      deadline_us=dl)
    eng.preempted.extend([
        mk(0, 0, None),          # best-effort, FIFO
        mk(1, 0, 1200.0),        # slack 200 (urgent)
        mk(2, 1, None),          # premium, no deadline
        mk(3, 0, 5000.0),        # slack 4000
        mk(4, 1, 1100.0),        # premium, slack 100 (most urgent)
    ])
    # Priority first; tightest slack within a tier; deadline-free last.
    assert eng._resume_order() == [4, 2, 1, 3, 0]
    slacks = [eng._slack(r) for r in eng._resume_candidates()]
    depth = eng.prefetch.plan_depth(slacks, eng.slo_urgency_us)
    assert depth == 2                       # two urgent beat base depth 1
    # Blown deadlines count as maximally urgent.
    eng._clock_us = 10_000.0
    slacks = [eng._slack(r) for r in eng._resume_candidates()]
    assert eng.prefetch.plan_depth(slacks, eng.slo_urgency_us) == 3
    # No deadlines -> base depth unchanged.
    pf = Prefetcher(depth=2)
    assert pf.plan_depth([None, None, None], 500.0) == 2
    assert pf.plan_depth([], 500.0) == 2


# ------------------------------------------------------------- summaries


def test_engine_stats_summary_reports_prefetch_and_prefix_counts():
    """Satellite: summary() must include the prefetch hit/miss/wasted
    split (and the duplex/prefix counters when active)."""
    s = EngineStats(prefill_tokens=10, decode_tokens=5, decode_steps=5,
                    wall_s=1.0, faults=3, fault_dmas=2, bytes_in=4096,
                    prefetch_hits=7, prefetch_misses=2, prefetch_wasted=1,
                    evict_pages=4, evict_dmas=2, bytes_out=8192,
                    prefix_hits=3, prefix_misses=1,
                    prefix_reused_tokens=40)
    line = s.summary()
    assert "prefetch 7/2/1 hit/miss/wasted" in line
    assert "out 4 pages in 2 DMAs" in line
    assert "prefix 3/1 hit/miss (40 tok reused)" in line
    # Prefix-less engines keep the line clean.
    assert "prefix" not in EngineStats().summary()
