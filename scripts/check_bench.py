#!/usr/bin/env python
"""Bench-regression gate (CI `bench-smoke` job; runnable locally):

Diffs a freshly-generated ``BENCH_serving.json`` against the committed
baseline and fails if any claim that was **true** at the baseline has
flipped to anything other than true.  Only booleans gate — float
datapoints (``hidden_fraction*``) ride in the claims dict for trajectory
tracking and are reported, never gated.  A baseline claim missing from
the fresh artifact is a warning, not a failure: partial ``--only`` runs
only refresh the suites they executed, and a renamed claim should fail
review, not CI.

    python scripts/check_bench.py \\
        --baseline /tmp/bench_baseline.json --fresh BENCH_serving.json

Without ``--baseline`` the committed copy is read via
``git show HEAD:BENCH_serving.json``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def load_baseline(path: str | None) -> dict:
    if path:
        with open(path) as f:
            return json.load(f)
    out = subprocess.run(
        ["git", "show", "HEAD:BENCH_serving.json"],
        cwd=ROOT, capture_output=True, text=True)
    if out.returncode != 0:
        print("no committed BENCH_serving.json baseline; nothing to gate")
        return {}
    return json.loads(out.stdout)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="baseline artifact (default: git show HEAD:...)")
    ap.add_argument("--fresh", default="BENCH_serving.json",
                    help="freshly generated artifact")
    args = ap.parse_args(argv)

    baseline = load_baseline(args.baseline).get("claims", {})
    try:
        with open(args.fresh) as f:
            fresh = json.load(f).get("claims", {})
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read fresh artifact {args.fresh}: {e}")
        return 1

    regressions, missing, floats = [], [], []
    for name, val in sorted(baseline.items()):
        if val is not True:
            # Floats (hidden_fraction*) and claims that were already
            # false at the baseline never gate; only green can regress.
            if isinstance(val, float):
                floats.append(name)
            continue
        if name not in fresh:
            missing.append(name)
        elif fresh[name] is not True:
            regressions.append((name, fresh[name]))

    for name in floats:
        cur = fresh.get(name, "absent")
        print(f"  info  {name}: baseline={baseline[name]} fresh={cur}")
    for name in missing:
        print(f"  warn  {name}: true at baseline, absent from fresh "
              f"artifact (suite not rerun?)")
    for name, val in regressions:
        print(f"  FAIL  {name}: true at baseline, now {val!r}")

    gated = sum(1 for v in baseline.values() if v is True)
    print(f"checked {gated} baseline claims: "
          f"{len(regressions)} regressed, {len(missing)} missing")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
