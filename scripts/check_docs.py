#!/usr/bin/env python
"""Docs gate (CI `docs` job; runnable locally):

1. `README.md` exists and is a real front door (not a stub);
2. every module under `src/repro/` has a module docstring;
3. when BASE_REF is set (pull requests), the diff against it updates
   `ROADMAP.md` or `CHANGES.md` — every PR leaves a trail for the next
   session.

    PYTHONPATH=src python scripts/check_docs.py
    BASE_REF=origin/main python scripts/check_docs.py
"""

from __future__ import annotations

import ast
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def check_readme() -> list:
    readme = ROOT / "README.md"
    if not readme.exists():
        return ["README.md is missing"]
    text = readme.read_text()
    errs = []
    if len(text) < 1000:
        errs.append("README.md looks like a stub (<1000 chars)")
    for needle in ("pytest", "benchmarks.run"):
        if needle not in text:
            errs.append(f"README.md does not mention `{needle}`")
    return errs


def check_docstrings() -> list:
    errs = []
    for path in sorted((ROOT / "src" / "repro").rglob("*.py")):
        src = path.read_text()
        if not src.strip():
            continue                       # empty __init__ namespace file
        try:
            mod = ast.parse(src)
        except SyntaxError as e:           # pragma: no cover
            errs.append(f"{path.relative_to(ROOT)}: syntax error: {e}")
            continue
        if ast.get_docstring(mod) is None:
            errs.append(f"{path.relative_to(ROOT)}: missing module "
                        f"docstring")
    return errs


def check_changelog(base_ref: str) -> list:
    try:
        out = subprocess.run(
            ["git", "diff", "--name-only", f"{base_ref}...HEAD"],
            cwd=ROOT, capture_output=True, text=True, check=True).stdout
    except subprocess.CalledProcessError as e:
        return [f"git diff against {base_ref} failed: {e.stderr.strip()}"]
    changed = set(out.split())
    if not changed:
        return []                          # empty diff: nothing to log
    if not changed & {"ROADMAP.md", "CHANGES.md"}:
        return ["PR does not update ROADMAP.md or CHANGES.md "
                f"(changed: {sorted(changed)[:10]}…)"]
    return []


def main() -> int:
    errs = check_readme() + check_docstrings()
    base = os.environ.get("BASE_REF", "").strip()
    if base:
        errs += check_changelog(base)
    for e in errs:
        print(f"docs-check FAIL: {e}")
    if not errs:
        print("docs-check OK: README present, all src/repro modules "
              "documented" + (f", changelog updated vs {base}" if base
                              else ""))
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
