"""Unified LM facade: init / train-loss / prefill / decode for all 10 archs.

``LM(cfg)`` dispatches on ``cfg.family``:

  dense, vlm      -> transformer stack (vlm prepends stub patch embeddings)
  moe             -> transformer stack with MoE FFN (+ dense-prefix layers)
  (moe w/ mla)    -> MLA attention, latent paged cache (absorbed decode)
  ssm             -> mamba2 stack (recurrent state, no KV pool)
  hybrid          -> zamba2: mamba backbone + shared paged-attention block
  encdec          -> seamless: encoder memory + decoder self/cross attention

The decode path consumes Mosaic page tables via
:class:`repro.models.transformer.PageCtx`; pool arrays live with the
caller (serving engine or dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ed
from repro.models import hybrid as hy
from repro.models import mamba2 as m2
from repro.models.common import cast, embed_init, shd, split_keys
from repro.models.layers import rms_norm
from repro.models.transformer import (
    DP,
    PageCtx,
    decoder_stack_decode,
    decoder_stack_prefill,
    decoder_stack_train,
    init_decoder_params,
)

AUX_LOSS_COEF = 0.01


def _dense_view(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, moe=None)


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- init

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = split_keys(key, 6)
        p: Dict[str, Any] = {
            "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model)),
            "final_norm": jnp.ones((cfg.d_model,)),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = embed_init(ks[1], (cfg.d_model, cfg.vocab_size))
        if cfg.family == "encdec":
            p.update(ed.init_encdec_params(ks[2], cfg))
            p["frontend_proj"] = embed_init(
                ks[3], (cfg.d_model, cfg.d_model))
        elif cfg.family == "ssm":
            p["decoder"] = m2.init_ssm_stack_params(ks[2], cfg, cfg.n_layers)
        elif cfg.family == "hybrid":
            p["decoder"] = hy.init_hybrid_params(ks[2], cfg)
        else:
            fd = cfg.moe.first_dense if cfg.moe else 0
            if fd:
                p["decoder_prefix"] = init_decoder_params(
                    ks[3], _dense_view(cfg), fd)
            p["decoder"] = init_decoder_params(ks[2], cfg, cfg.n_layers - fd)
            if cfg.family == "vlm":
                p["frontend_proj"] = embed_init(
                    ks[4], (cfg.d_model, cfg.d_model))
        return p

    # ------------------------------------------------------------- embed

    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        return shd(x.astype(jnp.dtype(self.cfg.dtype)), DP, None, None)

    def _logits(self, params, x):
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["unembed"])
        logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype))
        return shd(logits, DP, None, "model")

    # ------------------------------------------------------------- train

    def _backbone_train(self, params, x, positions, remat: bool):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        if cfg.family == "encdec":
            raise RuntimeError("use loss() for encdec")
        if cfg.family == "ssm":
            x = m2.ssm_stack_train(cfg, params["decoder"], x, remat=remat)
        elif cfg.family == "hybrid":
            x, _ = hy.hybrid_stack_train(cfg, params["decoder"], x, positions)
        else:
            if "decoder_prefix" in params:
                x, a0 = decoder_stack_train(
                    _dense_view(cfg), params["decoder_prefix"], x, positions,
                    remat=remat)
                aux = aux + a0
            x, a1 = decoder_stack_train(cfg, params["decoder"], x, positions,
                                        remat=remat)
            aux = aux + a1
        return x, aux

    def loss(self, params, batch, *, remat: bool = True):
        """batch: tokens [B,T] (+ patch_embeds / src_embeds per family)."""
        cfg = self.cfg
        params = cast(params, jnp.dtype(cfg.dtype))
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = self._embed(params, tokens)
        n_prefix = 0
        if cfg.family == "vlm":
            pe = batch["patch_embeds"].astype(x.dtype)
            pe = jnp.einsum("bpd,de->bpe", pe,
                            params["frontend_proj"].astype(x.dtype))
            x = jnp.concatenate([pe, x], axis=1)
            n_prefix = pe.shape[1]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     (B, x.shape[1]))
        if cfg.family == "encdec":
            src = batch["src_embeds"].astype(x.dtype)
            src = jnp.einsum("bsd,de->bse", src,
                             params["frontend_proj"].astype(x.dtype))
            memory = ed.encoder_apply(cfg, params, src, remat=remat)
            x = ed.decoder_stack_train(cfg, params, x, positions, memory,
                                       remat=remat)
            aux = jnp.float32(0.0)
        else:
            x, aux = self._backbone_train(params, x, positions, remat)
        x = x[:, n_prefix:]
        logits = self._logits(params, x).astype(jnp.float32)
        tgt = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        mask = (tgt >= 0).astype(jnp.float32)
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        total = loss + AUX_LOSS_COEF * aux
        return total, {"nll": loss, "aux": aux,
                       "tokens": mask.sum()}

    # ------------------------------------------------------------- pools

    def kv_layers(self) -> int:
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0
        if cfg.family == "hybrid":
            return hy.n_invocations(cfg)
        if cfg.family == "encdec":
            return cfg.encdec.dec_layers
        return cfg.n_layers

    def pool_shapes(self, num_pages: int, page_tokens: int,
                    dtype=jnp.bfloat16):
        """(k_pool, v_pool) ShapeDtypeStructs (None for ssm)."""
        cfg = self.cfg
        L = self.kv_layers()
        if L == 0:
            return None
        if cfg.mla is not None:
            m = cfg.mla
            kd = m.kv_lora_rank + m.qk_rope_head_dim
            k = (L, num_pages, page_tokens, 1, kd)
            v = (L, num_pages, page_tokens, 1, m.kv_lora_rank)
        else:
            dh = cfg.resolved_head_dim
            k = (L, num_pages, page_tokens, cfg.n_kv_heads, dh)
            v = k
        return (jax.ShapeDtypeStruct(k, dtype),
                jax.ShapeDtypeStruct(v, dtype))

    def init_state_shapes(self, batch: int, src_len: int = 0,
                          dtype=jnp.bfloat16) -> Dict[str, Any]:
        """Non-pool decode state (SSM states, cross-KV) as ShapeDtypeStructs."""
        cfg = self.cfg
        out: Dict[str, Any] = {}
        if cfg.family in ("ssm", "hybrid"):
            L = (cfg.n_layers if cfg.family in ("ssm", "hybrid") else 0)
            s_shape, c_shape = m2.state_shapes(cfg, L, batch)
            out["ssm"] = jax.ShapeDtypeStruct(s_shape, jnp.float32)
            out["conv"] = jax.ShapeDtypeStruct(c_shape, dtype)
        if cfg.family == "encdec":
            e = cfg.encdec
            dh = cfg.resolved_head_dim
            shape = (e.dec_layers, batch, src_len, cfg.n_kv_heads, dh)
            out["cross_k"] = jax.ShapeDtypeStruct(shape, dtype)
            out["cross_v"] = jax.ShapeDtypeStruct(shape, dtype)
        return out

    # ------------------------------------------------------------- prefill

    def prefill(self, params, batch, pools, ctx: PageCtx,
                last_pos=None, prefix_kv=None, prefix_len: int = 0):
        """Full-sequence forward; writes KV/latents into the paged pools.

        ``last_pos`` [B]: index of the last *valid* token per sequence
        (prompts are right-padded to a page multiple); defaults to T-1.
        Returns (logits at last_pos [B,V], pools', state).

        Suffix-only prefill (prefix-cache reuse, DESIGN.md §8): with
        ``prefix_kv=(k [L,B,P,Hkv,dh], v [...])`` and ``prefix_len=P``
        (a page multiple), ``tokens`` holds only the suffix — positions
        start at P, queries attend to the cached prefix KV, and only the
        suffix pages are scattered into the pool (the prefix pages are
        restored through the host tier).  ``last_pos`` stays an index
        into the given (suffix) tokens.  Transformer families only —
        recurrent state (ssm/hybrid), cross-attention (encdec) and MLA
        latents are not prefix-cacheable here.
        """
        cfg = self.cfg
        params = cast(params, jnp.dtype(cfg.dtype))
        tokens = batch["tokens"]
        B, T = tokens.shape
        if prefix_len:
            assert prefix_kv is not None
            # Dense-only: MoE capacity is a function of the forward's
            # token count (ceil(T·top_k/E·cf)), so a suffix-only pass
            # drops different tokens than the full pass — not bitwise.
            assert cfg.family == "dense" and cfg.mla is None, \
                f"prefix-cache reuse unsupported for {cfg.family}/mla"
        x = self._embed(params, tokens)
        state: Dict[str, Any] = {}
        if cfg.family == "vlm":
            pe = batch["patch_embeds"].astype(x.dtype)
            pe = jnp.einsum("bpd,de->bpe", pe,
                            params["frontend_proj"].astype(x.dtype))
            x = jnp.concatenate([pe, x], axis=1)
        positions = jnp.broadcast_to(prefix_len + jnp.arange(x.shape[1])[None],
                                     (B, x.shape[1]))
        if cfg.family == "encdec":
            src = batch["src_embeds"].astype(x.dtype)
            src = jnp.einsum("bsd,de->bse", src,
                             params["frontend_proj"].astype(x.dtype))
            memory = ed.encoder_apply(cfg, params, src)
            x, pools, (ck, cv) = ed.decoder_stack_prefill(
                cfg, params, x, positions, memory, pools, ctx)
            state["cross_k"], state["cross_v"] = ck, cv
        elif cfg.family == "ssm":
            x, hs, convs = m2.ssm_stack_prefill(cfg, params["decoder"], x)
            state["ssm"], state["conv"] = hs, convs
        elif cfg.family == "hybrid":
            x, pools, hs, convs = hy.hybrid_stack_prefill(
                cfg, params["decoder"], x, positions, pools, ctx)
            state["ssm"], state["conv"] = hs, convs
        else:
            fd = cfg.moe.first_dense if cfg.moe else 0
            if fd:
                kp, vp = pools
                pk0 = pk1 = None
                if prefix_kv is not None:
                    pk, pv = prefix_kv
                    pk0, pk1 = (pk[:fd], pv[:fd]), (pk[fd:], pv[fd:])
                x, (kp0, vp0) = decoder_stack_prefill(
                    _dense_view(cfg), params["decoder_prefix"], x, positions,
                    (kp[:fd], vp[:fd]), ctx, prefix_kv=pk0,
                    tok_offset=prefix_len)
                x, (kp1, vp1) = decoder_stack_prefill(
                    cfg, params["decoder"], x, positions,
                    (kp[fd:], vp[fd:]), ctx, prefix_kv=pk1,
                    tok_offset=prefix_len)
                pools = (jnp.concatenate([kp0, kp1], axis=0),
                         jnp.concatenate([vp0, vp1], axis=0))
            else:
                x, pools = decoder_stack_prefill(cfg, params["decoder"], x,
                                                 positions, pools, ctx,
                                                 prefix_kv=prefix_kv,
                                                 tok_offset=prefix_len)
        if last_pos is None:
            x_last = x[:, -1:, :]
        else:
            n_prefix = x.shape[1] - tokens.shape[1]
            idx = (n_prefix + last_pos)[:, None, None]
            x_last = jnp.take_along_axis(x, idx, axis=1)
        logits = self._logits(params, x_last)[:, 0]
        return logits, pools, state

    # ------------------------------------------------------------- decode

    def decode_step(self, params, tokens, pos, pools, ctx: PageCtx,
                    state: Optional[Dict[str, Any]] = None):
        """tokens [B] int32, pos [B] current positions (0-based).

        Returns (logits [B,V], pools', state').
        """
        cfg = self.cfg
        params = cast(params, jnp.dtype(cfg.dtype))
        state = dict(state or {})
        x = self._embed(params, tokens[:, None])
        if cfg.family == "encdec":
            x, pools = ed.decoder_stack_decode(
                cfg, params, x, pos, pools, ctx,
                (state["cross_k"], state["cross_v"]))
        elif cfg.family == "ssm":
            x, hs, convs = m2.ssm_stack_decode(
                cfg, params["decoder"], x, state["ssm"], state["conv"])
            state["ssm"], state["conv"] = hs, convs
        elif cfg.family == "hybrid":
            x, pools, hs, convs = hy.hybrid_stack_decode(
                cfg, params["decoder"], x, pos, pools, ctx,
                state["ssm"], state["conv"])
            state["ssm"], state["conv"] = hs, convs
        else:
            fd = cfg.moe.first_dense if cfg.moe else 0
            if fd:
                kp, vp = pools
                x, (kp0, vp0) = decoder_stack_decode(
                    _dense_view(cfg), params["decoder_prefix"], x, pos,
                    (kp[:fd], vp[:fd]), ctx)
                x, (kp1, vp1) = decoder_stack_decode(
                    cfg, params["decoder"], x, pos, (kp[fd:], vp[fd:]), ctx)
                pools = (jnp.concatenate([kp0, kp1], axis=0),
                         jnp.concatenate([vp0, vp1], axis=0))
            else:
                x, pools = decoder_stack_decode(cfg, params["decoder"], x,
                                                pos, pools, ctx)
        logits = self._logits(params, x)[:, 0]
        return logits, pools, state
