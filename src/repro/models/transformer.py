"""Unified decoder-only transformer stack (dense / MoE / MLA / VLM families).

One codebase, three lowerings:
  * ``train`` / ``prefill``: full-sequence causal flash attention
    (:func:`repro.models.layers.attention`), scan-over-layers with optional
    per-block remat.  Prefill additionally scatters K/V into the Mosaic
    paged pool (en-masse allocation — the paper's key observation).
  * ``decode``: one token per sequence against the paged pool, partial
    flash per page-shard combined with psum/pmax inside ``shard_map``
    (context-parallel paged attention; DESIGN.md §3).

Parameters are stacked with a leading layer axis and consumed by
``jax.lax.scan`` so compile time is layer-count independent.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.compat import get_abstract_mesh, shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import paged
from repro.models.common import dense_init, psum_point, shd, split_keys
from repro.models.layers import (
    apply_rope,
    attention,
    gqa_qkv,
    rms_norm,
    rope,
    rope_angles,
    swiglu,
)
from repro.models.moe import init_moe_params, moe_block

from repro.models.common import BATCH as DP  # batch sentinel (see common.shd)


# ------------------------------------------------------------------ page ctx


@dataclasses.dataclass
class PageCtx:
    """Device-side paged-KV addressing for one engine step.

    tables/ntok: [B, S, mpps]; wpage: [B, S]; wslot: [B].
    ``batch_sharded``: batch dim is split over the data axes (decode_32k)
    vs. replicated with pages spread over every axis (long_500k).
    """

    tables: jax.Array
    ntok: jax.Array
    wpage: jax.Array
    wslot: jax.Array
    # Fused gather-attend decode over partially-resident KV (DESIGN.md
    # §13): per-page staging slot mirroring `tables` (-1 = pool-resident,
    # >= 0 = read the page from the staging region) plus the step-local
    # staging pools [L, NS, ptok, n_kv, dh{,_v}].  None = the classic
    # all-resident path (sync/async fault modes, and fused steps with
    # nothing in flight).
    slots: Optional[jax.Array] = None
    stage_k: Optional[jax.Array] = None
    stage_v: Optional[jax.Array] = None
    batch_sharded: bool = True
    frame_pages: int = 16       # frame striping granularity (prefill scatter)

    def page_axes(self, mesh) -> tuple:
        """Axes a sequence's pages are striped over (== combine axes)."""
        names = set(mesh.axis_names)
        if self.batch_sharded:
            return tuple(a for a in ("model",) if a in names)
        return tuple(a for a in ("pod", "data", "model") if a in names)

    def pool_axes(self, mesh) -> tuple:
        """Axes the physical pool's page dim is sharded over.

        batch_sharded: every (data, model) cell owns a private sub-pool
        (its sub-batch's pages striped over model) — pages shard over
        dp x model, NOT model alone, or the pool would be replicated
        per data shard and blow per-chip HBM.
        """
        names = set(mesh.axis_names)
        return tuple(a for a in ("pod", "data", "model") if a in names)

    def batch_spec(self, mesh):
        names = set(mesh.axis_names)
        if not self.batch_sharded:
            return None
        dp = tuple(a for a in ("pod", "data") if a in names)
        return dp if dp else None


jax.tree_util.register_dataclass(
    PageCtx,
    data_fields=["tables", "ntok", "wpage", "wslot",
                 "slots", "stage_k", "stage_v"],
    meta_fields=["batch_sharded", "frame_pages"],
)


def _ambient_mesh():
    mesh = get_abstract_mesh()
    return None if (mesh is None or mesh.empty) else mesh


# ------------------------------------------------------------------ attention


def init_attn_params(key, cfg: ModelConfig, L: int) -> Dict[str, Any]:
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    ks = split_keys(key, 8)
    p = {
        "wq": dense_init(ks[0], (L, d, H, dh), in_axis=1),
        "wk": dense_init(ks[1], (L, d, Hkv, dh), in_axis=1),
        "wv": dense_init(ks[2], (L, d, Hkv, dh), in_axis=1),
        "wo": dense_init(ks[3], (L, H, dh, d), in_axis=1),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((L, H, dh))
        p["bk"] = jnp.zeros((L, Hkv, dh))
        p["bv"] = jnp.zeros((L, Hkv, dh))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((L, dh))
        p["k_norm"] = jnp.ones((L, dh))
    return p


def _project_qkv(cfg: ModelConfig, p, x, positions):
    """x [B,T,d] -> roped q [B,T,H,dh], k/v [B,T,Hkv,dh]."""
    q, k, v = gqa_qkv(
        x, p["wq"], p["wk"], p["wv"],
        p.get("bq"), p.get("bk"), p.get("bv"),
    )
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, q.shape[-1], cfg.rope_theta)
    q = apply_rope(q, cos[..., :, None, :], sin[..., :, None, :])
    k = apply_rope(k, cos[..., :, None, :], sin[..., :, None, :])
    return q, k, v


def _tp_geometry(cfg: ModelConfig, mesh):
    """Static explicit-TP geometry, or None if this config can't use it.

    Each model shard owns H_loc consecutive query heads and the (static)
    kv-head slice they attend to; returns (tp, H_loc, nkv_loc, kv_lo_of)
    where kv_lo_of[s] is shard s's first kv head.  None when heads don't
    divide, or a shard's q heads map to a non-uniform kv block (the
    grouped attention inside the shard would be wrong).
    """
    if mesh is None or "model" not in mesh.axis_names:
        return None
    from repro.models.common import batch_axes, tp_mode
    if tp_mode() == "auto" or "model" in batch_axes():
        return None
    tp = mesh.shape["model"]
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    if H == 0 or H % tp or H % Hkv:
        return None
    H_loc, group = H // tp, H // Hkv
    kv_lo, span = [], 0
    for s in range(tp):
        lo = (s * H_loc) // group
        hi = (s * H_loc + H_loc - 1) // group
        kv_lo.append(lo)
        span = max(span, hi - lo + 1)
    # Uniform grouped mapping inside the shard requires H_loc % span == 0
    # and every local q head j hitting kv (lo + j // (H_loc // span)).
    if H_loc % span:
        return None
    for s in range(tp):
        for j in range(H_loc):
            if (s * H_loc + j) // group != kv_lo[s] + j // (H_loc // span):
                return None
    return tp, H_loc, span, tuple(kv_lo)


def attn_block_train(cfg: ModelConfig, p, x, positions, *, causal=True,
                     kv_len=None, prefix_kv=None):
    """Full-sequence attention; ``prefix_kv=(pk, pv)`` ([B, P, Hkv, dh],
    already roped — cached pool bytes) prepends P cached-context keys the
    in-flight queries attend to causally (suffix-only prefill; the caller
    must offset ``positions`` by P).  Returns (out, k, v) with k/v
    covering the in-flight tokens only."""
    mesh = _ambient_mesh()
    geo = _tp_geometry(cfg, mesh)
    if geo is None or prefix_kv is not None:
        # Auto-sharded fallback (no mesh / fsdp / awkward head counts),
        # and the only path carrying cached-prefix KV (suffix prefill is
        # engine-side, mesh-free; the TP path asserts it never sees one).
        q, k, v = _project_qkv(cfg, p, x, positions)
        q = shd(q, DP, None, "model", None)
        k = shd(k, DP, None, None, None)
        v = shd(v, DP, None, None, None)
        if prefix_kv is not None:
            pk, pv = prefix_kv
            o = attention(q, jnp.concatenate([pk.astype(k.dtype), k], axis=1),
                          jnp.concatenate([pv.astype(v.dtype), v], axis=1),
                          causal=causal, q_offset=pk.shape[1], kv_len=kv_len)
        else:
            o = attention(q, k, v, causal=causal, kv_len=kv_len)
        o = shd(o, DP, None, "model", None)
        return psum_point(jnp.einsum("bthd,hdk->btk", o, p["wo"])), k, v
    return _attn_block_train_tp(cfg, p, x, positions, mesh, geo,
                                causal=causal, kv_len=kv_len)


def _attn_block_train_tp(cfg: ModelConfig, p, x, positions, mesh, geo, *,
                         causal, kv_len):
    """Explicit Megatron TP attention: one bf16 psum per layer.

    Column-parallel q / out-proj over heads; k/v are computed in full on
    every shard (kv heads rarely divide tp — the redundant kv-projection
    compute equals what the auto path already does) and each shard slices
    its static kv block.  The psum dtype is pinned to the activation
    dtype — the partitioner can no longer attach the reduction to an
    f32-upcast dot (EXPERIMENTS.md §Perf iteration 2).
    """
    tp, H_loc, nkv, kv_lo = geo
    from repro.models.common import batch_axes
    dp = tuple(a for a in batch_axes() if a in mesh.axis_names)
    if dp and x.shape[0] % int(np.prod([mesh.shape[a] for a in dp])):
        dp = ()
    bs = dp if dp else None
    kv_lo_arr = jnp.asarray(kv_lo, jnp.int32)
    has_bias, has_qkn = "bq" in p, "q_norm" in p
    # When kv heads divide tp *and* shard s's q heads attend exactly its
    # kv slice, shard the kv projection too: no redundant kv compute, no
    # kv-grad psums (otherwise compute k/v in full on every shard).
    Hkv = cfg.n_kv_heads
    kv_sharded = (Hkv % tp == 0 and nkv == Hkv // tp
                  and all(kv_lo[s] == s * (Hkv // tp) for s in range(tp)))

    def local(x, positions, wq, wk, wv, wo, *extra):
        extra = list(extra)
        bq = extra.pop(0) if has_bias else None
        bk = extra.pop(0) if has_bias else None
        bv = extra.pop(0) if has_bias else None
        qn = extra.pop(0) if has_qkn else None
        kn = extra.pop(0) if has_qkn else None
        s = jax.lax.axis_index("model")
        q = jnp.einsum("btd,dhk->bthk", x, wq)
        k = jnp.einsum("btd,dhk->bthk", x, wk)
        v = jnp.einsum("btd,dhk->bthk", x, wv)
        if has_bias:
            q, k, v = q + bq, k + bk, v + bv
        if has_qkn:
            q = rms_norm(q, qn, cfg.norm_eps)
            k = rms_norm(k, kn, cfg.norm_eps)
        cos, sin = rope_angles(positions, q.shape[-1], cfg.rope_theta)
        q = apply_rope(q, cos[..., :, None, :], sin[..., :, None, :])
        k = apply_rope(k, cos[..., :, None, :], sin[..., :, None, :])
        if kv_sharded:
            k_loc, v_loc = k, v
        else:
            k_loc = jax.lax.dynamic_slice_in_dim(k, kv_lo_arr[s], nkv,
                                                 axis=2)
            v_loc = jax.lax.dynamic_slice_in_dim(v, kv_lo_arr[s], nkv,
                                                 axis=2)
        o = attention(q, k_loc, v_loc, causal=causal, kv_len=kv_len)
        y = jnp.einsum("bthd,hdk->btk", o, wo)
        return jax.lax.psum(y, "model"), k, v

    kvs = P(None, "model", None) if kv_sharded else P(None, None, None)
    kvb = P("model", None) if kv_sharded else P(None, None)
    kv_out = (P(bs, None, "model", None) if kv_sharded
              else P(bs, None, None, None))
    in_specs = [P(bs, None, None), P(bs, None),
                P(None, "model", None),            # wq (heads col-parallel)
                kvs,                               # wk
                kvs,                               # wv
                P("model", None, None)]            # wo (heads row-parallel)
    args = [x, positions, p["wq"], p["wk"], p["wv"], p["wo"]]
    if has_bias:
        in_specs += [P("model", None), kvb, kvb]
        args += [p["bq"], p["bk"], p["bv"]]
    if has_qkn:
        in_specs += [P(None), P(None)]
        args += [p["q_norm"], p["k_norm"]]
    fn = shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=(P(bs, None, None), kv_out, kv_out),
                   check_vma=False)
    return fn(*args)


def paged_attn_op(q, k_new, v_new, k_pool, v_pool, ctx: PageCtx, *, scale):
    """Decode paged attention + pool write, sharded over page shards.

    q [B,H,dh]; k_new/v_new [B,n_kv,dh]; pools [NP, ptok, n_kv, dh].
    Returns (o [B,H,dh_v], k_pool', v_pool').
    """
    mesh = _ambient_mesh()

    def local(q, k_new, v_new, k_pool, v_pool, tables, ntok, wpage, wslot,
              axes=(), stage_k=None, stage_v=None, slots=None):
        tables = tables.reshape(tables.shape[0], -1)
        ntok = ntok.reshape(ntok.shape[0], -1)
        if slots is not None:
            slots = slots.reshape(slots.shape[0], -1)
        # One shard column holds the write page; the rest are -1 (also the
        # unsharded test path, where all S columns arrive at once).
        wpage = wpage.reshape(wpage.shape[0], -1).max(axis=1)
        k_pool, v_pool = paged.write_kv(k_pool, v_pool, k_new, v_new,
                                        wpage, wslot)
        o, m, l = paged.paged_attention_local(
            q, k_pool, v_pool, tables, ntok, scale=scale,
            stage_k=stage_k, stage_v=stage_v, slots=slots)
        o = paged.combine_partials(o, m, l, axes)
        return o.astype(q.dtype), k_pool, v_pool

    if mesh is None:
        return local(q, k_new, v_new, k_pool, v_pool,
                     ctx.tables, ctx.ntok, ctx.wpage, ctx.wslot,
                     stage_k=ctx.stage_k, stage_v=ctx.stage_v,
                     slots=ctx.slots)
    if ctx.slots is not None:
        # Staging consumption is an engine-local (mesh-free) decode path;
        # the sharded path would need staging sub-pools per page shard.
        raise NotImplementedError(
            "fused staging decode (PageCtx.slots) has no mesh path")

    axes = ctx.page_axes(mesh)
    bs = ctx.batch_spec(mesh)
    pool_spec = P(ctx.pool_axes(mesh) or None)
    fn = shard_map(
        functools.partial(local, axes=axes),
        mesh=mesh,
        in_specs=(
            P(bs),                      # q replicated over model
            P(bs), P(bs),               # k_new, v_new
            pool_spec, pool_spec,       # pools split on page dim
            P(bs, axes), P(bs, axes),   # tables, ntok
            P(bs, axes), P(bs),         # wpage, wslot
        ),
        out_specs=(P(bs), pool_spec, pool_spec),
        check_vma=False,
    )
    return fn(q, k_new, v_new, k_pool, v_pool, ctx.tables, ctx.ntok,
              ctx.wpage, ctx.wslot)


def prefill_write_op(k_seq, v_seq, k_pool, v_pool, ctx: PageCtx,
                     tok_offset: int = 0):
    """Scatter prefilled K/V [B,T,n_kv,dh] into the paged pool.

    Each page shard owns the stripe of frames f ≡ shard (mod S); the local
    writer reconstructs every local page's global vpn from that striping
    (ShardedKVCache contract) and gathers its tokens from the replicated
    sequence.  ``tok_offset`` (a page multiple) shifts the window for
    suffix-only prefill: only pages at token positions ≥ the offset are
    written (prefix-cache reuse, DESIGN.md §8).
    """
    mesh = _ambient_mesh()

    def local(k_seq, v_seq, k_pool, v_pool, tables, *, axes=()):
        tables = tables.reshape(tables.shape[0], -1)
        shard, n_shards = 0, 1
        for a in axes:
            n = compat.axis_size(a)
            shard = shard * n + jax.lax.axis_index(a)
            n_shards *= n
        return paged.write_prefill_kv(
            k_pool, v_pool, k_seq, v_seq, tables, shard_idx=shard,
            n_shards=n_shards, frame_pages=ctx.frame_pages,
            tok_offset=tok_offset)

    if mesh is None:
        return local(k_seq, v_seq, k_pool, v_pool, ctx.tables)
    axes = ctx.page_axes(mesh)
    bs = ctx.batch_spec(mesh)
    pool_spec = P(ctx.pool_axes(mesh) or None)
    fn = shard_map(
        functools.partial(local, axes=axes), mesh=mesh,
        in_specs=(P(bs), P(bs), pool_spec, pool_spec, P(bs, axes)),
        out_specs=(pool_spec, pool_spec),
        check_vma=False,
    )
    return fn(k_seq, v_seq, k_pool, v_pool, ctx.tables)


def attn_block_decode(cfg: ModelConfig, p, x, pos, k_pool, v_pool,
                      ctx: PageCtx):
    """x [B,1,d], pos [B] -> ([B,1,d], k_pool', v_pool')."""
    q, k, v = _project_qkv(cfg, p, x, pos[:, None])
    dh = cfg.resolved_head_dim
    o, k_pool, v_pool = paged_attn_op(
        q[:, 0], k[:, 0], v[:, 0], k_pool, v_pool, ctx, scale=dh ** -0.5)
    y = jnp.einsum("bhd,hdk->bk", o, p["wo"])[:, None, :]
    return y, k_pool, v_pool


# ------------------------------------------------------------------ FFN


def init_ffn_params(key, cfg: ModelConfig, L: int) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], (L, d, f), in_axis=1),
        "w_up": dense_init(ks[1], (L, d, f), in_axis=1),
        "w_down": dense_init(ks[2], (L, f, d), in_axis=1),
    }


def ffn_block(cfg: ModelConfig, p, x):
    mesh = _ambient_mesh()
    from repro.models.common import batch_axes, tp_mode
    tp = mesh.shape["model"] if (mesh is not None
                                 and "model" in mesh.axis_names) else 0
    if (not tp or tp_mode() == "auto" or "model" in batch_axes()
            or cfg.d_ff % tp):
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])

    # Explicit TP SwiGLU: hidden column-parallel, down row-parallel, one
    # bf16 psum (same rationale as _attn_block_train_tp).
    dp = tuple(a for a in batch_axes() if a in mesh.axis_names)
    if dp and x.shape[0] % int(np.prod([mesh.shape[a] for a in dp])):
        dp = ()
    bs = dp if dp else None

    def local(x, wg, wu, wd):
        g = jnp.einsum("btd,df->btf", x, wg)
        u = jnp.einsum("btd,df->btf", x, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return jax.lax.psum(jnp.einsum("btf,fd->btd", h, wd), "model")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(bs, None, None), P(None, "model"),
                             P(None, "model"), P("model", None)),
                   out_specs=P(bs, None, None), check_vma=False)
    return fn(x, p["w_gate"], p["w_up"], p["w_down"])


# ------------------------------------------------------------------ stack


def init_decoder_params(key, cfg: ModelConfig, L: Optional[int] = None):
    """Stacked decoder-layer params for the scanned stack."""
    L = cfg.n_layers if L is None else L
    ks = split_keys(key, 4)
    p: Dict[str, Any] = {
        "ln1": jnp.ones((L, cfg.d_model)),
        "ln2": jnp.ones((L, cfg.d_model)),
    }
    if cfg.mla is not None:
        from repro.models.mla import init_mla_params
        p["attn"] = init_mla_params(ks[0], cfg, L)
    else:
        p["attn"] = init_attn_params(ks[0], cfg, L)
    if cfg.moe is not None:
        p["moe"] = init_moe_params(ks[1], cfg, L)
    else:
        p["mlp"] = init_ffn_params(ks[1], cfg, L)
    return p


def _layer_train(cfg: ModelConfig, lp, x, positions):
    from jax.ad_checkpoint import checkpoint_name
    if cfg.mla is not None:
        from repro.models.mla import mla_block_train
        a, _ = mla_block_train(cfg, lp["attn"], rms_norm(x, lp["ln1"],
                                                         cfg.norm_eps),
                               positions)
    else:
        a, _, _ = attn_block_train(cfg, lp["attn"],
                                   rms_norm(x, lp["ln1"], cfg.norm_eps),
                                   positions)
    # Named so the 'save_collectives' remat policy can keep the psum'd
    # block outputs: the backward recompute then re-runs only *local*
    # math — no re-all-reduce (EXPERIMENTS.md §Perf iteration 3).
    a = checkpoint_name(a, "tp_psum")
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = moe_block(cfg, lp["moe"], h)
    else:
        f, aux = ffn_block(cfg, lp["mlp"], h), jnp.float32(0.0)
    f = checkpoint_name(f, "tp_psum")
    x = x + f
    return shd(x, DP, None, None), aux


def decoder_stack_train(cfg: ModelConfig, params, x, positions, *,
                        remat=True):
    """Returns (x, total MoE load-balance aux loss).

    remat: False | True (recompute everything, collectives included) |
    'save_collectives' (recompute local math only; the two psum'd block
    outputs per layer are saved — 4 instead of 6 all-reduces per layer
    at the cost of 2 activations/layer of residency).
    """

    def body(carry, lp):
        x, aux = carry
        fn = _layer_train
        if remat == "save_collectives":
            fn = jax.checkpoint(
                _layer_train, static_argnums=(0,),
                policy=jax.checkpoint_policies.save_only_these_names(
                    "tp_psum"))
        elif remat:
            fn = jax.checkpoint(_layer_train, static_argnums=(0,))
        x, a = fn(cfg, lp, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params)
    return x, aux


def _layer_prefill(cfg: ModelConfig, lp, x, positions, k_pool, v_pool, ctx,
                   prefix_kv=None, tok_offset: int = 0):
    """Like train, but also scatters this layer's K/V into its pool slice.

    ``prefix_kv``: this layer's cached-prefix K/V ([B, P, Hkv, dh] pair)
    for suffix-only prefill; the cached pages themselves are NOT
    re-written (``tok_offset`` masks them out of the scatter) — the
    host-tier fault-in restores them from the prefix cache instead.
    """
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        assert prefix_kv is None, "prefix-cache reuse unsupported for MLA"
        from repro.models.mla import mla_block_train
        a, lat = mla_block_train(cfg, lp["attn"], h, positions)
        k_pool, v_pool = prefill_write_op(lat["k"], lat["v"], k_pool,
                                          v_pool, ctx)
    else:
        a, k, v = attn_block_train(cfg, lp["attn"], h, positions,
                                   prefix_kv=prefix_kv)
        k_pool, v_pool = prefill_write_op(k, v, k_pool, v_pool, ctx,
                                          tok_offset=tok_offset)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    f = moe_block(cfg, lp["moe"], h)[0] if cfg.moe is not None else \
        ffn_block(cfg, lp["mlp"], h)
    return shd(x + f, DP, None, None), k_pool, v_pool


def decoder_stack_prefill(cfg: ModelConfig, params, x, positions, pools, ctx,
                          prefix_kv=None, tok_offset: int = 0):
    """pools: (k_pool [L,...], v_pool [L,...]) stacked over layers.

    ``prefix_kv``: stacked cached-prefix K/V ([L, B, P, Hkv, dh] pair)
    for suffix-only prefill (prefix-cache reuse, DESIGN.md §8); each
    layer's slice rides the scan alongside its pool slice."""
    k_pools, v_pools = pools

    def body(carry, inp):
        x = carry
        l, lp = inp
        pkv = (None if prefix_kv is None
               else (prefix_kv[0][l], prefix_kv[1][l]))
        x, kp, vp = _layer_prefill(cfg, lp, x, positions,
                                   k_pools[l], v_pools[l], ctx,
                                   prefix_kv=pkv, tok_offset=tok_offset)
        return x, (kp, vp)

    L = k_pools.shape[0]
    x, (kp, vp) = jax.lax.scan(body, x, (jnp.arange(L), params))
    return x, (kp, vp)


def decoder_stack_decode(cfg: ModelConfig, params, x, pos, pools, ctx):
    k_pools, v_pools = pools

    def body(carry, inp):
        x, kps, vps = carry
        l, lp = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        # Staging pools arrive layer-stacked [L, NS, ...] (DESIGN.md §13);
        # each layer's attention drains its own slice.
        lctx = ctx if ctx.stage_k is None else dataclasses.replace(
            ctx, stage_k=ctx.stage_k[l], stage_v=ctx.stage_v[l])
        if cfg.mla is not None:
            from repro.models.mla import mla_block_decode
            a, kp, vp = mla_block_decode(cfg, lp["attn"], h, pos,
                                         kps[l], vps[l], lctx)
        else:
            a, kp, vp = attn_block_decode(cfg, lp["attn"], h, pos,
                                          kps[l], vps[l], lctx)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f = moe_block(cfg, lp["moe"], h)[0] if cfg.moe is not None else \
            ffn_block(cfg, lp["mlp"], h)
        x = x + f
        kps = kps.at[l].set(kp)
        vps = vps.at[l].set(vp)
        return (x, kps, vps), None

    L = k_pools.shape[0]
    (x, k_pools, v_pools), _ = jax.lax.scan(
        body, (x, k_pools, v_pools), (jnp.arange(L), params))
    return x, (k_pools, v_pools)
