"""Shared model utilities: sharding hints, init, dtype handling."""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------- batch axes
#
# Which mesh axes the *batch* dimension shards over is a run-level choice:
#   megatron (default): ("pod", "data") — 'model' carries TP/EP.
#   fsdp:               ("pod", "data", "model") — every axis is data
#                       parallel; weights stream via per-layer all-gathers
#                       (ZeRO-3).  Selected by TrainHParams.parallelism.
# Model code marks batch dims with the BATCH sentinel; shd() resolves it
# against this context at trace time.

BATCH = "batch"
_BATCH_AXES = ("pod", "data")
_TP_MODE = "explicit"   # 'explicit' (shard_map TP blocks) | 'auto' (GSPMD)


def set_batch_axes(axes: Sequence[str]) -> None:
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def batch_axes() -> tuple:
    return _BATCH_AXES


def set_tp_mode(mode: str) -> None:
    global _TP_MODE
    _TP_MODE = mode


def tp_mode() -> str:
    return _TP_MODE


_SERVING = False


def set_serving_mode(on: bool) -> None:
    """Serving layouts differ from training (resident bf16 TP weights;
    2D expert-parallel MoE storage) — see launch/specs.py + models/moe.py."""
    global _SERVING
    _SERVING = bool(on)


def serving_mode() -> bool:
    return _SERVING


def shd(x: jax.Array, *spec) -> jax.Array:
    """Apply a sharding constraint if a mesh is ambient; no-op otherwise.

    Axis names that are absent from the ambient mesh are dropped, so the
    same model code runs on a laptop (no mesh), a single pod
    ``(data, model)``, and a multi-pod ``(pod, data, model)`` mesh.
    Compound entries (tuples of names) are filtered element-wise.  The
    BATCH sentinel resolves to the current batch-axes context; an axis
    already consumed by an earlier entry is dropped (e.g. the 'model'
    head-sharding hint degrades to replicated under fsdp, where 'model'
    belongs to the batch).
    """
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)
    used: set = set()

    def keep(e):
        if e == BATCH:
            e = _BATCH_AXES
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(n for n in e if n in names and n not in used)
            used.update(kept)
            return kept if kept else None
        if e in names and e not in used:
            used.add(e)
            return e
        return None

    return jax.lax.with_sharding_constraint(x, P(*[keep(e) for e in spec]))


def psum_point(x: jax.Array) -> jax.Array:
    """Pin the tensor-parallel all-reduce at this tensor's dtype.

    Placed between a row-parallel matmul output (bf16) and the residual
    add / next norm (whose fp32 upcast XLA's convert-mover otherwise
    hoists *through* the all-reduce, doubling its wire bytes — measured
    2x on llama3 train_4k, EXPERIMENTS.md §Perf iteration 2).  The
    barrier is linear, so its transpose pins the backward all-reduce at
    the cotangent's dtype at the same point.
    """
    from repro.compat import optimization_barrier
    return optimization_barrier(x)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-style), stored in fp32."""
    fan_in = shape[in_axis] if shape else 1
    scale = 1.0 / max(1.0, fan_in) ** 0.5
    return scale * jax.random.truncated_normal(key, -3, 3, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
