"""Model zoo: pure-JAX implementations of the 10 assigned architectures."""

from repro.models.lm import LM
from repro.models.transformer import PageCtx

__all__ = ["LM", "PageCtx"]
