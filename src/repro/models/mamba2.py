"""Mamba-2 (SSD — state-space duality) blocks: chunked train scan + decode.

Faithful to the minimal-SSD formulation of arXiv:2405.21060 §6: per chunk,
a quadratic intra-chunk term (the "duality" — it is an attention-like
matmul, MXU-friendly) plus an inter-chunk linear recurrence on the
[heads, head_dim, d_state] state.  Decode is the O(1) recurrent update —
which is why Mosaic's paged-KV path is N/A for this family (DESIGN.md §4).

Layout notes (TPU): the intra-chunk einsums are arranged as
[B, n_chunks, Q, ...] batched matmuls with Q=chunk (default 256, a multiple
of 128) so the MXU sees well-shaped contractions; the inter-chunk
recurrence is a ``lax.scan`` over n_chunks with a [B, nh, hd, N] carry.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, shd, split_keys
from repro.models.layers import rms_norm

from repro.models.common import BATCH as DP  # batch sentinel


def state_shapes(cfg: ModelConfig, L: int, B: int):
    """(ssm_state, conv_state) shapes for a stacked L-layer SSM."""
    s = cfg.ssm
    d_in, nh, conv_dim = dims(cfg)
    return ((L, B, nh, s.head_dim, s.d_state),
            (L, B, s.d_conv - 1, conv_dim))


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nh, conv_dim


def init_mamba_params(key, cfg: ModelConfig, L: int) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = dims(cfg)
    proj = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    ks = split_keys(key, 4)
    return {
        "w_in": dense_init(ks[0], (L, d, proj), in_axis=1),
        "conv_w": dense_init(ks[1], (L, s.d_conv, conv_dim), in_axis=1),
        "conv_b": jnp.zeros((L, conv_dim)),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.linspace(1.0, 16.0, nh), (L, nh)).copy()),
        "D": jnp.ones((L, nh)),
        "dt_bias": jnp.zeros((L, nh)),
        "norm_w": jnp.ones((L, d_in)),
        "w_out": dense_init(ks[2], (L, d_in, d), in_axis=1),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv along T.  u [B,T,C], w [K,C], b [C]."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(K):
        out = out + pad[:, i: i + u.shape[1], :] * w[i]
    return out + b


def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_in, nh, _ = dims(cfg)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in: 2 * d_in + 2 * gn]
    dt = zxbcdt[..., 2 * d_in + 2 * gn:]
    return z, xBC, dt


def _head_expand(cfg: ModelConfig, Bc):
    """[B,T,G,N] -> [B,T,nh,N] by broadcasting groups over their heads."""
    s = cfg.ssm
    _, nh, _ = dims(cfg)
    hpg = nh // s.n_groups
    return jnp.repeat(Bc, hpg, axis=2)


USE_PALLAS_SSD = False   # flip on real TPUs (interpret=False); the jnp
                         # path below is the oracle and the dry-run path.


def ssd_chunked(xh, dt, A, Bh, Ch, chunk: int, h0=None):
    """Chunked SSD scan (pure JNP oracle for the Pallas ``ssd_scan`` kernel).

    xh [B,T,nh,hd]; dt [B,T,nh] (post-softplus); A [nh] (negative);
    Bh/Ch [B,T,nh,N].  Returns (y [B,T,nh,hd], h_final [B,nh,hd,N]).
    """
    if USE_PALLAS_SSD:
        from repro.kernels.ssd_scan import ssd_scan as _kernel
        return _kernel(xh, dt.astype(jnp.float32), A, Bh, Ch, chunk=chunk,
                       h0=h0)
    Bsz, T, nh, hd = xh.shape
    N = Bh.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    r = lambda t: t.reshape(Bsz, nc, chunk, *t.shape[2:])
    xc, dtc, Bc, Cc = r(xh), r(dt), r(Bh), r(Ch)
    xdt = xc * dtc[..., None]                      # dt-weighted input
    dA = dtc * A[None, None, None, :]              # [B,nc,Q,nh]
    cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum
    # Intra-chunk (duality: attention-like lower-triangular matmul).
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Q,Q,nh]
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # Mask *before* exp: exp of a masked (i<j) positive segment overflows and
    # poisons gradients through the where (classic where-grad pitfall).
    Ldec = jnp.exp(jnp.where(causal, seg, -1e30))
    scores = jnp.einsum("bclhn,bcshn->bclsh", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    scores = scores * Ldec                                 # [B,nc,Q,Q,nh]
    y_diag = jnp.einsum("bclsh,bcshp->bclhp", scores,
                        xdt.astype(jnp.float32))
    # Chunk-final states.
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,Q,nh]
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        Bc.astype(jnp.float32),
                        decay_states, xdt.astype(jnp.float32))
    # Inter-chunk recurrence.
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,nc,nh]

    def body(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h                                    # emit h_{c-1}

    h_init = (jnp.zeros((Bsz, nh, hd, N), jnp.float32)
              if h0 is None else h0.astype(jnp.float32))
    h_last, h_prev = jax.lax.scan(
        body,
        h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)               # [B,nc,nh,hd,N]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Cc.astype(jnp.float32), h_prev, jnp.exp(cum))
    y = (y_diag + y_off).reshape(Bsz, T, nh, hd)
    return y, h_last


def mamba_block_train(cfg: ModelConfig, p, x, *, h0=None, conv0=None,
                      return_state: bool = False):
    """x [B,T,d] -> y [B,T,d] (+ optional (h_final, conv_tail) for prefill)."""
    s = cfg.ssm
    d_in, nh, conv_dim = dims(cfg)
    zxbcdt = jnp.einsum("btd,dp->btp", x, p["w_in"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC_pre = xBC                                          # pre-conv (cache)
    if conv0 is not None:
        xBC_in = jnp.concatenate([conv0, xBC], axis=1)
        xBC = _causal_conv(xBC_in, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    gn = s.n_groups * s.d_state
    xp = xBC[..., :d_in]
    Bg = xBC[..., d_in: d_in + gn].reshape(*x.shape[:2], s.n_groups, s.d_state)
    Cg = xBC[..., d_in + gn:].reshape(*x.shape[:2], s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xp.reshape(*x.shape[:2], nh, s.head_dim)
    Bh, Ch = _head_expand(cfg, Bg), _head_expand(cfg, Cg)
    # Pad T to a chunk multiple; dt=0 padding is inert (decay 1, no input).
    T = x.shape[1]
    chunk = min(s.chunk, T)
    pad = (-T) % chunk
    if pad:
        pt = ((0, 0), (0, pad))
        xh = jnp.pad(xh, (*pt, (0, 0), (0, 0)))
        dt = jnp.pad(dt, (*pt, (0, 0)))
        Bh = jnp.pad(Bh, (*pt, (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, (*pt, (0, 0), (0, 0)))
    y, h_last = ssd_chunked(xh, dt, A, Bh, Ch, chunk, h0=h0)
    if pad:
        y = y[:, :T]
        xh = xh[:, :T]
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("btp,pd->btd", y, p["w_out"])
    if return_state:
        ctx = (jnp.zeros((x.shape[0], s.d_conv - 1, conv_dim), x.dtype)
               if conv0 is None else conv0)
        conv_tail = jnp.concatenate([ctx, xBC_pre], axis=1)[:, -(s.d_conv - 1):]
        return out, (h_last, conv_tail)
    return out


def init_ssm_stack_params(key, cfg: ModelConfig, L: int):
    ks = split_keys(key, 2)
    return {"ln": jnp.ones((L, cfg.d_model)),
            "mamba": init_mamba_params(ks[0], cfg, L)}


def ssm_stack_train(cfg: ModelConfig, params, x, *, remat: bool = True):
    def layer(cfg, lp, ln, x):
        return shd(x + mamba_block_train(cfg, lp, rms_norm(x, ln,
                                                           cfg.norm_eps)),
                   DP, None, None)

    def body(x, inp):
        ln, lp = inp
        fn = jax.checkpoint(layer, static_argnums=(0,)) if remat else layer
        return fn(cfg, lp, ln, x), None

    x, _ = jax.lax.scan(body, x, (params["ln"], params["mamba"]))
    return x


def ssm_stack_prefill(cfg: ModelConfig, params, x):
    """Returns (x, ssm_states [L,B,nh,hd,N], conv_states [L,B,K-1,cd])."""

    def body(x, inp):
        ln, lp = inp
        y, (h, conv) = mamba_block_train(
            cfg, lp, rms_norm(x, ln, cfg.norm_eps), return_state=True)
        return shd(x + y, DP, None, None), (h, conv)

    x, (hs, convs) = jax.lax.scan(body, x, (params["ln"], params["mamba"]))
    return x, hs, convs


def ssm_stack_decode(cfg: ModelConfig, params, x, ssm_state, conv_state):
    def body(carry, inp):
        x = carry[0]
        ln, lp, h, conv = inp
        y, h_new, conv_new = mamba_block_decode(
            cfg, lp, rms_norm(x, ln, cfg.norm_eps), h, conv)
        return (x + y,), (h_new, conv_new)

    (x,), (hs, convs) = jax.lax.scan(
        body, (x,), (params["ln"], params["mamba"], ssm_state, conv_state))
    return x, hs, convs


def mamba_block_decode(cfg: ModelConfig, p, x, h, conv_cache):
    """One-token recurrent update.

    x [B,1,d]; h [B,nh,hd,N]; conv_cache [B,d_conv-1,conv_dim]
    -> (y [B,1,d], h', conv_cache')
    """
    s = cfg.ssm
    d_in, nh, conv_dim = dims(cfg)
    zxbcdt = jnp.einsum("btd,dp->btp", x, p["w_in"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([conv_cache, xBC], axis=1)    # [B,d_conv,cd]
    conv_out = (window * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
    conv_cache = window[:, 1:]
    xBC1 = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    gn = s.n_groups * s.d_state
    xp = xBC1[..., :d_in]
    Bg = xBC1[..., d_in: d_in + gn].reshape(-1, s.n_groups, s.d_state)
    Cg = xBC1[..., d_in + gn:].reshape(-1, s.n_groups, s.d_state)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    hpg = nh // s.n_groups
    Bh = jnp.repeat(Bg, hpg, axis=1)                       # [B,nh,N]
    Ch = jnp.repeat(Cg, hpg, axis=1)
    xh = xp.reshape(-1, nh, s.head_dim).astype(jnp.float32)
    dA = jnp.exp(dt1 * A[None, :])                         # [B,nh]
    h = h * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt1, xh, Bh.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(-1, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_w"], cfg.norm_eps)
    return jnp.einsum("btp,pd->btd", y, p["w_out"]), h, conv_cache
