"""Core layers: norms, RoPE, blockwise (flash-style) attention, SwiGLU.

Attention here is the *pure-JAX* implementation with flash-style blockwise
online softmax — it is both (a) what the dry-run lowers (so compiled memory
is O(T·block) not O(T²), like the Pallas kernel would be on real TPUs) and
(b) the oracle the Pallas kernels are verified against.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import BATCH, psum_point, shd

NEG_INF = -1e30


# ----------------------------------------------------------------- norms


def rms_norm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


# ----------------------------------------------------------------- RoPE


def rope_angles(positions, dim: int, theta: float):
    """positions [..., T] -> (cos, sin) [..., T, dim/2], fp32."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., T, H, dh]; cos/sin broadcastable [..., T, 1, dh/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rope(x, positions, theta: float):
    cos, sin = rope_angles(positions, x.shape[-1], theta)
    return apply_rope(x, cos[..., :, None, :], sin[..., :, None, :])


# ------------------------------------------------- blockwise attention


def _block_attn_scan(q, k, v, q_offset, causal: bool, kv_len, block: int,
                     scale: float):
    """Online-softmax attention: scan over KV blocks.

    q: [B, Tq, H, dh]   k/v: [B, Tk, Hkv, dh]  (Tk padded to block multiple)
    kv_len: [B] valid KV length (None -> all valid)
    Returns [B, Tq, H, dh].
    """
    B, Tq, H, dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    dh_v = v.shape[-1]
    assert Tk % block == 0, (Tk, block)
    groups = H // Hkv
    nblk = Tk // block
    # [B, Hkv, groups, Tq, dh]: grouped GQA, no repeated K/V materialized.
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)
    qf = qf.reshape(B, Hkv, groups, Tq, dh)

    def body(carry, blk):
        m, l, o = carry
        kb = jax.lax.dynamic_slice_in_dim(k, blk * block, block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, blk * block, block, axis=1)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        # scores [B, Hkv, groups, Tq, block]
        s = jnp.einsum("bngqd,bknd->bngqk", qf, kb)
        kpos = blk * block + jnp.arange(block)
        mask = jnp.ones((B, 1, 1, Tq, block), dtype=bool)
        if causal:
            qpos = q_offset + jnp.arange(Tq)
            mask &= (kpos[None, None, None, None, :]
                     <= qpos[None, None, None, :, None])
        if kv_len is not None:
            mask &= kpos[None, None, None, None, :] < kv_len[
                :, None, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum("bngqk,bknd->bngqd", p, vb)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Hkv, groups, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, groups, Tq), jnp.float32)
    o0 = jnp.zeros((B, Hkv, groups, Tq, dh_v), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), jnp.arange(nblk))
    o = o / jnp.maximum(l[..., None], 1e-30)
    o = o.reshape(B, H, Tq, dh_v).transpose(0, 2, 1, 3)
    return o.astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, q_offset=0,
              kv_len=None, block: int = 512, scale: Optional[float] = None):
    """Flash-style blockwise multi-head attention (GQA via head groups).

    Pads KV to a block multiple; masking handles the tail.
    """
    dh = q.shape[-1]
    scale = scale if scale is not None else dh ** -0.5
    Tk = k.shape[1]
    block = min(block, max(Tk, 1))
    pad = (-Tk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((q.shape[0],), Tk, jnp.int32)
    return _block_attn_scan(q, k, v, q_offset, causal, kv_len, block, scale)


# ----------------------------------------------------------------- MLPs


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("btd,df->btf", x, w_gate)
    u = jnp.einsum("btd,df->btf", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shd(h, BATCH, None, "model")
    return psum_point(jnp.einsum("btf,fd->btd", h, w_down))


def gqa_qkv(x, wq, wk, wv, bq=None, bk=None, bv=None):
    """x [B,T,d] -> q [B,T,H,dh], k/v [B,T,Hkv,dh]."""
    q = jnp.einsum("btd,dhk->bthk", x, wq)
    k = jnp.einsum("btd,dhk->bthk", x, wk)
    v = jnp.einsum("btd,dhk->bthk", x, wv)
    if bq is not None:
        q = q + bq
        k = k + bk
        v = v + bv
    return q, k, v
