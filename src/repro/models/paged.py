"""Paged KV-cache: the model-side consumer of Mosaic page tables.

Layout (per layer, per page-shard):
  k_pool / v_pool : [num_pages_local, page_tokens, n_kv, head_dim]
  latent_pool     : [num_pages_local, page_tokens, kv_lora + rope_dim]  (MLA)

The serving engine assigns each sequence to a data shard and spreads its
pages across that shard's sub-pools (one per model-axis shard) — frames
never straddle sub-pools, so CoCoA/coalescing operate shard-locally
(DESIGN.md §3, SP).  Device-side state is addressed through *packed local
tables* prepared by :class:`repro.serving.kv_cache.ShardedKVCache`:

  tables  : int32 [B, S, mpps]  local page id (-1 = hole)
  ntok    : int32 [B, S, mpps]  valid tokens in that page
  wpage   : int32 [B, S]        local page holding the current write slot
  wslot   : int32 [B]           slot within the write page

Attention across sub-pools uses partial flash-softmax stats combined with
``psum``/``pmax`` over the page-shard mesh axes; each shard computes an
*unnormalized* (o, m, l) over its local pages only.  This file is the pure
JNP oracle; ``repro.kernels.paged_attention`` is the Pallas TPU kernel with
the dual-granularity (coalesced-frame fast path vs base-page gather) that
realizes the paper's TLB-reach benefit.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def write_kv(k_pool, v_pool, k_new, v_new, wpage, wslot):
    """Write one new token's K/V into the local sub-pool (decode step).

    k_new/v_new: [B, n_kv, dh]; wpage: [B] local page id (-1: not owned
    here); wslot: [B].  Returns updated pools.
    """
    # Rows not owned by this shard (wpage == -1) scatter out of bounds and
    # are dropped — never clamp to page 0 (a live page): duplicate scatter
    # indices with different payloads are order-undefined.
    target = jnp.where(wpage >= 0, wpage, k_pool.shape[0])

    def upd(pool, new):
        return pool.at[target, wslot].set(new.astype(pool.dtype),
                                          mode="drop")

    return upd(k_pool, k_new), upd(v_pool, v_new)


def write_latent(latent_pool, lat_new, wpage, wslot):
    """MLA variant: lat_new [B, kv_lora+rope].  Holes drop (see write_kv)."""
    target = jnp.where(wpage >= 0, wpage, latent_pool.shape[0])
    return latent_pool.at[target, wslot].set(
        lat_new.astype(latent_pool.dtype), mode="drop")


def write_prefill_kv(k_pool, v_pool, k_seq, v_seq, tables, *,
                     shard_idx=0, n_shards: int = 1, frame_pages: int = 16,
                     tok_offset: int = 0):
    """Scatter a prefilled sequence's KV into the local sub-pool en masse.

    k_seq/v_seq: [B, T, n_kv, dh] (T multiple of page_tokens; the full
    sequence is replicated across page shards);
    tables: [B, mpps] local page ids owned by THIS shard, in local vpn
    order (-1 holes).  Pages stripe over shards by *frame* round-robin
    (global frame f lives on shard f % n_shards — the ShardedKVCache
    contract), so local page j of shard s backs global vpn

        ((s + (j // frame_pages) * n_shards) * frame_pages + j % frame_pages)

    and we gather that page's tokens from the replicated sequence.  With
    n_shards == 1 this degenerates to vpn == j (the single-shard and
    test path).

    ``tok_offset`` supports suffix-only prefill (prefix-cache reuse,
    DESIGN.md §8): ``k_seq`` then holds tokens ``[tok_offset,
    tok_offset + T)`` of the sequence, and only pages fully inside that
    window are written — the cached-prefix pages ahead of the window are
    restored by the host-tier fault-in path instead.  ``tok_offset`` must
    be a page multiple.
    """
    B, T, n_kv, dh = k_seq.shape
    dh_v = v_seq.shape[-1]                                # may differ (MLA)
    ptok = k_pool.shape[1]
    assert T % ptok == 0
    assert tok_offset % ptok == 0, (tok_offset, ptok)
    m = tables.shape[1]
    j = jnp.arange(m)
    gframe = shard_idx + (j // frame_pages) * n_shards
    vpn = gframe * frame_pages + (j % frame_pages)        # [m]
    tok0 = vpn * ptok
    tb = tables.reshape(-1)                               # [B*m]
    own = (tb >= 0) & jnp.tile(
        (tok0 >= tok_offset) & (tok0 < tok_offset + T), B)
    idx = jnp.clip(tok0[:, None] - tok_offset
                   + jnp.arange(ptok)[None, :], 0, T - 1)
    # Holes scatter out of bounds and are dropped (never clamp to a live
    # page: duplicate scatter indices with different payloads are
    # order-undefined).
    NP = k_pool.shape[0]
    target = jnp.where(own, tb, NP)

    def upd(pool, seq):
        new = seq[:, idx].reshape(B * m, ptok, n_kv, seq.shape[-1])
        return pool.at[target].set(new.astype(pool.dtype), mode="drop")

    return upd(k_pool, k_seq), upd(v_pool, v_seq)


def paged_attention_local(
    q, k_pool, v_pool, tables, ntok, *, scale: Optional[float] = None,
    page_block: int = 8,
    stage_k=None, stage_v=None, slots=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partial paged attention over this shard's pages (pure-JNP oracle).

    q:      [B, H, dh] single decode query per sequence
    tables: [B, mpps] local page ids; ntok: [B, mpps] valid tokens/page
    Returns unnormalized (o [B,H,dh], m [B,H], l [B,H]) fp32 partials to be
    flash-combined across page shards.

    ``stage_k``/``stage_v`` [NS, ptok, n_kv, dh{,_v}] + ``slots``
    [B, mpps] implement fused gather-attend over partially-resident KV
    (DESIGN.md §13): a page whose slot is >= 0 is read from the staging
    region at that slot instead of the pool — the readiness mask.  The
    accumulation order is unchanged (each block still folds in at its
    canonical position, only the load source differs), so when the
    staged bytes equal what a scatter would have written the result is
    bitwise-identical to the slot-free call.  ``slots=None`` keeps the
    classic all-resident path byte-for-byte.
    """
    B, H, dh = q.shape
    npages_pool, ptok, n_kv, _ = k_pool.shape
    dh_v = v_pool.shape[-1]                               # may differ (MLA)
    mpps = tables.shape[1]
    groups = H // n_kv
    scale = scale if scale is not None else dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    pb = min(page_block, mpps)
    pad = (-mpps) % pb
    if pad:
        tables = jnp.pad(tables, ((0, 0), (0, pad)), constant_values=-1)
        ntok = jnp.pad(ntok, ((0, 0), (0, pad)))
        if slots is not None:
            slots = jnp.pad(slots, ((0, 0), (0, pad)), constant_values=-1)
        mpps += pad
    nblk = mpps // pb

    def body(carry, blk):
        m, l, o = carry
        tb = jax.lax.dynamic_slice_in_dim(tables, blk * pb, pb, axis=1)
        nt = jax.lax.dynamic_slice_in_dim(ntok, blk * pb, pb, axis=1)
        safe = jnp.maximum(tb, 0)
        k = k_pool[safe]                                  # [B, pb, ptok, n_kv, dh]
        v = v_pool[safe]
        if slots is not None:
            sl = jax.lax.dynamic_slice_in_dim(slots, blk * pb, pb, axis=1)
            sel = (sl >= 0)[..., None, None, None]
            ssafe = jnp.maximum(sl, 0)
            k = jnp.where(sel, stage_k[ssafe], k)
            v = jnp.where(sel, stage_v[ssafe], v)
        k = k.reshape(B, pb * ptok, n_kv, dh).astype(jnp.float32)
        v = v.reshape(B, pb * ptok, n_kv, dh_v).astype(jnp.float32)
        # Grouped GQA scores without materializing repeated K/V.
        s = jnp.einsum("bngd,bknd->bngk", qg, k)          # [B,n_kv,g,K]
        slot = jnp.arange(ptok)[None, None, :]
        valid = (tb >= 0)[:, :, None] & (slot < nt[:, :, None])
        valid = valid.reshape(B, 1, 1, pb * ptok)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum("bngk,bknd->bngd", p, v)
        return (m_new, l_new, o_new), None

    qg = qf.reshape(B, n_kv, groups, dh)
    m0 = jnp.full((B, n_kv, groups), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, n_kv, groups), jnp.float32)
    o0 = jnp.zeros((B, n_kv, groups, dh_v), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), jnp.arange(nblk))
    return (o.reshape(B, H, dh_v), m.reshape(B, H), l.reshape(B, H))


def paged_attention_latent_local(
    q_lat, q_rope, latent_pool, tables, ntok, *, scale: float,
    kv_lora: int, page_block: int = 8,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """MLA absorbed-form paged attention over the compressed latent cache.

    q_lat:  [B, H, kv_lora]   (q_nope absorbed through W_UK)
    q_rope: [B, H, rope_dim]
    latent_pool: [np_local, ptok, kv_lora + rope_dim]
    Returns unnormalized (o [B,H,kv_lora], m, l): the 'values' are the
    latents themselves; the caller up-projects once via W_UV after combine.
    """
    B, H, _ = q_lat.shape
    _, ptok, lat_dim = latent_pool.shape
    mpps = tables.shape[1]
    pb = min(page_block, mpps)
    nblk = mpps // pb
    qf = jnp.concatenate([q_lat, q_rope], axis=-1).astype(jnp.float32) * scale

    def body(carry, blk):
        m, l, o = carry
        tb = jax.lax.dynamic_slice_in_dim(tables, blk * pb, pb, axis=1)
        nt = jax.lax.dynamic_slice_in_dim(ntok, blk * pb, pb, axis=1)
        safe = jnp.maximum(tb, 0)
        lat = latent_pool[safe].reshape(B, pb * ptok, lat_dim).astype(jnp.float32)
        s = jnp.einsum("bhd,bkd->bhk", qf, lat)
        slot = jnp.arange(ptok)[None, None, :]
        valid = (tb >= 0)[:, :, None] & (slot < nt[:, :, None])
        valid = valid.reshape(B, 1, pb * ptok)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhk,bkd->bhd", p, lat[..., :kv_lora]
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    o0 = jnp.zeros((B, H, kv_lora), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), jnp.arange(nblk))
    return o, m, l


def combine_partials(o, m, l, axes) -> jax.Array:
    """Flash-combine (o, m, l) partials across mesh axes (inside shard_map)."""
    if axes:
        m_g = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axes)
        o_g = jax.lax.psum(o * corr[..., None], axes)
    else:
        l_g, o_g = l, o
    return o_g / jnp.maximum(l_g[..., None], 1e-30)
