"""Multi-head Latent Attention (DeepSeek-V2), train + absorbed decode.

Train/prefill: latent ``c = RMSNorm(x·W_DKV)`` is up-projected to per-head
K_nope/V and attention runs expanded (flash).  The *paged cache stores only
the compressed latent + shared RoPE key* (kv_lora + rope_dim per token —
the MLA memory win survives paging).

Decode uses the absorbed form: q_nope is pushed through W_UK once
(``q_lat = q_nope·W_UK``), scores are taken directly against the cached
latent, and the attention output (a latent-space vector) is up-projected
through W_UV *after* the flash combine — so the paged kernel never
materializes per-head K/V.  The pool is addressed with n_kv=1,
k-payload = [latent ‖ k_rope] (dim kv_lora+rope), v-payload = latent.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, shd, split_keys
from repro.models.layers import apply_rope, attention, rms_norm, rope_angles

from repro.models.common import BATCH as DP  # batch sentinel


def init_mla_params(key, cfg: ModelConfig, L: int) -> Dict[str, Any]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qdim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = split_keys(key, 6)
    return {
        "wq": dense_init(ks[0], (L, d, H, qdim), in_axis=1),
        "w_dkv": dense_init(ks[1], (L, d, m.kv_lora_rank), in_axis=1),
        "w_kr": dense_init(ks[2], (L, d, m.qk_rope_head_dim), in_axis=1),
        "kv_norm": jnp.ones((L, m.kv_lora_rank)),
        "w_uk": dense_init(ks[3], (L, m.kv_lora_rank, H, m.qk_nope_head_dim),
                           in_axis=1),
        "w_uv": dense_init(ks[4], (L, m.kv_lora_rank, H, m.v_head_dim),
                           in_axis=1),
        "wo": dense_init(ks[5], (L, H, m.v_head_dim, d), in_axis=1),
    }


def _q_and_latent(cfg: ModelConfig, p, x, positions):
    """Shared projections: roped q halves + normalized latent + roped k_rope."""
    m = cfg.mla
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim:]
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[..., :, None, :], sin[..., :, None, :])
    lat = jnp.einsum("btd,dk->btk", x, p["w_dkv"])
    lat = rms_norm(lat, p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("btd,dk->btk", x, p["w_kr"])
    k_rope = apply_rope(k_rope[:, :, None, :], cos[..., :, None, :],
                        sin[..., :, None, :])[:, :, 0, :]
    return q_nope, q_rope, lat, k_rope


def mla_block_train(cfg: ModelConfig, p, x, positions,
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Expanded attention; returns (out, paged-cache payloads)."""
    m = cfg.mla
    q_nope, q_rope, lat, k_rope = _q_and_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("btk,khn->bthn", lat, p["w_uk"])
    v = jnp.einsum("btk,khn->bthn", lat, p["w_uv"])
    H = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_rope.shape[:2], H, m.qk_rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = shd(q, DP, None, "model", None)
    o = attention(q, k, v, causal=True,
                  scale=(m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    o = shd(o, DP, None, "model", None)
    out = jnp.einsum("bthv,hvd->btd", o, p["wo"])
    payload = {
        "k": jnp.concatenate([lat, k_rope], axis=-1)[:, :, None, :],
        "v": lat[:, :, None, :],
    }
    return out, payload


def mla_block_decode(cfg: ModelConfig, p, x, pos, k_pool, v_pool, ctx):
    """Absorbed-form decode over the latent paged pool.

    x [B,1,d]; pools: k [NP,ptok,1,lora+rope], v [NP,ptok,1,lora].
    """
    from repro.models.transformer import paged_attn_op
    m = cfg.mla
    q_nope, q_rope, lat, k_rope = _q_and_latent(cfg, p, x, pos[:, None])
    # Absorb W_UK into the query: scores vs latent directly.
    q_lat = jnp.einsum("bhn,khn->bhk", q_nope[:, 0], p["w_uk"])
    q_eff = jnp.concatenate([q_lat, q_rope[:, 0]], axis=-1)  # [B,H,lora+rope]
    k_new = jnp.concatenate([lat, k_rope], axis=-1)[:, 0, None, :]
    v_new = lat[:, 0, None, :]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    o_lat, k_pool, v_pool = paged_attn_op(
        q_eff, k_new, v_new, k_pool, v_pool, ctx, scale=scale)
    o = jnp.einsum("bhk,khv->bhv", o_lat.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])[:, None, :]
    return out, k_pool, v_pool
