"""Encoder–decoder stack (seamless-m4t-large-v2 backbone).

Encoder: bidirectional transformer over stub audio-frame embeddings.
Decoder: causal self-attention (Mosaic-paged at decode) + cross-attention
to the encoder memory.  Cross K/V are computed once per layer at prefill
and cached densely — an en-masse, read-only allocation that would be 100%
coalesced in the pool (kept dense for clarity; noted in DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import shd, split_keys
from repro.models.layers import attention, rms_norm
from repro.models.transformer import (
    DP,
    PageCtx,
    attn_block_decode,
    attn_block_train,
    init_attn_params,
    init_ffn_params,
    ffn_block,
    prefill_write_op,
)

def _dense_view(cfg: ModelConfig, L: int) -> ModelConfig:
    return dataclasses.replace(cfg, n_layers=L, moe=None, mla=None)


def init_encdec_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    e = cfg.encdec
    ks = split_keys(key, 6)
    enc = {
        "ln1": jnp.ones((e.enc_layers, cfg.d_model)),
        "ln2": jnp.ones((e.enc_layers, cfg.d_model)),
        "attn": init_attn_params(ks[0], cfg, e.enc_layers),
        "mlp": init_ffn_params(ks[1], cfg, e.enc_layers),
    }
    dec = {
        "ln1": jnp.ones((e.dec_layers, cfg.d_model)),
        "ln_cross": jnp.ones((e.dec_layers, cfg.d_model)),
        "ln2": jnp.ones((e.dec_layers, cfg.d_model)),
        "attn": init_attn_params(ks[2], cfg, e.dec_layers),
        "cross": init_attn_params(ks[3], cfg, e.dec_layers),
        "mlp": init_ffn_params(ks[4], cfg, e.dec_layers),
    }
    return {"encoder": enc, "decoder": dec,
            "enc_norm": jnp.ones((cfg.d_model,))}


def encoder_apply(cfg: ModelConfig, params, src, *, remat: bool = True):
    """src [B,S,d] stub frame embeddings -> memory [B,S,d]."""
    positions = jnp.broadcast_to(
        jnp.arange(src.shape[1])[None], src.shape[:2])

    def layer(cfg, lp, x):
        a, _, _ = attn_block_train(cfg, lp["attn"],
                                   rms_norm(x, lp["ln1"], cfg.norm_eps),
                                   positions, causal=False)
        x = x + a
        f = ffn_block(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return shd(x + f, DP, None, None)

    def body(x, lp):
        fn = jax.checkpoint(layer, static_argnums=(0,)) if remat else layer
        return fn(cfg, lp, x), None

    x, _ = jax.lax.scan(body, src, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg: ModelConfig, cp, memory):
    """Per-layer cross K/V from encoder memory: [B,S,Hkv,dh] each."""
    k = jnp.einsum("bsd,dhk->bshk", memory, cp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, cp["wv"])
    return k, v


def _cross_attend(cfg: ModelConfig, cp, h, ck, cv):
    """h [B,T,d] queries against cached cross K/V."""
    q = jnp.einsum("btd,dhk->bthk", h, cp["wq"])
    q = shd(q, DP, None, "model", None)
    o = attention(q, ck, cv, causal=False)
    o = shd(o, DP, None, "model", None)
    return jnp.einsum("bthd,hdk->btk", o, cp["wo"])


def decoder_stack_train(cfg: ModelConfig, params, x, positions, memory, *,
                        remat: bool = True):
    def layer(cfg, lp, x):
        a, _, _ = attn_block_train(cfg, lp["attn"],
                                   rms_norm(x, lp["ln1"], cfg.norm_eps),
                                   positions)
        x = x + a
        ck, cv = _cross_kv(cfg, lp["cross"], memory)
        c = _cross_attend(cfg, lp["cross"],
                          rms_norm(x, lp["ln_cross"], cfg.norm_eps), ck, cv)
        x = x + c
        f = ffn_block(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return shd(x + f, DP, None, None)

    def body(x, lp):
        fn = jax.checkpoint(layer, static_argnums=(0,)) if remat else layer
        return fn(cfg, lp, x), None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    return x


def decoder_stack_prefill(cfg: ModelConfig, params, x, positions, memory,
                          pools, ctx: PageCtx):
    """Returns (x, pools', cross_kv [L,...] cache for decode)."""
    k_pools, v_pools = pools

    def body(carry, inp):
        x = carry
        l, lp = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, k, v = attn_block_train(cfg, lp["attn"], h, positions)
        kp, vp = prefill_write_op(k, v, k_pools[l], v_pools[l], ctx)
        x = x + a
        ck, cv = _cross_kv(cfg, lp["cross"], memory)
        c = _cross_attend(cfg, lp["cross"],
                          rms_norm(x, lp["ln_cross"], cfg.norm_eps), ck, cv)
        x = x + c
        f = ffn_block(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return shd(x + f, DP, None, None), (kp, vp, ck, cv)

    L = k_pools.shape[0]
    x, (kp, vp, ck, cv) = jax.lax.scan(
        body, x, (jnp.arange(L), params["decoder"]))
    return x, (kp, vp), (ck, cv)


def decoder_stack_decode(cfg: ModelConfig, params, x, pos, pools, ctx,
                         cross_kv):
    k_pools, v_pools = pools
    cks, cvs = cross_kv

    def body(carry, inp):
        x, kps, vps = carry
        l, lp = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, kp, vp = attn_block_decode(cfg, lp["attn"], h, pos,
                                      kps[l], vps[l], ctx)
        x = x + a
        c = _cross_attend(cfg, lp["cross"],
                          rms_norm(x, lp["ln_cross"], cfg.norm_eps),
                          cks[l], cvs[l])
        x = x + c
        f = ffn_block(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        x = x + f
        kps = kps.at[l].set(kp)
        vps = vps.at[l].set(vp)
        return (x, kps, vps), None

    L = k_pools.shape[0]
    (x, k_pools, v_pools), _ = jax.lax.scan(
        body, (x, k_pools, v_pools), (jnp.arange(L), params["decoder"]))
    return x, (k_pools, v_pools)
