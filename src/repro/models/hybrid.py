"""Zamba2-style hybrid: Mamba2 backbone + shared attention block.

``n_layers`` Mamba2 blocks; after every ``period`` of them, a *single
shared* transformer block (attention + MLP, identical weights each
invocation) runs — its KV cache is Mosaic-paged, with one pool slice per
invocation (the activations differ per call even though weights are
shared).  The published model adds per-invocation LoRA deltas to the shared
block; we share weights exactly (disclosed in the config docstring).

Layout: groups of ``period`` mamba layers are scanned (params stacked
[G, period, ...]); the shared block runs eagerly between groups (G is
small); leftover mamba layers (n_layers % period) form a trailing scan.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import shd, split_keys
from repro.models.layers import rms_norm
from repro.models.mamba2 import (
    init_mamba_params,
    mamba_block_decode,
    mamba_block_train,
)
from repro.models.transformer import (
    DP,
    PageCtx,
    attn_block_decode,
    attn_block_train,
    ffn_block,
    init_attn_params,
    init_ffn_params,
    prefill_write_op,
)


def group_shape(cfg: ModelConfig) -> Tuple[int, int, int]:
    period = cfg.hybrid.period
    G = cfg.n_layers // period
    leftover = cfg.n_layers - G * period
    return G, period, leftover


def n_invocations(cfg: ModelConfig) -> int:
    return group_shape(cfg)[0]


def init_hybrid_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    G, period, leftover = group_shape(cfg)
    ks = split_keys(key, 5)
    grouped = init_mamba_params(ks[0], cfg, G * period)
    grouped = jax.tree.map(
        lambda a: a.reshape(G, period, *a.shape[1:]), grouped)
    p: Dict[str, Any] = {
        "mamba_ln": jnp.ones((G, period, cfg.d_model)),
        "mamba": grouped,
        "shared": {
            "ln1": jnp.ones((cfg.d_model,)),
            "ln2": jnp.ones((cfg.d_model,)),
            "attn": jax.tree.map(lambda a: a[0],
                                 init_attn_params(ks[1], cfg, 1)),
            "mlp": jax.tree.map(lambda a: a[0],
                                init_ffn_params(ks[2], cfg, 1)),
        },
    }
    if leftover:
        p["tail_ln"] = jnp.ones((leftover, cfg.d_model))
        p["tail"] = init_mamba_params(ks[3], cfg, leftover)
    return p


def _mamba_scan_train(cfg, lns, lps, x):
    def body(x, inp):
        ln, lp = inp
        y = mamba_block_train(cfg, lp, rms_norm(x, ln, cfg.norm_eps))
        return shd(x + y, DP, None, None), None

    x, _ = jax.lax.scan(body, x, (lns, lps))
    return x


def _shared_block_train(cfg, sp, x, positions, *, pools=None, ctx=None,
                        inv=None):
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    a, k, v = attn_block_train(cfg, sp["attn"], h, positions)
    if pools is not None:
        kp, vp = prefill_write_op(k, v, pools[0][inv], pools[1][inv], ctx)
        pools = (pools[0].at[inv].set(kp), pools[1].at[inv].set(vp))
    x = x + a
    f = ffn_block(cfg, sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
    return shd(x + f, DP, None, None), pools


def hybrid_stack_train(cfg: ModelConfig, params, x, positions, *,
                       pools=None, ctx: PageCtx = None):
    """Train/prefill path.  If pools given, prefill-writes shared-block KV."""
    G, period, leftover = group_shape(cfg)
    for g in range(G):
        lps = jax.tree.map(lambda a: a[g], params["mamba"])
        x = _mamba_scan_train(cfg, params["mamba_ln"][g], lps, x)
        x, pools = _shared_block_train(cfg, params["shared"], x, positions,
                                       pools=pools, ctx=ctx, inv=g)
    if leftover:
        x = _mamba_scan_train(cfg, params["tail_ln"], params["tail"], x)
    return x, pools


def _mamba_scan_prefill(cfg, lns, lps, x):
    def body(x, inp):
        ln, lp = inp
        y, (h, conv) = mamba_block_train(
            cfg, lp, rms_norm(x, ln, cfg.norm_eps), return_state=True)
        return shd(x + y, DP, None, None), (h, conv)

    return jax.lax.scan(body, x, (lns, lps))


def hybrid_stack_prefill(cfg: ModelConfig, params, x, positions, pools,
                         ctx: PageCtx):
    """Returns (x, pools', ssm_states [L,...], conv_states [L,...])."""
    G, period, leftover = group_shape(cfg)
    hs_all, conv_all = [], []
    for g in range(G):
        lps = jax.tree.map(lambda a: a[g], params["mamba"])
        x, (hs, convs) = _mamba_scan_prefill(cfg, params["mamba_ln"][g],
                                             lps, x)
        hs_all.append(hs)
        conv_all.append(convs)
        x, pools = _shared_block_train(cfg, params["shared"], x, positions,
                                       pools=pools, ctx=ctx, inv=g)
    if leftover:
        x, (hs, convs) = _mamba_scan_prefill(cfg, params["tail_ln"],
                                             params["tail"], x)
        hs_all.append(hs)
        conv_all.append(convs)
    return (x, pools, jnp.concatenate(hs_all, axis=0),
            jnp.concatenate(conv_all, axis=0))


def hybrid_stack_decode(cfg: ModelConfig, params, x, pos, pools, ctx,
                        ssm_state, conv_state):
    """Decode: recurrent mamba updates + paged shared-block attention.

    ssm_state [L, B, nh, hd, N]; conv_state [L, B, d_conv-1, conv_dim];
    pools: (k [G, NP, ...], v [G, NP, ...]).
    """
    G, period, leftover = group_shape(cfg)
    k_pools, v_pools = pools
    l = 0
    for g in range(G):
        for j in range(period):
            lp = jax.tree.map(lambda a: a[g, j], params["mamba"])
            h = rms_norm(x, params["mamba_ln"][g, j], cfg.norm_eps)
            y, s_new, c_new = mamba_block_decode(
                cfg, lp, h, ssm_state[l], conv_state[l])
            ssm_state = ssm_state.at[l].set(s_new)
            conv_state = conv_state.at[l].set(c_new)
            x = x + y
            l += 1
        sp = params["shared"]
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        a, kp, vp = attn_block_decode(cfg, sp["attn"], h, pos,
                                      k_pools[g], v_pools[g], ctx)
        k_pools = k_pools.at[g].set(kp)
        v_pools = v_pools.at[g].set(vp)
        x = x + a
        f = ffn_block(cfg, sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
        x = x + f
    for j in range(leftover):
        lp = jax.tree.map(lambda a: a[j], params["tail"])
        h = rms_norm(x, params["tail_ln"][j], cfg.norm_eps)
        y, s_new, c_new = mamba_block_decode(
            cfg, lp, h, ssm_state[l], conv_state[l])
        ssm_state = ssm_state.at[l].set(s_new)
        conv_state = conv_state.at[l].set(c_new)
        x = x + y
        l += 1
    return x, (k_pools, v_pools), ssm_state, conv_state
