"""Mixture-of-Experts: top-k router + capacity-based scatter dispatch.

Used by deepseek-v2-lite (64 routed top-6 + 2 shared, first layer dense)
and dbrx (16 routed top-4).  Dispatch is scatter/gather-based (GShard-style
capacity buffers without the O(S·E·C) one-hot einsum): tokens are placed
into per-expert capacity slots, experts run as one batched matmul sharded
over the ``model`` axis (EP), and outputs gather back with gate weights.

Distribution: the scatter/gather dispatch uses *batched indices*, which
GSPMD cannot partition — left to XLA's auto-spmd it materializes the
dispatch tensors at GLOBAL batch (f32[B_global, T, K, d]) and all-reduces
them every layer (~300 GB/layer/chip at deepseek-v2-lite train_4k scale;
see EXPERIMENTS.md §Perf iteration 1).  We therefore run the whole block
inside ``shard_map``: batch over the data axes, experts over ``model``.
Every scatter/gather is then shard-local; the only collective is one
bf16 ``psum`` of the combined output over ``model`` (the Megatron-style
row-parallel reduction), plus a tiny psum for the aux loss.

Capacity per sequence: C = ceil(T · top_k / E · capacity_factor); overflow
tokens are dropped (standard GShard semantics) via out-of-bounds scatter
indices.  Router runs in fp32.  A Switch-style load-balance aux loss is
returned.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from repro.compat import get_abstract_mesh, shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import (
    BATCH as DP,   # batch sentinel (see common.shd)
    batch_axes,
    dense_init,
    serving_mode,
    shd,
    split_keys,
)


def ep2d_geometry(cfg: ModelConfig, mesh):
    """2D expert-parallel geometry for *serving*, or None.

    Storage: experts over 'data', expert-hidden over 'model' — per-chip
    expert bytes P_exp/(data*model), which is what lets dbrx-132b's 254 GB
    of experts fit 16 GB chips (EXPERIMENTS.md §Dry-run).  Returns
    (E_loc, fe_loc).
    """
    mo = cfg.moe
    if mo is None or mesh is None:
        return None
    d_sz = mesh.shape.get("data", 1)
    tp = mesh.shape.get("model", 1)
    if d_sz <= 1 or mo.n_experts % d_sz or mo.d_expert % tp:
        return None
    return mo.n_experts // d_sz, mo.d_expert // tp


def init_moe_params(key, cfg: ModelConfig, L: int) -> Dict[str, Any]:
    mo = cfg.moe
    d, fe, E = cfg.d_model, mo.d_expert, mo.n_experts
    ks = split_keys(key, 7)
    p = {
        "router": dense_init(ks[0], (L, d, E), in_axis=1),
        "w_gate": dense_init(ks[1], (L, E, d, fe), in_axis=2),
        "w_up": dense_init(ks[2], (L, E, d, fe), in_axis=2),
        "w_down": dense_init(ks[3], (L, E, fe, d), in_axis=2),
    }
    if mo.n_shared:
        fs = mo.n_shared * fe
        p["ws_gate"] = dense_init(ks[4], (L, d, fs), in_axis=1)
        p["ws_up"] = dense_init(ks[5], (L, d, fs), in_axis=1)
        p["ws_down"] = dense_init(ks[6], (L, fs, d), in_axis=1)
    return p


def capacity(S: int, E: int, top_k: int, cf: float) -> int:
    return max(1, math.ceil(S * top_k / E * cf))


def _ambient_mesh():
    mesh = get_abstract_mesh()
    return None if (mesh is None or mesh.empty) else mesh


def _moe_routed(cfg: ModelConfig, p, x, e0, E_local, axes):
    """Routed-expert block over this shard's expert slice [e0, e0+E_local).

    x [G, S, d] (this shard's batch rows, replicated over ``model``).
    All scatters/gathers are local; OOB indices drop.  Returns the
    *partial* output (psum over ``axes`` pending) and the local aux stats.
    """
    mo = cfg.moe
    G, S, d = x.shape
    E, K = mo.n_experts, mo.top_k
    C = capacity(S, E, K, mo.capacity_factor)

    logits = jnp.einsum("gsd,de->gse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, K)                   # [G,S,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance stats (combined across shards by caller).
    me = probs.mean(axis=(0, 1))                           # [E]
    fe = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(
        1.0 / sel.size)

    # Position of each (token, k) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.int32).sum(2)  # [G,S,E]
    cum = jnp.cumsum(onehot, axis=1)                         # inclusive
    pos = jnp.take_along_axis(cum, sel, axis=2) - 1          # [G,S,K]
    keep = pos < C

    # Local experts only: shift sel into [0, E_local); overflow and
    # remote-expert entries go out of bounds and are dropped.
    sel_l = jnp.where(keep, sel - e0, E_local)
    pos_l = jnp.where(keep, pos, C)
    g_idx = jnp.arange(G)[:, None, None]
    xs = jnp.zeros((G, E_local, C, d), x.dtype)
    xs = xs.at[g_idx, sel_l, pos_l].add(
        x[:, :, None, :] * keep[..., None].astype(x.dtype), mode="drop")

    # Expert FFN (SwiGLU), batched over this shard's experts.
    h = jnp.einsum("gecd,edf->gecf", xs, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xs, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    ys = jnp.einsum("gecf,efd->gecd", h, p["w_down"])

    # Gather back: OOB (remote/overflow) reads fill 0 -> partial sum.
    out_k = ys.at[g_idx, sel_l, pos_l].get(mode="fill", fill_value=0)
    w = (gates * keep).astype(x.dtype)
    y = jnp.einsum("gskd,gsk->gsd", out_k, w)
    return y, me, fe


def _moe_shared(cfg: ModelConfig, p, x):
    """Always-on shared experts (plain TP SwiGLU over the hidden dim)."""
    g = jnp.einsum("gsd,df->gsf", x, p["ws_gate"])
    u2 = jnp.einsum("gsd,df->gsf", x, p["ws_up"])
    hs = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u2
    return jnp.einsum("gsf,fd->gsd", hs, p["ws_down"])


def moe_block(cfg: ModelConfig, p, x) -> Tuple[jax.Array, jax.Array]:
    """x [B, T, d] -> (y [B, T, d], aux load-balance loss scalar)."""
    mo = cfg.moe
    E = mo.n_experts
    mesh = _ambient_mesh()

    # Local/auto path: no mesh, no model axis, or fsdp mode ('model' is a
    # batch axis: experts stay replicated and FSDP streams their weights).
    if (mesh is None or "model" not in mesh.axis_names
            or "model" in batch_axes()):
        y, me, fe = _moe_routed(cfg, p, x, 0, E, ())
        if mo.n_shared:
            y = y + _moe_shared(cfg, p, x)
        return y, E * jnp.sum(me * fe)

    if serving_mode() and ep2d_geometry(cfg, mesh) is not None:
        return _moe_block_serving(cfg, p, x, mesh)

    tp = mesh.shape["model"]
    assert E % tp == 0, f"n_experts={E} not divisible by model={tp}"
    E_local = E // tp
    dp = tuple(a for a in batch_axes() if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    # Batch shards over dp only when divisible (long_500k decodes B=1:
    # replicate the row, shard experts only).
    if not dp or x.shape[0] % dp_size != 0:
        dp = ()
    bs = dp if dp else None
    fs_ax = "model"   # shared experts: hidden dim over model (TP)

    def local(x, router, wg, wu, wd, *shared):
        e0 = jax.lax.axis_index("model") * E_local
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        y, me, fe = _moe_routed(cfg, pl, x, e0, E_local, ("model",))
        # One bf16 reduction of the combined output (row-parallel style).
        y = jax.lax.psum(y, "model")
        if shared:
            ps = dict(zip(("ws_gate", "ws_up", "ws_down"), shared))
            y = y + jax.lax.psum(_moe_shared(cfg, ps, x), "model")
        # aux stats are identical on every model shard (router is
        # replicated); average over data shards only.
        if dp:
            me = jax.lax.pmean(me, dp)
            fe = jax.lax.pmean(fe, dp)
        return y, E * jnp.sum(me * fe)

    in_specs = [
        P(bs, None, None),            # x: batch over dp, repl. over model
        P(None, None),                # router (replicated)
        P("model", None, None),       # w_gate  [E, d, fe] -> EP
        P("model", None, None),       # w_up
        P("model", None, None),       # w_down  [E, fe, d]
    ]
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    if mo.n_shared:
        in_specs += [P(None, fs_ax), P(None, fs_ax), P(fs_ax, None)]
        args += [p["ws_gate"], p["ws_up"], p["ws_down"]]
    fn = shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=(P(bs, None, None), P()), check_vma=False)
    y, aux = fn(*args)
    return shd(y, DP, None, None), aux


def _moe_block_serving(cfg: ModelConfig, p, x, mesh):
    """Serving MoE over 2D-EP storage (experts x 'data', hidden x 'model').

    Two compute schedules off the same layout, chosen statically by T:

      decode (T == 1): token-gather EP — all_gather the (tiny) token
        batch over the batch axes, every (data, model) cell runs its
        resident expert slice over all tokens (dense-masked: the E/top_k
        redundancy is irrelevant at decode scale), one psum returns the
        combined rows, each shard keeps its own.  Weights never move.

      prefill (T > 1): weight-streaming EP — all_gather the expert
        weights over 'data' (transient, per layer) and dispatch locally;
        token traffic never crosses shards.  The gather amortizes over
        the 32k-token prefill (~0.3 s vs 2.8 s compute on dbrx).
    """
    mo = cfg.moe
    E = mo.n_experts
    E_loc, fe_loc = ep2d_geometry(cfg, mesh)
    dp = tuple(a for a in batch_axes() if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if not dp or x.shape[0] % dp_size != 0:
        dp = ()
    bs = dp if dp else None
    gather_axes = tuple(dp) + ("model",)
    decode = x.shape[1] == 1

    def local_decode(x, router, wg, wu, wd, *shared):
        # x [B_loc, 1, d] -> gather all rows everywhere (tiny at decode).
        # Gather innermost-axis-first so the final layout is dp[0]-major,
        # matching the row0 linearization below.
        xg = x[:, 0, :]
        for a in reversed(dp):
            xg = jax.lax.all_gather(xg, a, axis=0, tiled=True)  # [Ball, d]
        logits = (xg @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, sel = jax.lax.top_k(probs, mo.top_k)              # [Ball, K]
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        gate_full = jnp.zeros((xg.shape[0], E), jnp.float32)
        gate_full = gate_full.at[jnp.arange(xg.shape[0])[:, None],
                                 sel].add(gates)
        e0 = jax.lax.axis_index("data") * E_loc if "data" in \
            mesh.axis_names else 0
        g_loc = jax.lax.dynamic_slice_in_dim(gate_full, e0, E_loc, axis=1)
        # Dense-masked expert FFN over the resident slice.
        h = jnp.einsum("bd,edf->bef", xg, wg)
        u = jnp.einsum("bd,edf->bef", xg, wu)
        h = jax.nn.silu(h.astype(jnp.float32)).astype(xg.dtype) * u
        ye = jnp.einsum("bef,efd->bed", h, wd)
        y = jnp.einsum("bed,be->bd", ye, g_loc.astype(xg.dtype))
        if shared:
            ps = dict(zip(("ws_gate", "ws_up", "ws_down"), shared))
            y = y + _moe_shared(cfg, ps, xg[:, None, :])[:, 0, :]
        y = jax.lax.psum(y, gather_axes)
        # Keep this shard's rows.
        B_loc = x.shape[0]
        row0 = 0
        for a in dp:
            row0 = row0 * mesh.shape[a] + jax.lax.axis_index(a)
        y = jax.lax.dynamic_slice_in_dim(y, row0 * B_loc, B_loc, axis=0)
        return y[:, None, :], jnp.float32(0.0)

    def local_prefill(x, router, wg, wu, wd, *shared):
        if "data" in mesh.axis_names:
            wg = jax.lax.all_gather(wg, "data", axis=0, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=0, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=0, tiled=True)
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        y, me, fe = _moe_routed(cfg, pl, x, 0, E, ("model",))
        if shared:
            ps = dict(zip(("ws_gate", "ws_up", "ws_down"), shared))
            y = y + _moe_shared(cfg, ps, x)
        return jax.lax.psum(y, "model"), E * jnp.sum(me * fe)

    e_ax = "data" if "data" in mesh.axis_names else None
    in_specs = [
        P(bs, None, None),                 # x
        P(None, None),                     # router (replicated)
        P(e_ax, None, "model"),            # w_gate [E, d, fe]
        P(e_ax, None, "model"),            # w_up
        P(e_ax, "model", None),            # w_down [E, fe, d]
    ]
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    if mo.n_shared:
        in_specs += [P(None, "model"), P(None, "model"), P("model", None)]
        args += [p["ws_gate"], p["ws_up"], p["ws_down"]]
    fn = shard_map(local_decode if decode else local_prefill, mesh=mesh,
                   in_specs=tuple(in_specs),
                   out_specs=(P(bs, None, None), P()), check_vma=False)
    y, aux = fn(*args)
    return shd(y, DP, None, None), aux
