"""Deadline-aware request router + work-stealing migration (DESIGN.md §10).

The cluster front door: requests are submitted to the router, not to an
engine.  Each router step runs three phases:

1. **Dispatch** — pending requests are assigned to engines.  Under the
   default ``policy="slack"`` the pending set is ordered highest
   priority first, tightest deadline first within a tier (deadline-free
   requests last, FIFO — the same rank the engines' own admission loops
   use, so the cluster and the engine agree about who is urgent), and
   each request goes to the least-loaded engine at that moment.
   ``policy="fifo"`` keeps arrival order and round-robins engines — the
   baseline the ``cluster`` bench compares SLO attainment against.
2. **Step** — every engine with work runs one
   :meth:`~repro.serving.engine.ServingEngine.step`.  Afterwards the
   engines' modeled µs clocks are synced to the cluster maximum: the
   cluster has *one* wall clock, so deadlines and slack mean the same
   thing on every replica (the sync only moves idle clocks forward —
   it never rewinds, and it never touches model state, so tokens are
   unaffected).
3. **Steal** — if an engine holds preempted requests it cannot resume
   (batch full, or no pool headroom) while another engine has spare
   batch slots *and* enough free pages, the best resume candidate
   (priority, then slack) migrates: the source engine exports its pure
   host-side bundle (Request + decode state + saved token count), the
   shared tier re-leases the request's host frames to the destination
   domain (whole-frame owner flips when exclusive — zero copies), and
   the destination imports it into its resume queue.  The request then
   faults in through the destination's own DMA lanes and continues
   decoding — **no re-prefill, no device-to-device copy**, only
   host-resident base pages changing hands: the paper's "no costly base
   page migration", lifted to the cluster.

Migration requires the shared host tier (without it the payload bytes
live in the source engine's private store); the router degrades to
dispatch-only when ``tier`` is None.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

from repro.serving.engine import Request, ServingEngine


@dataclasses.dataclass
class RouterStats:
    submitted: int = 0
    dispatched: Dict[int, int] = dataclasses.field(default_factory=dict)
    migrations: int = 0
    migrated_pages: int = 0
    steal_rounds: int = 0            # steal scans that found a candidate
    # Failure recovery (DESIGN.md §12): injected engine deaths, preempted
    # bundles re-homed to survivors (zero re-prefill), and in-flight or
    # queued victims re-dispatched from the prompt.
    crashes: int = 0
    recovered_bundles: int = 0
    recovered_requeued: int = 0


class RequestRouter:
    def __init__(self, engines: List[ServingEngine], *, tier=None,
                 policy: str = "slack", migrate: bool = True,
                 injector=None) -> None:
        assert policy in ("slack", "fifo"), policy
        assert engines
        self.engines = engines
        self.tier = tier
        self.policy = policy
        # Work stealing needs the shared tier: the bundle is host-side
        # state, and the payload bytes must be visible to the thief.
        self.migrate = migrate and tier is not None
        # Failure injection (DESIGN.md §12): scheduled engine crashes
        # fire at the start of their router step.
        self.injector = injector
        self._step_no = 0
        self.pending: List[Tuple[int, Request]] = []    # (arrival, req)
        self._arrival = itertools.count()
        self._rr = 0                                    # fifo round-robin
        self._owner: Dict[int, int] = {}                # rid → engine idx
        self.stats = RouterStats()

    def _live(self) -> List[ServingEngine]:
        return [e for e in self.engines if e.alive]

    # ------------------------------------------------------------- submit

    def submit(self, req: Request, engine: Optional[int] = None) -> None:
        """Queue a request for dispatch; ``engine`` pins it to a replica
        (benches use this to construct controlled scenarios)."""
        assert req.rid not in self._owner \
            and all(r.rid != req.rid for _, r in self.pending), \
            f"rid {req.rid} already routed (cluster rids must be unique)"
        self.stats.submitted += 1
        if engine is not None:
            self._assign(req, engine)
        else:
            self.pending.append((next(self._arrival), req))

    def _assign(self, req: Request, idx: int) -> None:
        self._owner[req.rid] = idx
        self.engines[idx].submit(req)
        self.stats.dispatched[idx] = self.stats.dispatched.get(idx, 0) + 1

    # ------------------------------------------------------------- dispatch

    @staticmethod
    def engine_load(eng: ServingEngine) -> int:
        """Outstanding-work estimate in page-ish units: remaining decode
        tokens of admitted/preempted requests plus prompt pages + decode
        tokens of the still-queued.  Deterministic and cheap — the
        router only needs a consistent ordering, not a perf model."""
        ptok = max(eng.geo.page_tokens, 1)
        load = 0
        for r in list(eng.active) + list(eng.preempted):
            load += max(r.max_new - len(r.out), 1)
        for r in eng.queue:
            load += len(r.prompt) // ptok + max(r.max_new - len(r.out), 1)
        return load

    def _rank(self, item: Tuple[int, Request]):
        arrival, r = item
        deadline = r.deadline_us if r.deadline_us is not None \
            else float("inf")
        return (-r.priority, deadline, arrival)

    def dispatch(self) -> None:
        if not self.pending:
            return
        live = [i for i, e in enumerate(self.engines) if e.alive]
        assert live, "no live engine to dispatch to"
        if self.policy == "slack":
            order = sorted(self.pending, key=self._rank)
            for _, req in order:
                idx = min(live,
                          key=lambda i: (self.engine_load(self.engines[i]),
                                         i))
                self._assign(req, idx)
        else:                           # fifo: arrival order, round-robin
            for _, req in sorted(self.pending):
                while not self.engines[self._rr].alive:
                    self._rr = (self._rr + 1) % len(self.engines)
                self._assign(req, self._rr)
                self._rr = (self._rr + 1) % len(self.engines)
        self.pending.clear()

    # ------------------------------------------------------------- stepping

    def _busy(self, eng: ServingEngine) -> bool:
        if not eng.alive:
            return False
        return bool(eng.queue or eng.active or eng.preempted)

    def step(self) -> bool:
        if self.injector is not None:
            for idx in self.injector.crashes_due(self._step_no):
                self._crash(idx)
        self._step_no += 1
        self.dispatch()
        progressed = False
        for eng in self.engines:
            if self._busy(eng):
                progressed = bool(eng.step()) or progressed
        # One cluster wall clock: idle replicas' modeled clocks advance
        # with the busy ones, so slack/deadlines agree everywhere.
        live = self._live()
        now = max(e._clock_us for e in live)
        for e in live:
            e._clock_us = max(e._clock_us, now)
        if self.migrate:
            self._steal()
        return progressed or bool(self.pending)

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        steps = 0
        while self.pending or any(self._busy(e) for e in self.engines):
            if steps >= max_steps:
                # Livelock detection: silently returning here used to
                # hand callers a half-drained cluster that looked done.
                stuck = sorted(
                    r.rid for e in self.engines
                    for r in list(e.queue) + list(e.active)
                    + list(e.preempted)
                    if e.alive) + sorted(r.rid for _, r in self.pending)
                raise RuntimeError(
                    f"run_until_drained: {len(stuck)} request(s) still "
                    f"outstanding after max_steps={max_steps} (rids "
                    f"{stuck[:16]}{'…' if len(stuck) > 16 else ''}) — "
                    f"the cluster is livelocked, or max_steps is too "
                    f"small for this workload")
            self.step()
            steps += 1
        for e in self._live():
            if e.fault_mode == "async" and not self._busy(e):
                # Settle transfers still riding the channels (same rule
                # as ServingEngine.run_until_drained).
                e._clock_us = max(e._clock_us, e.dma.busy_until())
                e._drain_prefetches()
        return steps

    # ------------------------------------------------------ crash recovery

    def _crash(self, idx: int) -> None:
        """Kill engine ``idx`` and recover its workload (DESIGN.md §12).

        Its device state (pools, staging, in-flight DMA) is gone by
        definition.  What survives is host-side, per protection domain:

        * **Preempted (and held) requests** are pure host-side bundles —
          Request + decode state + saved tokens, payloads in the shared
          store.  Each migrates to the least-loaded survivor through the
          existing export → ``migrate_seq`` → import path and resumes
          with **zero re-prefill**, byte-identical tokens.
        * **In-flight and queued requests** lose their device KV:
          they re-dispatch from the prompt (cleared outputs) — the
          deterministic decoder replays the same tokens.
        * The dead domain's remaining host frames are reclaimed whole
          (:meth:`SharedHostTier.reclaim_domain`); prefix-domain frames
          belong to a different domain by construction and survive.
        """
        victim = self.engines[idx]
        if not victim.alive:
            return
        victim.alive = False
        self.stats.crashes += 1
        live = self._live()
        if not live:
            raise RuntimeError(
                f"engine {victim.engine_id} crashed with no survivor — "
                f"the cluster cannot recover")
        victim.preempted.extend(victim._held)
        victim._held.clear()
        if self.tier is not None:
            for r in list(victim.preempted):
                bundle = victim.export_preempted(r.rid)
                dst = min(live, key=lambda e: (self.engine_load(e),
                                               e.engine_id))
                self.tier.migrate_seq(r.rid, dst.engine_id)
                dst.import_preempted(bundle)
                self._owner[r.rid] = self.engines.index(dst)
                self.stats.recovered_bundles += 1
        requeue = list(victim.active) + list(victim.preempted) \
            + list(victim.queue)
        victim.active.clear()
        victim.preempted.clear()
        victim.queue.clear()
        victim.states.clear()
        victim._saved_tokens.clear()
        for r in requeue:
            r.out.clear()
            r.done = False
            self._owner.pop(r.rid, None)
            self.pending.append((next(self._arrival), r))
            self.stats.recovered_requeued += 1
        if self.tier is not None:
            self.tier.reclaim_domain(victim.engine_id)

    # --------------------------------------------------------- work stealing

    def _src_blocked(self, src: ServingEngine, pages_needed: int) -> bool:
        """Can ``src`` NOT resume this request itself right now?  Only
        then is stealing worth it — otherwise the local resume is
        strictly cheaper (no lease moves, warm prefetch state) and
        stealing would just ping-pong the request."""
        if len(src.active) >= src.max_batch:
            return True
        return src._free_pages_total() < \
            pages_needed + len(src.active) + 2

    def _dst_fits(self, dst: ServingEngine, pages_needed: int) -> bool:
        if len(dst.active) + len(dst.queue) + len(dst.preempted) \
                >= dst.max_batch:
            return False
        return dst._free_pages_total() >= \
            pages_needed + len(dst.active) + 2

    def _steal(self) -> None:
        """At most one migration per router step (keeps the schedule
        deterministic and easy to reason about; pressure that persists
        steals again next step)."""
        dsts = sorted(self._live(),
                      key=lambda e: (self.engine_load(e), e.engine_id))
        for dst in dsts:
            for src in sorted(self._live(),
                              key=lambda e: (-self.engine_load(e),
                                             e.engine_id)):
                if src is dst or not src.preempted:
                    continue
                for cand in src._resume_candidates():
                    pages = src.cache.pages_needed(
                        src._saved_tokens[cand.rid])
                    if not self._src_blocked(src, pages):
                        continue
                    if not self._dst_fits(dst, pages):
                        continue
                    self._migrate(cand.rid, src, dst)
                    self.stats.steal_rounds += 1
                    return

    def _migrate(self, rid: int, src: ServingEngine,
                 dst: ServingEngine) -> None:
        bundle = src.export_preempted(rid)
        assert bundle is not None
        moved = self.tier.migrate_seq(rid, dst.engine_id)
        dst.import_preempted(bundle)
        self._owner[rid] = self.engines.index(dst)
        self.stats.migrations += 1
        self.stats.migrated_pages += moved
