"""Deadline-aware request router + work-stealing migration (DESIGN.md §10/§14).

The cluster front door: requests are submitted to the router, not to an
engine.  Each router step runs four phases:

1. **Dispatch** — pending requests are assigned to engines.  Under the
   default ``policy="slack"`` the pending set is ordered highest
   priority first, tightest deadline first within a tier (deadline-free
   requests last, rid order — the same rank the engines' own admission
   loops use, so the cluster and the engine agree about who is urgent),
   and each request goes to the **cheapest** engine at that moment:
   :meth:`engine_cost_us`, a modeled-µs completion estimate built from
   the engine's wall clock, its DMA link-lane occupancy, its batched
   decode backlog (critical path vs throughput), and — when a shared
   tier is attached — disk promote debt and write-back (host-lane)
   occupancy
   (DESIGN.md §14).  ``cost_model="tokens"`` keeps the PR 4 token-count
   heuristic for A/B benches; ``policy="fifo"`` keeps arrival order and
   round-robins engines.
2. **Step** — every engine with work runs one
   :meth:`~repro.serving.engine.ServingEngine.step`.  Afterwards the
   engines' modeled µs clocks are synced to the cluster maximum: the
   cluster has *one* wall clock, so deadlines and slack mean the same
   thing on every replica (the sync only moves idle clocks forward —
   it never rewinds, and it never touches model state, so tokens are
   unaffected).
3. **Steal (preempted)** — if an engine holds preempted requests it
   cannot resume (batch full, or no pool headroom) while another engine
   has spare batch slots *and* enough free pages, the best resume
   candidate (priority, then slack) migrates: the source engine exports
   its pure host-side bundle (Request + decode state + saved token
   count), the shared tier re-leases the request's host frames to the
   destination domain (whole-frame owner flips when exclusive — zero
   copies), and the destination imports it into its resume queue.  The
   request then faults in through the destination's own DMA lanes and
   continues decoding — **no re-prefill, no device-to-device copy**.
4. **Steal (queued)** — a *queued, never-admitted* request is pure
   router state (no device KV, no host leases), so re-dispatching it is
   free.  At most one moves per step, under a deterministic rule
   (DESIGN.md §14): the cheapest engine takes the most urgent
   non-pinned queued request of the costliest engine, and only when the
   source stays strictly costlier than the destination *plus* the
   request's own cost — the hysteresis that makes ping-pong impossible.

**Proactive pre-staging** (DESIGN.md §14, opt-in via ``prestage=True``):
the moment dispatch (or a queued steal) picks a target engine, the
request's prefix-index hits and resume pages start faulting toward that
engine's staging buffers over the ordinary prefetch DMA "in" lanes —
admission later finds the transfers staged or in flight and skips
issuing them again.  A steal or crash that retargets the request
cancels its pre-stage with a lane-time refund for the un-elapsed
transfer remainder.  Pre-staging only moves *when* bytes arrive, never
what decode computes: tokens are byte-identical with it on or off.

Migration requires the shared host tier (without it the payload bytes
live in the source engine's private store); the router degrades to
dispatch-only when ``tier`` is None.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

from repro.serving.engine import Request, ServingEngine


@dataclasses.dataclass
class RouterStats:
    submitted: int = 0
    dispatched: Dict[int, int] = dataclasses.field(default_factory=dict)
    migrations: int = 0
    migrated_pages: int = 0
    steal_rounds: int = 0            # steal scans that found a candidate
    # Failure recovery (DESIGN.md §12): injected engine deaths, preempted
    # bundles re-homed to survivors (zero re-prefill), and in-flight or
    # queued victims re-dispatched from the prompt.
    crashes: int = 0
    recovered_bundles: int = 0
    recovered_requeued: int = 0
    # Queued-work re-dispatch + proactive pre-staging (DESIGN.md §14).
    queued_steals: int = 0
    prestaged_requests: int = 0
    prestage_cancels: int = 0
    prestage_refund_us: float = 0.0


class RequestRouter:
    def __init__(self, engines: List[ServingEngine], *, tier=None,
                 policy: str = "slack", migrate: bool = True,
                 injector=None, cost_model: str = "modeled",
                 prestage: bool = False,
                 steal_queued: bool = True,
                 translation_aware: bool = True) -> None:
        assert policy in ("slack", "fifo"), policy
        assert cost_model in ("modeled", "tokens"), cost_model
        assert engines
        self.engines = engines
        self.tier = tier
        self.policy = policy
        self.cost_model = cost_model
        # Translation-interference term (DESIGN.md §15): charge each
        # engine's booked walker backlog in the modeled dispatch cost.
        # With the engines' translation meters off the term is 0.0, so
        # this default changes nothing for meter-less clusters.
        self.translation_aware = translation_aware
        # Proactive pre-staging of queued requests (DESIGN.md §14).
        self.prestage = prestage
        # Queued-steal is gated separately from preempted-steal: a queued
        # request carries no host-side state, so it needs no tier.
        self.steal_queued = steal_queued
        # Work stealing needs the shared tier: the bundle is host-side
        # state, and the payload bytes must be visible to the thief.
        self.migrate = migrate and tier is not None
        # Failure injection (DESIGN.md §12): scheduled engine crashes
        # fire at the start of their router step.
        self.injector = injector
        self._step_no = 0
        self.pending: List[Tuple[int, Request]] = []    # (arrival, req)
        self._arrival = itertools.count()
        self._rr = 0                                    # fifo round-robin
        self._owner: Dict[int, int] = {}                # rid → engine idx
        # rid → engine idx its pre-stage targets.  Invariant: an entry
        # exists only while the request sits in that engine's queue —
        # pruned after each step, cancelled on steal/crash retarget —
        # so a crash can never double-cancel (or double-stage) a rid.
        self._prestaged: Dict[int, int] = {}
        # Explicitly placed rids (submit(engine=...)): benches pin these
        # to construct controlled scenarios — queued-steal respects that
        # and never re-dispatches them.
        self._pinned: set = set()
        self.stats = RouterStats()

    def _live(self) -> List[ServingEngine]:
        return [e for e in self.engines if e.alive]

    # ------------------------------------------------------------- submit

    def submit(self, req: Request, engine: Optional[int] = None) -> None:
        """Queue a request for dispatch; ``engine`` pins it to a replica
        (benches use this to construct controlled scenarios)."""
        assert req.rid not in self._owner \
            and all(r.rid != req.rid for _, r in self.pending), \
            f"rid {req.rid} already routed (cluster rids must be unique)"
        self.stats.submitted += 1
        if engine is not None:
            self._pinned.add(req.rid)
            self._assign(req, engine)
        else:
            self.pending.append((next(self._arrival), req))

    def _assign(self, req: Request, idx: int) -> None:
        self._owner[req.rid] = idx
        self.engines[idx].submit(req)
        self.stats.dispatched[idx] = self.stats.dispatched.get(idx, 0) + 1
        self._prestage_to(req, idx)

    # --------------------------------------------------------- pre-staging

    def _prestage_to(self, req: Request, idx: int) -> None:
        """Start faulting ``req``'s reusable pages toward engine ``idx``
        (DESIGN.md §14).  Exactly-once discipline: any stale pre-stage
        at another engine is cancelled first, and the tracking entry is
        recorded only when pages were actually issued."""
        if not self.prestage:
            return
        if self._prestaged.get(req.rid, idx) != idx:
            self._cancel_prestage(req.rid)
        staged = self.engines[idx].prestage_queued(req)
        if staged:
            self._prestaged[req.rid] = idx
            self.stats.prestaged_requests += 1

    def _cancel_prestage(self, rid: int) -> None:
        """Cancel ``rid``'s pre-stage at whichever engine holds it (a
        steal or crash retargeted the request).  The un-elapsed lane
        time refunded by the DMA engine is accounted cluster-side."""
        idx = self._prestaged.pop(rid, None)
        if idx is None:
            return
        refund = self.engines[idx].cancel_prestage(rid)
        self.stats.prestage_cancels += 1
        self.stats.prestage_refund_us += refund

    def _prune_prestaged(self) -> None:
        """Drop tracking entries whose request left the target engine's
        queue (admitted, or retired) — the engine-side accounting took
        over at admission.  Keeping them would make a later crash
        "cancel" staged payloads an admission already dedup'd against."""
        for rid, idx in list(self._prestaged.items()):
            if all(r.rid != rid for r in self.engines[idx].queue):
                del self._prestaged[rid]

    # ------------------------------------------------------------- dispatch

    @staticmethod
    def engine_load(eng: ServingEngine) -> int:
        """Outstanding-work estimate in page-ish units: remaining decode
        tokens of admitted/preempted requests plus prompt pages + decode
        tokens of the still-queued.  The PR 4 heuristic, kept as the
        ``cost_model="tokens"`` A/B baseline: cheap and consistent, but
        blind to the *rate* at which each unit retires — a decode token
        costs a whole batched window while a prompt token costs only
        ``prefill_us_per_token``, so token counts misroute whenever the
        mix is heterogeneous (the ``router`` bench scenario)."""
        ptok = max(eng.geo.page_tokens, 1)
        load = 0
        for r in list(eng.active) + list(eng.preempted):
            load += max(r.max_new - len(r.out), 1)
        for r in eng.queue:
            load += len(r.prompt) // ptok + max(r.max_new - len(r.out), 1)
        return load

    def engine_cost_us(self, eng: ServingEngine) -> float:
        """Modeled µs until ``eng`` would drain the work it already owns
        (DESIGN.md §14) — the dispatch cost a newcomer queues behind.

        Terms, all from state the engine/tier already track:

        * **link lanes** — DMA backlog beyond the engine's clock
          (``dma.busy_until()``): transfers a new admission's fault-ins
          queue behind;
        * **decode backlog** — remaining new tokens across active /
          preempted / held / queued requests.  Window count is the max
          of the throughput bound (total remaining / ``max_batch``) and
          the critical path (largest single request's remaining tokens,
          since a request retires at most one token per window);
        * prefill carries **no term**: on the modeled clock admission
          compute is wall work hidden inside the decode window, so
          queued prompt pages are free — exactly the heterogeneity the
          token-count baseline overweights (its misroute the ``router``
          bench demonstrates);
        * **disk lanes** — each preempted/held request whose saved pages
          spilled owes one seek + per-page disk reads before it can
          resume;
        * **host lanes** — the shared tier's write-back DMA backlog
          (identical for every engine, but it keeps absolute costs
          honest for hysteresis thresholds);
        * **walker backlog** — booked page-walk time on the engine's
          MMU (DESIGN.md §15), when ``translation_aware`` and the
          engine's translation meter is on.

        Monotone by construction: adding a request, a DMA booking, or a
        spilled page can only raise the cost.  The sim-side mirror is
        :meth:`repro.core.tlb_sim.Link.engine_occupancy`.
        """
        now = eng._clock_us
        window = (eng.decode_window_us
                  if eng.decode_window_us is not None else 1000.0)
        cost = max(0.0, eng.dma.busy_until() - now)
        remaining = 0
        longest = 0
        for r in (list(eng.active) + list(eng.preempted)
                  + list(eng._held) + list(eng.queue)):
            rem = max(r.max_new - len(r.out), 1)
            remaining += rem
            longest = max(longest, rem)
        if remaining:
            cost += window * max(-(-remaining // max(eng.max_batch, 1)),
                                 longest)
        if self.tier is not None:
            for r in list(eng.preempted) + list(eng._held):
                spilled = self.tier.spilled_keys_of(r.rid)
                if spilled:
                    cost += (self.tier.disk_seek_us + len(spilled)
                             * self.tier.disk_read_us_per_page)
            wb = getattr(self.tier, "wb_dma", None)
            if wb is not None:
                cost += max(0.0, wb.busy_until() - now)
        if self.translation_aware:
            # Walker backlog (DESIGN.md §15): a newcomer's translations
            # queue behind the booked walks of the engine's MMU.  0.0
            # when the engine's translation meter is off — the term is
            # inert unless translation modeling was asked for.  Monotone:
            # booking a walk can only raise the backlog.
            cost += eng.translation_backlog_us()
        return cost

    def _load(self, eng: ServingEngine) -> float:
        """The active cost model's load figure for ``eng``."""
        if self.cost_model == "tokens":
            return float(self.engine_load(eng))
        return self.engine_cost_us(eng)

    def _request_cost(self, r: Request, eng: ServingEngine) -> float:
        """What ``r`` itself would add to ``eng``'s load figure — the
        queued-steal hysteresis margin, in the active model's units."""
        rem = max(r.max_new - len(r.out), 1)
        if self.cost_model == "tokens":
            ptok = max(eng.geo.page_tokens, 1)
            return float(len(r.prompt) // ptok + rem)
        window = (eng.decode_window_us
                  if eng.decode_window_us is not None else 1000.0)
        return window * (-(-rem // max(eng.max_batch, 1)))

    def _rank(self, item: Tuple[int, Request]):
        arrival, r = item
        deadline = r.deadline_us if r.deadline_us is not None \
            else float("inf")
        # rid (not arrival) breaks equal-slack ties: submission-order
        # shuffles of equivalent requests must not change the dispatch
        # (the §14 determinism property) — arrival stays as the final
        # tiebreak for the degenerate duplicate-rid case.
        return (-r.priority, deadline, r.rid, arrival)

    def dispatch(self) -> None:
        if not self.pending:
            return
        live = [i for i, e in enumerate(self.engines) if e.alive]
        assert live, "no live engine to dispatch to"
        if self.policy == "slack":
            order = sorted(self.pending, key=self._rank)
            for _, req in order:
                idx = min(live,
                          key=lambda i: (self._load(self.engines[i]), i))
                self._assign(req, idx)
        else:                           # fifo: arrival order, round-robin
            for _, req in sorted(self.pending):
                while not self.engines[self._rr].alive:
                    self._rr = (self._rr + 1) % len(self.engines)
                self._assign(req, self._rr)
                self._rr = (self._rr + 1) % len(self.engines)
        self.pending.clear()

    # ------------------------------------------------------------- stepping

    def _busy(self, eng: ServingEngine) -> bool:
        if not eng.alive:
            return False
        return bool(eng.queue or eng.active or eng.preempted)

    def step(self) -> bool:
        if self.injector is not None:
            for idx in self.injector.crashes_due(self._step_no):
                self._crash(idx)
        self._step_no += 1
        self.dispatch()
        progressed = False
        for eng in self.engines:
            if self._busy(eng):
                progressed = bool(eng.step()) or progressed
        # One cluster wall clock: idle replicas' modeled clocks advance
        # with the busy ones, so slack/deadlines agree everywhere.
        live = self._live()
        now = max(e._clock_us for e in live)
        for e in live:
            e._clock_us = max(e._clock_us, now)
        self._prune_prestaged()
        if self.migrate:
            self._steal()
        if self.steal_queued:
            self._steal_queued()
        return progressed or bool(self.pending)

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        steps = 0
        while self.pending or any(self._busy(e) for e in self.engines):
            if steps >= max_steps:
                # Livelock detection: silently returning here used to
                # hand callers a half-drained cluster that looked done.
                stuck = sorted(
                    r.rid for e in self.engines
                    for r in list(e.queue) + list(e.active)
                    + list(e.preempted)
                    if e.alive) + sorted(r.rid for _, r in self.pending)
                raise RuntimeError(
                    f"run_until_drained: {len(stuck)} request(s) still "
                    f"outstanding after max_steps={max_steps} (rids "
                    f"{stuck[:16]}{'…' if len(stuck) > 16 else ''}) — "
                    f"the cluster is livelocked, or max_steps is too "
                    f"small for this workload")
            self.step()
            steps += 1
        for e in self._live():
            if e.fault_mode == "async" and not self._busy(e):
                # Settle transfers still riding the channels (same rule
                # as ServingEngine.run_until_drained).
                e._clock_us = max(e._clock_us, e.dma.busy_until())
                e._drain_prefetches()
        return steps

    # ------------------------------------------------------ crash recovery

    def _crash(self, idx: int) -> None:
        """Kill engine ``idx`` and recover its workload (DESIGN.md §12).

        Its device state (pools, staging, in-flight DMA) is gone by
        definition.  What survives is host-side, per protection domain:

        * **Preempted (and held) requests** are pure host-side bundles —
          Request + decode state + saved tokens, payloads in the shared
          store.  Each migrates to the least-loaded survivor through the
          existing export → ``migrate_seq`` → import path and resumes
          with **zero re-prefill**, byte-identical tokens.
        * **In-flight and queued requests** lose their device KV:
          they re-dispatch from the prompt (cleared outputs) — the
          deterministic decoder replays the same tokens.
        * **Pre-staged queued requests** (DESIGN.md §14): the pre-stage
          died with the victim's staging buffers.  The tracking entry is
          dropped *before* the requeue — with the victim-side pages
          written off as cancelled, never refunded into live lanes — so
          each such request re-enters ``pending`` exactly once and
          pre-stages afresh at whichever survivor dispatch picks (no
          double-charge of DMA lane time, no double dispatch).
        * The dead domain's remaining host frames are reclaimed whole
          (:meth:`SharedHostTier.reclaim_domain`); prefix-domain frames
          belong to a different domain by construction and survive.
        """
        victim = self.engines[idx]
        if not victim.alive:
            return
        victim.alive = False
        self.stats.crashes += 1
        live = self._live()
        if not live:
            raise RuntimeError(
                f"engine {victim.engine_id} crashed with no survivor — "
                f"the cluster cannot recover")
        # Write off pre-stages targeting the victim: its lanes are dead,
        # so the "refund" is bookkeeping only (counted on the victim,
        # not credited to any live lane) — the rid's entry must be gone
        # before the requeue below re-dispatches it.
        for rid, pidx in list(self._prestaged.items()):
            if pidx == idx:
                del self._prestaged[rid]
                victim.cancel_prestage(rid)
                self.stats.prestage_cancels += 1
        victim.preempted.extend(victim._held)
        victim._held.clear()
        if self.tier is not None:
            for r in list(victim.preempted):
                bundle = victim.export_preempted(r.rid)
                dst = min(live, key=lambda e: (self._load(e), e.engine_id))
                self.tier.migrate_seq(r.rid, dst.engine_id)
                dst.import_preempted(bundle)
                self._owner[r.rid] = self.engines.index(dst)
                self.stats.recovered_bundles += 1
        requeue = list(victim.active) + list(victim.preempted) \
            + list(victim.queue)
        victim.active.clear()
        victim.preempted.clear()
        victim.queue.clear()
        victim.states.clear()
        victim._saved_tokens.clear()
        for r in requeue:
            r.out.clear()
            r.done = False
            self._owner.pop(r.rid, None)
            self.pending.append((next(self._arrival), r))
            self.stats.recovered_requeued += 1
        if self.tier is not None:
            self.tier.reclaim_domain(victim.engine_id)

    # --------------------------------------------------------- work stealing

    def _src_blocked(self, src: ServingEngine, pages_needed: int) -> bool:
        """Can ``src`` NOT resume this request itself right now?  Only
        then is stealing worth it — otherwise the local resume is
        strictly cheaper (no lease moves, warm prefetch state) and
        stealing would just ping-pong the request."""
        if len(src.active) >= src.max_batch:
            return True
        return src._free_pages_total() < \
            pages_needed + len(src.active) + 2

    def _dst_fits(self, dst: ServingEngine, pages_needed: int) -> bool:
        if len(dst.active) + len(dst.queue) + len(dst.preempted) \
                >= dst.max_batch:
            return False
        return dst._free_pages_total() >= \
            pages_needed + len(dst.active) + 2

    def _steal(self) -> None:
        """At most one migration per router step (keeps the schedule
        deterministic and easy to reason about; pressure that persists
        steals again next step)."""
        dsts = sorted(self._live(),
                      key=lambda e: (self._load(e), e.engine_id))
        for dst in dsts:
            for src in sorted(self._live(),
                              key=lambda e: (-self._load(e),
                                             e.engine_id)):
                if src is dst or not src.preempted:
                    continue
                for cand in src._resume_candidates():
                    pages = src.cache.pages_needed(
                        src._saved_tokens[cand.rid])
                    if not self._src_blocked(src, pages):
                        continue
                    if not self._dst_fits(dst, pages):
                        continue
                    self._migrate(cand.rid, src, dst)
                    self.stats.steal_rounds += 1
                    return

    def _steal_queued(self) -> None:
        """Re-dispatch at most one *queued, never-admitted* request per
        step (DESIGN.md §14).  Deterministic rule: the cheapest live
        engine takes the most urgent non-pinned queued request — rank
        ``(-priority, deadline, rid)``, rid breaking ties — of the
        costliest engine, and only when the source remains strictly
        costlier than the destination plus the request's own cost
        (hysteresis: a moved request can never bounce straight back).
        The request's pre-stage, if any, is cancelled at the source
        (lane-time refund) and restarted at the thief."""
        live = self._live()
        if len(live) < 2:
            return
        dst = min(live, key=lambda e: (self._load(e), e.engine_id))
        for src in sorted(live, key=lambda e: (-self._load(e),
                                               e.engine_id)):
            if src is dst:
                continue
            cands = [r for r in src.queue if r.rid not in self._pinned]
            if not cands:
                continue
            cand = min(cands, key=lambda r: (
                -r.priority,
                r.deadline_us if r.deadline_us is not None
                else float("inf"),
                r.rid))
            if self._load(src) <= self._load(dst) \
                    + self._request_cost(cand, dst):
                continue
            src.queue.remove(cand)
            self._cancel_prestage(cand.rid)
            dst_idx = self.engines.index(dst)
            self._owner[cand.rid] = dst_idx
            dst.submit(cand)
            self.stats.queued_steals += 1
            self.stats.dispatched[dst_idx] = \
                self.stats.dispatched.get(dst_idx, 0) + 1
            self._prestage_to(cand, dst_idx)
            return

    def _migrate(self, rid: int, src: ServingEngine,
                 dst: ServingEngine) -> None:
        bundle = src.export_preempted(rid)
        assert bundle is not None
        moved = self.tier.migrate_seq(rid, dst.engine_id)
        dst.import_preempted(bundle)
        self._owner[rid] = self.engines.index(dst)
        self.stats.migrations += 1
        self.stats.migrated_pages += moved
