"""Multi-tenant continuous-batching serving engine on the Mosaic pool.

Lifecycle per request: admit → (en-masse) prefill → join the decode batch →
complete → deallocate (whole frames return to CoCoA thanks to the soft
guarantee; CAC compacts any splintered leftovers and the engine executes
the copy plan with the ``page_compact`` kernel between steps).

This is the paper's multi-application GPU setting transplanted: tenants
share one physical pool; the manager flag flips between ``mosaic`` and the
``gpu-mmu`` baseline so benchmarks can measure both (Figs. 5/6 analogue:
same workload, different manager).

The engine is deliberately host-driven: page tables are packed on host per
step (Mosaic's runtime half), while the device step (prefill/decode +
pool writes) is a single jitted call (the hardware half).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, PoolGeometry
from repro.kernels import ops as kops
from repro.models.lm import LM
from repro.serving.kv_cache import ShardedKVCache


@dataclasses.dataclass
class Request:
    rid: int
    tenant: int
    prompt: np.ndarray           # int32 [T]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    compaction_copies: int = 0
    wall_s: float = 0.0
    coalesced_sum: float = 0.0   # running sum of per-step coalesced fraction
    occupancy_sum: float = 0.0

    @property
    def coalesced_mean(self) -> float:
        return self.coalesced_sum / max(self.decode_steps, 1)

    @property
    def occupancy_mean(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)

    def tok_per_s(self) -> float:
        return (self.prefill_tokens + self.decode_tokens) / max(
            self.wall_s, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, *, geometry: PoolGeometry,
                 max_batch: int, max_seq: int, manager_kind: str = "mosaic",
                 n_shards: int = 1, params=None, seed: int = 0,
                 use_pallas: bool = False):
        self.cfg = cfg
        self.lm = LM(cfg)
        self.geo = geometry
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.use_pallas = use_pallas
        pages_per_seq = (max_seq + geometry.page_tokens - 1) \
            // geometry.page_tokens
        self.mpps = int(np.ceil(pages_per_seq / n_shards
                                / geometry.frame_pages)
                        ) * geometry.frame_pages
        per_shard = int(geometry.pages_for(max_seq, max_batch) / n_shards)
        per_shard = ((per_shard + geometry.frame_pages - 1)
                     // geometry.frame_pages) * geometry.frame_pages
        self.cache = ShardedKVCache(geometry, per_shard, n_shards,
                                    manager_kind)
        self.params = params if params is not None else self.lm.init(
            jax.random.PRNGKey(seed))
        shapes = self.lm.pool_shapes(per_shard * n_shards,
                                     geometry.page_tokens)
        self.pools = (tuple(jnp.zeros(s.shape, s.dtype) for s in shapes)
                      if shapes else None)
        self.states: Dict[int, dict] = {}
        self.queue: Deque[Request] = deque()
        self.active: List[Request] = []
        self.stats = EngineStats()
        self._decode_jit = jax.jit(
            lambda p, t, pos, pools, ctx, st: self.lm.decode_step(
                p, t, pos, pools, ctx, st))

    # ------------------------------------------------------------- admission

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        while self.queue and len(self.active) < self.max_batch:
            req = self.queue.popleft()
            self._prefill(req)
            self.active.append(req)

    def _prefill(self, req: Request):
        ptok = self.geo.page_tokens
        T = len(req.prompt)
        Tpad = ((T + ptok - 1) // ptok) * ptok
        # VLM: patch-embedding prefix occupies KV positions before the text
        # (frontend_tokens is page-aligned in all full configs).
        n_prefix = (self.cfg.frontend_tokens
                    if self.cfg.family == "vlm" else 0)
        self.cache.allocate(req.rid, n_prefix + T)
        # Allocation under memory pressure may have compacted: the tables
        # already point at the new locations, so the data copies must land
        # BEFORE the device reads them (and before the pages freed by
        # compaction are overwritten by this prefill).
        self._run_compaction()
        ctx = self._ctx_global(self.cache.pack_ctx([req.rid], self.mpps))
        tokens = np.full((1, Tpad), 0, np.int32)
        tokens[0, :T] = req.prompt
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.frontend_tokens, self.cfg.d_model))
        if self.cfg.family == "encdec":
            batch["src_embeds"] = jnp.zeros(
                (1, self.cfg.encdec.source_len, self.cfg.d_model))
        logits, pools_new, state = self.lm.prefill(
            self.params, batch, self._pools_for([req.rid]), ctx,
            last_pos=jnp.asarray([T - 1], jnp.int32))
        self._merge_pools([req.rid], pools_new)
        self.states[req.rid] = state
        nxt = int(jnp.argmax(logits[0]))
        req.out.append(nxt)
        # tokens beyond T within the padded page are unused; tracked length
        # stays T (+1 for the decode append below).
        self.stats.prefill_tokens += T

    # ------------------------------------------------------------- pools

    # For simplicity pools are global arrays addressed by global page id =
    # shard * pages_per_shard + local id; pack_ctx returns local ids, so we
    # offset per shard here.
    def _pools_for(self, seqs):
        return self.pools

    def _merge_pools(self, seqs, pools_new):
        self.pools = pools_new

    def _ctx_global(self, ctx):
        """Convert per-shard local page ids to global pool ids."""
        S = self.cache.S
        pps = self.cache.pages_per_shard
        off = (jnp.arange(S) * pps)[None, :, None]
        tables = jnp.where(ctx.tables >= 0, ctx.tables + off, -1)
        woff = (jnp.arange(S) * pps)[None, :]
        wpage = jnp.where(ctx.wpage >= 0, ctx.wpage + woff, -1)
        return dataclasses.replace(ctx, tables=tables, wpage=wpage)

    # ------------------------------------------------------------- stepping

    def step(self):
        """One engine iteration: admit, one batched decode step, retire."""
        t0 = time.time()
        self._admit()
        if not self.active:
            return False
        seqs = [r.rid for r in self.active]
        # Append this step's token slot, then pack tables.
        for r in self.active:
            self.cache.append(r.rid, 1)
        # Appends under pressure may compact; execute the copy plan before
        # the decode step consumes the updated tables (ordering matters:
        # tables are rewritten at plan time, payloads move here).
        self._run_compaction()
        ctx = self._ctx_global(self.cache.pack_ctx(seqs, self.mpps))
        toks = jnp.asarray([r.out[-1] for r in self.active], jnp.int32)
        pos = jnp.asarray([self.cache.seq_tokens[r.rid] - 1
                           for r in self.active], jnp.int32)
        state = self._stack_states(seqs)
        logits, self.pools, state = self._decode_jit(
            self.params, toks, pos, self.pools, ctx, state)
        self._unstack_states(seqs, state)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        done_now = []
        for i, r in enumerate(self.active):
            r.out.append(int(nxt[i]))
            self.stats.decode_tokens += 1
            if len(r.out) >= r.max_new \
                    or self.cache.seq_tokens[r.rid] >= self.max_seq - 1:
                r.done = True
                done_now.append(r)
        for r in done_now:
            self.active.remove(r)
            self.cache.free(r.rid)
            self.states.pop(r.rid, None)
        # Execute any CAC compaction plans on-device.
        self._run_compaction()
        st = self.cache.stats()
        self.stats.coalesced_sum += st.get("coalesced_fraction", 0.0)
        self.stats.occupancy_sum += st.get("occupancy", 0.0)
        self.stats.decode_steps += 1
        self.stats.wall_s += time.time() - t0
        return True

    def _run_compaction(self):
        ops = self.cache.drain_copy_ops()
        if not ops or self.pools is None:
            return
        pps = self.cache.pages_per_shard
        src = jnp.asarray([s * pps + op.src_ppn for s, op in ops],
                          jnp.int32)
        dst = jnp.asarray([s * pps + op.dst_ppn for s, op in ops],
                          jnp.int32)
        k, v = self.pools
        # pools are stacked [L, NP, ...]: compact every layer's pool.
        k = jax.vmap(lambda pool: kops.page_compact(
            pool, src, dst, use_pallas=self.use_pallas))(k)
        v = jax.vmap(lambda pool: kops.page_compact(
            pool, src, dst, use_pallas=self.use_pallas))(v)
        self.pools = (k, v)
        self.stats.compaction_copies += len(ops)

    # ------------------------------------------------------------- states

    def _stack_states(self, seqs):
        if not self.states:
            return {}
        keys = self.states[seqs[0]].keys()
        return {k: jnp.concatenate(
            [self._state_of(s)[k] for s in seqs],
            axis=1 if k in ("ssm", "conv", "cross_k", "cross_v") else 0)
            for k in keys}

    def _state_of(self, seq):
        return self.states[seq]

    def _unstack_states(self, seqs, stacked):
        if not stacked:
            return
        for k, v in stacked.items():
            ax = 1 if k in ("ssm", "conv", "cross_k", "cross_v") else 0
            parts = jnp.split(v, len(seqs), axis=ax)
            for s, part in zip(seqs, parts):
                self.states[s][k] = part

    # ------------------------------------------------------------- run

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return steps
