"""Multi-tenant continuous-batching serving engine on the Mosaic pool.

Lifecycle per request: admit → (en-masse) prefill → join the decode batch →
complete → deallocate (whole frames return to CoCoA thanks to the soft
guarantee; CAC compacts any splintered leftovers and the engine executes
the copy plan with the ``page_compact`` kernel between steps).

This is the paper's multi-application GPU setting transplanted: tenants
share one physical pool; the manager flag flips between ``mosaic`` and the
``gpu-mmu`` baseline so benchmarks can measure both (Figs. 5/6 analogue:
same workload, different manager).

Host tier (DESIGN.md §6): the pool may be *oversubscribed* — sized below
the workload's peak KV working set.  Each step ``touch()``es the pages its
packed tables will read and batch-faults the missing ones in from the
:class:`~repro.serving.host_tier.HostPageStore` as one gather-transfer
(contiguous runs merge into single DMAs — Mosaic's contiguity pays on the
I/O bus too).  When an allocation hits ``OutOfMemory`` even after CAC
compaction, the engine preempts the cheapest-to-evict active request
(cost-aware score: resident pages × priority × remaining tokens) —
evicting its frames to the host store at base-page granularity — instead
of failing, and resumes it later via demand fault-in; a resumed request
produces exactly the tokens it would have produced unpreempted.

Async fault-in (DESIGN.md §7): with ``fault_mode="async"`` (the default)
each step runs a two-stage pipeline — drain the prefetches that completed
during the previous decode into the double-buffered staging region, fault
only the remaining misses synchronously (*exposed* µs), then issue the
predicted next-step touches to the :class:`~repro.serving.dma.
AsyncDMAEngine` so their transfers run on DMA channels *while* this
step's decode computes (*hidden* µs).  ``fault_mode="sync"`` keeps PR 1's
blocking path; both modes produce byte-identical tokens because the
prefetch machinery never alters allocation or scheduling, only when
transfers are modeled to happen.

Fused gather-attend decode (DESIGN.md §13): ``fault_mode="fused"`` goes
one step further and removes the pre-decode DMA barrier entirely.  The
step pipeline becomes admit → start-decode-on-resident →
drain-within-kernel: fault-in resolves this step's misses to *sources*
(staged payloads, in-flight prefetch jobs, fresh demand jobs) without a
single ``dma.wait``, decode launches immediately with a per-page
readiness mask (``PageCtx.slots``) that lets attention read late
arrivals straight from the staging pools, and the collected jobs settle
against the *end* of the decode window — only transfer tails that
outlive the window are exposed.  Tokens stay byte-identical to
sync/async because the accumulation order never changes; only each
page's load source (pool vs. staging) differs, and the staged bytes
equal what the scatter would have written.

Prefix-cache reuse (DESIGN.md §8): finished prompts park their full
pages' KV in the :class:`~repro.serving.host_tier.PrefixIndex`, keyed by
chained per-page content hash.  An admission whose prompt shares a
cached page-aligned prefix skips decode for those tokens: the pages
fault in from the host tier through the async DMA pipeline *at
admission time* (merged DMAs — they were allocated en masse, so they are
contiguous) and only the suffix is prefilled, its queries attending over
the cached KV.  Tokens are byte-identical with the cache on or off
(suffix prefill reproduces full prefill bitwise; dense-transformer
families only).  The DMA timeline is full-duplex: preemption eviction
gathers and prefix parking ride the channels' "out" lanes, visible in
the per-direction stats without delaying inbound fault-ins.  Resume
scheduling is SLO-aware: within a priority tier, preempted requests
resume tightest-deadline-first and the deadline pressure widens the
resume-prefetch window (``Prefetcher.plan_depth``).

Cluster tier (DESIGN.md §10): an engine can be one replica of a
:class:`~repro.serving.cluster.ServingCluster` — it then holds a
domain-bound view of the shared host store (``host=``), a cluster-wide
prefix index (``prefix_index=``), and an ``engine_id`` naming its
frame-lease protection domain.  ``export_preempted``/``import_preempted``
hand a fully-swapped-out request to another replica (work-stealing
migration, driven by the :class:`~repro.serving.router.RequestRouter`)
with zero re-prefill.  Completions with a deadline record per-priority-
tier hit/miss counters (``EngineStats.deadline_*``; ``summary()`` prints
SLO attainment).  Non-dense model families never park into (or match
from) a prefix index — suffix prefill could not replay their KV — and
count the skips in ``prefix_park_skipped`` instead.

The engine is deliberately host-driven: page tables are packed on host per
step (Mosaic's runtime half), while the device step (prefill/decode +
pool writes) is a single jitted call (the hardware half).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, PoolGeometry
from repro.core.cocoa import OutOfMemory
from repro.core.demand_paging import LinkModel
from repro.kernels import ops as kops
from repro.models.lm import LM
from repro.serving.dma import (AsyncDMAEngine, DMAJob, Key, Prefetcher,
                               StagingBuffer)
from repro.serving.host_tier import HostPageStore, PrefixIndex
from repro.serving.kv_cache import ShardedKVCache


@dataclasses.dataclass
class Request:
    rid: int
    tenant: int
    prompt: np.ndarray           # int32 [T]
    max_new: int
    priority: int = 0            # higher = more important (preempt lowest)
    # SLO deadline on the engine's modeled µs clock (DESIGN.md §8):
    # among same-priority resume candidates, tighter slack resumes (and
    # prefetches) first.  None = best-effort, FIFO within its tier.
    deadline_us: Optional[float] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    compaction_copies: int = 0
    wall_s: float = 0.0
    coalesced_sum: float = 0.0   # running sum of per-step coalesced fraction
    occupancy_sum: float = 0.0
    # Host-tier demand paging (DESIGN.md §6).
    faults: int = 0              # base pages faulted in
    fault_dmas: int = 0          # DMA descriptors (contiguous runs)
    fault_steps: int = 0         # engine steps that faulted at all
    bytes_in: int = 0
    transfer_us: float = 0.0
    swaps_out: int = 0           # whole-request preemptions
    swaps_in: int = 0            # whole-request resumes
    # Async fault-in pipeline (DESIGN.md §7).
    fault_exposed_us: float = 0.0   # transfer µs the engine stalled on
    fault_hidden_us: float = 0.0    # transfer µs overlapped with decode
    prefetch_hits: int = 0          # faults served from staging/in-flight
    prefetch_misses: int = 0        # demand faults the prefetcher missed
    prefetch_wasted: int = 0        # prefetched pages never consumed
    # Full-duplex outbound DMA (DESIGN.md §8): eviction gathers + parking.
    evict_pages: int = 0            # pages gathered device→host on channels
    evict_dmas: int = 0             # outbound DMA descriptors
    bytes_out: int = 0
    evict_us: float = 0.0           # outbound transfer µs on the timeline
    # Prefix-cache reuse (DESIGN.md §8).
    prefix_hits: int = 0            # admissions that matched a cached prefix
    prefix_misses: int = 0          # cache-enabled admissions with no match
    prefix_reused_tokens: int = 0   # prompt tokens NOT re-prefilled
    prefix_parked_pages: int = 0    # pages parked into the index
    prefix_fault_us: float = 0.0    # modeled µs to fault reused prefixes in
    admit_hits: int = 0             # admissions via the suffix-prefill path
    admit_colds: int = 0            # admissions via the full-prefill path
    admit_hit_us: float = 0.0       # wall µs spent in cache-hit admissions
    admit_cold_us: float = 0.0      # wall µs spent in cold admissions
    # Non-dense fallback (DESIGN.md §10): parks skipped because the model
    # family cannot replay cached KV (MoE routing / MLA latents / ssm
    # state) — counted instead of silently caching unreplayable pages.
    prefix_park_skipped: int = 0
    # Disk spill tier (DESIGN.md §11): parks refused by host-tier
    # back-pressure (write-back buffer saturated), admissions that had
    # to promote spilled frames, and the modeled disk-read stall µs.
    prefix_park_refused: int = 0
    promotions: int = 0
    promote_stall_us: float = 0.0
    # Per-admission modeled latency samples (µs): suffix/full prefill
    # compute at prefill_us_per_token plus any promote stall — the
    # distribution behind the spill bench's p99 claim.
    admit_lat_us: List[float] = dataclasses.field(default_factory=list)
    # Cross-engine migration (DESIGN.md §10): preempted requests handed
    # off through the shared host tier, never re-prefilled.
    migrations_out: int = 0
    migrations_in: int = 0
    # Failure recovery (DESIGN.md §12): requests restarted from the
    # prompt after a spill quarantine destroyed their swapped-out
    # payloads, and cache-hit admissions that fell back to full prefill
    # because the matched prefix payloads were quarantined mid-admission.
    lost_restarts: int = 0
    prefix_rederives: int = 0
    # Deadline accounting per priority tier (ROADMAP follow-up): a
    # request with a deadline counts as a hit when it completes with
    # ``clock_us <= deadline_us`` on the engine's modeled clock.
    deadline_hits: Dict[int, int] = dataclasses.field(default_factory=dict)
    deadline_misses: Dict[int, int] = dataclasses.field(default_factory=dict)
    # Fused gather-attend decode (DESIGN.md §13): pages the kernel
    # consumed straight from staging — already landed when the step's
    # decode window opened (*ready*) vs. arriving inside the window
    # (*drained* in-kernel) — and the µs the engine stalled on transfer
    # tails that outlived their window.
    fused_ready_pages: int = 0
    fused_drained_pages: int = 0
    fused_tail_us: float = 0.0
    # Proactive pre-staging of queued work (DESIGN.md §14): pages the
    # router faulted toward this engine before the owning request's
    # admission step.  *hit* — admission (or fault-in) found the page
    # staged/in-flight and skipped its own transfer; *wasted* — staged
    # but never consumed (prefix re-matched differently, request
    # retired first); *cancelled* — dropped by a steal/crash retarget,
    # with the un-elapsed lane time refunded (``prestage_refund_us``).
    prestaged_pages: int = 0
    prestage_hits: int = 0
    prestage_wasted: int = 0
    prestage_cancelled: int = 0
    prestage_refund_us: float = 0.0
    # Translation meter (DESIGN.md §15): per-step KV page translations
    # through the coalesced-TLB + radix-walker model.  Observational —
    # decode timing and tokens are identical with the meter on or off —
    # but the walker backlog is the router's translation-interference
    # term, and translation_us is the modeled µs the lookups would cost.
    translation_lookups: int = 0
    translation_tlb_hits: int = 0
    translation_walks: int = 0
    translation_walk_cycles: float = 0.0
    translation_queue_cycles: float = 0.0
    translation_us: float = 0.0

    def note_deadline(self, priority: int, hit: bool) -> None:
        d = self.deadline_hits if hit else self.deadline_misses
        d[priority] = d.get(priority, 0) + 1

    def slo_attainment(self, priority: Optional[int] = None
                       ) -> Optional[float]:
        """Fraction of deadline-carrying completions that met their
        deadline — overall, or for one priority tier.  None when no
        deadline-carrying request has completed (not 1.0: 'no SLOs set'
        must be distinguishable from 'all SLOs met')."""
        if priority is None:
            hits = sum(self.deadline_hits.values())
            total = hits + sum(self.deadline_misses.values())
        else:
            hits = self.deadline_hits.get(priority, 0)
            total = hits + self.deadline_misses.get(priority, 0)
        return None if total == 0 else hits / total

    @property
    def coalesced_mean(self) -> float:
        return self.coalesced_sum / max(self.decode_steps, 1)

    @property
    def occupancy_mean(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)

    def admit_hit_mean_us(self) -> float:
        return self.admit_hit_us / max(self.admit_hits, 1)

    def admit_cold_mean_us(self) -> float:
        return self.admit_cold_us / max(self.admit_colds, 1)

    def admit_p99_us(self, start: int = 0) -> float:
        """p99 of the modeled per-admission latencies (µs), optionally
        over the samples from index ``start`` on (benches slice off a
        warm-up wave).  0.0 when no samples."""
        samples = self.admit_lat_us[start:]
        if not samples:
            return 0.0
        return float(np.percentile(np.asarray(samples), 99))

    def tok_per_s(self) -> float:
        # A zero-step engine (or mocked clock) must report 0, not explode.
        if self.wall_s <= 0.0:
            return 0.0
        return (self.prefill_tokens + self.decode_tokens) / self.wall_s

    def summary(self) -> str:
        """One-line human summary: throughput, the exposed/hidden fault
        split, the prefetch hit/miss/wasted counts, duplex outbound
        traffic and swap/prefix-reuse totals."""
        line = (
            f"{self.tok_per_s():.1f} tok/s | "
            f"{self.prefill_tokens} prefill + {self.decode_tokens} decode "
            f"tok in {self.decode_steps} steps | "
            f"faults {self.faults} in {self.fault_dmas} DMAs "
            f"({self.bytes_in / 1024:.0f} KiB, "
            f"{self.fault_hidden_us:.0f}us hidden / "
            f"{self.fault_exposed_us:.0f}us exposed) | "
            f"prefetch {self.prefetch_hits}/{self.prefetch_misses}/"
            f"{self.prefetch_wasted} hit/miss/wasted | "
            f"out {self.evict_pages} pages in {self.evict_dmas} DMAs "
            f"({self.bytes_out / 1024:.0f} KiB) | "
            f"swaps {self.swaps_out}/{self.swaps_in}")
        if self.prefix_hits or self.prefix_misses:
            line += (f" | prefix {self.prefix_hits}/{self.prefix_misses} "
                     f"hit/miss ({self.prefix_reused_tokens} tok reused)")
        if self.prefix_park_skipped:
            line += f" | parks skipped {self.prefix_park_skipped} (non-dense)"
        if self.prefix_park_refused:
            line += (f" | parks refused {self.prefix_park_refused} "
                     f"(wb back-pressure)")
        if self.promotions:
            line += (f" | promotes {self.promotions} "
                     f"({self.promote_stall_us:.0f}us stall)")
        if self.migrations_out or self.migrations_in:
            line += (f" | migrated {self.migrations_out} out / "
                     f"{self.migrations_in} in")
        if self.lost_restarts or self.prefix_rederives:
            line += (f" | quarantine: {self.lost_restarts} restarts, "
                     f"{self.prefix_rederives} prefix re-derives")
        if self.fused_ready_pages or self.fused_drained_pages:
            line += (f" | fused {self.fused_ready_pages} ready + "
                     f"{self.fused_drained_pages} drained in-kernel "
                     f"({self.fused_tail_us:.0f}us tail)")
        if self.prestaged_pages:
            line += (f" | prestage {self.prestaged_pages} pages "
                     f"({self.prestage_hits}/{self.prestage_wasted}/"
                     f"{self.prestage_cancelled} hit/wasted/cancelled)")
        if self.translation_lookups:
            line += (f" | translation {self.translation_lookups} lookups, "
                     f"{self.translation_walks} walks "
                     f"({self.translation_us:.0f}us, queue "
                     f"{self.translation_queue_cycles:.0f} cyc)")
        att = self.slo_attainment()
        if att is not None:
            tiers = sorted(set(self.deadline_hits) | set(self.deadline_misses),
                           reverse=True)
            per = ", ".join(
                f"t{t} {self.deadline_hits.get(t, 0)}/"
                f"{self.deadline_hits.get(t, 0) + self.deadline_misses.get(t, 0)}"
                for t in tiers)
            line += f" | SLO {att:.1%} ({per})"
        return line


class ServingEngine:
    def __init__(self, cfg: ModelConfig, *, geometry: PoolGeometry,
                 max_batch: int, max_seq: int, manager_kind: str = "mosaic",
                 n_shards: int = 1, params=None, seed: int = 0,
                 use_pallas: bool = False, oversubscription: float = 1.0,
                 link: Optional[LinkModel] = None,
                 fault_mode: str = "async", dma_channels: int = 2,
                 prefetch_depth: int = 2, victim_policy: str = "cost",
                 decode_window_us: Optional[float] = None,
                 prefill_us_per_token: float = 50.0,
                 prefix_cache: bool = True,
                 prefix_capacity_pages: int = 4096,
                 duplex: bool = True,
                 slo_urgency_us: float = 1000.0,
                 host: Optional[HostPageStore] = None,
                 prefix_index: Optional[PrefixIndex] = None,
                 engine_id: int = 0,
                 injector=None,
                 translation: str = "off",
                 translation_kw: Optional[dict] = None):
        # ValueError, not assert: configuration validation must survive
        # ``python -O`` (asserts compile away under optimization).
        if fault_mode not in ("async", "sync", "fused"):
            raise ValueError(
                f"fault_mode must be 'async', 'sync' or 'fused', "
                f"got {fault_mode!r}")
        if fault_mode == "fused" and cfg.mla is not None:
            # The fused path stages dense (k, v) page payloads into the
            # attention kernel; MLA's latent pools take a different
            # decode path that cannot consume them.
            raise ValueError("fault_mode='fused' supports dense-attention "
                             "families only (not MLA)")
        if victim_policy not in ("cost", "priority"):
            raise ValueError(
                f"victim_policy must be 'cost' or 'priority', "
                f"got {victim_policy!r}")
        if translation not in ("off", "flat", "radix"):
            raise ValueError(
                f"translation must be 'off', 'flat' or 'radix', "
                f"got {translation!r}")
        self.cfg = cfg
        # Replica identity within a cluster (DESIGN.md §10): the host-tier
        # frame-lease protection domain and the reporting label.
        self.engine_id = engine_id
        # Failure model (DESIGN.md §12): False after an injected crash —
        # the router stops dispatching here and recovers the workload.
        self.alive = True
        self.injector = injector
        self.fault_mode = fault_mode
        self.victim_policy = victim_policy
        # Full-duplex outbound modeling (DESIGN.md §8): eviction gathers
        # and prefix parking ride the DMA channels' "out" lanes.  Only
        # the async pipeline has a channel timeline to ride.
        self.duplex = duplex and fault_mode in ("async", "fused")
        # Deadline slack below which a resume candidate counts as urgent
        # for SLO-aware prefetch-depth planning.
        self.slo_urgency_us = slo_urgency_us
        # Modeled compute window per decode step for the DMA timeline.
        # None = measured decode wall time; on CPU that includes jit
        # compilation (seconds), which dwarfs the µs-scale transfers —
        # set an explicit window to model a real accelerator's step time
        # and exercise partial overlap deterministically.
        self.decode_window_us = decode_window_us
        # Modeled prefill compute cost per prompt token (µs) — the basis
        # of the per-admission latency samples (admit_lat_us): a cache
        # hit pays only its suffix (+ any spill-promote stall), a cold
        # admission the full prompt.  Deliberately on the same modeled
        # timeline as decode_window_us, not wall time: CPU jit wall time
        # would drown the µs-scale effects the benches measure.
        self.prefill_us_per_token = prefill_us_per_token
        self.lm = LM(cfg)
        self.geo = geometry
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.use_pallas = use_pallas
        pages_per_seq = (max_seq + geometry.page_tokens - 1) \
            // geometry.page_tokens
        self.mpps = int(np.ceil(pages_per_seq / n_shards
                                / geometry.frame_pages)
                        ) * geometry.frame_pages
        # oversubscription > 1 shrinks HBM below the sized-for-peak working
        # set; the host tier absorbs the overflow (DESIGN.md §6).
        per_shard = int(geometry.pages_for(max_seq, max_batch) / n_shards
                        / max(oversubscription, 1e-9))
        per_shard = max(per_shard, self.mpps)  # ≥ one max-length sequence
        per_shard = ((per_shard + geometry.frame_pages - 1)
                     // geometry.frame_pages) * geometry.frame_pages
        probe = self.lm.pool_shapes(1, geometry.page_tokens)
        if probe:
            # True KV bytes of one base page across all layers (k + v).
            page_bytes = sum(
                int(np.prod(s.shape[2:])) * s.shape[0]
                * np.dtype(s.dtype).itemsize for s in probe)
        else:
            page_bytes = 0      # attention-free: nominal paper default
        self.page_bytes = page_bytes
        self.link = link or LinkModel()
        self.cache = ShardedKVCache(geometry, per_shard, n_shards,
                                    manager_kind, link=self.link,
                                    page_bytes=page_bytes)
        # ``host`` may be a cluster-shared store view (DESIGN.md §10);
        # standalone engines own a private store as before.
        self.host = host if host is not None else HostPageStore()
        # Content-hash prefix cache (DESIGN.md §8).  Suffix-only prefill
        # needs full-sequence attention over cached KV pages, which only
        # the dense-transformer family supports bitwise (MoE capacity
        # routing is batch-shape-dependent; ssm/hybrid carry recurrent
        # state; encdec cross-attends; MLA caches latents).
        self.prefix_supported = (cfg.family == "dense" and cfg.mla is None
                                 and bool(page_bytes))
        self.prefix: Optional[PrefixIndex] = None
        if prefix_index is not None:
            # Cluster-shared index: keep the reference even when this
            # replica's model family cannot replay cached KV — the
            # match/park paths skip and count instead of caching
            # unreplayable pages (the MoE/MLA fallback, DESIGN.md §10).
            self.prefix = prefix_index if prefix_cache else None
        elif prefix_cache and self.prefix_supported:
            self.prefix = PrefixIndex(self.host, geometry.page_tokens,
                                      capacity_pages=prefix_capacity_pages)
        self.params = params if params is not None else self.lm.init(
            jax.random.PRNGKey(seed))
        shapes = self.lm.pool_shapes(per_shard * n_shards,
                                     geometry.page_tokens)
        self.pools = (tuple(jnp.zeros(s.shape, s.dtype) for s in shapes)
                      if shapes else None)
        self.states: Dict[int, dict] = {}
        self.queue: Deque[Request] = deque()
        self.preempted: Deque[Request] = deque()
        self._held: List[Request] = []
        self._saved_tokens: Dict[int, int] = {}
        self.active: List[Request] = []
        # rids migrated away to another engine (DESIGN.md §10): their
        # in-flight prefetch payloads must settle as waste here, never
        # re-stage — the destination engine owns the host copies now.
        self._foreign: set = set()
        self._stalled_steps = 0      # consecutive no-decode steps
        self.stats = EngineStats()
        # Async fault-in pipeline (DESIGN.md §7): DMA channel timeline +
        # double-buffered staging + next-step touch predictor.  The clock
        # is modeled µs: advanced by measured decode wall time (compute
        # the transfers hide behind) and by exposed fault stalls.
        self.dma = AsyncDMAEngine(self.link, n_channels=dma_channels,
                                  duplex=duplex, injector=injector)
        self.staging = StagingBuffer()
        self.prefetch = Prefetcher(depth=prefetch_depth)
        # Keys pre-staged toward this engine for still-queued requests
        # (DESIGN.md §14), mapped to the source page's owner id (prefix
        # owners are minted once and never reused, so a matching owner
        # proves the staged bytes are the ones admission would fetch).
        # Consumed → prestage_hits, invalidated at retire/export →
        # prestage_wasted, retargeted by steal/crash → cancelled.
        self._prestage_keys: Dict[Key, int] = {}
        # Translation meter (DESIGN.md §15): the decode loop feeds it the
        # KV page tables each step's batch reads; subregion span defaults
        # to the allocator's frame size — exactly the contiguity unit
        # CoCoA preserves, so an unsplintered frame is one TLB entry.
        self.translation = translation
        self.translation_meter = None
        if translation != "off":
            from repro.core.ptw import TranslationMeter
            self.translation_meter = TranslationMeter(
                translation, span=max(1, geometry.frame_pages),
                **(translation_kw or {}))
        self._clock_us = 0.0
        # Fused decode step state (DESIGN.md §13): DMA jobs whose pages
        # this step's kernel consumes (settled at the decode-window end,
        # not before decode), the staged ((shard, ppn), payload,
        # arrive_us) entries awaiting the post-decode pool scatter, and
        # the window-open timestamp splitting ready vs drained pages.
        self._fused_jobs: List[DMAJob] = []
        self._fused_staged: List[tuple] = []
        self._fused_t0 = 0.0
        self._decode_jit = jax.jit(
            lambda p, t, pos, pools, ctx, st: self.lm.decode_step(
                p, t, pos, pools, ctx, st))

    # ------------------------------------------------------------- admission

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        # One admission order across resumes and new arrivals: highest
        # priority first; within a tier, tightest SLO deadline first
        # (deadline-free requests rank last and stay FIFO — max() is
        # stable), and resumes beat arrivals (they are older and already
        # hold host payloads + decode state).  This keeps a premium
        # arrival from being head-of-line blocked behind an unadmittable
        # best-effort request — in either pool.
        def rank(r: Request):
            return (r.priority, -self._slack_or_inf(r))

        skipped: set = set()     # failed this round; don't block the rest
        while True:
            cand = max((r for r in self.preempted
                        if r.rid not in skipped),
                       key=rank, default=None)
            queued = max((r for r in self.queue if r.rid not in skipped),
                         key=rank, default=None)
            resume = cand is not None and (
                queued is None or cand.priority >= queued.priority)
            if not resume:
                cand = queued
            if cand is None:
                break
            if len(self.active) >= self.max_batch:
                # Batch slots are a resource too: a premium candidate
                # displaces a strictly-lower-priority active request (the
                # strictness makes displacement chains terminate).  With a
                # full batch and no displaceable victim, no lower-priority
                # candidate can enter either — stop the round.
                victim = self._pick_victim(below_priority=cand.priority)
                if victim is None:
                    break
                self._preempt(victim)
            ok = self._resume(cand) if resume else self._admit_one(cand)
            if not ok:
                # Memory can't fit this candidate right now; a smaller or
                # lower-priority one may still fill the idle capacity (and
                # any victims it preempted in vain resume right here).
                skipped.add(cand.rid)
                continue
            (self.preempted if resume else self.queue).remove(cand)
            self.active.append(cand)
        if not self.active and (self.queue or self.preempted):
            raise RuntimeError(
                "pool cannot hold a single request: shrink max_seq or grow "
                "the pool (oversubscription too aggressive)")

    # --------------------------------------------------- preemption / resume

    def _victim_score(self, r: Request) -> float:
        """Cost of evicting ``r``: resident pages (gather + fault-back
        traffic) × priority (importance) × remaining tokens (how long it
        still needs its memory — a nearly-done request vacates cheaply
        and re-finishes quickly).  Lower = better victim."""
        remaining = max(r.max_new - len(r.out), 1)
        return (float(self.cache.resident_page_count(r.rid))
                * (r.priority + 1) * remaining)

    def _pick_victim(self, *, below_priority: Optional[int] = None,
                     exclude: Tuple[int, ...] = ()) -> Optional[Request]:
        """Cheapest-to-evict active request under the configured policy.

        ``victim_policy="cost"`` (default) minimizes the eviction score;
        ``"priority"`` keeps PR 1's lowest-priority-only rule.  Both
        respect ``below_priority`` (a candidate never displaces its own
        tier or above at admission) and tie-break youngest-first.
        """
        cands = [r for r in self.active if r.rid not in exclude]
        if below_priority is not None:
            cands = [r for r in cands if r.priority < below_priority]
        if not cands:
            return None
        if self.victim_policy == "priority":
            return min(cands, key=lambda r: (r.priority, -r.rid))
        return min(cands,
                   key=lambda r: (self._victim_score(r), r.priority, -r.rid))

    def _alloc_with_preemption(self, req: Request, n_tokens: int, *,
                               below_priority: Optional[int],
                               exclude: Tuple[int, ...] = ()) -> bool:
        """Allocate with growth headroom, preempting victims as needed.

        The growth guard is part of the loop: when an allocation succeeds
        but would leave no room for one decode step of the batch, another
        victim is evicted and the allocation retried — so victims are only
        ever swapped out on a path that ends in admission, never stranded
        by a post-hoc guard failure.  Returns False (leaving ``req``
        unallocated) when no victim remains.
        """
        while True:
            try:
                self.cache.allocate(req.rid, n_tokens)
            except OutOfMemory:
                # Roll back the partial allocation before retrying.
                self.cache.free(req.rid)
                victim = self._pick_victim(below_priority=below_priority,
                                           exclude=exclude + (req.rid,))
                if victim is None:
                    return False
                self._preempt(victim)
                continue
            if self._growth_guard_ok(req):
                return True
            # Allocated but starved of growth headroom (resume↔preempt
            # livelock otherwise): evict one more victim and re-place.
            self.cache.free(req.rid)
            victim = self._pick_victim(below_priority=below_priority,
                                       exclude=exclude + (req.rid,))
            if victim is None:
                return False
            self._preempt(victim)

    def _gather_pages(self, entries: List[Tuple[int, int, int]]
                      ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Device→host gather of [(shard, vpn, ppn)] pool pages as one
        batched launch; returns per-page (k_page, v_page) payloads."""
        if not entries or self.pools is None:
            return []
        pps = self.cache.pages_per_shard
        gidx = jnp.asarray([s * pps + ppn for s, _v, ppn in entries],
                           jnp.int32)
        k, v = self.pools
        kp = jax.vmap(lambda pool: kops.page_gather(
            pool, gidx, use_pallas=self.use_pallas))(k)
        vp = jax.vmap(lambda pool: kops.page_gather(
            pool, gidx, use_pallas=self.use_pallas))(v)
        kp, vp = np.asarray(kp), np.asarray(vp)       # [L, n, ptok, kv, dh]
        return [(kp[:, i], vp[:, i]) for i in range(len(entries))]

    def _enqueue_outbound(self, keys: List[Tuple[int, int, int]],
                          entries: List[Tuple[int, int, int]],
                          payloads: List[Tuple[np.ndarray, np.ndarray]],
                          kind: str) -> None:
        """Account a device→host gather on the DMA channels' "out" lanes
        (full-duplex, DESIGN.md §8).  The host copy is synchronous in the
        model (write-back buffering), so the engine never stalls on these
        jobs — they occupy the outbound timeline, contend with other
        outbound traffic, and settle as hidden µs at the next drain."""
        if not self.duplex or not entries:
            return
        by_shard: Dict[int, List[int]] = {}
        for i, (s, _vpn, _ppn) in enumerate(entries):
            by_shard.setdefault(s, []).append(i)
        for s, idxs in sorted(by_shard.items()):
            job = self.dma.enqueue(
                [keys[i] for i in idxs],
                [entries[i][2] for i in idxs],
                self.cache.mgrs[s].residency.page_bytes,
                [payloads[i] for i in idxs],
                self._clock_us, kind=kind, direction="out")
            self.stats.evict_pages += len(job.keys)
            self.stats.evict_dmas += job.dma_count
            self.stats.bytes_out += job.nbytes
            self.stats.evict_us += job.transfer_us

    def _preempt(self, victim: Request) -> None:
        """Swap a request out: frames → host store at base-page granularity,
        decode state retained host-side, pages freed for other tenants."""
        rid = victim.rid
        # Pending compaction plans rewrote tables already; land the payload
        # copies before gathering through those tables.
        self._run_compaction()
        pages = self.cache.mapped_pages(rid)     # [(shard, vpn, ppn)]
        # A just-resumed victim may still hold non-resident pages whose
        # payloads never left the host store — gather only resident ones
        # (the rest keep their existing host copies).
        resident = [
            (s, vpn, ppn) for s, vpn, ppn in pages
            if self.cache.mgrs[s].residency.resident[ppn]
        ]
        payloads = self._gather_pages(resident)
        for (s, vpn, _ppn), (kp, vp) in zip(resident, payloads):
            self.host.put(rid, s, vpn, kp, vp)
        # The gather itself is outbound DMA traffic: it rides the
        # channels' "out" lanes (hidden behind compute on a full-duplex
        # link; contending with fault-ins when half-duplex).
        self._enqueue_outbound([(rid, s, vpn) for s, vpn, _p in resident],
                               resident, payloads, kind="evict")
        self.cache.evict_pages(resident)
        self._saved_tokens[rid] = self.cache.seq_tokens[rid]
        self.cache.free(rid)
        self.active.remove(victim)
        victim.preemptions += 1
        self.preempted.append(victim)
        self.host.note_swap_out()
        self.stats.swaps_out += 1

    def preempt(self, rid: int, *, hold: bool = False) -> bool:
        """Proactively swap an active request out (external-scheduler hook,
        cf. proactive memory scheduling).  It resumes automatically when
        capacity allows, unless ``hold`` is set — a held request stays
        swapped out until :meth:`release`.  Returns False if ``rid`` is not
        active."""
        for r in self.active:
            if r.rid == rid:
                self._preempt(r)
                if hold:
                    self.preempted.remove(r)
                    self._held.append(r)
                return True
        return False

    def release(self, rid: int) -> bool:
        """Make a held request eligible for resume again."""
        for r in self._held:
            if r.rid == rid:
                self._held.remove(r)
                self.preempted.append(r)
                return True
        return False

    # ------------------------------------------------- cross-engine handoff

    def export_preempted(self, rid: int) -> Optional[dict]:
        """Detach a preempted request for migration to another engine
        (DESIGN.md §10).  The request must be fully swapped out (it is:
        preemption gathers every resident page to the host store), so
        the bundle is pure host-side state — the Request, its decode
        state, and its saved token count.  Its host-resident pages stay
        in the (shared) store; the cluster re-leases their frames to the
        destination domain.  Local staging/prefetch state for the rid is
        invalidated, and in-flight DMA payloads will settle as waste."""
        for r in self.preempted:
            if r.rid == rid:
                break
        else:
            return None
        self.preempted.remove(r)
        bundle = {"request": r, "state": self.states.pop(rid, None),
                  "saved_tokens": self._saved_tokens.pop(rid)}
        self._note_prestage_waste(rid)
        dropped = self.staging.invalidate_seq(rid)
        self.stats.prefetch_wasted += dropped
        self.prefetch.stats["wasted_pages"] += dropped
        self.prefetch.cancel_seq(rid)
        self._foreign.add(rid)
        self.stats.migrations_out += 1
        return bundle

    def import_preempted(self, bundle: dict) -> None:
        """Adopt a migrated request: it joins this engine's resume queue
        and faults its pages in from the (shared) host store through
        this engine's own DMA lanes — no device-to-device copy and no
        re-prefill, ever."""
        r = bundle["request"]
        self._foreign.discard(r.rid)
        self.preempted.append(r)
        if bundle["state"] is not None:
            self.states[r.rid] = bundle["state"]
        self._saved_tokens[r.rid] = bundle["saved_tokens"]
        self.stats.migrations_in += 1

    # ------------------------------------------------ proactive pre-staging
    # (DESIGN.md §14) The router calls these for *queued*, never-admitted
    # requests: once a target engine is picked, the request's prefix-index
    # hits and resume pages start faulting toward this engine's staging
    # buffers over the regular prefetch DMA lanes, so the later admission
    # step finds the transfers already in flight.  Strictly timing-only:
    # the probe is read-only (``peek_match``), allocation and scheduling
    # are untouched, and the staged payloads are byte-identical to what
    # admission would have fetched — tokens cannot change.

    def prestage_queued(self, req: Request) -> int:
        """Fault ``req``'s known-reusable pages toward staging before its
        admission step; returns the number of pages issued.

        Two sources: host copies the rid already owns (a re-queued /
        crash-requeued request resuming from the host tier) and
        prefix-index hits, staged under the exact ``(rid, shard, vpn)``
        keys :meth:`_prefill_suffix` will enqueue, so admission dedups
        against them.  Spilled frames are promoted here — moving the
        disk read off the admission critical path is the point — but the
        promote stall is *not* charged to this engine's clock: it is
        background work on the tier's disk lanes.
        """
        if self.fault_mode not in ("async", "fused") or not self.alive:
            return 0
        tier = getattr(self.host, "tier", None)
        keys: List[Key] = []
        srcs: List[Key] = []
        for key in self.host.seq_pages(req.rid):
            if self.staging.contains(key) or key in self.prefetch.in_flight:
                continue
            keys.append(key)
            srcs.append(key)
        prefix_pages = []
        if self.prefix is not None and self.prefix_supported:
            ptok = self.geo.page_tokens
            n, pages = self.prefix.peek_match(req.prompt)
            n = min(n, (len(req.prompt) - 1) // ptok)
            for pg in pages[:n]:
                key = (req.rid, pg.shard, pg.vpn)
                if self.staging.contains(key) \
                        or key in self.prefetch.in_flight:
                    continue
                keys.append(key)
                srcs.append((pg.owner, pg.shard, pg.vpn))
                prefix_pages.append((key, pg))
        if not keys:
            return 0
        # Promote any spilled source frames now (tier-modeled disk time,
        # off the admission path); a quarantine may destroy sources —
        # drop those from the batch rather than staging garbage.
        self.host.ensure_resident(srcs, now_us=self._clock_us)
        live = [(k, s) for k, s in zip(keys, srcs) if self.host.has(*s)]
        if not live:
            return 0
        keys = [k for k, _ in live]
        payloads = [self.host.peek(*s) for _, s in live]
        if any(p is None for p in payloads):
            return 0
        job = self.dma.enqueue(keys, list(range(len(keys))),
                               self.page_bytes, payloads, self._clock_us,
                               kind="prefetch")
        self._account_prefetch(job)
        for (k, s) in live:
            self._prestage_keys[k] = s[0]       # source owner fingerprint
        self.stats.prestaged_pages += len(keys)
        return len(keys)

    def cancel_prestage(self, rid: int) -> float:
        """Cancel ``rid``'s pre-staged pages (a steal or a crash
        retargeted the request before admission).  In-flight jobs whose
        pages are all pre-stage work for this rid are cancelled with a
        lane-time refund for the un-elapsed transfer remainder; payloads
        already staged are dropped.  Returns the refunded µs."""
        refunded = 0.0
        jobs: Dict[int, "DMAJob"] = {}
        for key, job in list(self.prefetch.in_flight.items()):
            if key[0] == rid and key in self._prestage_keys:
                jobs[job.job_id] = job
        for job in jobs.values():
            if not all(k[0] == rid and k in self._prestage_keys
                       for k in job.keys):
                continue            # mixed job: let it settle normally
            refunded += self.dma.cancel(job, self._clock_us)
            self.prefetch.forget(job.keys)
            self.stats.prestage_cancelled += len(job.keys)
            for k in job.keys:
                self._prestage_keys.pop(k, None)
        mine = [k for k in self._prestage_keys if k[0] == rid]
        if mine:
            for k in mine:
                self._prestage_keys.pop(k, None)
            self.stats.prestage_cancelled += \
                self.staging.invalidate_seq(rid)
        self.prefetch.cancel_seq(rid)
        self.stats.prestage_refund_us += refunded
        self.stats.transfer_us = max(0.0, self.stats.transfer_us - refunded)
        return refunded

    def _note_prestage_hit(self, key: Key) -> None:
        if self._prestage_keys.pop(key, None) is not None:
            self.stats.prestage_hits += 1

    def _note_prestage_waste(self, rid: int) -> None:
        """Account pre-staged pages the request never consumed (counted
        at the same invalidation points as prefetch waste)."""
        stale = [k for k in self._prestage_keys if k[0] == rid]
        for k in stale:
            self._prestage_keys.pop(k, None)
            self.stats.prestage_wasted += 1

    def _free_pages_total(self) -> int:
        return sum(m.config.num_pages - int(m.pool.page_allocated.sum())
                   for m in self.cache.mgrs)

    def _growth_guard_ok(self, req: Request) -> bool:
        """Admitting ``req`` must leave room for ≥ one decode step of the
        whole batch, or the newcomer would be preempted again before
        producing a token (resume↔preempt livelock)."""
        if not self.active:
            return True          # a sole request always fits (pool ≥ mpps)
        return self._free_pages_total() >= len(self.active) + 2

    def _resume(self, req: Request) -> bool:
        """Re-map a preempted request; payloads fault in on next touch.

        If the tier quarantined a spill frame holding this request's
        swapped-out payloads (DESIGN.md §12), the saved state is
        unusable — restart from the prompt instead.  The deterministic
        decoder makes the replay byte-identical to an unfaulted run."""
        if self.host.take_lost(req.rid):
            self._forget_request(req)
            self.stats.lost_restarts += 1
            return self._admit_one(req)
        tokens = self._saved_tokens[req.rid]
        if not self._alloc_with_preemption(req, tokens,
                                           below_priority=req.priority):
            return False
        # Allocation under pressure may have planned compaction: execute the
        # copies before anything reads the rewritten tables.
        self._run_compaction()
        self.cache.demote_host_backed(req.rid, self.host)
        del self._saved_tokens[req.rid]
        self.host.note_swap_in()
        self.stats.swaps_in += 1
        return True

    def _forget_request(self, r: Request) -> None:
        """Erase every trace of a request whose saved payloads were lost
        to a spill quarantine (§12) so it can restart from the prompt:
        device pages, decode state, host copies, staged and in-flight
        prefetches, saved token count, and any tokens already emitted."""
        self.cache.free(r.rid)
        self.states.pop(r.rid, None)
        self.host.drop_seq(r.rid)
        self.host.take_lost(r.rid)   # clear a flag re-set during the drop
        self._note_prestage_waste(r.rid)
        dropped = self.staging.invalidate_seq(r.rid)
        self.stats.prefetch_wasted += dropped
        self.prefetch.stats["wasted_pages"] += dropped
        self.prefetch.cancel_seq(r.rid)
        self._saved_tokens.pop(r.rid, None)
        r.out.clear()
        r.done = False

    def _restart_lost(self, rids: set) -> None:
        """Pull active requests whose payloads a quarantine destroyed
        out of the batch and re-queue them from the prompt (head of the
        queue: they are the oldest work).  Deterministic decode makes
        the replay byte-identical to an unfaulted run."""
        for r in [r for r in self.active if r.rid in rids]:
            self.active.remove(r)
            self._forget_request(r)
            self.queue.appendleft(r)
            self.stats.lost_restarts += 1

    def _admit_one(self, req: Request) -> bool:
        ptok = self.geo.page_tokens
        T = len(req.prompt)
        n_prefix = (self.cfg.frontend_tokens
                    if self.cfg.family == "vlm" else 0)
        if not self._alloc_with_preemption(req, n_prefix + T,
                                           below_priority=req.priority):
            return False
        self._prefill(req)
        return True

    # --------------------------------------------------- demand fault-in

    def _fault_in(self, seqs: List[int]) -> set:
        """touch() this step's pages; fault the missing ones in (blocking
        under ``fault_mode="sync"``, staged/overlapped under ``"async"``).
        Returns the rids whose payloads were lost to a spill quarantine
        during the promote (§12) — their entries were skipped and the
        caller must restart them."""
        if self.fault_mode == "sync":
            return self._fault_in_sync(seqs)
        if self.fault_mode == "fused":
            return self._fault_in_fused(seqs)
        return self._fault_in_async(seqs)

    @staticmethod
    def _drop_lost_entries(missing: Dict, lost: set) -> Dict:
        """Remove a lost rid's entries from a missing-pages map — their
        host payloads no longer exist, so they must not be popped."""
        if not lost:
            return missing
        return {s: kept for s, entries in missing.items()
                if (kept := [e for e in entries if e[1] not in lost])}

    def _promote_missing(self, missing: Dict) -> set:
        """Before popping payloads, promote any spilled frames the step's
        misses live in (DESIGN.md §11) — the modeled disk-read stall is
        exposed time, charged to the clock like a demand fault.

        Returns the rids whose payloads the promote *destroyed* (frame
        quarantine after corruption or a permanent disk error, §12):
        the caller must skip their entries and restart them."""
        keys = [(owner, s, vpn) for s, entries in missing.items()
                for _ppn, owner, vpn in entries]
        promote_us = self.host.ensure_resident(keys, now_us=self._clock_us)
        if promote_us:
            self._clock_us += promote_us
            self.stats.promote_stall_us += promote_us
            self.stats.promotions += 1
        return {k[0] for k in keys
                if k[0] >= 0 and self.host.take_lost(k[0])}

    def _scatter_pages(self, gidx: List[int],
                       payloads: List[Tuple[np.ndarray, np.ndarray]]
                       ) -> None:
        """Land faulted payloads in the device pools (one batched launch)."""
        if self.pools is None or not gidx:
            return
        idx = jnp.asarray(gidx, jnp.int32)
        kp = jnp.asarray(np.stack([p[0] for p in payloads], axis=1))
        vp = jnp.asarray(np.stack([p[1] for p in payloads], axis=1))
        k, v = self.pools
        k = jax.vmap(lambda pool, pages: kops.page_scatter(
            pool, idx, pages, use_pallas=self.use_pallas))(k, kp)
        v = jax.vmap(lambda pool, pages: kops.page_scatter(
            pool, idx, pages, use_pallas=self.use_pallas))(v, vp)
        self.pools = (k, v)

    def _fault_in_sync(self, seqs: List[int]) -> set:
        """PR 1's blocking path: the whole batch stalls on the transfer,
        so every µs is exposed."""
        missing = self.cache.missing_pages(seqs)
        if not missing:
            return set()
        lost = self._promote_missing(missing)
        missing = self._drop_lost_entries(missing, lost)
        if not missing:
            return lost
        pps = self.cache.pages_per_shard
        gidx: List[int] = []
        payloads: List[Tuple[np.ndarray, np.ndarray]] = []
        step_us = 0.0
        for s, entries in missing.items():
            batch = self.cache.mgrs[s].residency.fault_in(
                [ppn for ppn, _o, _v in entries])
            self.stats.faults += len(batch.ppns)
            self.stats.fault_dmas += batch.dma_count
            self.stats.bytes_in += batch.nbytes
            self.stats.transfer_us += batch.transfer_us
            self.stats.fault_exposed_us += batch.transfer_us
            step_us += batch.transfer_us
            for ppn, owner, vpn in entries:
                gidx.append(s * pps + ppn)
                payloads.append(self.host.pop(owner, s, vpn))
        self.stats.fault_steps += 1
        self._clock_us += step_us       # the whole transfer stalls the step
        self._scatter_pages(gidx, payloads)
        return lost

    def _fault_in_async(self, seqs: List[int]) -> set:
        """Stage 1 of the pipeline: serve this step's misses from the
        staging region (hidden), stall on in-flight prefetches (partially
        hidden), and demand-fault only the never-predicted remainder
        (fully exposed, and queued behind in-flight prefetch DMAs —
        shared-channel contention is part of the model)."""
        missing = self.cache.missing_pages(seqs)
        if not missing:
            return set()
        lost = self._promote_missing(missing)
        missing = self._drop_lost_entries(missing, lost)
        pps = self.cache.pages_per_shard
        now = self._clock_us
        gidx: List[int] = []
        payloads: List[Tuple[np.ndarray, np.ndarray]] = []
        waited: Dict[Tuple[int, int, int],
                     Tuple[np.ndarray, np.ndarray]] = {}
        for s, entries in sorted(missing.items()):
            demand: List[Tuple[int, int, int]] = []
            for ppn, owner, vpn in entries:
                key = (owner, s, vpn)
                payload = waited.pop(key, None)
                if payload is None:
                    payload = self.staging.consume(key)
                if payload is None and key in self.prefetch.in_flight:
                    # Partially-hidden: the transfer started during the
                    # previous decode; stall only for the remainder.
                    job = self.prefetch.in_flight[key]
                    now = self.dma.wait(job, now)
                    self.prefetch.forget(job.keys)
                    for k2, p2 in zip(job.keys, job.payloads):
                        waited[k2] = p2
                    payload = waited.pop(key)
                if payload is None:
                    demand.append((ppn, owner, vpn))
                    continue
                # Prefetch hit: payload already on device (staging);
                # scatter it to its mapped frame and retire the host copy.
                self.cache.mgrs[s].residency.mark_resident([ppn])
                self.host.pop(owner, s, vpn)
                self.stats.faults += 1
                self.stats.prefetch_hits += 1
                self._note_prestage_hit(key)
                self.prefetch.stats["hits"] += 1
                gidx.append(s * pps + ppn)
                payloads.append(payload)
            if demand:
                batch = self.cache.mgrs[s].residency.fault_in(
                    [ppn for ppn, _o, _v in demand])
                dpay = [self.host.pop(owner, s, vpn)
                        for _ppn, owner, vpn in demand]
                job = self.dma.enqueue(
                    [(owner, s, vpn) for _p, owner, vpn in demand],
                    [ppn for ppn, _o, _v in demand],
                    self.cache.mgrs[s].residency.page_bytes, dpay,
                    now, kind="demand")
                now = self.dma.wait(job, now)
                self.stats.faults += len(demand)
                self.stats.fault_dmas += job.dma_count
                self.stats.bytes_in += job.nbytes
                self.stats.transfer_us += job.transfer_us
                self.stats.prefetch_misses += len(demand)
                self.prefetch.stats["misses"] += len(demand)
                for (ppn, _o, _v), p in zip(demand, dpay):
                    gidx.append(s * pps + ppn)
                    payloads.append(p)
        # Leftover payloads of a waited multi-page job: keep for later
        # steps (their keys weren't in this step's touch set); a key
        # whose owner retired (or migrated away) mid-flight is wasted
        # transfer.
        for key, payload in waited.items():
            if self.host.has(*key) and key[0] not in self._foreign:
                self.staging.stage(key, payload)
            else:
                self.prefetch.stats["wasted_pages"] += 1
                self.stats.prefetch_wasted += 1
        self.stats.fault_steps += 1
        # Engine-level exposed = the step's stall (includes channel-queue
        # wait); the DMA engine keeps the strict per-transfer split.
        self.stats.fault_exposed_us += now - self._clock_us
        self.stats.fault_hidden_us = self.dma.stats["hidden_us"]
        self._clock_us = now
        self._scatter_pages(gidx, payloads)
        return lost

    # ------------------------------------------------ fused decode path

    def _write_page_set(self, seqs: List[int]) -> set:
        """(shard, ppn) of each sequence's current write page: the page
        ``write_kv`` lands the new token in.  A staged write page must be
        merged into the pool *before* decode — attention would otherwise
        read the pre-write staged bytes and miss the new token."""
        ftok = self.geo.frame_pages * self.geo.page_tokens
        out = set()
        for seq in seqs:
            pos = self.cache.seq_tokens[seq] - 1
            s = self.cache._shard_of_frame(pos // ftok)
            table = self.cache.mgrs[s].tables[seq]
            out.add((s, table.ppn[len(table.ppn) - 1]))
        return out

    def _fault_in_fused(self, seqs: List[int]) -> set:
        """Fused gather-attend path (DESIGN.md §13): no pre-decode DMA
        barrier.  This step's misses are resolved to *sources* — staged
        payloads (consumed in-kernel from the staging region), in-flight
        prefetch jobs, and freshly enqueued demand jobs — but the engine
        never calls ``dma.wait`` here.  Decode launches immediately with
        a per-page readiness mask; the collected jobs settle at the end
        of the decode window (:meth:`_settle_fused`), exposing only the
        transfer tail that outlives the window."""
        self._fused_jobs = []
        self._fused_staged = []
        self._fused_t0 = self._clock_us
        missing = self.cache.missing_pages(seqs)
        if not missing:
            return set()
        lost = self._promote_missing(missing)       # disk stall stays exposed
        missing = self._drop_lost_entries(missing, lost)
        self._fused_t0 = self._clock_us
        now = self._clock_us
        pps = self.cache.pages_per_shard
        write_pages = self._write_page_set(
            [s for s in seqs if s in self.cache.seq_tokens])
        jobs: Dict[int, DMAJob] = {}
        waited: Dict[Tuple[int, int, int],
                     Tuple[np.ndarray, np.ndarray]] = {}
        arrive: Dict[Tuple[int, int, int], float] = {}
        for s, entries in sorted(missing.items()):
            demand: List[Tuple[int, int, int]] = []
            for ppn, owner, vpn in entries:
                key = (owner, s, vpn)
                when = now          # staging hits: landed before this step
                payload = waited.pop(key, None)
                if payload is not None:
                    when = arrive.get(key, now)
                if payload is None:
                    payload = self.staging.consume(key)
                if payload is None and key in self.prefetch.in_flight:
                    # In flight: consume in-kernel, do NOT stall — record
                    # the page's modeled arrival on the µs timeline.
                    job = self.prefetch.in_flight[key]
                    jobs[job.job_id] = job
                    self.prefetch.forget(job.keys)
                    for i2, (k2, p2) in enumerate(
                            zip(job.keys, job.payloads)):
                        waited[k2] = p2
                        arrive[k2] = job.page_done_us(i2)
                    payload = waited.pop(key)
                    when = arrive[key]
                if payload is None:
                    demand.append((ppn, owner, vpn))
                    continue
                self.cache.mgrs[s].residency.mark_resident([ppn])
                self.host.pop(owner, s, vpn)
                self.stats.faults += 1
                self.stats.prefetch_hits += 1
                self._note_prestage_hit(key)
                self.prefetch.stats["hits"] += 1
                self._fused_staged.append(((s, ppn), payload, when))
            if demand:
                self.cache.mgrs[s].residency.fault_in(
                    [ppn for ppn, _o, _v in demand])
                dpay = [self.host.pop(owner, s, vpn)
                        for _ppn, owner, vpn in demand]
                job = self.dma.enqueue(
                    [(owner, s, vpn) for _p, owner, vpn in demand],
                    [ppn for ppn, _o, _v in demand],
                    self.cache.mgrs[s].residency.page_bytes, dpay,
                    now, kind="demand")
                jobs[job.job_id] = job
                self.stats.faults += len(demand)
                self.stats.fault_dmas += job.dma_count
                self.stats.bytes_in += job.nbytes
                self.stats.transfer_us += job.transfer_us
                self.stats.prefetch_misses += len(demand)
                self.prefetch.stats["misses"] += len(demand)
                for i2, ((ppn, _o, _v), p) in enumerate(zip(demand, dpay)):
                    self._fused_staged.append(
                        ((s, ppn), p, job.page_done_us(i2)))
        for key, payload in waited.items():
            if self.host.has(*key) and key[0] not in self._foreign:
                self.staging.stage(key, payload)
            else:
                self.prefetch.stats["wasted_pages"] += 1
                self.stats.prefetch_wasted += 1
        self._fused_jobs = sorted(jobs.values(), key=lambda j: j.job_id)
        # The write page is merged at consumption time (it is mutated by
        # this step's token write); everything else stays in staging for
        # the kernel.  Its job still settles at the window end.
        pre = [(sp, pl) for sp, pl, _t in self._fused_staged
               if sp in write_pages]
        if pre:
            self._scatter_pages([s * pps + p for (s, p), _pl in pre],
                                [pl for _sp, pl in pre])
            self._fused_staged = [e for e in self._fused_staged
                                  if e[0] not in write_pages]
        self.stats.fault_steps += 1
        return lost

    def _attach_staging(self, ctx):
        """Expose this step's staged arrivals to the decode kernel
        (DESIGN.md §13): a dense step-local stage pool [L, NS, ptok,
        n_kv, dh{,_v}] plus a slot table mirroring ``ctx.tables``
        (-1 = pool-resident).  NS is padded to a power of two to bound
        jit retraces across steps with different arrival counts."""
        if not self._fused_staged or self.pools is None:
            return ctx
        pps = self.cache.pages_per_shard
        gid = {s * pps + ppn: i
               for i, ((s, ppn), _pl, _t) in enumerate(self._fused_staged)}
        tables = np.asarray(ctx.tables)
        slots = np.full(tables.shape, -1, np.int32)
        for g, i in gid.items():
            slots[tables == g] = i
        kp = np.stack([pl[0] for _sp, pl, _t in self._fused_staged], axis=1)
        vp = np.stack([pl[1] for _sp, pl, _t in self._fused_staged], axis=1)
        ns = 1 << (kp.shape[1] - 1).bit_length()
        if ns > kp.shape[1]:
            kp = np.concatenate(
                [kp, np.zeros((kp.shape[0], ns - kp.shape[1],
                               *kp.shape[2:]), kp.dtype)], axis=1)
            vp = np.concatenate(
                [vp, np.zeros((vp.shape[0], ns - vp.shape[1],
                               *vp.shape[2:]), vp.dtype)], axis=1)
        return dataclasses.replace(
            ctx, slots=jnp.asarray(slots),
            stage_k=jnp.asarray(kp), stage_v=jnp.asarray(vp))

    def _settle_fused(self) -> None:
        """Post-decode sync point (DESIGN.md §13): the kernel drained
        every staged page during the decode window, so the collected
        jobs settle against the window *end* — transfer µs inside the
        window are hidden, only tails past it are exposed.  The staged
        payloads are then scattered so the device pool is authoritative
        again before parking/preemption gathers run (same data-only
        timing model as every `_scatter_pages` landing)."""
        t_end = self._clock_us
        now = t_end
        for job in self._fused_jobs:
            now = max(now, self.dma.wait(job, t_end))
        if self._fused_jobs:
            self.stats.fault_exposed_us += now - t_end
            self.stats.fused_tail_us += now - t_end
            self._clock_us = now
        self.stats.fault_hidden_us = self.dma.stats["hidden_us"]
        self._fused_jobs = []
        if self._fused_staged:
            t0 = self._fused_t0
            ready = sum(1 for _sp, _pl, t in self._fused_staged if t <= t0)
            self.stats.fused_ready_pages += ready
            self.stats.fused_drained_pages += \
                len(self._fused_staged) - ready
            pps = self.cache.pages_per_shard
            self._scatter_pages(
                [s * pps + p for (s, p), _pl, _t in self._fused_staged],
                [pl for _sp, pl, _t in self._fused_staged])
            self._fused_staged = []

    # --------------------------------------------- async prefetch pipeline

    def _drain_prefetches(self) -> None:
        """Step start: publish the transfers that completed during the
        previous decode into the staging front buffer (double-buffer
        swap; see StagingBuffer ownership rules)."""
        for job in self.dma.drain(self._clock_us):
            if job.direction == "out":
                continue    # outbound gathers: settled by drain, no staging
            self.prefetch.forget(job.keys)
            for key, payload in zip(job.keys, job.payloads):
                # Pre-staged keys (DESIGN.md §14) stage under the rid
                # *before* admission registers the host copy, so they
                # pass on _prestage_keys membership, not host.has.
                if (self.host.has(*key) or key in self._prestage_keys) \
                        and key[0] not in self._foreign:
                    self.staging.stage(key, payload)
                else:   # owner retired/migrated while the DMA was in flight
                    self.prefetch.stats["wasted_pages"] += 1
                    self.stats.prefetch_wasted += 1
        self.staging.swap()
        self.stats.fault_hidden_us = self.dma.stats["hidden_us"]

    def _slack(self, r: Request) -> Optional[float]:
        """Deadline slack on the modeled clock (None = no deadline)."""
        if r.deadline_us is None:
            return None
        return r.deadline_us - self._clock_us

    def _slack_or_inf(self, r: Request) -> float:
        s = self._slack(r)
        return float("inf") if s is None else s

    def _resume_candidates(self) -> List[Request]:
        """Resume candidates in the order _admit will consider them:
        highest priority first; within a tier tightest deadline slack
        first, deadline-free requests FIFO last (stable sort)."""
        return sorted(self.preempted,
                      key=lambda r: (-r.priority, self._slack_or_inf(r)))

    def _resume_order(self) -> List[int]:
        return [r.rid for r in self._resume_candidates()]

    def _issue_prefetch(self) -> None:
        """Step end (just before decode): issue the predicted next-step
        touches to the DMA channels so they transfer while we compute.
        The resume-prefetch window is SLO-aware (DESIGN.md §8): the
        deadline pressure of the resume queue widens ``Prefetcher.depth``
        so urgent resumes have their pages staged in time."""
        resume = self._resume_candidates()
        depth = self.prefetch.plan_depth(
            [self._slack(r) for r in resume], self.slo_urgency_us)
        preds = self.prefetch.predict(
            self.cache, self.host, [r.rid for r in self.active],
            [r.rid for r in resume], depth=depth)
        by_shard: Dict[int, List[Tuple[Tuple[int, int, int], int]]] = {}
        by_seq: Dict[int, List[Tuple[int, int, int]]] = {}
        for key, ppn in preds:
            if self.staging.contains(key) or key in self.prefetch.in_flight:
                continue        # already staged or on a channel
            if not self.host.has(*key):
                continue
            if ppn is not None:
                by_shard.setdefault(key[1], []).append((key, ppn))
            else:
                by_seq.setdefault(key[0], []).append(key)
        page_bytes = self.page_bytes or self.cache.mgrs[0].residency.page_bytes
        jobs = []
        for s, group in sorted(by_shard.items()):
            # Mapped targets: real ppns drive the contiguous-run cost.
            jobs.append(self.dma.enqueue(
                [k for k, _p in group], [p for _k, p in group], page_bytes,
                [self.host.peek(*k) for k, _p in group],
                self._clock_us, kind="prefetch"))
        for rid, keys in sorted(by_seq.items()):
            # Resume candidates have no frames yet: the transfer gathers
            # into contiguous staging slots, so it merges into one DMA.
            jobs.append(self.dma.enqueue(
                keys, list(range(len(keys))), page_bytes,
                [self.host.peek(*k) for k in keys],
                self._clock_us, kind="prefetch"))
        for job in jobs:
            self._account_prefetch(job)

    def _account_prefetch(self, job) -> None:
        """Register an issued inbound prefetch job: in-flight tracking +
        the engine-side transfer accounting (one site for both the
        per-step predictor and admission-time prefix prefetches)."""
        for key in job.keys:
            self.prefetch.in_flight[key] = job
        self.prefetch.stats["issued_pages"] += len(job.keys)
        self.stats.fault_dmas += job.dma_count
        self.stats.bytes_in += job.nbytes
        self.stats.transfer_us += job.transfer_us

    def _match_prefix(self, req: Request):
        """Longest cached page-aligned prefix usable for this admission.

        Capped one page short of the prompt when the whole prompt is
        cached: the engine always prefills ≥ 1 real token, so the first
        output token comes from live computation (byte-identical to the
        cache-off run by construction — suffix prefill reproduces full
        prefill bitwise; see tests/test_prefix_cache.py).

        A shared (cluster) index attached to a non-dense replica never
        matches: this engine could not replay the cached KV."""
        if self.prefix is None or not self.prefix_supported:
            return None
        ptok = self.geo.page_tokens
        T = len(req.prompt)
        n, pages = self.prefix.match(req.prompt)
        n = min(n, (T - 1) // ptok)
        if n <= 0:
            self.stats.prefix_misses += 1
            return None
        self.stats.prefix_hits += 1
        return pages[:n]

    def _prefill(self, req: Request):
        """Run prefill for an already-allocated request (see _admit_one):
        suffix-only when a cached prefix matches, full otherwise.  Each
        admission also records a *modeled* latency sample (admit_lat_us):
        tokens actually prefilled × prefill_us_per_token, plus the disk
        promote stall a cache hit paid to bring spilled prefix frames
        back (DESIGN.md §11) — the wall-µs counters stay, but on CPU
        they measure jit time, not the serving effect."""
        t0 = time.perf_counter()
        T = len(req.prompt)
        match = self._match_prefix(req)
        promote_us = self._prefill_suffix(req, match) if match else None
        if promote_us is not None:
            self.stats.admit_hits += 1
            self.stats.admit_hit_us += (time.perf_counter() - t0) * 1e6
            model_us = (T - len(match) * self.geo.page_tokens) \
                * self.prefill_us_per_token + promote_us
        else:
            if match:
                # The matched payloads were quarantined mid-admission
                # (§12): fall back to full prefill — the prefix will be
                # re-derived (re-parked) when this request completes.
                self.stats.prefix_rederives += 1
            self._prefill_full(req)
            self.stats.admit_colds += 1
            self.stats.admit_cold_us += (time.perf_counter() - t0) * 1e6
            model_us = T * self.prefill_us_per_token
        self.stats.admit_lat_us.append(model_us)

    def _prefill_suffix(self, req: Request, pages) -> Optional[float]:
        """Cache-hit admission (DESIGN.md §8): restore the matched prefix
        pages through the host tier instead of recomputing them, and
        forward only the suffix (queries attend over the cached KV).

        The matched pages' payloads are (1) registered in the host store
        under this request — the index's own copies stay, shared and
        unpopped — (2) their freshly-allocated frames demoted to
        non-resident, and (3) prefetched through the DMA pipeline *now*,
        at admission, so the transfer overlaps whatever runs before the
        first decode step touches them.

        Promote-on-admission (DESIGN.md §11): matched pages whose frames
        were spilled to disk are promoted back *before* the payload
        reads, and the modeled disk stall — returned to the caller —
        advances the engine clock and the admission latency sample.
        Spill on/off changes only this timing, never the payload bytes,
        so tokens stay byte-identical.

        Returns None — *before* any request-visible side effect — when
        the promote quarantined a matched page's payload (§12): the
        caller falls back to full prefill and re-derives the prefix."""
        ptok = self.geo.page_tokens
        T = len(req.prompt)
        P = len(pages) * ptok
        self._run_compaction()
        promote_us = self.host.ensure_resident(
            [(pg.owner, pg.shard, pg.vpn) for pg in pages],
            now_us=self._clock_us)
        if any(not self.host.has(pg.owner, pg.shard, pg.vpn)
               for pg in pages):
            return None
        if promote_us:
            self._clock_us += promote_us
            self.stats.promote_stall_us += promote_us
            self.stats.promotions += 1
        payloads = [self.prefix.payload(pg) for pg in pages]
        locs = [(pg.shard, pg.vpn) for pg in pages]
        for (s, vpn), (kp, vp) in zip(locs, payloads):
            self.host.put(req.rid, s, vpn, kp, vp, kind="reuse")
        entries = self.cache.demote_prefix_pages(req.rid, locs)
        self.prefix.stats["reused_tokens"] += P
        # [L, B=1, P, kv, dh] stacked prefix KV for the layer scan.
        pk = np.stack([p[0] for p in payloads], axis=1)
        pv = np.stack([p[1] for p in payloads], axis=1)
        L = pk.shape[0]
        pk = pk.reshape(L, 1, P, *pk.shape[3:])
        pv = pv.reshape(L, 1, P, *pv.shape[3:])
        Tpad = ((T + ptok - 1) // ptok) * ptok
        tokens = np.full((1, Tpad - P), 0, np.int32)
        tokens[0, :T - P] = req.prompt[P:]
        ctx = self._ctx_global(self.cache.pack_ctx([req.rid], self.mpps))
        logits, pools_new, state = self.lm.prefill(
            self.params, {"tokens": jnp.asarray(tokens)},
            self._pools_for([req.rid]), ctx,
            last_pos=jnp.asarray([T - 1 - P], jnp.int32),
            prefix_kv=(jnp.asarray(pk), jnp.asarray(pv)), prefix_len=P)
        self._merge_pools([req.rid], pools_new)
        self.states[req.rid] = state
        req.out.append(int(jnp.argmax(logits[0])))
        self.stats.prefill_tokens += T - P      # compute actually done
        self.stats.prefix_reused_tokens += P
        # Admission-time fault-in through the async pipeline: the first
        # decode step that touches these pages finds them in flight (or
        # already staged) instead of paying a cold demand fault.
        if self.fault_mode in ("async", "fused"):
            # Pre-staged keys whose source owner no longer matches are
            # stale — the index churned between the router's probe and
            # this admission and re-parked different bytes at the same
            # (shard, vpn).  Cancel the whole rid's pre-stage before the
            # dedup pass: byte identity beats saving a transfer.
            if any(self._prestage_keys.get((req.rid, s, vpn),
                                           pages[i].owner) != pages[i].owner
                   for i, (s, vpn, _ppn) in enumerate(entries)):
                self.cancel_prestage(req.rid)
            by_shard: Dict[int, List[int]] = {}
            for i, (s, vpn, _ppn) in enumerate(entries):
                key = (req.rid, s, vpn)
                if key in self._prestage_keys and (
                        self.staging.contains(key)
                        or key in self.prefetch.in_flight):
                    # Pre-staged toward this engine while the request
                    # was still queued (DESIGN.md §14): the identical
                    # payload is already staged or in flight under this
                    # exact key — issuing (and charging) the transfer
                    # again would double-book the lane.
                    self._note_prestage_hit(key)
                    continue
                by_shard.setdefault(s, []).append(i)
            for s, idxs in sorted(by_shard.items()):
                job = self.dma.enqueue(
                    [(req.rid, entries[i][0], entries[i][1]) for i in idxs],
                    [entries[i][2] for i in idxs],
                    self.cache.mgrs[s].residency.page_bytes,
                    [payloads[i] for i in idxs],
                    self._clock_us, kind="prefetch")
                self._account_prefetch(job)
                self.stats.prefix_fault_us += job.transfer_us
        return promote_us

    def _park_prefix(self, req: Request) -> None:
        """Completion hook (DESIGN.md §8): park the finished request's
        full prompt pages in the prefix index so future admissions
        sharing the prefix fault them in instead of re-decoding.

        Only the chain suffix the index is missing is parked (chained
        hashes dedupe shared prefixes for free).  Payloads come from the
        device pool (resident pages — one batched gather that rides the
        outbound DMA lanes) or from the request's own host copies (pages
        still swapped out); a page with neither truncates the chain,
        keeping the index prefix-closed.

        Non-dense fallback (DESIGN.md §10): a model family whose KV a
        suffix prefill cannot replay bitwise (MoE capacity routing, MLA
        latents, recurrent state) must not park — a cluster-shared index
        would hand those pages to dense replicas as unreplayable KV.
        The park is skipped and counted instead."""
        if self.prefix is None:
            return
        if not self.prefix_supported or self.pools is None:
            self.stats.prefix_park_skipped += 1
            return
        if not self.host.park_allowed():
            # §11 back-pressure: the host tier's write-back buffer is
            # saturated — parking more cold pages would queue unbounded
            # dirty data in front of the disk.  Refuse (and count) the
            # park; the prefix is simply not cached this time, which is
            # always token-safe.
            self.stats.prefix_park_refused += 1
            return
        hashes = self.prefix.chain_hashes(req.prompt)
        start = self.prefix.missing_from(hashes)
        if start >= len(hashes):
            return
        # Pending compaction plans rewrote tables; land the copies before
        # gathering through them (same rule as _preempt).
        self._run_compaction()
        rid = req.rid
        to_park: List[Tuple[int, int, int, Optional[Tuple]]] = []
        gather_entries: List[Tuple[int, int, int]] = []
        for gp in range(start, len(hashes)):
            s, vpn = self.cache.locate_page(gp)
            mgr = self.cache.mgrs[s]
            if rid not in mgr.tables or vpn >= len(mgr.tables[rid].ppn):
                break
            ppn = mgr.tables[rid].ppn[vpn]
            if ppn >= 0 and mgr.residency.resident[ppn]:
                gather_entries.append((s, vpn, ppn))
                to_park.append((gp, s, vpn, None))
            elif self.host.has(rid, s, vpn):
                to_park.append((gp, s, vpn, self.host.peek(rid, s, vpn)))
            else:
                break
        if not to_park:
            return
        gathered = self._gather_pages(gather_entries)
        git = iter(gathered)
        out_keys: List[Tuple[int, int, int]] = []
        parent = hashes[start - 1] if start else None
        for gp, s, vpn, payload in to_park:
            from_device = payload is None
            if from_device:
                payload = next(git)
            page = self.prefix.park(hashes[gp], parent, gp, s, vpn,
                                    *payload)
            if from_device:
                out_keys.append((page.owner, s, vpn))
            parent = hashes[gp]
        self.stats.prefix_parked_pages += len(to_park)
        # The device gather is outbound traffic on the duplex channels.
        self._enqueue_outbound(out_keys, gather_entries, gathered,
                               kind="park")

    def _prefill_full(self, req: Request):
        """Cold admission: full-prompt forward (PR 2's only path)."""
        ptok = self.geo.page_tokens
        T = len(req.prompt)
        Tpad = ((T + ptok - 1) // ptok) * ptok
        # Allocation under memory pressure may have compacted: the tables
        # already point at the new locations, so the data copies must land
        # BEFORE the device reads them (and before the pages freed by
        # compaction are overwritten by this prefill).
        self._run_compaction()
        ctx = self._ctx_global(self.cache.pack_ctx([req.rid], self.mpps))
        tokens = np.full((1, Tpad), 0, np.int32)
        tokens[0, :T] = req.prompt
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.frontend_tokens, self.cfg.d_model))
        if self.cfg.family == "encdec":
            batch["src_embeds"] = jnp.zeros(
                (1, self.cfg.encdec.source_len, self.cfg.d_model))
        logits, pools_new, state = self.lm.prefill(
            self.params, batch, self._pools_for([req.rid]), ctx,
            last_pos=jnp.asarray([T - 1], jnp.int32))
        self._merge_pools([req.rid], pools_new)
        self.states[req.rid] = state
        nxt = int(jnp.argmax(logits[0]))
        req.out.append(nxt)
        # tokens beyond T within the padded page are unused; tracked length
        # stays T (+1 for the decode append below).
        self.stats.prefill_tokens += T

    # ------------------------------------------------------------- pools

    # For simplicity pools are global arrays addressed by global page id =
    # shard * pages_per_shard + local id; pack_ctx returns local ids, so we
    # offset per shard here.
    def _pools_for(self, seqs):
        return self.pools

    def _merge_pools(self, seqs, pools_new):
        self.pools = pools_new

    def _ctx_global(self, ctx):
        """Convert per-shard local page ids to global pool ids."""
        S = self.cache.S
        pps = self.cache.pages_per_shard
        off = (jnp.arange(S) * pps)[None, :, None]
        tables = jnp.where(ctx.tables >= 0, ctx.tables + off, -1)
        woff = (jnp.arange(S) * pps)[None, :]
        wpage = jnp.where(ctx.wpage >= 0, ctx.wpage + woff, -1)
        return dataclasses.replace(ctx, tables=tables, wpage=wpage)

    # ------------------------------------------------------------- stepping

    def _append_with_preemption(self) -> List[Request]:
        """Grow active requests by one token slot, highest priority first.

        Under pool pressure a request may displace peers of its own tier or
        below (never a higher-priority one); with no displaceable victim it
        *stalls* — keeps its pages but sits this step out.  If nobody can
        grow, the lowest-priority request is forcibly swapped out so the
        rest make progress next step.  Returns this step's decode batch.
        """
        order = sorted(self.active, key=lambda r: -r.priority)  # stable
        appended: List[Request] = []
        for r in order:
            if r not in self.active:
                continue            # preempted as someone else's victim
            while r in self.active:
                try:
                    self.cache.append(r.rid, 1)
                    appended.append(r)
                    break
                except OutOfMemory:
                    victim = self._pick_victim(
                        below_priority=r.priority + 1,
                        exclude=tuple(a.rid for a in appended) + (r.rid,))
                    if victim is None:
                        break       # stall: retry next step
                    self._preempt(victim)
        if not appended and self.active:
            victim = self._pick_victim()
            if victim is not None and len(self.active) > 1:
                self._preempt(victim)
        return [r for r in self.active if r in appended]

    def step(self):
        """One engine iteration as a two-stage pipeline: drain completed
        prefetches → admit → fault remaining misses (exposed) → decode
        while the next step's prefetch is in flight → retire."""
        if not self.alive:
            return False        # a crashed engine does no work (§12)
        t0 = time.perf_counter()
        # Advance the host tier's write-back pipeline to the engine clock
        # (DESIGN.md §11): frames whose spill completed during previous
        # steps persist now, freeing write-back queue slots before this
        # step's admissions and parks consult park_allowed().
        self.host.pump(self._clock_us)
        if self.fault_mode in ("async", "fused"):
            # Stage 0: publish transfers that finished during the last
            # decode (double-buffer swap) so admission's resumes and this
            # step's fault-in see them as hits.
            self._drain_prefetches()
        self._admit()
        if not self.active:
            self.stats.wall_s += time.perf_counter() - t0
            return False
        # Append this step's token slot, then pack tables.
        runnable = self._append_with_preemption()
        if not runnable:
            # An occasional all-stalled step is normal under pressure
            # (capacity frees as others complete), but a *permanent* stall
            # means some request can never grow — fail loudly rather than
            # spinning run_until_drained to its step cap.
            self._stalled_steps += 1
            if self._stalled_steps > 64:
                raise OutOfMemory(
                    f"engine stalled {self._stalled_steps} consecutive "
                    f"steps: active requests "
                    f"{sorted(r.rid for r in self.active)} cannot grow "
                    f"(pool too small or fragmentation unrecoverable)")
            # Stalled steps still did real work (admission attempts, forced
            # preemption gathers) — keep them in the tok/s denominator.
            self.stats.wall_s += time.perf_counter() - t0
            return bool(self.active or self.queue or self.preempted)
        self._stalled_steps = 0
        seqs = [r.rid for r in runnable]
        # Appends under pressure may compact; execute the copy plan before
        # the decode step consumes the updated tables (ordering matters:
        # tables are rewritten at plan time, payloads move here).
        self._run_compaction()
        # touch() the pages this step's packed tables will read and
        # batch-fault the missing ones in from the host tier.
        lost = self._fault_in(seqs)
        if lost:
            # A spill quarantine destroyed some batch members' payloads
            # mid-promote (§12): restart them from the prompt and decode
            # the survivors this step.
            self._restart_lost(lost)
            runnable = [r for r in runnable if r.rid not in lost]
            seqs = [r.rid for r in runnable]
            if not runnable:
                if self.fault_mode == "fused":
                    # No decode window opens: settle collected jobs and
                    # land staged payloads at the current clock.
                    self._settle_fused()
                self.stats.wall_s += time.perf_counter() - t0
                return bool(self.active or self.queue or self.preempted)
        ctx = self._ctx_global(self.cache.pack_ctx(seqs, self.mpps))
        if self.fault_mode == "fused":
            # Start-decode-on-resident: hand the kernel this step's
            # staged arrivals + readiness mask instead of stalling for
            # them (DESIGN.md §13).
            ctx = self._attach_staging(ctx)
        if self.fault_mode in ("async", "fused"):
            # Stage 2: predicted next-step touches ride the DMA channels
            # while the decode below computes — their µs become hidden.
            self._issue_prefetch()
        toks = jnp.asarray([r.out[-1] for r in runnable], jnp.int32)
        pos = jnp.asarray([self.cache.seq_tokens[r.rid] - 1
                           for r in runnable], jnp.int32)
        state = self._stack_states(seqs)
        t_dec = time.perf_counter()
        logits, self.pools, state = self._decode_jit(
            self.params, toks, pos, self.pools, ctx, state)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        # The decode step is the compute window in-flight DMAs hide in:
        # modeled width if configured, else measured wall time.
        self._clock_us += (self.decode_window_us
                           if self.decode_window_us is not None
                           else (time.perf_counter() - t_dec) * 1e6)
        if self.fault_mode == "fused":
            # Drain-within-kernel settled: jobs consumed this step charge
            # only the tail past the window, and the staged payloads are
            # scattered so the pool is authoritative before the parking
            # gathers in the retire loop below (DESIGN.md §13).
            self._settle_fused()
        # The decode window may have carried queued write-backs past
        # their disk-ready time: persist them before the completion
        # parks below ask park_allowed().
        self.host.pump(self._clock_us)
        self._meter_translation(runnable)
        self._unstack_states(seqs, state)
        done_now = []
        for i, r in enumerate(runnable):
            r.out.append(int(nxt[i]))
            self.stats.decode_tokens += 1
            if len(r.out) >= r.max_new \
                    or self.cache.seq_tokens[r.rid] >= self.max_seq - 1:
                r.done = True
                done_now.append(r)
                if r.deadline_us is not None:
                    # SLO attainment on the modeled clock, per priority
                    # tier (DESIGN.md §10).
                    self.stats.note_deadline(
                        r.priority, self._clock_us <= r.deadline_us)
        for r in done_now:
            # Park the finished prompt's pages in the prefix cache before
            # the frames are freed / host copies dropped (DESIGN.md §8).
            self._park_prefix(r)
            if self.translation_meter is not None:
                # Address space retires with the sequence: its coalesced
                # entries and in-flight MSHR keys go with it.
                for s in range(self.cache.S):
                    self.translation_meter.drop_space((r.rid, s))
            self.active.remove(r)
            self.cache.free(r.rid)
            self.states.pop(r.rid, None)
            self.host.drop_seq(r.rid)
            self._note_prestage_waste(r.rid)
            dropped = self.staging.invalidate_seq(r.rid)
            self.stats.prefetch_wasted += dropped
            self.prefetch.stats["wasted_pages"] += dropped
            self.prefetch.cancel_seq(r.rid)
            self._saved_tokens.pop(r.rid, None)
        # Execute any CAC compaction plans on-device.
        self._run_compaction()
        st = self.cache.stats()
        self.stats.coalesced_sum += st.get("coalesced_fraction", 0.0)
        self.stats.occupancy_sum += st.get("occupancy", 0.0)
        self.stats.decode_steps += 1
        self.stats.wall_s += time.perf_counter() - t0
        return True

    def _meter_translation(self, runnable) -> None:
        """Run this step's packed KV page touches through the translation
        meter (DESIGN.md §15).  Each (seq, shard) pair is a distinct
        address space; latency is charged to the request's tenant.  Pure
        observation — decode results and the engine clock are untouched."""
        if self.translation_meter is None:
            return
        tables = []
        for r in runnable:
            for s, m in enumerate(self.cache.mgrs):
                t = m.tables.get(r.rid)
                if t is not None:
                    tables.append(((r.rid, s), r.tenant, t.ppn))
        d = self.translation_meter.step_access(self._clock_us, tables)
        st = self.stats
        st.translation_lookups += int(d["lookups"])
        st.translation_tlb_hits += int(d["tlb_hits"])
        st.translation_walks += int(d["walks"])
        st.translation_walk_cycles += d["walk_cycles"]
        st.translation_queue_cycles += d["queue_cycles"]
        st.translation_us += self.translation_meter.cycles_us(
            d["latency_cycles"])

    def translation_backlog_us(self) -> float:
        """Booked walker time beyond the engine clock, in modeled µs —
        the translation-interference term the router's dispatch cost
        charges.  0.0 when the meter is off (router claims unchanged)."""
        if self.translation_meter is None:
            return 0.0
        return self.translation_meter.backlog_us(self._clock_us)

    def _run_compaction(self):
        ops = self.cache.drain_copy_ops()
        if ops and self.translation_meter is not None:
            # CAC remapped pages: splinter exactly the touched subregions
            # out of the TLB (the selective shootdown the coalesced-entry
            # model requires).  rmap already points at the destination.
            for s, op in ops:
                owner_vpn = self.cache.mgrs[s].rmap.get(op.dst_ppn)
                if owner_vpn is not None:
                    self.translation_meter.splinter(
                        (owner_vpn[0], s), owner_vpn[1])
        if not ops or self.pools is None:
            return
        pps = self.cache.pages_per_shard
        src = jnp.asarray([s * pps + op.src_ppn for s, op in ops],
                          jnp.int32)
        dst = jnp.asarray([s * pps + op.dst_ppn for s, op in ops],
                          jnp.int32)
        k, v = self.pools
        # pools are stacked [L, NP, ...]: compact every layer's pool.
        k = jax.vmap(lambda pool: kops.page_compact(
            pool, src, dst, use_pallas=self.use_pallas))(k)
        v = jax.vmap(lambda pool: kops.page_compact(
            pool, src, dst, use_pallas=self.use_pallas))(v)
        self.pools = (k, v)
        self.stats.compaction_copies += len(ops)

    # ------------------------------------------------------------- states

    def _stack_states(self, seqs):
        if not self.states:
            return {}
        keys = self.states[seqs[0]].keys()
        return {k: jnp.concatenate(
            [self._state_of(s)[k] for s in seqs],
            axis=1 if k in ("ssm", "conv", "cross_k", "cross_v") else 0)
            for k in keys}

    def _state_of(self, seq):
        return self.states[seq]

    def _unstack_states(self, seqs, stacked):
        if not stacked:
            return
        for k, v in stacked.items():
            ax = 1 if k in ("ssm", "conv", "cross_k", "cross_v") else 0
            parts = jnp.split(v, len(seqs), axis=ax)
            for s, part in zip(seqs, parts):
                self.states[s][k] = part

    # ------------------------------------------------------------- run

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or self.active or self.preempted) \
                and steps < max_steps:
            self.step()
            steps += 1
        if self.fault_mode in ("async", "fused") and not (
                self.queue or self.active or self.preempted):
            # Settle transfers still riding the channels so the reported
            # hidden/exposed/wasted split covers every issued byte (a
            # prefetch issued on the final step would otherwise stay
            # unaccounted while its µs sit in transfer_us).
            self._clock_us = max(self._clock_us, self.dma.busy_until())
            self._drain_prefetches()
        self.host.pump(self._clock_us)
        return steps
