"""Host memory tier under the Mosaic pool: evicted/cold KV page payloads.

The paper's demand-paging setting (§1) assumes the application's working
set can exceed device memory: pages live in host DRAM and move over the
system I/O bus at *base-page* granularity on first touch.  For the serving
engine that means HBM holds only the KV pages active decode steps actually
read; everything else — preempted requests, cold prefixes — parks here.

Payloads are keyed by **logical** identity ``(seq, shard, vpn)``, not by
physical page: eviction frees the physical page (another tenant reuses it
immediately), and a resumed sequence is re-mapped to whatever frames CoCoA
hands out — the fault-in path looks the payload up by who owns the page,
scatters it to the page's *new* physical location, and drops the host copy
(the device copy is authoritative once resident; decode appends write it).

Two kinds of tenant share the store:

* **Swapped requests** (``seq`` = a live request id ≥ 0): preemption parks
  their resident pages; resume + fault-in pops them back.
* **Cached prefixes** (``seq`` = a negative owner id minted by
  :class:`PrefixIndex`): cold *shared* prompt prefixes keyed by chained
  content hash (DESIGN.md §8).  These are read with :meth:`peek` —
  never popped by fault-in — so any number of requests can reuse one
  parked prefix, and ``drop_seq`` of a finished request (ids ≥ 0) can
  never evict them; only the index's own LRU eviction does.

Below host DRAM sits a third, disk-backed tier (DESIGN.md §11):
:class:`SpillStore` persists **whole frames** — one file per host frame,
all pages of one protection domain, so the single-domain-per-frame
invariant survives on disk verbatim.  The spill/promote orchestration
(LRU victim choice, the write-back queue riding the outbound DMA lanes,
promote-on-touch) lives in :class:`~repro.serving.cluster.SharedHostTier`;
this module only owns the file format and the byte-exact round-trip.

The device⇄host movement itself is the engine's job
(:func:`repro.kernels.ops.page_gather` / ``page_scatter``); this class is
pure host-side bookkeeping and therefore trivially testable.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import shutil
import tempfile
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

Key = Tuple[int, int, int]          # (seq, shard, local vpn)


class HostPageStore:
    """Host-DRAM store of KV base-page payloads.

    Each entry is one base page of one sub-pool: a pair of numpy arrays
    ``(k_page, v_page)`` shaped ``[L, page_tokens, kv_heads, head_dim]``
    (whatever the model's pool page slice is — the store is shape-agnostic).
    """

    def __init__(self) -> None:
        self._pages: Dict[Key, Tuple[np.ndarray, np.ndarray]] = {}
        self.stats = {
            "swapped_out_pages": 0, "swapped_in_pages": 0,
            "swap_out_requests": 0, "swap_in_requests": 0,
            "peak_pages": 0, "cached_pages": 0, "reused_pages": 0,
            "promoted_pages": 0,
        }

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._pages)

    def has(self, seq: int, shard: int, vpn: int) -> bool:
        return (seq, shard, vpn) in self._pages

    def seq_pages(self, seq: int) -> List[Key]:
        return sorted(k for k in self._pages if k[0] == seq)

    def nbytes(self) -> int:
        return sum(k.nbytes + v.nbytes for k, v in self._pages.values())

    def request_pages(self) -> int:
        """Pages owned by live requests (seq ≥ 0) — excludes cached
        prefixes, which deliberately outlive their source requests."""
        return sum(1 for k in self._pages if k[0] >= 0)

    # ------------------------------------------------------------- movement

    def put(self, seq: int, shard: int, vpn: int,
            k_page: np.ndarray, v_page: np.ndarray, *,
            kind: str = "swap") -> None:
        """Park one page's payload (device→host already gathered).

        ``kind="swap"`` counts toward the preemption traffic stats;
        ``kind="prefix"`` is a :class:`PrefixIndex` insertion;
        ``kind="reuse"`` a per-request copy of a cached prefix page
        registered at cache-hit admission (host-side memcpy, no bus
        traffic — the transfer is accounted by the admission prefetch);
        ``kind="promote"`` a page returning from the disk spill tier
        (DESIGN.md §11 — the read is accounted by the promoting tier)."""
        assert kind in ("swap", "prefix", "reuse", "promote"), kind
        self._pages[(seq, shard, vpn)] = (np.asarray(k_page),
                                          np.asarray(v_page))
        key = {"swap": "swapped_out_pages", "prefix": "cached_pages",
               "reuse": "reused_pages", "promote": "promoted_pages"}[kind]
        self.stats[key] += 1
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       len(self._pages))

    def pop(self, seq: int, shard: int, vpn: int
            ) -> Tuple[np.ndarray, np.ndarray]:
        """Retrieve and drop one payload for fault-in (host→device)."""
        kv = self._pages.pop((seq, shard, vpn))
        self.stats["swapped_in_pages"] += 1
        return kv

    def peek(self, seq: int, shard: int, vpn: int
             ) -> Tuple[np.ndarray, np.ndarray]:
        """Read a payload without dropping it (async prefetch staging and
        prefix-cache reads: the host copy stays authoritative until the
        page is actually scattered into the pool, so a wrong prediction —
        or a shared prefix reused by many requests — loses nothing)."""
        return self._pages[(seq, shard, vpn)]

    def discard(self, seq: int, shard: int, vpn: int) -> bool:
        """Drop one payload without transfer accounting (index eviction)."""
        return self._pages.pop((seq, shard, vpn), None) is not None

    def note_swap_out(self) -> None:
        """One whole-request preemption (for the bench's swap counts)."""
        self.stats["swap_out_requests"] += 1

    def note_swap_in(self) -> None:
        """One whole-request resume."""
        self.stats["swap_in_requests"] += 1

    def drop_seq(self, seq: int) -> int:
        """Discard a sequence's parked pages (request cancelled/finished).

        Only touches keys owned by ``seq`` itself — prefix-cache pages
        live under negative :class:`PrefixIndex` owner ids, so finishing
        a request that *sourced* a cached prefix never evicts the cache.
        """
        keys = [k for k in self._pages if k[0] == seq]
        for k in keys:
            del self._pages[k]
        return len(keys)

    # -------------------------------------------------------- tier hooks
    # A standalone engine's private store has no disk tier underneath;
    # these mirror the LeasedStoreView/SharedHostTier surface (DESIGN.md
    # §11) so the engine never branches on which host it was given.

    def park_allowed(self) -> bool:
        """Back-pressure probe: an unbounded store always accepts parks."""
        return True

    def ensure_resident(self, keys: Iterable[Key],
                        now_us: Optional[float] = None) -> float:
        """Promote ``keys`` from the spill tier; returns the stall µs
        (always 0 here — nothing is ever spilled from a private store)."""
        return 0.0

    def pump(self, now_us: float) -> None:
        """Advance the tier's write-back pipeline to ``now_us`` (no-op)."""

    def take_lost(self, seq: int) -> bool:
        """Whether ``seq``'s pages were destroyed by a spill quarantine
        (always False here — a private store has no disk underneath)."""
        return False


# ------------------------------------------------------------------- disk


class SpillStore:
    """Disk tier under the host store: whole-frame spill files (§11).

    One ``.npz`` file per spilled host frame, holding every page payload
    of that frame plus its keys and protection domain — so a frame comes
    back from disk exactly as it left, and the single-domain-per-frame
    invariant holds on disk *by construction* (a file cannot mix domains
    because a frame cannot).  Round-trips are byte-exact; the modeled
    disk latency/bandwidth lives in the orchestrating tier, not here.

    Integrity (DESIGN.md §12): every frame is written with a blake2b
    digest of its true payload bytes (stored both in the file and in
    the in-memory frame map), and :meth:`read_frame` re-hashes what it
    loaded before returning anything — a flipped bit anywhere in the
    payload raises :class:`~repro.serving.faults.SpillCorruptionError`
    and the corrupted KV is **never decoded from**.  An optional
    :class:`~repro.serving.faults.FaultInjector` injects read/write
    errors and on-disk bit flips at exactly these seams.

    ``root=None`` creates (lazily) and owns a temp directory, removed by
    :meth:`close`; a caller-supplied ``root`` is reused and kept.  A
    pre-existing ``root`` is swept of orphaned ``frame_*.npz`` files at
    construction — a crashed run's leftovers carry no in-memory frame
    map, so they could never be promoted and must not be misread by (or
    collide with) the next run's frame ids.  The store is a context
    manager: ``with SpillStore() as s: ...`` closes (and, when owned,
    removes) the directory on exit even if the run died mid-spill.
    """

    def __init__(self, root: Optional[str] = None, *,
                 injector=None) -> None:
        self.root = root
        self._owned = root is None
        self._dir: Optional[str] = None
        self.injector = injector
        # frame id → (path, keys in file order, domain, per-page
        # (k_dtype, k_shape, v_dtype, v_shape) — payloads are stored as
        # raw bytes so non-native dtypes (bfloat16) round-trip exactly,
        # and blake2b digest of the true payload bytes)
        self._frames: Dict[int, Tuple[str, Tuple[Key, ...], Hashable,
                                      Tuple[tuple, ...], bytes]] = {}
        self.stats = {
            "frames_written": 0, "pages_written": 0, "bytes_written": 0,
            "frames_read": 0, "pages_read": 0, "bytes_read": 0,
            "frames_deleted": 0, "peak_frames": 0,
            "orphans_swept": 0, "frames_quarantined": 0,
            "checksum_failures": 0,
        }
        if root is not None and os.path.isdir(root):
            self._sweep_orphans(root)

    def _sweep_orphans(self, d: str) -> None:
        """Remove frame files a previous (crashed) run left behind: the
        in-memory frame map is empty at construction, so every existing
        ``frame_*.npz`` is unreachable and would only risk being misread
        under a recycled frame id."""
        for name in sorted(os.listdir(d)):
            if name.startswith("frame_") and name.endswith(".npz"):
                try:
                    os.remove(os.path.join(d, name))
                    self.stats["orphans_swept"] += 1
                except OSError:
                    pass        # already gone / unreadable: harmless

    def __enter__(self) -> "SpillStore":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _ensure_dir(self) -> str:
        if self._dir is None:
            if self.root is not None:
                os.makedirs(self.root, exist_ok=True)
                self._dir = self.root
            else:
                self._dir = tempfile.mkdtemp(prefix="mosaic-spill-")
        return self._dir

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._frames)

    def has_frame(self, frame: int) -> bool:
        return frame in self._frames

    def frame_ids(self) -> List[int]:
        return sorted(self._frames)

    def frame_keys(self, frame: int) -> Tuple[Key, ...]:
        return self._frames[frame][1]

    @staticmethod
    def _pack(arr: np.ndarray) -> Tuple[np.ndarray, np.dtype, tuple]:
        """Flatten to raw uint8 — npz can't hold bfloat16 natively."""
        a = np.ascontiguousarray(arr)
        return a.view(np.uint8).reshape(-1), a.dtype, a.shape

    # ------------------------------------------------------------- movement

    @staticmethod
    def _digest(packed: Sequence[np.ndarray]) -> bytes:
        """blake2b over a frame's packed payload bytes, in page order."""
        h = hashlib.blake2b(b"mosaic-spill-v1", digest_size=16)
        for a in packed:
            h.update(a.tobytes())
        return h.digest()

    def write_frame(self, frame: int, domain: Hashable,
                    pages: Sequence[Tuple[Key, Tuple[np.ndarray,
                                                     np.ndarray]]]) -> int:
        """Persist one whole frame; returns the payload byte count.

        May raise :class:`~repro.serving.faults.SpillIOError` (injected
        disk failure) *before* any state mutates — a failed write leaves
        the store exactly as it was, so the tier can retry or cancel."""
        assert pages, "spilling an empty frame"
        assert frame not in self._frames, f"frame {frame} already on disk"
        if self.injector is not None:
            self.injector.disk_write_fault(frame)
        path = os.path.join(self._ensure_dir(), f"frame_{frame:08d}.npz")
        arrs: Dict[str, np.ndarray] = {
            "keys": np.asarray([k for k, _ in pages], np.int64),
            "domain": np.asarray(repr(domain)),
        }
        nbytes = 0
        meta = []
        packed: List[np.ndarray] = []
        for i, (_key, (kp, vp)) in enumerate(pages):
            arrs[f"k{i}"], kdt, ksh = self._pack(kp)
            arrs[f"v{i}"], vdt, vsh = self._pack(vp)
            packed.extend((arrs[f"k{i}"], arrs[f"v{i}"]))
            meta.append((kdt, ksh, vdt, vsh))
            nbytes += kp.nbytes + vp.nbytes
        arrs["dtypes"] = np.asarray([f"{m[0]}:{m[2]}" for m in meta])
        # The digest is of the TRUE bytes; injected corruption flips a
        # bit only in what lands on disk, so verification must catch it.
        digest = self._digest(packed)
        arrs["checksum"] = np.frombuffer(digest, np.uint8).copy()
        if self.injector is not None:
            bad = self.injector.corrupt_written(frame, arrs["k0"].tobytes())
            if bad is not None:
                arrs["k0"] = np.frombuffer(bad, np.uint8)
        np.savez(path, **arrs)
        self._frames[frame] = (path, tuple(k for k, _ in pages), domain,
                               tuple(meta), digest)
        self.stats["frames_written"] += 1
        self.stats["pages_written"] += len(pages)
        self.stats["bytes_written"] += nbytes
        self.stats["peak_frames"] = max(self.stats["peak_frames"],
                                        len(self._frames))
        return nbytes

    def read_frame(self, frame: int, expect_domain: Hashable = None
                   ) -> List[Tuple[Key, Tuple[np.ndarray, np.ndarray]]]:
        """Load a whole frame back (promote); file stays until deleted.

        Raises :class:`~repro.serving.faults.SpillIOError` on an
        (injected) disk error and :class:`~repro.serving.faults.
        SpillCorruptionError` when the loaded payload bytes fail
        checksum verification — in both cases **before** returning any
        payload, so corrupted or unreadable KV is never decoded from."""
        path, keys, domain, meta, digest = self._frames[frame]
        if self.injector is not None:
            self.injector.disk_read_fault(frame)
        if expect_domain is not None:
            assert domain == expect_domain, \
                f"frame {frame} spilled under {domain!r}, " \
                f"promoted under {expect_domain!r}"
        out: List[Tuple[Key, Tuple[np.ndarray, np.ndarray]]] = []
        nbytes = 0
        with np.load(path) as z:
            stored = tuple(tuple(int(x) for x in row) for row in z["keys"])
            assert stored == keys, f"frame {frame} file/index key mismatch"
            raw = [z[f"{kv}{i}"] for i in range(len(stored))
                   for kv in ("k", "v")]
            if self._digest(raw) != digest:
                from repro.serving.faults import SpillCorruptionError
                self.stats["checksum_failures"] += 1
                raise SpillCorruptionError(frame)
            for i, key in enumerate(stored):
                kdt, ksh, vdt, vsh = meta[i]
                kp = z[f"k{i}"].view(kdt).reshape(ksh)
                vp = z[f"v{i}"].view(vdt).reshape(vsh)
                nbytes += kp.nbytes + vp.nbytes
                out.append((key, (kp, vp)))
        self.stats["frames_read"] += 1
        self.stats["pages_read"] += len(out)
        self.stats["bytes_read"] += nbytes
        return out

    def delete_frame(self, frame: int) -> None:
        path = self._frames.pop(frame)[0]
        if os.path.exists(path):
            os.remove(path)
        self.stats["frames_deleted"] += 1

    def quarantine_frame(self, frame: int) -> None:
        """Drop a corrupted/unreadable frame without counting it as a
        normal delete: the file (if any) is removed so a bad payload can
        never be read again, and the frame id leaves the map so the
        tier can rebuild its contents from upstream truth."""
        path = self._frames.pop(frame)[0]
        try:
            if os.path.exists(path):
                os.remove(path)
        except OSError:
            pass                # unreadable file may also be unlinkable
        self.stats["frames_quarantined"] += 1

    def close(self) -> None:
        """Drop every file; removes the temp directory when owned."""
        for f in list(self._frames):
            self.delete_frame(f)
        if self._owned and self._dir is not None \
                and os.path.isdir(self._dir):
            shutil.rmtree(self._dir, ignore_errors=True)
        self._dir = None


# ---------------------------------------------------------------- prefixes


@dataclasses.dataclass
class PrefixPage:
    """One cached prompt page: the payload key + chain bookkeeping."""

    chain_hash: bytes               # H(parent_hash ‖ page tokens)
    page_index: int                 # global page number within the prompt
    owner: int                      # negative HostPageStore namespace
    shard: int
    vpn: int                        # local vpn in ``shard``
    parent: Optional[bytes]
    tick: int                       # LRU clock of the last lookup/insert
    hits: int = 0


class PrefixIndex:
    """Content-hash index over cold shared prompt prefixes (DESIGN.md §8).

    Prompts are hashed per *base page* with a chained hash — page ``i``'s
    key is ``H(key[i-1] ‖ tokens[i·ptok:(i+1)·ptok])`` — so a key match
    implies the **whole prefix up to and including that page** matches,
    and divergent prompts share index entries exactly up to their common
    page-aligned prefix.  Payloads (the pages' KV, bitwise as prefill
    wrote them) live in the :class:`HostPageStore` under per-page negative
    owner ids; the index maps hash → payload key.

    Invariant: the set of cached hashes is *prefix-closed* — a page is
    only inserted when its parent is present, and eviction removes a page
    together with all of its descendants — so the longest cached prefix
    of a prompt is found by walking its chain until the first miss.

    Eviction is LRU over chains: lookups and insertions touch every page
    of the matched chain with one tick, so a parent's tick is always ≥
    its children's, and the least-recently-used *childless* page is the
    tail of the stalest chain.  ``capacity_pages`` bounds host DRAM spent
    on cold prefixes.
    """

    def __init__(self, store: HostPageStore, page_tokens: int,
                 capacity_pages: int = 4096, *,
                 owner_start: int = -1, owner_step: int = -1) -> None:
        """``owner_start``/``owner_step`` namespace the negative owner
        ids this index mints — several indexes sharing one store (a
        cluster's per-engine indexes over the shared host tier,
        DESIGN.md §10) use disjoint arithmetic progressions so their
        payload keys can never collide."""
        assert page_tokens >= 1 and capacity_pages >= 1
        assert owner_start < 0 and owner_step < 0
        self.store = store
        self.page_tokens = page_tokens
        self.capacity_pages = capacity_pages
        self._pages: Dict[bytes, PrefixPage] = {}
        self._children: Dict[bytes, set] = {}
        self._tick = 0
        self._next_owner = owner_start
        self._owner_step = owner_step
        self.stats = {"lookups": 0, "hit_pages": 0, "parked_pages": 0,
                      "evicted_pages": 0, "reused_tokens": 0}

    def __len__(self) -> int:
        return len(self._pages)

    # ------------------------------------------------------------- hashing

    def chain_hashes(self, tokens: np.ndarray) -> List[bytes]:
        """Chained content hash of every *full* page of ``tokens``."""
        ptok = self.page_tokens
        toks = np.ascontiguousarray(np.asarray(tokens, np.int64))
        out: List[bytes] = []
        h = b"mosaic-prefix-v1"
        for p in range(len(toks) // ptok):
            page = toks[p * ptok:(p + 1) * ptok]
            h = hashlib.blake2b(h + page.tobytes(), digest_size=16).digest()
            out.append(h)
        return out

    # ------------------------------------------------------------- lookup

    def match(self, tokens: np.ndarray) -> Tuple[int, List[PrefixPage]]:
        """Longest cached page-aligned prefix of ``tokens``.

        Returns ``(n_pages, pages)`` and touches the matched chain's LRU
        tick.  ``n_pages`` may cover the whole prompt; callers that need
        a non-empty suffix (the engine always prefills ≥ 1 real token)
        cap it themselves.
        """
        self.stats["lookups"] += 1
        self._tick += 1
        pages: List[PrefixPage] = []
        for h in self.chain_hashes(tokens):
            page = self._pages.get(h)
            if page is None:
                break
            page.tick = self._tick
            page.hits += 1
            pages.append(page)
        self.stats["hit_pages"] += len(pages)
        return len(pages), pages

    def peek_match(self, tokens: np.ndarray) -> Tuple[int, List[PrefixPage]]:
        """Read-only probe of :meth:`match`: the same longest-cached-
        prefix walk, but it touches *nothing* — no LRU tick, no per-page
        hit counters, no lookup stats.  The router's pre-staging probes
        with this before a request is admitted (DESIGN.md §14), so a
        probe that is later cancelled by a steal or a crash can never
        perturb eviction order or the hit-rate numbers the benches pin.
        """
        pages: List[PrefixPage] = []
        for h in self.chain_hashes(tokens):
            page = self._pages.get(h)
            if page is None:
                break
            pages.append(page)
        return len(pages), pages

    def payload(self, page: PrefixPage) -> Tuple[np.ndarray, np.ndarray]:
        return self.store.peek(page.owner, page.shard, page.vpn)

    def missing_from(self, hashes: Sequence[bytes]) -> int:
        """First index of ``hashes`` not cached (prefix-closure: every
        later index is missing too) — the pages a parker must supply."""
        for i, h in enumerate(hashes):
            if h not in self._pages:
                return i
        return len(hashes)

    # ------------------------------------------------------------- insert

    def park(self, chain_hash: bytes, parent: Optional[bytes],
             page_index: int, shard: int, vpn: int,
             k_page: np.ndarray, v_page: np.ndarray) -> PrefixPage:
        """Insert one page (its chain prefix must already be cached)."""
        assert parent is None or parent in self._pages, \
            "prefix chains must be parked root-first"
        if chain_hash in self._pages:           # concurrent duplicate park
            return self._pages[chain_hash]
        # Never evict the chain being extended (tiny-capacity edge: the
        # freshly-parked parent is childless until this insert lands).
        protect = set()
        anc = parent
        while anc is not None:
            protect.add(anc)
            anc = self._pages[anc].parent
        self._evict_to(self.capacity_pages - 1, protect=frozenset(protect))
        self._tick += 1
        page = PrefixPage(chain_hash=chain_hash, page_index=page_index,
                          owner=self._next_owner, shard=shard, vpn=vpn,
                          parent=parent, tick=self._tick)
        self._next_owner += self._owner_step
        self._pages[chain_hash] = page
        if parent is not None:
            self._children.setdefault(parent, set()).add(chain_hash)
        self.store.put(page.owner, shard, vpn, k_page, v_page,
                       kind="prefix")
        self.stats["parked_pages"] += 1
        return page

    # ------------------------------------------------------------- evict

    def _evict_to(self, capacity: int,
                  protect: frozenset = frozenset()) -> None:
        """LRU-evict childless pages until ≤ ``capacity`` remain
        (``protect``: hashes exempt — the chain an insert is extending)."""
        while len(self._pages) > capacity:
            victim = min(
                (p for p in self._pages.values()
                 if not self._children.get(p.chain_hash)
                 and p.chain_hash not in protect),
                key=lambda p: (p.tick, p.page_index), default=None)
            if victim is None:      # only protected chains remain
                break
            self._evict_page(victim)

    def _evict_page(self, page: PrefixPage) -> None:
        # Descendants first (recursion keeps the prefix-closure invariant
        # even if called on an inner page directly).
        for child in list(self._children.get(page.chain_hash, ())):
            if child in self._pages:
                self._evict_page(self._pages[child])
        self._children.pop(page.chain_hash, None)
        if page.parent is not None and page.parent in self._children:
            self._children[page.parent].discard(page.chain_hash)
        del self._pages[page.chain_hash]
        self.store.discard(page.owner, page.shard, page.vpn)
        self.stats["evicted_pages"] += 1

    def evict_owner_pages(self, owners: Iterable[int]) -> int:
        """Evict every cached page whose payload owner id is in ``owners``
        (descendants included — prefix-closure survives).  The hard-capped
        host tier (DESIGN.md §11, ``spill=False``) uses this to drop whole
        prefix frames *through* the index, so index and store can never
        disagree about what is cached.  Returns pages evicted."""
        owners = set(owners)
        before = self.stats["evicted_pages"]
        for page in [p for p in self._pages.values() if p.owner in owners]:
            if page.chain_hash in self._pages:      # not already cascaded
                self._evict_page(page)
        return self.stats["evicted_pages"] - before

    def drop_all(self) -> int:
        n = len(self._pages)
        self._evict_to(0)
        return n
