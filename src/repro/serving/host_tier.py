"""Host memory tier under the Mosaic pool: evicted/cold KV page payloads.

The paper's demand-paging setting (§1) assumes the application's working
set can exceed device memory: pages live in host DRAM and move over the
system I/O bus at *base-page* granularity on first touch.  For the serving
engine that means HBM holds only the KV pages active decode steps actually
read; everything else — preempted requests, cold prefixes — parks here.

Payloads are keyed by **logical** identity ``(seq, shard, vpn)``, not by
physical page: eviction frees the physical page (another tenant reuses it
immediately), and a resumed sequence is re-mapped to whatever frames CoCoA
hands out — the fault-in path looks the payload up by who owns the page,
scatters it to the page's *new* physical location, and drops the host copy
(the device copy is authoritative once resident; decode appends write it).

The device⇄host movement itself is the engine's job
(:func:`repro.kernels.ops.page_gather` / ``page_scatter``); this class is
pure host-side bookkeeping and therefore trivially testable.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

Key = Tuple[int, int, int]          # (seq, shard, local vpn)


class HostPageStore:
    """Host-DRAM store of KV base-page payloads.

    Each entry is one base page of one sub-pool: a pair of numpy arrays
    ``(k_page, v_page)`` shaped ``[L, page_tokens, kv_heads, head_dim]``
    (whatever the model's pool page slice is — the store is shape-agnostic).
    """

    def __init__(self) -> None:
        self._pages: Dict[Key, Tuple[np.ndarray, np.ndarray]] = {}
        self.stats = {
            "swapped_out_pages": 0, "swapped_in_pages": 0,
            "swap_out_requests": 0, "swap_in_requests": 0,
            "peak_pages": 0,
        }

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._pages)

    def has(self, seq: int, shard: int, vpn: int) -> bool:
        return (seq, shard, vpn) in self._pages

    def seq_pages(self, seq: int) -> List[Key]:
        return sorted(k for k in self._pages if k[0] == seq)

    def nbytes(self) -> int:
        return sum(k.nbytes + v.nbytes for k, v in self._pages.values())

    # ------------------------------------------------------------- movement

    def put(self, seq: int, shard: int, vpn: int,
            k_page: np.ndarray, v_page: np.ndarray) -> None:
        """Park one evicted page's payload (device→host already gathered)."""
        self._pages[(seq, shard, vpn)] = (np.asarray(k_page),
                                          np.asarray(v_page))
        self.stats["swapped_out_pages"] += 1
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       len(self._pages))

    def pop(self, seq: int, shard: int, vpn: int
            ) -> Tuple[np.ndarray, np.ndarray]:
        """Retrieve and drop one payload for fault-in (host→device)."""
        kv = self._pages.pop((seq, shard, vpn))
        self.stats["swapped_in_pages"] += 1
        return kv

    def peek(self, seq: int, shard: int, vpn: int
             ) -> Tuple[np.ndarray, np.ndarray]:
        """Read a payload without dropping it (async prefetch staging:
        the host copy stays authoritative until the page is actually
        scattered into the pool, so a wrong prediction loses nothing)."""
        return self._pages[(seq, shard, vpn)]

    def note_swap_out(self) -> None:
        """One whole-request preemption (for the bench's swap counts)."""
        self.stats["swap_out_requests"] += 1

    def note_swap_in(self) -> None:
        """One whole-request resume."""
        self.stats["swap_in_requests"] += 1

    def drop_seq(self, seq: int) -> int:
        """Discard a sequence's parked pages (request cancelled/finished)."""
        keys = [k for k in self._pages if k[0] == seq]
        for k in keys:
            del self._pages[k]
        return len(keys)
