"""Cluster serving tier: N engine replicas over one shared host tier.

Mosaic's core invariant — a large frame holds base pages of **one**
memory protection domain, so contiguity survives without migration — has
so far lived inside a single :class:`~repro.serving.engine.ServingEngine`.
This module lifts it to the cluster level (DESIGN.md §10): several engine
replicas (one per accelerator) share one process-wide host DRAM tier, and
the *host* large frames obey the same single-domain rule via per-engine
**frame leases**:

* :class:`HostFrameTable` — places every parked page payload into a host
  frame of ``frame_pages`` slots.  A frame is leased to exactly one
  protection domain (an engine id, or the shared prefix-cache domain);
  pages of different domains never share a frame, and a frame whose last
  page leaves is returned whole to the free pool (the soft guarantee,
  host-side).  ``migrate()`` re-leases a request's pages to another
  domain — flipping the owner of exclusively-held frames outright, and
  re-placing only the pages of mixed frames — which is the entire data
  cost of moving a request between engines: host-side bookkeeping, zero
  device↔device traffic.
* :class:`LeasedStoreView` — the :class:`~repro.serving.host_tier.
  HostPageStore` facade each engine (and the prefix index) holds: same
  interface, one shared store underneath, every put/pop/discard
  mirrored into the frame table under the view's domain.
* :class:`SharedHostTier` — one ``HostPageStore`` + one
  :class:`~repro.serving.host_tier.PrefixIndex` (or per-engine indexes
  with disjoint owner namespaces, for the A/B bench) + the frame table.
* :class:`ServingCluster` — builds the replicas (shared params, so all
  replicas are bitwise-identical models), wires them to the tier and to
  the deadline-aware :class:`~repro.serving.router.RequestRouter`, and
  aggregates :class:`ClusterStats`.

Cross-engine prefix sharing falls out for free: the index's payloads
live under negative owner ids in the *shared* store, and page locations
``(shard, vpn)`` are deterministic per geometry, so a prefix parked by
replica 0 faults into replica 1's pool through replica 1's own DMA
lanes.  Work-stealing migration (router) hands a preempted request to an
idle replica by re-leasing its host frames — the request resumes there
with **zero re-prefill**, exactly the paper's "no costly base page
migration" story at cluster scale.

Request ids must be unique cluster-wide (the shared store keys payloads
by ``(rid, shard, vpn)``); the frame table asserts double-placement.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

from repro.configs.base import ModelConfig, PoolGeometry
from repro.serving.engine import EngineStats, Request, ServingEngine
from repro.serving.host_tier import HostPageStore, PrefixIndex
from repro.serving.router import RequestRouter, RouterStats

Key = Tuple[int, int, int]          # (seq, shard, local vpn)
Domain = Hashable                   # engine id, or ("prefix", …)

PREFIX_DOMAIN: Domain = "prefix"


class HostFrameTable:
    """Host-DRAM frame leases: the single-domain-per-frame rule, lifted.

    Frames are numbered from 0 and hold ``frame_pages`` page slots each.
    ``place(domain, key)`` finds (or leases) a frame of that domain with
    a free slot; ``release(key)`` frees the slot and returns the frame
    whole to the free pool when it empties — so, as in CoCoA, frames
    recycle at frame granularity and never fragment across domains.
    """

    def __init__(self, frame_pages: int) -> None:
        assert frame_pages >= 1
        self.frame_pages = frame_pages
        self._key_frame: Dict[Key, int] = {}
        self._frame_keys: Dict[int, Set[Key]] = {}
        self._frame_owner: Dict[int, Domain] = {}
        self._open: Dict[Domain, Set[int]] = {}   # leased, ≥1 free slot
        self._free: List[int] = []                # recycled frame ids
        self._next = 0
        self.stats = {
            "frames_leased": 0, "frames_recycled": 0, "peak_frames": 0,
            "placed_pages": 0, "page_moves": 0, "whole_frame_moves": 0,
        }

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._frame_owner)

    def owner_of(self, key: Key) -> Optional[Domain]:
        f = self._key_frame.get(key)
        return None if f is None else self._frame_owner[f]

    def frames_of(self, domain: Domain) -> int:
        return sum(1 for d in self._frame_owner.values() if d == domain)

    # ------------------------------------------------------------- mutate

    def _lease(self, domain: Domain) -> int:
        if self._free:
            f = self._free.pop()            # LIFO: reuse hot frame ids
        else:
            f = self._next
            self._next += 1
        self._frame_owner[f] = domain
        self._frame_keys[f] = set()
        self._open.setdefault(domain, set()).add(f)
        self.stats["frames_leased"] += 1
        self.stats["peak_frames"] = max(self.stats["peak_frames"],
                                        len(self._frame_owner))
        return f

    def place(self, domain: Domain, key: Key) -> int:
        """Assign ``key`` a slot in a frame of ``domain``; returns the
        frame id.  Placing an already-placed key is an error — it would
        mean two engines parked the same ``(rid, shard, vpn)``, i.e. a
        cluster-wide rid collision."""
        assert key not in self._key_frame, \
            f"host page {key} already placed (cluster-wide rid collision?)"
        open_frames = self._open.setdefault(domain, set())
        f = min(open_frames) if open_frames else self._lease(domain)
        self._frame_keys[f].add(key)
        self._key_frame[key] = f
        if len(self._frame_keys[f]) >= self.frame_pages:
            open_frames.discard(f)
        self.stats["placed_pages"] += 1
        return f

    def release(self, key: Key) -> None:
        f = self._key_frame.pop(key, None)
        if f is None:
            return                          # never placed (private store)
        keys = self._frame_keys[f]
        keys.discard(key)
        domain = self._frame_owner[f]
        if not keys:                        # whole-frame return
            del self._frame_keys[f]
            del self._frame_owner[f]
            self._open.get(domain, set()).discard(f)
            self._free.append(f)
            self.stats["frames_recycled"] += 1
        else:
            self._open.setdefault(domain, set()).add(f)

    def migrate(self, keys: Sequence[Key], dst: Domain) -> int:
        """Re-lease ``keys`` (one request's host pages) to ``dst``.

        A frame every one of whose pages is migrating just flips its
        owner — the whole-frame handoff, zero data movement even in
        host DRAM.  Pages sharing a frame with a non-migrating tenant
        are re-placed into ``dst`` frames (a host-side memcpy in the
        model; still no device traffic).  Returns the page count.
        """
        moving = set(keys)
        by_frame: Dict[int, List[Key]] = {}
        for k in keys:
            f = self._key_frame.get(k)
            if f is not None:
                by_frame.setdefault(f, []).append(k)
        for f, ks in sorted(by_frame.items()):
            src = self._frame_owner[f]
            if src == dst:
                continue
            if set(ks) == self._frame_keys[f]:
                self._frame_owner[f] = dst
                if f in self._open.get(src, set()):
                    self._open[src].discard(f)
                    self._open.setdefault(dst, set()).add(f)
                self.stats["whole_frame_moves"] += 1
            else:
                for k in ks:
                    self.release(k)
                    self.place(dst, k)
                    self.stats["page_moves"] += 1
        return len(moving)

    # ------------------------------------------------------------- checks

    def check_invariants(self) -> None:
        for f, keys in self._frame_keys.items():
            assert f in self._frame_owner, f"frame {f} leased to nobody"
            assert 0 < len(keys) <= self.frame_pages, \
                f"frame {f} slot count {len(keys)}"
            for k in keys:
                assert self._key_frame.get(k) == f, (k, f)
        for domain, frames in self._open.items():
            for f in frames:
                assert self._frame_owner.get(f) == domain, \
                    f"open frame {f} not owned by {domain}"
                assert len(self._frame_keys[f]) < self.frame_pages
        # The invariant this whole class exists for: every placed page's
        # frame is leased to exactly one domain (structural here — the
        # dict can't hold two owners — but place() is the only write).
        assert len(self._key_frame) == sum(
            len(ks) for ks in self._frame_keys.values())


class LeasedStoreView:
    """Per-domain facade over the shared :class:`HostPageStore`.

    Same interface as the store (engines and the prefix index are
    oblivious), with every payload movement mirrored into the frame
    table under this view's protection domain.  Queries and stats
    delegate to the shared store — all views see all payloads (the
    point: a prefix parked by one engine is readable by every other),
    but each *write* lands in this domain's frames only.
    """

    def __init__(self, store: HostPageStore, frames: HostFrameTable,
                 domain: Domain) -> None:
        self.store = store
        self.frames = frames
        self.domain = domain

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self.store)

    @property
    def stats(self) -> dict:
        return self.store.stats

    @property
    def _pages(self):
        return self.store._pages

    def has(self, seq: int, shard: int, vpn: int) -> bool:
        return self.store.has(seq, shard, vpn)

    def seq_pages(self, seq: int) -> List[Key]:
        return self.store.seq_pages(seq)

    def nbytes(self) -> int:
        return self.store.nbytes()

    def request_pages(self) -> int:
        return self.store.request_pages()

    def peek(self, seq: int, shard: int, vpn: int):
        return self.store.peek(seq, shard, vpn)

    # ------------------------------------------------------------- movement

    def put(self, seq: int, shard: int, vpn: int, k_page, v_page, *,
            kind: str = "swap") -> None:
        if not self.store.has(seq, shard, vpn):
            self.frames.place(self.domain, (seq, shard, vpn))
        self.store.put(seq, shard, vpn, k_page, v_page, kind=kind)

    def pop(self, seq: int, shard: int, vpn: int):
        kv = self.store.pop(seq, shard, vpn)
        self.frames.release((seq, shard, vpn))
        return kv

    def discard(self, seq: int, shard: int, vpn: int) -> bool:
        if self.store.discard(seq, shard, vpn):
            self.frames.release((seq, shard, vpn))
            return True
        return False

    def drop_seq(self, seq: int) -> int:
        keys = self.store.seq_pages(seq)
        n = self.store.drop_seq(seq)
        for k in keys:
            self.frames.release(k)
        return n

    def note_swap_out(self) -> None:
        self.store.note_swap_out()

    def note_swap_in(self) -> None:
        self.store.note_swap_in()


class SharedHostTier:
    """One host DRAM tier for the whole cluster: shared payload store,
    frame leases, and the prefix index (shared by default; per-engine
    indexes with disjoint owner namespaces when ``share_prefix=False``
    — the A/B the ``cluster`` bench measures)."""

    def __init__(self, geometry: PoolGeometry, *, n_engines: int,
                 share_prefix: bool = True,
                 prefix_capacity_pages: int = 4096) -> None:
        self.geo = geometry
        self.n_engines = n_engines
        self.store = HostPageStore()
        self.frames = HostFrameTable(geometry.frame_pages)
        self.share_prefix = share_prefix
        if share_prefix:
            self.prefix: Optional[PrefixIndex] = PrefixIndex(
                self.view(PREFIX_DOMAIN), geometry.page_tokens,
                capacity_pages=prefix_capacity_pages)
            self._engine_prefix: List[Optional[PrefixIndex]] = []
        else:
            self.prefix = None
            # Disjoint owner progressions: engine i mints
            # -(i+1), -(i+1+n), -(i+1+2n), … so per-engine payload keys
            # in the one shared store can never collide.
            self._engine_prefix = [
                PrefixIndex(self.view((PREFIX_DOMAIN, i)),
                            geometry.page_tokens,
                            capacity_pages=prefix_capacity_pages,
                            owner_start=-(i + 1), owner_step=-n_engines)
                for i in range(n_engines)]

    def view(self, domain: Domain) -> LeasedStoreView:
        return LeasedStoreView(self.store, self.frames, domain)

    def prefix_for(self, engine_id: int) -> Optional[PrefixIndex]:
        if self.share_prefix:
            return self.prefix
        return self._engine_prefix[engine_id]

    def migrate_seq(self, seq: int, dst_engine: int) -> int:
        """Re-lease a request's host pages to another engine's domain —
        the data half of work-stealing migration."""
        return self.frames.migrate(self.store.seq_pages(seq), dst_engine)

    def check_invariants(self) -> None:
        self.frames.check_invariants()
        # Every stored payload is placed, and in a frame of one domain.
        for key in self.store._pages:
            assert self.frames.owner_of(key) is not None, \
                f"host page {key} stored but not leased"


# ---------------------------------------------------------------- cluster


def aggregate_engine_stats(stats: Sequence[EngineStats]) -> EngineStats:
    """Sum scalar counters (and merge the per-tier deadline dicts) of
    several replicas into one cluster-wide :class:`EngineStats` — the
    result supports the same ``summary()`` / ``slo_attainment()`` API."""
    agg = EngineStats()
    for st in stats:
        for f in dataclasses.fields(EngineStats):
            v = getattr(st, f.name)
            if isinstance(v, (int, float)):
                setattr(agg, f.name, getattr(agg, f.name) + v)
        for tier, n in st.deadline_hits.items():
            agg.deadline_hits[tier] = agg.deadline_hits.get(tier, 0) + n
        for tier, n in st.deadline_misses.items():
            agg.deadline_misses[tier] = agg.deadline_misses.get(tier, 0) + n
    return agg


class ClusterStats:
    """Cluster-wide rollup: per-engine EngineStats aggregated, router
    dispatch/migration counters, and host-tier frame-lease stats."""

    def __init__(self, engines: Sequence[ServingEngine],
                 router: RequestRouter,
                 tier: Optional[SharedHostTier]) -> None:
        self.engines = list(engines)
        self.router = router
        self.tier = tier

    @property
    def totals(self) -> EngineStats:
        return aggregate_engine_stats([e.stats for e in self.engines])

    def slo_attainment(self, priority: Optional[int] = None
                       ) -> Optional[float]:
        return self.totals.slo_attainment(priority)

    def prefix_hit_rate(self) -> float:
        t = self.totals
        return t.prefix_hits / max(t.prefix_hits + t.prefix_misses, 1)

    def summary(self) -> str:
        lines = [f"cluster: {len(self.engines)} engines | "
                 f"{self.totals.summary()}"]
        for e in self.engines:
            lines.append(f"  engine[{e.engine_id}]: {e.stats.summary()}")
        r = self.router.stats
        lines.append(
            f"  router: {r.submitted} submitted | dispatched "
            + (", ".join(f"e{i}:{n}" for i, n in sorted(r.dispatched.items()))
               or "-")
            + f" | migrations {r.migrations} ({r.migrated_pages} pages)")
        if self.tier is not None:
            fs = self.tier.frames.stats
            lines.append(
                f"  host tier: {len(self.tier.store)} pages in "
                f"{len(self.tier.frames)} frames (peak {fs['peak_frames']}) "
                f"| moves {fs['whole_frame_moves']} whole-frame / "
                f"{fs['page_moves']} page")
        return "\n".join(lines)


class ServingCluster:
    """N :class:`ServingEngine` replicas + shared host tier + router.

    All replicas share one ``params`` pytree (replica equivalence is what
    makes cross-engine prefix reuse and migration bitwise-safe), their
    own pools/DMA lanes/clocks, and — unless ``share_host=False`` (the
    per-engine baseline the benches compare against) — one
    :class:`SharedHostTier`.
    """

    def __init__(self, cfg: ModelConfig, *, geometry: PoolGeometry,
                 n_engines: int = 2, max_batch: int = 4, max_seq: int = 128,
                 manager_kind: str = "mosaic", seed: int = 0,
                 share_host: bool = True, share_prefix: bool = True,
                 prefix_cache: bool = True,
                 prefix_capacity_pages: int = 4096,
                 router_policy: str = "slack", migrate: bool = True,
                 **engine_kw) -> None:
        assert n_engines >= 1
        self.cfg = cfg
        self.geo = geometry
        self.tier: Optional[SharedHostTier] = None
        if share_host:
            self.tier = SharedHostTier(
                geometry, n_engines=n_engines, share_prefix=share_prefix,
                prefix_capacity_pages=prefix_capacity_pages)
        self.engines: List[ServingEngine] = []
        params = None
        for i in range(n_engines):
            eng = ServingEngine(
                cfg, geometry=geometry, max_batch=max_batch,
                max_seq=max_seq, manager_kind=manager_kind, seed=seed,
                params=params, engine_id=i,
                host=self.tier.view(i) if self.tier else None,
                prefix_index=(self.tier.prefix_for(i)
                              if self.tier and prefix_cache else None),
                prefix_cache=prefix_cache,
                prefix_capacity_pages=prefix_capacity_pages,
                **engine_kw)
            params = eng.params          # replicas share one weight tree
            self.engines.append(eng)
        self.router = RequestRouter(self.engines, tier=self.tier,
                                    policy=router_policy, migrate=migrate)

    # ------------------------------------------------------------- serving

    def submit(self, req: Request, engine: Optional[int] = None) -> None:
        self.router.submit(req, engine=engine)

    def step(self) -> bool:
        return self.router.step()

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        return self.router.run_until_drained(max_steps=max_steps)

    # ------------------------------------------------------------- stats

    def stats(self) -> ClusterStats:
        return ClusterStats(self.engines, self.router, self.tier)

    def check_invariants(self) -> None:
        for e in self.engines:
            e.cache.check_invariants()
        if self.tier is not None:
            self.tier.check_invariants()
