"""Cluster serving tier: N engine replicas over one shared host tier.

Mosaic's core invariant — a large frame holds base pages of **one**
memory protection domain, so contiguity survives without migration — has
so far lived inside a single :class:`~repro.serving.engine.ServingEngine`.
This module lifts it to the cluster level (DESIGN.md §10): several engine
replicas (one per accelerator) share one process-wide host DRAM tier, and
the *host* large frames obey the same single-domain rule via per-engine
**frame leases**:

* :class:`HostFrameTable` — places every parked page payload into a host
  frame of ``frame_pages`` slots.  A frame is leased to exactly one
  protection domain (an engine id, or the shared prefix-cache domain);
  pages of different domains never share a frame, and a frame whose last
  page leaves is returned whole to the free pool (the soft guarantee,
  host-side).  ``migrate()`` re-leases a request's pages to another
  domain — flipping the owner of exclusively-held frames outright, and
  re-placing only the pages of mixed frames — which is the entire data
  cost of moving a request between engines: host-side bookkeeping, zero
  device↔device traffic.
* :class:`LeasedStoreView` — the :class:`~repro.serving.host_tier.
  HostPageStore` facade each engine (and the prefix index) holds: same
  interface, one shared store underneath, every put/pop/discard
  mirrored into the frame table under the view's domain.
* :class:`SharedHostTier` — one ``HostPageStore`` + one
  :class:`~repro.serving.host_tier.PrefixIndex` (or per-engine indexes
  with disjoint owner namespaces, for the A/B bench) + the frame table.
* :class:`ServingCluster` — builds the replicas (shared params, so all
  replicas are bitwise-identical models), wires them to the tier and to
  the deadline-aware :class:`~repro.serving.router.RequestRouter`, and
  aggregates :class:`ClusterStats`.

Cross-engine prefix sharing falls out for free: the index's payloads
live under negative owner ids in the *shared* store, and page locations
``(shard, vpn)`` are deterministic per geometry, so a prefix parked by
replica 0 faults into replica 1's pool through replica 1's own DMA
lanes.  Work-stealing migration (router) hands a preempted request to an
idle replica by re-leasing its host frames — the request resumes there
with **zero re-prefill**, exactly the paper's "no costly base page
migration" story at cluster scale.

Request ids must be unique cluster-wide (the shared store keys payloads
by ``(rid, shard, vpn)``); the frame table asserts double-placement.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.configs.base import ModelConfig, PoolGeometry
from repro.core.demand_paging import LinkModel
from repro.serving.dma import AsyncDMAEngine
from repro.serving.engine import EngineStats, Request, ServingEngine
from repro.serving.faults import (FaultInjector, SpillCorruptionError,
                                  SpillIOError)
from repro.serving.host_tier import HostPageStore, PrefixIndex, SpillStore
from repro.serving.router import RequestRouter, RouterStats

Key = Tuple[int, int, int]          # (seq, shard, local vpn)
Domain = Hashable                   # engine id, or ("prefix", …)

PREFIX_DOMAIN: Domain = "prefix"

# Host-frame state machine (DESIGN.md §11).  A frame's payloads live in
# host DRAM while HOST or PENDING_WRITE_BACK (the write-back buffer holds
# a snapshot *reference*, not a copy — reads stay free until the frame
# actually lands on disk) and on disk while SPILLED.
FRAME_HOST = "host"
FRAME_PENDING_WB = "pending_write_back"
FRAME_SPILLED = "spilled"


class HostFrameTable:
    """Host-DRAM frame leases: the single-domain-per-frame rule, lifted.

    Frames are numbered from 0 and hold ``frame_pages`` page slots each.
    ``place(domain, key)`` finds (or leases) a frame of that domain with
    a free slot; ``release(key)`` frees the slot and returns the frame
    whole to the free pool when it empties — so, as in CoCoA, frames
    recycle at frame granularity and never fragment across domains.

    With a disk tier underneath (DESIGN.md §11) every frame also carries
    a state — ``FRAME_HOST`` → ``FRAME_PENDING_WB`` → ``FRAME_SPILLED``
    → (promote) ``FRAME_HOST`` — and an LRU tick refreshed by placements
    and touches; ``capacity_frames`` is the host-DRAM bound the owning
    :class:`SharedHostTier` enforces by spilling the LRU victim.  Only
    ``FRAME_HOST`` frames accept placements (pending/spilled frames are
    withdrawn from the open sets), and a spilled frame must be promoted
    before any of its pages is released.
    """

    def __init__(self, frame_pages: int,
                 capacity_frames: Optional[int] = None,
                 victim_scoring: str = "lru") -> None:
        assert frame_pages >= 1
        assert capacity_frames is None or capacity_frames >= 1
        if victim_scoring not in ("lru", "cost"):
            raise ValueError(f"victim_scoring must be 'lru' or 'cost', "
                             f"got {victim_scoring!r}")
        self.frame_pages = frame_pages
        self.capacity_frames = capacity_frames
        self.victim_scoring = victim_scoring
        self._key_frame: Dict[Key, int] = {}
        self._frame_keys: Dict[int, Set[Key]] = {}
        self._frame_owner: Dict[int, Domain] = {}
        self._open: Dict[Domain, Set[int]] = {}   # leased, ≥1 free slot
        self._free: List[int] = []                # recycled frame ids
        self._next = 0
        self._state: Dict[int, str] = {}          # leased frame → FRAME_*
        self._frame_tick: Dict[int, int] = {}     # LRU clock per frame
        self._tick = 0
        self._frame_hits: Dict[int, int] = {}     # touches since lease
        self.stats = {
            "frames_leased": 0, "frames_recycled": 0, "peak_frames": 0,
            "placed_pages": 0, "page_moves": 0, "whole_frame_moves": 0,
            "spilled_frames": 0, "promoted_frames": 0, "spill_cancels": 0,
        }

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._frame_owner)

    def owner_of(self, key: Key) -> Optional[Domain]:
        f = self._key_frame.get(key)
        return None if f is None else self._frame_owner[f]

    def frames_of(self, domain: Domain) -> int:
        return sum(1 for d in self._frame_owner.values() if d == domain)

    def frame_of(self, key: Key) -> Optional[int]:
        return self._key_frame.get(key)

    def keys_of(self, frame: int) -> Set[Key]:
        return set(self._frame_keys.get(frame, ()))

    def state_of(self, frame: int) -> Optional[str]:
        return self._state.get(frame)

    def resident_frames(self) -> int:
        """Frames whose payloads occupy host DRAM (HOST + PENDING_WB)."""
        return sum(1 for s in self._state.values() if s != FRAME_SPILLED)

    def spill_victim(self, exclude: Set[int] = frozenset(),
                     owner_ok=None) -> Optional[int]:
        """Pick the ``FRAME_HOST`` frame to spill, outside ``exclude``
        (``owner_ok``: optional domain predicate — the hard-capped tier
        restricts victims to prefix-cache domains).

        ``victim_scoring="lru"`` (baseline): least-recently-touched.
        ``victim_scoring="cost"`` (ROADMAP spill follow-on): minimize
        hit-frequency × promote cost — a rarely-touched frame that is
        also cheap to bring back (few occupied pages ⇒ a short disk
        read on promote) carries the least expected future stall.  The
        LRU tick breaks score ties so the policies agree on cold sets.
        """
        cands = [f for f, s in self._state.items()
                 if s == FRAME_HOST and f not in exclude
                 and (owner_ok is None or owner_ok(self._frame_owner[f]))]
        if not cands:
            return None
        if self.victim_scoring == "cost":
            return min(cands, key=lambda f: (
                self._frame_hits.get(f, 0)
                * (1 + len(self._frame_keys.get(f, ()))),
                self._frame_tick.get(f, 0), f))
        return min(cands, key=lambda f: (self._frame_tick.get(f, 0), f))

    # ------------------------------------------------------------- mutate

    def _touch_frame(self, f: int) -> None:
        self._tick += 1
        self._frame_tick[f] = self._tick
        self._frame_hits[f] = self._frame_hits.get(f, 0) + 1

    def touch(self, key: Key) -> Optional[str]:
        """Refresh the LRU tick of ``key``'s frame; returns its state."""
        f = self._key_frame.get(key)
        if f is None:
            return None
        self._touch_frame(f)
        return self._state[f]

    def _lease(self, domain: Domain) -> int:
        if self._free:
            f = self._free.pop()            # LIFO: reuse hot frame ids
        else:
            f = self._next
            self._next += 1
        self._frame_owner[f] = domain
        self._frame_keys[f] = set()
        self._open.setdefault(domain, set()).add(f)
        self._state[f] = FRAME_HOST
        self._frame_hits[f] = 0       # recycled ids must not inherit heat
        self._touch_frame(f)
        self.stats["frames_leased"] += 1
        self.stats["peak_frames"] = max(self.stats["peak_frames"],
                                        len(self._frame_owner))
        return f

    def place(self, domain: Domain, key: Key) -> int:
        """Assign ``key`` a slot in a frame of ``domain``; returns the
        frame id.  Placing an already-placed key is an error — it would
        mean two engines parked the same ``(rid, shard, vpn)``, i.e. a
        cluster-wide rid collision."""
        assert key not in self._key_frame, \
            f"host page {key} already placed (cluster-wide rid collision?)"
        open_frames = self._open.setdefault(domain, set())
        f = min(open_frames) if open_frames else self._lease(domain)
        self._frame_keys[f].add(key)
        self._key_frame[key] = f
        if len(self._frame_keys[f]) >= self.frame_pages:
            open_frames.discard(f)
        self._touch_frame(f)
        self.stats["placed_pages"] += 1
        return f

    def release(self, key: Key) -> None:
        f = self._key_frame.pop(key, None)
        if f is None:
            return                          # never placed (private store)
        assert self._state[f] != FRAME_SPILLED, \
            f"release of spilled page {key} — promote the frame first"
        keys = self._frame_keys[f]
        keys.discard(key)
        domain = self._frame_owner[f]
        if not keys:                        # whole-frame return
            st = self._state.pop(f)
            assert st == FRAME_HOST, \
                f"frame {f} recycled while {st} (cancel the write-back)"
            del self._frame_keys[f]
            del self._frame_owner[f]
            self._frame_tick.pop(f, None)
            self._frame_hits.pop(f, None)
            self._open.get(domain, set()).discard(f)
            self._free.append(f)
            self.stats["frames_recycled"] += 1
        elif self._state[f] == FRAME_HOST:
            self._open.setdefault(domain, set()).add(f)

    # ------------------------------------------------------- spill states

    def mark_pending_writeback(self, f: int) -> None:
        """HOST → PENDING_WB: the frame joins the write-back buffer and
        stops accepting placements (it is about to leave DRAM)."""
        assert self._state[f] == FRAME_HOST, (f, self._state[f])
        self._state[f] = FRAME_PENDING_WB
        self._open.get(self._frame_owner[f], set()).discard(f)

    def cancel_writeback(self, f: int) -> None:
        """PENDING_WB → HOST: a touch (or emptying) beat the disk."""
        assert self._state[f] == FRAME_PENDING_WB, (f, self._state[f])
        self._state[f] = FRAME_HOST
        self._touch_frame(f)
        if len(self._frame_keys[f]) < self.frame_pages:
            self._open.setdefault(self._frame_owner[f], set()).add(f)
        self.stats["spill_cancels"] += 1

    def mark_spilled(self, f: int) -> None:
        """PENDING_WB → SPILLED: the whole frame landed on disk."""
        assert self._state[f] == FRAME_PENDING_WB, (f, self._state[f])
        self._state[f] = FRAME_SPILLED
        self.stats["spilled_frames"] += 1

    def promote(self, f: int) -> None:
        """SPILLED → HOST: the frame's payloads are back in DRAM."""
        assert self._state[f] == FRAME_SPILLED, (f, self._state[f])
        self._state[f] = FRAME_HOST
        self._touch_frame(f)
        if len(self._frame_keys[f]) < self.frame_pages:
            self._open.setdefault(self._frame_owner[f], set()).add(f)
        self.stats["promoted_frames"] += 1

    # ------------------------------------------------------------ migrate

    def migrate(self, keys: Sequence[Key], dst: Domain) -> int:
        """Re-lease ``keys`` (one request's host pages) to ``dst``.

        A frame every one of whose pages is migrating just flips its
        owner — the whole-frame handoff, zero data movement even in
        host DRAM.  Pages sharing a frame with a non-migrating tenant
        are re-placed into ``dst`` frames (a host-side memcpy in the
        model; still no device traffic).  Returns the number of pages
        actually re-leased: keys that were never placed (stale bundle
        entries) and keys already in ``dst`` frames don't count, so
        migration stats never overcount.  Spilled frames must be
        promoted before their pages migrate (the caller's job — the
        on-disk file records a single domain).
        """
        moved = 0
        by_frame: Dict[int, List[Key]] = {}
        for k in keys:
            f = self._key_frame.get(k)
            if f is not None:
                by_frame.setdefault(f, []).append(k)
        for f, ks in sorted(by_frame.items()):
            src = self._frame_owner[f]
            assert self._state[f] != FRAME_SPILLED, \
                f"migrating pages of spilled frame {f} — promote first"
            if src == dst:
                continue
            if set(ks) == self._frame_keys[f]:
                self._frame_owner[f] = dst
                if f in self._open.get(src, set()):
                    self._open[src].discard(f)
                    self._open.setdefault(dst, set()).add(f)
                self.stats["whole_frame_moves"] += 1
            else:
                for k in ks:
                    self.release(k)
                    self.place(dst, k)
                    self.stats["page_moves"] += 1
            moved += len(ks)
        return moved

    # ------------------------------------------------------------- checks

    def check_invariants(self) -> None:
        for f, keys in self._frame_keys.items():
            assert f in self._frame_owner, f"frame {f} leased to nobody"
            assert 0 < len(keys) <= self.frame_pages, \
                f"frame {f} slot count {len(keys)}"
            assert self._state.get(f) in (FRAME_HOST, FRAME_PENDING_WB,
                                          FRAME_SPILLED), \
                f"frame {f} in unknown state {self._state.get(f)}"
            for k in keys:
                assert self._key_frame.get(k) == f, (k, f)
        assert set(self._state) == set(self._frame_owner)
        for domain, frames in self._open.items():
            for f in frames:
                assert self._frame_owner.get(f) == domain, \
                    f"open frame {f} not owned by {domain}"
                assert len(self._frame_keys[f]) < self.frame_pages
                # Only DRAM-resident, not-yet-queued frames accept
                # placements (§11 state machine).
                assert self._state[f] == FRAME_HOST, (f, self._state[f])
        # The invariant this whole class exists for: every placed page's
        # frame is leased to exactly one domain (structural here — the
        # dict can't hold two owners — but place() is the only write).
        assert len(self._key_frame) == sum(
            len(ks) for ks in self._frame_keys.values())


class LeasedStoreView:
    """Per-domain facade over the shared :class:`HostPageStore`.

    Same interface as the store (engines and the prefix index are
    oblivious), with every payload movement mirrored into the frame
    table under this view's protection domain.  Queries and stats
    delegate to the shared store — all views see all payloads (the
    point: a prefix parked by one engine is readable by every other),
    but each *write* lands in this domain's frames only.

    When the owning :class:`SharedHostTier` has a disk tier (``tier`` is
    set, DESIGN.md §11) every access is routed through the tier's hooks:
    reads promote spilled frames back to DRAM (promote-on-touch) and
    refresh the LRU tick, removals cancel a pending write-back whose
    frame they would empty, and writes trigger capacity enforcement.
    A ``tier=None`` view behaves exactly as before — zero overhead for
    clusters without a capacity bound.
    """

    def __init__(self, store: HostPageStore, frames: HostFrameTable,
                 domain: Domain, tier: "Optional[SharedHostTier]" = None
                 ) -> None:
        self.store = store
        self.frames = frames
        self.domain = domain
        self.tier = tier

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self.store)

    @property
    def stats(self) -> dict:
        return self.store.stats

    @property
    def _pages(self):
        return self.store._pages

    def has(self, seq: int, shard: int, vpn: int) -> bool:
        if self.store.has(seq, shard, vpn):
            return True
        return self.tier is not None \
            and self.tier.is_spilled((seq, shard, vpn))

    def seq_pages(self, seq: int) -> List[Key]:
        if self.tier is not None:
            return self.tier.seq_pages(seq)
        return self.store.seq_pages(seq)

    def nbytes(self) -> int:
        return self.store.nbytes()

    def request_pages(self) -> int:
        return self.store.request_pages()

    def peek(self, seq: int, shard: int, vpn: int):
        if self.tier is not None:
            self.tier.before_read((seq, shard, vpn))
        return self.store.peek(seq, shard, vpn)

    # ------------------------------------------------------------- movement

    def put(self, seq: int, shard: int, vpn: int, k_page, v_page, *,
            kind: str = "swap") -> None:
        key = (seq, shard, vpn)
        if self.tier is not None:
            self.tier.before_write(key)
        if not self.store.has(seq, shard, vpn):
            self.frames.place(self.domain, key)
        self.store.put(seq, shard, vpn, k_page, v_page, kind=kind)
        if self.tier is not None:
            self.tier.after_put(key)

    def pop(self, seq: int, shard: int, vpn: int):
        key = (seq, shard, vpn)
        if self.tier is not None:
            self.tier.before_remove(key)
        kv = self.store.pop(seq, shard, vpn)
        self.frames.release(key)
        return kv

    def discard(self, seq: int, shard: int, vpn: int) -> bool:
        key = (seq, shard, vpn)
        if self.tier is not None:
            self.tier.before_remove(key)
        if self.store.discard(seq, shard, vpn):
            self.frames.release(key)
            return True
        return False

    def drop_seq(self, seq: int) -> int:
        if self.tier is not None:
            # Promote the sequence's spilled frames first: a dropped key
            # must leave the frame table, and spilled frames may hold
            # surviving co-tenants (promote, then release normally).
            self.tier.ensure_resident(self.tier.spilled_keys_of(seq))
        keys = self.store.seq_pages(seq)
        n = self.store.drop_seq(seq)
        for k in keys:
            if self.tier is not None:
                self.tier.before_remove(k)
            self.frames.release(k)
        return n

    # -------------------------------------------------------- tier hooks
    # Mirrors HostPageStore's no-op surface so engines can call these on
    # whichever host they hold (DESIGN.md §11).

    def park_allowed(self) -> bool:
        return True if self.tier is None else self.tier.park_allowed()

    def ensure_resident(self, keys, now_us: Optional[float] = None
                        ) -> float:
        if self.tier is None:
            return 0.0
        return self.tier.ensure_resident(keys, now_us)

    def take_lost(self, seq: int) -> bool:
        return False if self.tier is None else self.tier.take_lost(seq)

    def pump(self, now_us: float) -> None:
        if self.tier is not None:
            self.tier.pump(now_us)

    def note_swap_out(self) -> None:
        self.store.note_swap_out()

    def note_swap_in(self) -> None:
        self.store.note_swap_in()


class SharedHostTier:
    """One host DRAM tier for the whole cluster: shared payload store,
    frame leases, and the prefix index (shared by default; per-engine
    indexes with disjoint owner namespaces when ``share_prefix=False``
    — the A/B the ``cluster`` bench measures).

    With ``capacity_frames`` set, host DRAM is *bounded* and a third,
    disk-backed tier opens underneath (DESIGN.md §11):

    * **Spill** (``spill=True``): when DRAM-resident frames exceed the
      bound, the LRU ``FRAME_HOST`` victim enters the write-back buffer
      — its pages ride the outbound DMA lanes as one contiguous
      ``kind="spill"`` job (whole frame ⇒ one descriptor), then stream
      to disk at the modeled seek + per-page write cost.  :meth:`pump`
      (called by every engine step with the modeled clock) persists
      frames whose write-back completed: payloads leave the store, the
      whole frame lands as one :class:`SpillStore` file, and the frame
      turns ``FRAME_SPILLED``.  Any touch before persistence cancels
      the write-back (the data never left DRAM); a touch after it
      promotes the whole frame back synchronously, charging the
      modeled disk-read stall to the toucher (promote-on-touch).
      The write-back buffer is bounded (``wb_queue_frames``): while it
      is full, :meth:`park_allowed` goes False and engines *refuse*
      new prefix parks instead of queueing unbounded dirty data —
      the back-pressure rule.
    * **Hard cap** (``spill=False``, the bench baseline): over-capacity
      prefix-cache frames are simply evicted *through* their index
      (:meth:`PrefixIndex.evict_owner_pages` keeps index↔store
      consistent).  Request-owned frames are never dropped — their
      payloads are not reconstructible — so only cache hit rate pays.

    **Failure handling** (DESIGN.md §12, with a :class:`~repro.serving.
    faults.FaultInjector` wired in): transient disk errors on the spill
    path are retried up to ``disk_retries`` times with exponential
    backoff charged to the tier clock; a frame whose read fails
    permanently — or whose payload fails checksum verification — is
    *quarantined*: its file is destroyed, prefix payloads are evicted
    through their index (future matches re-derive via suffix
    re-prefill) and request sequences are marked **lost** so the owning
    engine restarts them from the prompt (:meth:`take_lost`).  When the
    observed disk error rate crosses ``disk_error_rate_threshold`` the
    tier *degrades*: all queued write-backs are cancelled (their data
    never left DRAM) and the tier drops to the hard-cap path — already-
    spilled frames stay promotable, no request is dropped.
    :meth:`reclaim_domain` recycles a dead engine's frames whole.
    """

    def __init__(self, geometry: PoolGeometry, *, n_engines: int,
                 share_prefix: bool = True,
                 prefix_capacity_pages: int = 4096,
                 capacity_frames: Optional[int] = None,
                 spill: bool = True,
                 spill_dir: Optional[str] = None,
                 wb_queue_frames: int = 4,
                 wb_lanes: int = 1,
                 disk_read_us_per_page: float = 25.0,
                 disk_write_us_per_page: float = 25.0,
                 disk_seek_us: float = 100.0,
                 link: Optional[LinkModel] = None,
                 injector: Optional[FaultInjector] = None,
                 disk_retries: int = 3,
                 retry_backoff_us: float = 50.0,
                 disk_error_rate_threshold: float = 0.5,
                 victim_scoring: str = "lru",
                 undegrade_probe_interval_us: Optional[float] = 10_000.0,
                 undegrade_probe_successes: int = 3) -> None:
        assert wb_queue_frames >= 1
        self.geo = geometry
        self.n_engines = n_engines
        self.store = HostPageStore()
        self.frames = HostFrameTable(geometry.frame_pages,
                                     capacity_frames=capacity_frames,
                                     victim_scoring=victim_scoring)
        self.capacity_frames = capacity_frames
        self.spill_enabled = spill and capacity_frames is not None
        self.wb_queue_frames = wb_queue_frames
        self.disk_read_us_per_page = disk_read_us_per_page
        self.disk_write_us_per_page = disk_write_us_per_page
        self.disk_seek_us = disk_seek_us
        self.injector = injector
        self.disk_retries = disk_retries
        self.retry_backoff_us = retry_backoff_us
        self.disk_error_rate_threshold = disk_error_rate_threshold
        self.degraded = False
        # Un-degrade re-probing (ROADMAP fault-tolerance follow-on): a
        # degraded tier probes the disk every interval with a tiny
        # write/read/delete round-trip; after ``undegrade_probe_successes``
        # consecutive clean probes it re-enables the spill path.  None
        # disables probing (degrade stays terminal, the pre-PR-7
        # behavior).  Probes never feed the _note_disk error-rate window.
        self.undegrade_probe_interval_us = undegrade_probe_interval_us
        self.undegrade_probe_successes = undegrade_probe_successes
        self._last_probe_us = 0.0
        self._probe_streak = 0
        self.lost_seqs: Set[int] = set()
        self._disk_ops = 0
        self._disk_errors = 0
        # Reentrancy guard for quarantine: evicting a quarantined owner
        # through its index can touch keys in *other* spilled frames;
        # those are dropped wholesale afterwards instead of recursing.
        self._quarantine_depth = 0
        self._quarantine_queue: List[int] = []
        self.spill_store = SpillStore(spill_dir, injector=injector) \
            if self.spill_enabled else None
        # The write-back buffer rides its own outbound DMA lane(s) on the
        # host link — same AsyncDMAEngine timeline model the engines use,
        # so spill traffic is µs-accounted like every other transfer.
        self.wb_dma = AsyncDMAEngine(link or LinkModel(),
                                     n_channels=max(1, wb_lanes),
                                     injector=injector) \
            if self.spill_enabled else None
        self._pending_wb: Dict[int, float] = {}   # frame → disk-ready µs
        self._spilled: Dict[Key, int] = {}        # key → on-disk frame
        self._now_us = 0.0
        self.stats = {
            "spilled_frames": 0, "spilled_pages": 0,
            "promoted_frames": 0, "promoted_pages": 0,
            "promote_us": 0.0, "spill_write_us": 0.0,
            "spill_cancels": 0, "wb_peak_depth": 0,
            "hard_evicted_pages": 0,
            "disk_errors": 0, "disk_retries": 0, "retry_backoff_us": 0.0,
            "frames_quarantined": 0, "quarantined_pages": 0,
            "quarantine_collateral_frames": 0,
            "lost_seq_count": 0, "reclaimed_frames": 0, "degraded": 0,
            "degrades": 0, "undegrades": 0,
            "probes": 0, "probe_failures": 0,
        }
        self.share_prefix = share_prefix
        if share_prefix:
            self.prefix: Optional[PrefixIndex] = PrefixIndex(
                self.view(PREFIX_DOMAIN), geometry.page_tokens,
                capacity_pages=prefix_capacity_pages)
            self._engine_prefix: List[Optional[PrefixIndex]] = []
        else:
            self.prefix = None
            # Disjoint owner progressions: engine i mints
            # -(i+1), -(i+1+n), -(i+1+2n), … so per-engine payload keys
            # in the one shared store can never collide.
            self._engine_prefix = [
                PrefixIndex(self.view((PREFIX_DOMAIN, i)),
                            geometry.page_tokens,
                            capacity_pages=prefix_capacity_pages,
                            owner_start=-(i + 1), owner_step=-n_engines)
                for i in range(n_engines)]

    def view(self, domain: Domain) -> LeasedStoreView:
        return LeasedStoreView(self.store, self.frames, domain,
                               tier=self if self.capacity_frames is not None
                               else None)

    def prefix_for(self, engine_id: int) -> Optional[PrefixIndex]:
        if self.share_prefix:
            return self.prefix
        return self._engine_prefix[engine_id]

    # -------------------------------------------------------- tier queries

    def is_spilled(self, key: Key) -> bool:
        return key in self._spilled

    def spilled_keys_of(self, seq: int) -> List[Key]:
        return sorted(k for k in self._spilled if k[0] == seq)

    def seq_pages(self, seq: int) -> List[Key]:
        """A sequence's host pages across *both* lower tiers (DRAM +
        disk) — has/seq_pages must see spilled pages or engines would
        treat them as lost."""
        keys = set(self.store.seq_pages(seq))
        keys.update(k for k in self._spilled if k[0] == seq)
        return sorted(keys)

    def park_allowed(self) -> bool:
        """The §11 back-pressure rule: parks are refused while the
        write-back buffer is saturated (never refused when spill is off
        — the hard cap sheds load by evicting instead)."""
        if not self.spill_enabled:
            return True
        return len(self._pending_wb) < self.wb_queue_frames

    def take_lost(self, seq: int) -> bool:
        """True exactly once per sequence whose request-owned host pages
        were destroyed by a frame quarantine (§12) — the owning engine
        checks this and restarts the request from its prompt (the
        deterministic decoder makes the replay byte-identical)."""
        if seq in self.lost_seqs:
            self.lost_seqs.discard(seq)
            return True
        return False

    # --------------------------------------------------------- view hooks

    def before_read(self, key: Key) -> None:
        f = self._spilled.get(key)
        if f is not None:
            self._promote_frame(f)
        self.frames.touch(key)

    def before_write(self, key: Key) -> None:
        f = self._spilled.get(key)
        if f is not None:            # overwrite of a spilled page
            self._promote_frame(f)

    def before_remove(self, key: Key) -> None:
        f = self._spilled.get(key)
        if f is not None:
            if self._quarantine_depth:
                # A quarantine eviction is destroying this key anyway:
                # don't promote (this frame may be corrupt too, and its
                # chain-mates are mid-eviction) — defer a wholesale drop
                # of the frame.  The key stays leased until then, so the
                # caller's store.discard is a no-op.
                self._spilled.pop(key, None)
                if f not in self._quarantine_queue:
                    self._quarantine_queue.append(f)
                return
            self._promote_frame(f)
        f = self.frames.frame_of(key)
        if f is not None and f in self._pending_wb \
                and len(self.frames.keys_of(f)) == 1:
            # The removal would empty (and recycle) a queued frame: the
            # write-back is moot — cancel before the id is reused.
            self._cancel_writeback(f)

    def after_put(self, key: Key) -> None:
        self.frames.touch(key)
        f = self.frames.frame_of(key)
        self._enforce_capacity(
            protect=frozenset(() if f is None else (f,)))

    # ------------------------------------------------------ write-back pump

    def pump(self, now_us: float) -> None:
        """Advance the tier clock; persist write-backs whose DMA + disk
        write completed by ``now_us``, then refill the freed queue slots
        if DRAM is still over capacity.  Engines call this every step."""
        if self.capacity_frames is None:
            return
        self._now_us = max(self._now_us, float(now_us))
        if (self.degraded and self.spill_store is not None
                and self.undegrade_probe_interval_us is not None
                and self._now_us - self._last_probe_us
                >= self.undegrade_probe_interval_us):
            self._last_probe_us = self._now_us
            self.stats["probes"] += 1
            if self._probe_disk():
                self._probe_streak += 1
                if self._probe_streak >= self.undegrade_probe_successes:
                    self._undegrade()
            else:
                self._probe_streak = 0
                self.stats["probe_failures"] += 1
        if not self.spill_enabled:
            return
        self.wb_dma.drain(self._now_us)
        for f in sorted(f for f, t in self._pending_wb.items()
                        if t <= self._now_us):
            if f in self._pending_wb:    # a degrade cancels mid-loop
                self._persist(f)
        self._enforce_capacity()

    def flush(self) -> None:
        """Advance past every queued write-back and persist (tests and
        benches settle the spill pipeline deterministically).  Persisting
        may re-enforce the capacity bound and queue the *next* LRU victim
        behind the now-free buffer slot, so drain until quiescent."""
        if not self.spill_enabled:
            return
        while self._pending_wb:
            self.pump(max(max(self._pending_wb.values()),
                          self.wb_dma.busy_until()))

    def _persist(self, f: int) -> None:
        assert self.frames.state_of(f) == FRAME_PENDING_WB, f
        keys = sorted(self.frames.keys_of(f))
        pages = [(k, self.store.peek(*k)) for k in keys]
        owner = self.frames._frame_owner[f]
        ok, _ = self._with_retries(
            lambda: self.spill_store.write_frame(f, owner, pages))
        if not ok:
            # Retries exhausted (or the tier degraded mid-retry): the
            # data never left DRAM — cancel the write-back and keep
            # serving from the store.  Nothing is lost.
            if f in self._pending_wb:
                self._cancel_writeback(f)
            return
        del self._pending_wb[f]
        for k in keys:
            self.store.discard(*k)
            self._spilled[k] = f
        self.frames.mark_spilled(f)
        self.stats["spilled_frames"] += 1
        self.stats["spilled_pages"] += len(keys)

    def _cancel_writeback(self, f: int) -> None:
        self._pending_wb.pop(f, None)
        self.frames.cancel_writeback(f)
        self.stats["spill_cancels"] += 1

    # ------------------------------------------------------ failure model

    def _with_retries(self, fn):
        """Run a spill-store disk op with bounded retry + exponential
        backoff charged to the tier clock (§12).  Returns ``(ok,
        result)``; transient :class:`SpillIOError`\\ s are retried up to
        ``disk_retries`` times, permanent errors (and exhaustion) yield
        ``ok=False``.  :class:`SpillCorruptionError` is *not* retried —
        re-reading corrupt bytes cannot help — and propagates to the
        caller's quarantine path."""
        delay = self.retry_backoff_us
        for attempt in range(self.disk_retries + 1):
            try:
                out = fn()
                self._note_disk(error=False)
                return True, out
            except SpillIOError as e:
                self._note_disk(error=True)
                if not e.transient or attempt >= self.disk_retries \
                        or self.degraded:
                    return False, None
                self._now_us += delay
                self.stats["disk_retries"] += 1
                self.stats["retry_backoff_us"] += delay
                delay *= 2.0
        return False, None

    def _note_disk(self, *, error: bool) -> None:
        self._disk_ops += 1
        if error:
            self._disk_errors += 1
            self.stats["disk_errors"] += 1
        if (self.spill_enabled and not self.degraded
                and self._disk_ops >= 4
                and self._disk_errors / self._disk_ops
                >= self.disk_error_rate_threshold):
            self._degrade()

    def _degrade(self) -> None:
        """The graceful-degradation rule (§12): the disk is unhealthy,
        so stop trusting it for *new* data — cancel every queued
        write-back (payloads never left DRAM) and drop to the hard-cap
        path.  Frames already spilled stay promotable on touch, parks
        are no longer refused (the hard cap sheds prefix frames through
        the index instead), and no request is dropped."""
        if self.degraded:
            return
        self.degraded = True
        self.spill_enabled = False
        self.stats["degraded"] = 1
        self.stats["degrades"] += 1
        self._probe_streak = 0
        self._last_probe_us = self._now_us
        for f in list(self._pending_wb):
            self._cancel_writeback(f)

    # Probes use a reserved frame id no real lease can hold (HostFrameTable
    # ids count up from 0), so injector budgets and the spill directory
    # never collide with live frames.
    _PROBE_FRAME = -1
    _PROBE_DOMAIN = "__probe__"

    def _probe_disk(self) -> bool:
        """One health probe against the degraded disk: write a tiny
        frame, read it back checksum-verified, delete it.  Failures are
        counted per probe, never fed into the ``_note_disk`` error-rate
        window (a probe must not re-trigger the degrade it is trying to
        lift)."""
        z = np.zeros((1,), np.float32)
        try:
            self.spill_store.write_frame(
                self._PROBE_FRAME, self._PROBE_DOMAIN,
                [((-1, -1, -1), (z, z))])
        except (SpillIOError, SpillCorruptionError):
            return False
        try:
            self.spill_store.read_frame(self._PROBE_FRAME,
                                        expect_domain=self._PROBE_DOMAIN)
            return True
        except (SpillIOError, SpillCorruptionError):
            return False
        finally:
            self.spill_store.delete_frame(self._PROBE_FRAME)

    def _undegrade(self) -> None:
        """Exit hard-cap mode (ROADMAP fault-tolerance follow-on): the
        disk answered ``undegrade_probe_successes`` consecutive probes,
        so new write-backs may trust it again.  The error-rate window
        restarts from zero — a still-flaky disk will re-degrade on its
        own evidence, not on stale counts."""
        if not self.degraded:
            return
        self.degraded = False
        self.spill_enabled = self.spill_store is not None
        self._disk_ops = 0
        self._disk_errors = 0
        self._probe_streak = 0
        self.stats["degraded"] = 0
        self.stats["undegrades"] += 1

    # --------------------------------------------------------- spill policy

    def _enforce_capacity(self, protect: frozenset = frozenset()) -> None:
        if self.capacity_frames is None:
            return
        if not self.spill_enabled:
            self._hard_evict(protect)
            return
        busy = set(protect) | set(self._pending_wb)
        # Queued frames are DRAM-resident but already leaving; count the
        # still-staying frames against the bound, and stop at the
        # write-back buffer's edge — that saturation is exactly what
        # park_allowed() reports upward as back-pressure.
        while (self.frames.resident_frames() - len(self._pending_wb)
               > self.capacity_frames
               and len(self._pending_wb) < self.wb_queue_frames):
            f = self.frames.spill_victim(exclude=busy)
            if f is None:
                break
            self._enqueue_spill(f)
            busy.add(f)

    def _enqueue_spill(self, f: int) -> None:
        """HOST → PENDING_WB: one whole-frame gather on the outbound
        lane (contiguous staging slots ⇒ a single DMA descriptor), then
        the modeled disk write; :meth:`pump` persists at the ready µs."""
        keys = sorted(self.frames.keys_of(f))
        payloads = [self.store.peek(*k) for k in keys]
        page_bytes = int(payloads[0][0].nbytes + payloads[0][1].nbytes)
        job = self.wb_dma.enqueue(keys, list(range(len(keys))), page_bytes,
                                  payloads, self._now_us, kind="spill",
                                  direction="out")
        self.frames.mark_pending_writeback(f)
        self._pending_wb[f] = job.done_us + self.disk_seek_us \
            + len(keys) * self.disk_write_us_per_page
        self.stats["spill_write_us"] += job.transfer_us \
            + self.disk_seek_us + len(keys) * self.disk_write_us_per_page
        self.stats["wb_peak_depth"] = max(self.stats["wb_peak_depth"],
                                          len(self._pending_wb))

    def ensure_resident(self, keys, now_us: Optional[float] = None
                        ) -> float:
        """Promote every spilled frame holding one of ``keys``; returns
        the modeled stall µs (seek + per-page read, per frame) — the
        engine charges it to its clock and to the admission latency."""
        if now_us is not None:
            self._now_us = max(self._now_us, float(now_us))
        stall = 0.0
        for key in keys:
            f = self._spilled.get(tuple(key))
            if f is not None:
                stall += self._promote_frame(f)
        return stall

    def _promote_frame(self, f: int) -> float:
        """SPILLED → HOST: whole-frame disk read back into the store.

        An unreadable frame (permanent error, retries exhausted) or a
        checksum mismatch quarantines instead of promoting — corrupted
        payloads are never put back in the store, so they can never be
        decoded from."""
        try:
            ok, pages = self._with_retries(
                lambda: self.spill_store.read_frame(
                    f, expect_domain=self.frames._frame_owner[f]))
        except SpillCorruptionError:
            ok, pages = False, None
        if not ok:
            return self._quarantine_frame(f)
        cost = self.disk_seek_us + len(pages) * self.disk_read_us_per_page
        for key, (kp, vp) in pages:
            self._spilled.pop(key, None)
            self.store.put(key[0], key[1], key[2], kp, vp, kind="promote")
        self.frames.promote(f)
        self.spill_store.delete_frame(f)
        self.stats["promoted_frames"] += 1
        self.stats["promoted_pages"] += len(pages)
        self.stats["promote_us"] += cost
        self._now_us += cost
        # The promote may itself overflow DRAM: spill someone colder
        # (never the frame just promoted — it is the hottest by touch).
        self._enforce_capacity(protect=frozenset((f,)))
        return cost

    def _quarantine_frame(self, f: int) -> float:
        """A spill frame is unreadable or corrupt (§12): destroy it and
        rebuild its contents from upstream truth.  The frame's keys
        leave both lower tiers; prefix payloads are evicted through
        their index so future matches re-derive (suffix re-prefill on
        the next admission), and request sequences are marked *lost* so
        the owning engine restarts them from the prompt.

        Evicting an owner can cascade through its chain descendants
        into *other* spilled frames; :meth:`before_remove` defers those
        to ``_quarantine_queue`` (promoting mid-eviction would recurse
        into this method and double-evict chain pages), and they are
        dropped wholesale here once the triggering eviction unwinds.
        Returns the modeled stall (the seek that discovered the failure
        — backoff for any retries was already charged)."""
        self._quarantine_depth += 1
        try:
            self._drop_quarantined(f, corrupt=True)
            while self._quarantine_queue:
                self._drop_quarantined(self._quarantine_queue.pop(),
                                       corrupt=False)
        finally:
            self._quarantine_depth -= 1
        return self.disk_seek_us

    def _drop_quarantined(self, f: int, *, corrupt: bool) -> None:
        """Destroy one spilled frame and re-sync every owner it held:
        ``corrupt=False`` marks a collateral drop — a healthy frame
        whose pages were chained to a quarantined owner."""
        keys = sorted(self.frames.keys_of(f))
        if corrupt:
            self.spill_store.quarantine_frame(f)
            self.stats["frames_quarantined"] += 1
        else:
            self.spill_store.delete_frame(f)
            self.stats["quarantine_collateral_frames"] += 1
        self.frames.promote(f)          # table-only: SPILLED → HOST
        for k in keys:
            self._spilled.pop(k, None)
            self.frames.release(k)
        for owner in sorted({k[0] for k in keys}):
            idx = self._index_for_owner(owner)
            if idx is not None:
                # Losing any page breaks the chain: evict the whole
                # owner through the index so index↔store stay in sync
                # and descendants never match a hole (no-op for owners
                # the triggering eviction already removed).
                idx.evict_owner_pages({owner})
            elif owner >= 0:
                self.lost_seqs.add(owner)
                self.stats["lost_seq_count"] += 1
        self.stats["quarantined_pages"] += len(keys)

    def _hard_evict(self, protect: frozenset = frozenset()) -> None:
        """The no-spill baseline: shed over-capacity *prefix* frames by
        evicting their owners through the index (request frames hold
        unreconstructible payloads and are never dropped)."""
        while self.frames.resident_frames() > self.capacity_frames:
            f = self.frames.spill_victim(exclude=protect,
                                         owner_ok=self._is_prefix_domain)
            if f is None:
                break
            evicted = 0
            for owner in sorted({k[0] for k in self.frames.keys_of(f)}):
                idx = self._index_for_owner(owner)
                if idx is not None:
                    evicted += idx.evict_owner_pages({owner})
            if evicted == 0:
                break               # nothing reachable through an index
            self.stats["hard_evicted_pages"] += evicted

    @staticmethod
    def _is_prefix_domain(domain: Domain) -> bool:
        return domain == PREFIX_DOMAIN or (
            isinstance(domain, tuple) and bool(domain)
            and domain[0] == PREFIX_DOMAIN)

    def _index_for_owner(self, owner: int) -> Optional[PrefixIndex]:
        """The index that minted a negative payload owner id (per-engine
        indexes use the progression owner = -(i+1) - k·n, DESIGN.md §10)."""
        if owner >= 0:
            return None
        if self.share_prefix:
            return self.prefix
        return self._engine_prefix[(-owner - 1) % self.n_engines]

    # ------------------------------------------------------------ migrate

    def migrate_seq(self, seq: int, dst_engine: int) -> int:
        """Re-lease a request's host pages to another engine's domain —
        the data half of work-stealing migration.  Spilled frames are
        promoted first and queued write-backs cancelled: the on-disk
        file records a single domain, and a migrating frame's domain is
        about to change."""
        keys = self.seq_pages(seq)
        self.ensure_resident([k for k in keys if k in self._spilled])
        for k in keys:
            f = self.frames.frame_of(k)
            if f is not None and f in self._pending_wb:
                self._cancel_writeback(f)
        return self.frames.migrate(keys, dst_engine)

    # ------------------------------------------------------- crash reclaim

    def reclaim_domain(self, domain: Domain) -> int:
        """Reclaim every frame leased to ``domain`` whole (engine death,
        §12).  The router calls this *after* the victim's preempted
        bundles have migrated to survivors — whatever still belongs to
        the dead engine's domain is unreachable state, recycled at
        frame granularity exactly like a normal whole-frame return.
        Prefix-domain frames are a different domain by construction and
        survive untouched (parked KV outlives its parker).  Returns the
        number of frames reclaimed."""
        victims = sorted(f for f, d in self.frames._frame_owner.items()
                         if d == domain)
        for f in victims:
            if f in self._pending_wb:
                self._cancel_writeback(f)
            keys = sorted(self.frames.keys_of(f))
            if self.frames.state_of(f) == FRAME_SPILLED:
                # Discard the on-disk frame wholesale — no need to read
                # payloads that are about to be dropped.
                self.frames.promote(f)      # table-only state flip
                for k in keys:
                    self._spilled.pop(k, None)
                    self.frames.release(k)
                self.spill_store.delete_frame(f)
            else:
                for k in keys:
                    self.store.discard(*k)
                    self.frames.release(k)
        self.stats["reclaimed_frames"] += len(victims)
        return len(victims)

    def check_invariants(self) -> None:
        self.frames.check_invariants()
        # Every stored payload is placed, in a DRAM-resident frame.
        for key in self.store._pages:
            f = self.frames.frame_of(key)
            assert f is not None, f"host page {key} stored but not leased"
            assert self.frames.state_of(f) != FRAME_SPILLED, \
                f"stored page {key} in spilled frame {f}"
        # Placed keys partition across the two lower tiers by state.
        for f, keys in self.frames._frame_keys.items():
            spilled = self.frames.state_of(f) == FRAME_SPILLED
            for k in keys:
                if spilled:
                    assert k in self._spilled and k not in self.store._pages
                else:
                    assert k in self.store._pages, \
                        f"page {k} leased in DRAM frame {f} but not stored"
        for key, f in self._spilled.items():
            assert self.frames.state_of(f) == FRAME_SPILLED, (key, f)
            assert self.spill_store.has_frame(f)
        for f in self._pending_wb:
            assert self.frames.state_of(f) == FRAME_PENDING_WB, f
        if self.spill_store is not None:
            # Guard on the store, not spill_enabled: a degraded tier
            # (§12) still owns promotable on-disk frames.
            for f in self.spill_store.frame_ids():
                assert self.frames.state_of(f) == FRAME_SPILLED, f


# ---------------------------------------------------------------- cluster


def aggregate_engine_stats(stats: Sequence[EngineStats]) -> EngineStats:
    """Sum scalar counters (and merge the per-tier deadline dicts) of
    several replicas into one cluster-wide :class:`EngineStats` — the
    result supports the same ``summary()`` / ``slo_attainment()`` API."""
    agg = EngineStats()
    for st in stats:
        for f in dataclasses.fields(EngineStats):
            v = getattr(st, f.name)
            if isinstance(v, list):
                # Per-admission samples (admit_lat_us): concatenate so
                # cluster-wide percentiles see every engine's tail.
                getattr(agg, f.name).extend(v)
            elif isinstance(v, (int, float)):
                setattr(agg, f.name, getattr(agg, f.name) + v)
        for tier, n in st.deadline_hits.items():
            agg.deadline_hits[tier] = agg.deadline_hits.get(tier, 0) + n
        for tier, n in st.deadline_misses.items():
            agg.deadline_misses[tier] = agg.deadline_misses.get(tier, 0) + n
    return agg


class ClusterStats:
    """Cluster-wide rollup: per-engine EngineStats aggregated, router
    dispatch/migration counters, and host-tier frame-lease stats."""

    def __init__(self, engines: Sequence[ServingEngine],
                 router: RequestRouter,
                 tier: Optional[SharedHostTier]) -> None:
        self.engines = list(engines)
        self.router = router
        self.tier = tier

    @property
    def totals(self) -> EngineStats:
        return aggregate_engine_stats([e.stats for e in self.engines])

    def slo_attainment(self, priority: Optional[int] = None
                       ) -> Optional[float]:
        return self.totals.slo_attainment(priority)

    def prefix_hit_rate(self) -> float:
        t = self.totals
        return t.prefix_hits / max(t.prefix_hits + t.prefix_misses, 1)

    def summary(self) -> str:
        lines = [f"cluster: {len(self.engines)} engines | "
                 f"{self.totals.summary()}"]
        for e in self.engines:
            lines.append(f"  engine[{e.engine_id}]: {e.stats.summary()}")
        r = self.router.stats
        lines.append(
            f"  router: {r.submitted} submitted | dispatched "
            + (", ".join(f"e{i}:{n}" for i, n in sorted(r.dispatched.items()))
               or "-")
            + f" | migrations {r.migrations} ({r.migrated_pages} pages)")
        if r.queued_steals or r.prestaged_requests:
            lines.append(
                f"  router §14: {r.queued_steals} queued steals | "
                f"{r.prestaged_requests} pre-staged, "
                f"{r.prestage_cancels} cancelled "
                f"({r.prestage_refund_us:.0f}us refunded)")
        if self.tier is not None:
            fs = self.tier.frames.stats
            lines.append(
                f"  host tier: {len(self.tier.store)} pages in "
                f"{len(self.tier.frames)} frames (peak {fs['peak_frames']}) "
                f"| moves {fs['whole_frame_moves']} whole-frame / "
                f"{fs['page_moves']} page")
            ts = self.tier.stats
            if ts["spilled_frames"] or ts["promoted_frames"] \
                    or ts["hard_evicted_pages"]:
                lines.append(
                    f"  spill: {ts['spilled_frames']} frames out "
                    f"({ts['spilled_pages']} pages) / "
                    f"{ts['promoted_frames']} promoted back "
                    f"({ts['promote_us']:.0f}us stall) | cancels "
                    f"{ts['spill_cancels']} | hard-evicted "
                    f"{ts['hard_evicted_pages']} pages")
        return "\n".join(lines)


class ServingCluster:
    """N :class:`ServingEngine` replicas + shared host tier + router.

    All replicas share one ``params`` pytree (replica equivalence is what
    makes cross-engine prefix reuse and migration bitwise-safe), their
    own pools/DMA lanes/clocks, and — unless ``share_host=False`` (the
    per-engine baseline the benches compare against) — one
    :class:`SharedHostTier`.
    """

    def __init__(self, cfg: ModelConfig, *, geometry: PoolGeometry,
                 n_engines: int = 2, max_batch: int = 4, max_seq: int = 128,
                 manager_kind: str = "mosaic", seed: int = 0,
                 share_host: bool = True, share_prefix: bool = True,
                 prefix_cache: bool = True,
                 prefix_capacity_pages: int = 4096,
                 router_policy: str = "slack", migrate: bool = True,
                 router_cost_model: str = "modeled",
                 router_prestage: bool = False,
                 router_steal_queued: bool = True,
                 router_translation_aware: bool = True,
                 capacity_frames: Optional[int] = None,
                 spill: bool = True, spill_dir: Optional[str] = None,
                 wb_queue_frames: int = 4, wb_lanes: int = 1,
                 disk_read_us_per_page: float = 25.0,
                 disk_write_us_per_page: float = 25.0,
                 disk_seek_us: float = 100.0,
                 fault_injector: Optional[FaultInjector] = None,
                 disk_retries: int = 3,
                 retry_backoff_us: float = 50.0,
                 disk_error_rate_threshold: float = 0.5,
                 victim_scoring: str = "lru",
                 undegrade_probe_interval_us: Optional[float] = 10_000.0,
                 undegrade_probe_successes: int = 3,
                 **engine_kw) -> None:
        assert n_engines >= 1
        self.cfg = cfg
        self.geo = geometry
        self.fault_injector = fault_injector
        self.tier: Optional[SharedHostTier] = None
        if share_host:
            self.tier = SharedHostTier(
                geometry, n_engines=n_engines, share_prefix=share_prefix,
                prefix_capacity_pages=prefix_capacity_pages,
                capacity_frames=capacity_frames, spill=spill,
                spill_dir=spill_dir, wb_queue_frames=wb_queue_frames,
                wb_lanes=wb_lanes,
                disk_read_us_per_page=disk_read_us_per_page,
                disk_write_us_per_page=disk_write_us_per_page,
                disk_seek_us=disk_seek_us,
                injector=fault_injector, disk_retries=disk_retries,
                retry_backoff_us=retry_backoff_us,
                disk_error_rate_threshold=disk_error_rate_threshold,
                victim_scoring=victim_scoring,
                undegrade_probe_interval_us=undegrade_probe_interval_us,
                undegrade_probe_successes=undegrade_probe_successes)
        self.engines: List[ServingEngine] = []
        params = None
        for i in range(n_engines):
            eng = ServingEngine(
                cfg, geometry=geometry, max_batch=max_batch,
                max_seq=max_seq, manager_kind=manager_kind, seed=seed,
                params=params, engine_id=i,
                host=self.tier.view(i) if self.tier else None,
                prefix_index=(self.tier.prefix_for(i)
                              if self.tier and prefix_cache else None),
                prefix_cache=prefix_cache,
                prefix_capacity_pages=prefix_capacity_pages,
                injector=fault_injector,
                **engine_kw)
            params = eng.params          # replicas share one weight tree
            self.engines.append(eng)
        self.router = RequestRouter(self.engines, tier=self.tier,
                                    policy=router_policy, migrate=migrate,
                                    injector=fault_injector,
                                    cost_model=router_cost_model,
                                    prestage=router_prestage,
                                    steal_queued=router_steal_queued,
                                    translation_aware=(
                                        router_translation_aware))

    # ------------------------------------------------------------- serving

    def submit(self, req: Request, engine: Optional[int] = None) -> None:
        self.router.submit(req, engine=engine)

    def step(self) -> bool:
        return self.router.step()

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        return self.router.run_until_drained(max_steps=max_steps)

    # ------------------------------------------------------------- stats

    def stats(self) -> ClusterStats:
        return ClusterStats(self.engines, self.router, self.tier)

    def check_invariants(self) -> None:
        for e in self.engines:
            if e.alive:                 # a crashed engine's device state
                e.cache.check_invariants()   # is gone by definition
        if self.tier is not None:
            self.tier.check_invariants()
