"""ShardedKVCache: host-side bridge between Mosaic managers and the device.

Pages of one sequence are spread over ``S`` sub-pools (one per page shard:
the ``model`` axis for batched decode, every mesh axis for single-sequence
long-context).  Global virtual frame ``f`` of a sequence lives in sub-pool
``f % S`` — a static striping, so frames never straddle shards and each
sub-pool runs its own CoCoA/coalescer/CAC instance (DESIGN.md §3).

The cache produces the device-facing :class:`PageCtx` arrays each step:

  tables[B, S, mpps]   local page ids       (-1 holes)
  ntok  [B, S, mpps]   valid tokens per page
  wpage [B, S]         local page receiving this step's token (-1 if not
                       owned by that shard)
  wslot [B]            slot within the write page

plus, for the dual-granularity Pallas kernel, per-shard coalesced frame
lists and splintered page lists (``pack_dual``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import PoolGeometry
from repro.core import make_manager
from repro.core.compaction import CopyOp
from repro.models.transformer import PageCtx

import jax.numpy as jnp


class ShardedKVCache:
    def __init__(self, geometry: PoolGeometry, pages_per_shard: int,
                 n_shards: int, manager_kind: str = "mosaic", *,
                 link=None, page_bytes: int = 0):
        from repro.core.pagepool import PoolConfig
        self.geo = geometry
        self.S = n_shards
        self.pages_per_shard = pages_per_shard
        self.mgrs = [
            make_manager(manager_kind, PoolConfig(
                num_pages=pages_per_shard,
                frame_pages=geometry.frame_pages,
                page_tokens=geometry.page_tokens,
                compact_threshold=geometry.compact_threshold,
            ), link=link, page_bytes=page_bytes) for _ in range(n_shards)
        ]
        self.seq_tokens: Dict[int, int] = {}

    # ---------------------------------------------------------------- alloc

    def _shard_of_frame(self, f: int) -> int:
        return f % self.S

    def allocate(self, seq: int, n_tokens: int) -> None:
        """En-masse allocation (prefill): frames striped across sub-pools."""
        ptok = self.geo.page_tokens
        ftok = self.geo.frame_pages * ptok
        start = self.seq_tokens.get(seq, 0)
        end = start + n_tokens
        self.seq_tokens[seq] = end
        t = start
        while t < end:
            frame = t // ftok
            take = min(end, (frame + 1) * ftok) - t
            self.mgrs[self._shard_of_frame(frame)].allocate_tokens(seq, take)
            t += take

    def append(self, seq: int, n_tokens: int = 1) -> None:
        """Decode growth: token-by-token, striped by frame."""
        ptok = self.geo.page_tokens
        ftok = self.geo.frame_pages * ptok
        for _ in range(n_tokens):
            t = self.seq_tokens.get(seq, 0)
            frame = t // ftok
            self.mgrs[self._shard_of_frame(frame)].append_tokens(seq, 1)
            self.seq_tokens[seq] = t + 1

    def free(self, seq: int) -> None:
        for m in self.mgrs:
            if seq in m.tables:
                m.deallocate(seq)
        self.seq_tokens.pop(seq, None)

    def drain_copy_ops(self) -> List[Tuple[int, CopyOp]]:
        """[(shard, op), ...] for the page_compact kernel (per sub-pool)."""
        out = []
        for s, m in enumerate(self.mgrs):
            for op in m.drain_copy_ops():
                out.append((s, op))
        return out

    # ------------------------------------------------------- host tier

    def locate_page(self, gp: int) -> Tuple[int, int]:
        """Global page index → (shard, local vpn) under frame striping.

        The inverse of ``write_prefill_kv``'s vpn reconstruction: global
        frame ``f = gp // frame_pages`` lives on shard ``f % S`` as its
        ``f // S``-th local frame.  Deterministic per geometry, so the
        same prompt page lands at the same (shard, vpn) for every
        sequence — the property the prefix cache's content-hash keys
        rely on (DESIGN.md §8).
        """
        fp = self.geo.frame_pages
        f = gp // fp
        return f % self.S, (f // self.S) * fp + gp % fp

    def demote_prefix_pages(self, seq: int,
                            pages: Sequence[Tuple[int, int]]
                            ) -> List[Tuple[int, int, int]]:
        """Mark freshly-allocated pages of ``seq`` non-resident so the
        fault-in path restores them from cached-prefix host payloads.
        ``pages``: [(shard, local vpn)].  Returns [(shard, vpn, ppn)] in
        input order for admission-prefetch enqueueing."""
        out: List[Tuple[int, int, int]] = []
        by_shard: Dict[int, List[int]] = {}
        for s, vpn in pages:
            ppn = self.mgrs[s].tables[seq].ppn[vpn]
            by_shard.setdefault(s, []).append(ppn)
            out.append((s, vpn, ppn))
        for s, ppns in by_shard.items():
            self.mgrs[s].residency.demote(ppns)
        return out

    def mapped_pages(self, seq: int) -> List[Tuple[int, int, int]]:
        """All of ``seq``'s mapped pages as [(shard, local vpn, ppn)]."""
        out = []
        for s, m in enumerate(self.mgrs):
            if seq not in m.tables:
                continue
            table = m.tables[seq]
            for vpn in table.mapped_vpns():
                out.append((s, vpn, table.ppn[vpn]))
        return out

    def evict_pages(self, pages: Sequence[Tuple[int, int, int]]) -> int:
        """Account a device→host spill of [(shard, vpn, ppn)] pages."""
        by_shard: Dict[int, List[int]] = {}
        for s, _vpn, ppn in pages:
            by_shard.setdefault(s, []).append(ppn)
        return sum(self.mgrs[s].residency.evict(ppns)
                   for s, ppns in by_shard.items())

    def demote_host_backed(self, seq: int, host) -> int:
        """After a resume re-allocation: pages whose payload sits in the
        host store become non-resident so the next step faults them in."""
        n = 0
        for s, m in enumerate(self.mgrs):
            if seq not in m.tables:
                continue
            table = m.tables[seq]
            ppns = [table.ppn[vpn] for vpn in table.mapped_vpns()
                    if host.has(seq, s, vpn)]
            m.residency.demote(ppns)
            n += len(ppns)
        return n

    def host_backed_pages(self, seqs: Sequence[int], host
                          ) -> List[Tuple[int, int, int, int]]:
        """Mapped-but-non-resident pages of ``seqs`` whose payload sits in
        the host store, as [(seq, shard, vpn, ppn)] — the prefetchable
        set, carrying the owner so callers need no reverse-map lookup."""
        out: List[Tuple[int, int, int, int]] = []
        for s, m in enumerate(self.mgrs):
            for seq in seqs:
                if seq not in m.tables:
                    continue
                table = m.tables[seq]
                for vpn in table.mapped_vpns():
                    ppn = table.ppn[vpn]
                    if not m.residency.resident[ppn] \
                            and host.has(seq, s, vpn):
                        out.append((seq, s, vpn, ppn))
        return out

    def pages_needed(self, n_tokens: int) -> int:
        """Pages a fresh en-masse allocation of ``n_tokens`` consumes
        (whole frames — CoCoA's reservation granularity).  Used by the
        cluster router's steal guard to size a migration target without
        touching the destination pool (DESIGN.md §10)."""
        ftok = self.geo.frame_pages * self.geo.page_tokens
        frames = (n_tokens + ftok - 1) // ftok
        return frames * self.geo.frame_pages

    def resident_page_count(self, seq: int) -> int:
        """HBM-resident pages mapped by ``seq`` (the eviction-cost term
        of the engine's cost-aware victim score)."""
        n = 0
        for m in self.mgrs:
            if seq not in m.tables:
                continue
            table = m.tables[seq]
            n += sum(1 for vpn in table.mapped_vpns()
                     if m.residency.resident[table.ppn[vpn]])
        return n

    def missing_pages(self, seqs: Sequence[int]
                      ) -> Dict[int, List[Tuple[int, int, int]]]:
        """touch(): per shard, the non-resident (ppn, owner, vpn) triples
        among the pages the given sequences' packed tables will read."""
        out: Dict[int, List[Tuple[int, int, int]]] = {}
        for s, m in enumerate(self.mgrs):
            ppns = []
            for seq in seqs:
                if seq in m.tables:
                    table = m.tables[seq]
                    ppns.extend(table.ppn[v] for v in table.mapped_vpns())
            missing = m.residency.touch(ppns)
            if missing:
                out[s] = [(p, *m.rmap[p]) for p in missing]
        return out

    # ---------------------------------------------------------------- pack

    def pack_ctx(self, seqs: Sequence[int], mpps: int,
                 batch_sharded: bool = True) -> PageCtx:
        """Build the PageCtx for one decode step over ``seqs``.

        Call *after* ``append`` for the step's token.  mpps = max pages per
        (sequence, shard).
        """
        B, S = len(seqs), self.S
        ptok = self.geo.page_tokens
        tables = np.full((B, S, mpps), -1, np.int32)
        ntok = np.zeros((B, S, mpps), np.int32)
        wpage = np.full((B, S), -1, np.int32)
        wslot = np.zeros((B,), np.int32)
        for i, seq in enumerate(seqs):
            total = self.seq_tokens[seq]
            pos = total - 1
            for s, mgr in enumerate(self.mgrs):
                if seq not in mgr.tables:
                    continue
                table = mgr.tables[seq]
                loc_tok = mgr.seq_tokens[seq]
                n = len(table.ppn)
                if n > mpps:
                    raise ValueError(f"mpps {mpps} too small for {n}")
                for vp in range(n):
                    if table.ppn[vp] >= 0:
                        tables[i, s, vp] = table.ppn[vp]
                        ntok[i, s, vp] = min(ptok, loc_tok - vp * ptok)
            # write target = page holding `pos`
            ftok = self.geo.frame_pages * ptok
            frame = pos // ftok
            s = self._shard_of_frame(frame)
            mgr = self.mgrs[s]
            table = mgr.tables[seq]
            local_vpn = len(table.ppn) - 1  # tail page just appended
            wpage[i, s] = table.ppn[local_vpn]
            wslot[i] = pos % ptok
        return PageCtx(tables=jnp.asarray(tables), ntok=jnp.asarray(ntok),
                       wpage=jnp.asarray(wpage), wslot=jnp.asarray(wslot),
                       batch_sharded=batch_sharded)

    def pack_dual(self, seqs: Sequence[int], shard: int, max_frames: int,
                  max_pages: int):
        """Per-shard dual-granularity tables for the Pallas kernel.

        Returns (frame_tables, frame_ntok, page_tables, page_ntok) int32
        [B, max_frames] / [B, max_pages]: coalesced vframes go to the frame
        list (one entry per frame), everything else to the page list.
        """
        B = len(seqs)
        fp, ptok = self.geo.frame_pages, self.geo.page_tokens
        ft = np.full((B, max_frames), -1, np.int32)
        fn = np.zeros((B, max_frames), np.int32)
        pt = np.full((B, max_pages), -1, np.int32)
        pn = np.zeros((B, max_pages), np.int32)
        mgr = self.mgrs[shard]
        for i, seq in enumerate(seqs):
            if seq not in mgr.tables:
                continue
            table = mgr.tables[seq]
            loc_tok = mgr.seq_tokens[seq]
            fi = pi = 0
            for vf in range(table.num_vframes):
                vpns = table.vpns_of_vframe(vf)
                if vf < len(table.coalesced) and table.coalesced[vf]:
                    ok, pframe = table.vframe_contiguous_aligned(vf)
                    assert ok
                    ft[i, fi] = pframe
                    fn[i, fi] = min(fp * ptok,
                                    loc_tok - vf * fp * ptok)
                    fi += 1
                else:
                    for vp in vpns:
                        if table.ppn[vp] >= 0:
                            pt[i, pi] = table.ppn[vp]
                            pn[i, pi] = max(0, min(
                                ptok, loc_tok - vp * ptok))
                            pi += 1
        return (jnp.asarray(ft), jnp.asarray(fn),
                jnp.asarray(pt), jnp.asarray(pn))

    # ---------------------------------------------------------------- stats

    def stats(self) -> Dict[str, float]:
        agg: Dict[str, float] = {}
        for m in self.mgrs:
            for k, v in m.stats().items():
                agg[k] = agg.get(k, 0.0) + float(v)
        n = len(self.mgrs)
        for k in ("occupancy", "coalesced_fraction", "memory_bloat"):
            if k in agg:
                agg[k] /= n
        return agg

    def check_invariants(self):
        for m in self.mgrs:
            m.check_invariants()
