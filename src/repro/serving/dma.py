"""Async double-buffered fault-in: hide host→device DMA behind decode.

PR 1's demand paging is synchronous: the whole batch stalls on the full
gather-transfer before decode runs, so every host-tier fault is exposed
latency.  Mosaic's en-masse, contiguity-preserving allocation makes page
touches *predictable* — the pages step N+1 will read are knowable at step
N — so (GPUVM-style) the transfer can run on a DMA channel *while* step N
decodes, and only the remainder is exposed.

Three cooperating pieces (DESIGN.md §7):

* :class:`AsyncDMAEngine` — models ``n_channels`` DMA channels on the
  host↔device link with an explicit microsecond timeline.  An enqueued
  job gets a start timestamp (``max(now, channel_free)``) and a
  completion timestamp (``start + transfer_us`` from the shared
  :class:`~repro.core.demand_paging.LinkModel` / contiguous-run cost
  model).  Per-job transfer time is split into *hidden* µs (overlapped
  with compute: the job completed before anyone waited on it, or the
  waited-on portion that had already elapsed) and *exposed* µs (the
  portion the engine stalled on); ``hidden + exposed == transfer_us``
  for every job, and channel-queueing delay beyond the transfer itself
  is tracked separately as ``queue_us``.  The link is *full-duplex*
  (DESIGN.md §8): outbound device→host traffic — preemption eviction
  gathers and cold-prefix parking — rides the same channels on
  independent per-direction timelines, accounted under the ``*_out``
  stat keys with the same per-direction hidden/exposed/queue split.
* :class:`StagingBuffer` — the double-buffered staging region completed
  prefetches scatter into.  Ownership rule: the DMA engine's completions
  land only in the *back* buffer; the engine's fault-in path reads only
  the *front* buffer; :meth:`StagingBuffer.swap` (called once at step
  start, before admission) publishes back→front.  Unconsumed front
  entries are retained across swaps — the host copy stays authoritative
  until a payload is actually scattered into a mapped pool page, so a
  retained (or even dropped) staged page is never a correctness hazard,
  only accounted waste.
* :class:`Prefetcher` — predicts step N+1's page touches at step N: the
  host-backed pages among each active request's mapped set (its next
  token-slot page included) plus the pages of the next preempted
  requests eligible for resume, in the same priority-then-FIFO order
  the engine's admission loop uses.  Predicted pages are issued to the
  DMA engine right before the decode call and drain into staging while
  decode runs.

Payloads are staged as *copies* keyed by logical identity
``(seq, shard, vpn)`` (same keying as the
:class:`~repro.serving.host_tier.HostPageStore`), so compaction moving a
page's physical location never invalidates a staged entry, and a wrong
prediction loses nothing: the host copy is only popped at consumption.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.demand_paging import FaultBatch, LinkModel

Key = Tuple[int, int, int]          # (seq, shard, local vpn)


@dataclasses.dataclass
class DMAJob:
    """One enqueued gather-transfer on a DMA channel.

    ``ppns`` feed the contiguous-run cost model: real physical pages for
    demand faults (device-side scatter targets), synthetic contiguous
    staging slots for resume prefetches (the staging region is a
    contiguous device buffer, so a host→staging gather always merges).

    ``direction`` is the link direction the job occupies: ``"in"``
    (host→device: demand faults, prefetches) or ``"out"`` (device→host:
    preemption eviction gathers, cold-prefix parking, and the host
    tier's whole-frame ``"spill"`` write-backs toward disk — DESIGN.md
    §11).  On a full-duplex link the two directions have independent
    per-channel timelines.
    """

    job_id: int
    keys: List[Key]
    batch: FaultBatch
    start_us: float
    done_us: float
    payloads: List[Tuple[np.ndarray, np.ndarray]]
    kind: str = "prefetch"   # "prefetch" | "demand" | "evict" | "park" | "spill"
    direction: str = "in"           # "in" (h→d) | "out" (d→h)
    channel: int = -1
    settled: bool = False           # hidden/exposed already accounted

    @property
    def transfer_us(self) -> float:
        return self.batch.transfer_us

    @property
    def dma_count(self) -> int:
        return self.batch.dma_count

    @property
    def nbytes(self) -> int:
        return self.batch.nbytes

    def page_done_us(self, i: int) -> float:
        """Modeled completion timestamp of this job's ``i``-th page.

        Pages land in key order along the merged transfer, so page ``i``
        becomes readable at ``start + transfer · (i+1)/n`` — the
        per-page readiness timeline the fused decode path consumes:
        pages whose timestamp falls inside the decode window are drained
        in-kernel for free, only the tail past ``done_us`` is exposed
        (DESIGN.md §13).
        """
        n = max(len(self.keys), 1)
        return self.start_us + self.transfer_us * (i + 1) / n


class AsyncDMAEngine:
    """N-channel host⇄device DMA timeline with hidden/exposed accounting.

    The clock is *modeled* microseconds supplied by the caller (the
    engine advances it by measured decode wall time and by exposed
    stalls), so the engine, the benches and the tests all reason on one
    explicit timeline.

    The link is **full-duplex** by default (real PCIe is): each channel
    carries one inbound (host→device) and one outbound (device→host)
    transfer concurrently, so eviction gathers riding the "out" lanes
    never delay fault-ins riding the "in" lanes — they only queue behind
    other outbound traffic.  ``duplex=False`` degrades to a half-duplex
    link where both directions contend for the same channel timeline
    (the PR 2 single-timeline model, kept for comparison benches).

    Stats are kept per direction: the un-suffixed keys (``transfer_us``,
    ``hidden_us``, ``exposed_us``, ``queue_us``, ``pages``, ``bytes``,
    ``dma_count``) are the **inbound** totals — exactly what they meant
    before outbound modeling existed — and the ``*_out`` keys account the
    outbound lanes.  The per-direction invariant ``hidden + exposed ==
    Σ transfer_us`` holds over settled jobs in each direction.
    """

    def __init__(self, link: Optional[LinkModel] = None,
                 n_channels: int = 2, duplex: bool = True,
                 injector=None):
        assert n_channels >= 1
        self.link = link or LinkModel()
        self.duplex = duplex
        # Failure model (DESIGN.md §12): an injector may stall a lane —
        # the job (and its channel) finishes late by the injected µs.
        self.injector = injector
        free_in = [0.0] * n_channels
        # Half-duplex shares the *same list object*, so either direction's
        # enqueue occupies the single per-channel timeline.
        free_out = [0.0] * n_channels if duplex else free_in
        self.channel_free = {"in": free_in, "out": free_out}
        self._ids = itertools.count()
        self.in_flight: Dict[int, DMAJob] = {}
        self.stats = {
            "jobs": 0, "prefetch_jobs": 0, "demand_jobs": 0,
            "evict_jobs": 0, "park_jobs": 0, "spill_jobs": 0,
            "pages": 0, "dma_count": 0, "bytes": 0,
            "transfer_us": 0.0,     # Σ per-job transfer_us (hidden+exposed)
            "hidden_us": 0.0,       # overlapped with compute
            "exposed_us": 0.0,      # stalled-on portion of transfers
            "queue_us": 0.0,        # stalled waiting for a busy channel
            "pages_out": 0, "dma_count_out": 0, "bytes_out": 0,
            "transfer_us_out": 0.0, "hidden_us_out": 0.0,
            "exposed_us_out": 0.0, "queue_us_out": 0.0,
            "injected_stall_us": 0.0,
            "cancelled_jobs": 0,
            "refunded_us": 0.0, "refunded_us_out": 0.0,
        }

    @staticmethod
    def _sfx(direction: str) -> str:
        return "" if direction == "in" else "_out"

    # ------------------------------------------------------------- enqueue

    def enqueue(self, keys: Sequence[Key], ppns: Sequence[int],
                page_bytes: int,
                payloads: Sequence[Tuple[np.ndarray, np.ndarray]],
                now_us: float, kind: str = "prefetch",
                direction: str = "in") -> DMAJob:
        """Queue one gather-transfer; returns the job with its timeline."""
        assert len(keys) == len(ppns) == len(payloads)
        assert direction in ("in", "out"), direction
        batch = FaultBatch([int(p) for p in ppns], page_bytes, self.link)
        free = self.channel_free[direction]
        ch = min(range(len(free)), key=lambda c: free[c])
        start = max(float(now_us), free[ch])
        done = start + batch.transfer_us
        if self.injector is not None:
            # An injected lane stall delays this job's completion and
            # occupies the channel for the extra µs (a throttled lane
            # backs up everything queued behind it).
            extra = self.injector.dma_stall(kind, direction)
            if extra:
                done += extra
                self.stats["injected_stall_us"] += extra
        free[ch] = done
        job = DMAJob(job_id=next(self._ids), keys=list(keys), batch=batch,
                     start_us=start, done_us=done, payloads=list(payloads),
                     kind=kind, direction=direction, channel=ch)
        self.in_flight[job.job_id] = job
        sfx = self._sfx(direction)
        self.stats["jobs"] += 1
        self.stats[f"{kind}_jobs"] += 1
        self.stats[f"pages{sfx}"] += len(job.keys)
        self.stats[f"dma_count{sfx}"] += job.dma_count
        self.stats[f"bytes{sfx}"] += job.nbytes
        self.stats[f"transfer_us{sfx}"] += job.transfer_us
        return job

    # ------------------------------------------------------------- settle

    def wait(self, job: DMAJob, now_us: float) -> float:
        """Stall until ``job`` completes; returns the advanced clock.

        The stall splits into the *exposed* part of the transfer itself
        (at most ``transfer_us``) and channel-*queueing* delay (the job
        had not even started because the channel was busy); the
        remainder of the transfer was *hidden* behind compute that
        already ran.
        """
        stall = max(0.0, job.done_us - now_us)
        if not job.settled:
            sfx = self._sfx(job.direction)
            exposed = min(stall, job.transfer_us)
            self.stats[f"exposed_us{sfx}"] += exposed
            self.stats[f"hidden_us{sfx}"] += job.transfer_us - exposed
            self.stats[f"queue_us{sfx}"] += stall - exposed
            job.settled = True
        self.in_flight.pop(job.job_id, None)
        return max(float(now_us), job.done_us)

    def drain(self, now_us: float) -> List[DMAJob]:
        """Harvest jobs whose completion timestamp has passed.

        A drained job completed strictly in the background, so its whole
        transfer was hidden behind compute.
        """
        done = [j for j in self.in_flight.values()
                if j.done_us <= float(now_us)]
        for j in done:
            if not j.settled:
                self.stats[f"hidden_us{self._sfx(j.direction)}"] \
                    += j.transfer_us
                j.settled = True
            del self.in_flight[j.job_id]
        return sorted(done, key=lambda j: (j.done_us, j.job_id))

    def cancel(self, job: DMAJob, now_us: float) -> float:
        """Cancel an in-flight job and refund the un-elapsed lane time.

        Used by pre-staging when a steal or a crash retargets a queued
        request (DESIGN.md §14).  The elapsed portion of the transfer
        already moved bytes; it settles as *hidden* µs (wasted, but the
        lane time was genuinely spent overlapped with other work).  The
        un-elapsed remainder is refunded: if the job is still the last
        booking on its channel the lane's busy horizon rolls back to the
        cancellation point, and the refunded µs leave ``transfer_us`` so
        the per-direction ``hidden + exposed == Σ transfer_us`` invariant
        holds over settled jobs.  A job that later transfers already
        queued behind cannot be un-booked — the lane stays busy either
        way — so its whole transfer settles as hidden with zero refund.
        Returns the refunded µs.
        """
        if job.settled or job.job_id not in self.in_flight:
            return 0.0
        sfx = self._sfx(job.direction)
        now = float(now_us)
        elapsed = min(max(0.0, now - job.start_us), job.transfer_us)
        free = self.channel_free[job.direction]
        refund = 0.0
        if free[job.channel] == job.done_us:
            refund = job.transfer_us - elapsed
            # Roll the lane back to start+elapsed (this also drops any
            # injected stall tail — a cancelled job no longer occupies
            # its throttled lane past the cancellation point).
            free[job.channel] = max(job.start_us, min(now, job.done_us))
        else:
            elapsed = job.transfer_us
        self.stats[f"hidden_us{sfx}"] += elapsed
        self.stats[f"transfer_us{sfx}"] -= refund
        self.stats[f"refunded_us{sfx}"] += refund
        self.stats["cancelled_jobs"] += 1
        job.settled = True
        del self.in_flight[job.job_id]
        return refund

    # ------------------------------------------------------------- queries

    def busy_until(self) -> float:
        return max(max(self.channel_free["in"]),
                   max(self.channel_free["out"]))


class StagingBuffer:
    """Double-buffered staging region for completed prefetch payloads.

    Ownership rules (DESIGN.md §7): DMA completions are staged into the
    *back* buffer only; the engine's fault-in path consumes from the
    *front* buffer only; ``swap()`` runs once per step, before admission,
    publishing back→front.  Unconsumed front entries are retained (the
    payload was already transferred; the host copy stays authoritative
    until consumption), and invalidation simply drops entries — safe
    because staged payloads are copies.

    Every staged key also gets a monotonically increasing ``slot_of``
    id: the stable address of that page inside the staging region.  The
    fused decode path (DESIGN.md §13) re-bases the slots it consumes
    into a dense step-local stage pool addressable by the kernel's page
    table, so attention reads late arrivals straight from staging with
    no second copy.
    """

    def __init__(self) -> None:
        self._front: Dict[Key, Tuple[np.ndarray, np.ndarray]] = {}
        self._back: Dict[Key, Tuple[np.ndarray, np.ndarray]] = {}
        self._slots: Dict[Key, int] = {}
        self._next_slot = 0
        self.stats = {"staged": 0, "consumed": 0, "invalidated": 0,
                      "peak_front": 0}

    def __len__(self) -> int:
        return len(self._front) + len(self._back)

    def stage(self, key: Key,
              payload: Tuple[np.ndarray, np.ndarray]) -> None:
        self._back[key] = payload
        if key not in self._slots:
            self._slots[key] = self._next_slot
            self._next_slot += 1
        self.stats["staged"] += 1

    def slot_of(self, key: Key) -> Optional[int]:
        """Staging-region slot of a currently staged key (None if absent)."""
        return self._slots.get(key) if self.contains(key) else None

    def swap(self) -> None:
        self._front.update(self._back)
        self._back = {}
        self.stats["peak_front"] = max(self.stats["peak_front"],
                                       len(self._front))

    def has(self, key: Key) -> bool:
        return key in self._front

    def contains(self, key: Key) -> bool:
        """In either buffer (prefetch dedup: staged ⇒ don't re-issue)."""
        return key in self._front or key in self._back

    def consume(self, key: Key
                ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        payload = self._front.pop(key, None)
        if payload is not None:
            if key not in self._back:
                self._slots.pop(key, None)
            self.stats["consumed"] += 1
        return payload

    def invalidate_seq(self, seq: int) -> int:
        """Drop a sequence's staged pages (request completed/cancelled)."""
        n = 0
        for buf in (self._front, self._back):
            for k in [k for k in buf if k[0] == seq]:
                del buf[k]
                self._slots.pop(k, None)
                n += 1
        self.stats["invalidated"] += n
        return n


class Prefetcher:
    """Predicts step N+1's host-backed page touches and tracks issues.

    ``depth`` bounds how many preemption victims ahead of the resume
    queue are prefetched per step (the engine may resume several in one
    admission round when capacity frees en masse).  Under SLO-aware
    resume scheduling (DESIGN.md §8) the *effective* depth follows the
    deadline pressure of the resume queue: :meth:`plan_depth` widens the
    window to cover every candidate whose deadline slack is inside
    ``urgency_us``, so urgent resumes have their pages staged before the
    admission round that re-admits them.
    """

    def __init__(self, depth: int = 2):
        self.depth = depth
        self.in_flight: Dict[Key, DMAJob] = {}
        self.stats = {"issued_pages": 0, "hits": 0, "misses": 0,
                      "wasted_pages": 0, "planned_depth": depth,
                      "max_planned_depth": depth}

    # ------------------------------------------------------------- depth

    def plan_depth(self, slacks: Sequence[Optional[float]],
                   urgency_us: float) -> int:
        """Deadline-weighted prefetch depth for this step.

        ``slacks`` are the resume candidates' ``deadline − now`` in µs,
        in resume order (``None`` = no deadline).  The planned depth is
        the base ``depth`` widened to cover all candidates with slack ≤
        ``urgency_us`` (deadline already blown counts as maximally
        urgent), capped at the queue length.
        """
        urgent = sum(1 for s in slacks if s is not None and s <= urgency_us)
        eff = max(self.depth, urgent)
        if slacks:
            eff = min(eff, len(slacks))
        self.stats["planned_depth"] = eff
        self.stats["max_planned_depth"] = max(
            self.stats["max_planned_depth"], eff)
        return eff

    # ------------------------------------------------------------- predict

    def predict(self, cache, host, active_seqs: Sequence[int],
                resume_order: Sequence[int], depth: Optional[int] = None
                ) -> List[Tuple[Key, Optional[int]]]:
        """[(key, ppn-or-None)] the next step will touch but is not
        HBM-resident.

        * Active requests: the non-resident subset of their mapped pages
          (the packed tables of step N+1 read all of them; this includes
          the next token-slot page).  These have physical targets, so
          their ``ppn`` rides along for contiguity costing.
        * The next ``depth`` preempted requests in resume order (the
          caller passes :meth:`plan_depth`'s value when scheduling is
          SLO-aware): every host-parked page (no physical target yet —
          the resume will re-map them; transfers land in staging).
        """
        out: List[Tuple[Key, Optional[int]]] = []
        for seq, s, vpn, ppn in cache.host_backed_pages(active_seqs, host):
            out.append(((seq, s, vpn), ppn))
        for rid in list(resume_order)[:self.depth if depth is None else depth]:
            for key in host.seq_pages(rid):
                out.append((key, None))
        return out

    # ------------------------------------------------------------- issue

    def cancel_seq(self, seq: int) -> None:
        for k in [k for k in self.in_flight if k[0] == seq]:
            del self.in_flight[k]

    def forget(self, keys: Iterable[Key]) -> None:
        for k in keys:
            self.in_flight.pop(k, None)
