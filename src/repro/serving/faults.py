"""Deterministic failure injection for the serving stack (DESIGN.md §12).

Mosaic's single-domain-per-frame invariant is what makes recovery
*cheap*: a host frame is owned by exactly one protection domain, so a
dead engine's frames can be reclaimed (or re-leased to a survivor)
whole, with no base-page migration, and the shared prefix-cache frames
— a different domain by construction — survive the owner's death
untouched.  This module supplies the failure model that exercises those
properties end-to-end:

* :class:`FaultPlan` — a declarative, seeded schedule of faults:
  engine crashes at specific router steps, transient/permanent disk
  read and write errors, spill-frame corruption (bit flips written to
  disk), and DMA lane stalls.  Same plan + same seed ⇒ the same faults
  fire at the same points in any run, so recovery benches and tests are
  exactly reproducible.
* :class:`FaultInjector` — the runtime half: hook methods called from
  the injection sites (:class:`~repro.serving.router.RequestRouter`
  for crashes, :class:`~repro.serving.host_tier.SpillStore` for disk
  I/O and corruption, :class:`~repro.serving.dma.AsyncDMAEngine` for
  lane stalls).  Every injected fault is counted and logged.  A
  component given ``injector=None`` (the default everywhere) pays zero
  overhead — the hooks are never consulted.
* :class:`SpillIOError` / :class:`SpillCorruptionError` — the error
  vocabulary the recovery machinery speaks: transient I/O errors are
  retried with exponential backoff charged to the modeled clock
  (:class:`~repro.serving.cluster.SharedHostTier`), permanent errors
  and checksum mismatches quarantine the frame and trigger rebuild
  (prefix frames re-derived through their index, request frames
  restarted from the prompt), and a rising error rate degrades the
  tier to the hard-cap (``spill=False``) path without dropping
  requests.

The injector is *process-wide* state shared by every component of one
cluster, so a plan reads like an incident script: "crash engine 0 at
step 6; every third disk read fails once; frame 2's file is corrupted
on disk".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


class SpillIOError(IOError):
    """A disk read/write of a spill frame failed.

    ``transient=True`` models a retryable error (bus hiccup, throttled
    device): the tier retries with exponential backoff charged to the
    modeled clock.  ``transient=False`` is permanent (bad sector, file
    vanished): the frame is quarantined and rebuilt."""

    def __init__(self, frame: int, op: str, *, transient: bool) -> None:
        self.frame = frame
        self.op = op
        self.transient = transient
        kind = "transient" if transient else "permanent"
        super().__init__(f"{kind} disk {op} error on spill frame {frame}")


class SpillCorruptionError(ValueError):
    """A spill frame's payload bytes failed checksum verification.

    Raised by :meth:`SpillStore.read_frame` *before* any payload is
    returned — corrupted KV is never decoded from.  The tier
    quarantines the frame and rebuilds its contents from upstream
    truth (the prefix index re-derives, requests re-prefill)."""

    def __init__(self, frame: int) -> None:
        self.frame = frame
        super().__init__(
            f"spill frame {frame} failed checksum verification "
            f"(on-disk corruption) — payload quarantined, not decoded")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, declarative fault schedule (all fields default to "no
    faults", so a plan only states what it breaks).

    * ``engine_crashes`` — ``(router_step, engine_id)`` pairs: the
      engine dies at the *start* of that router step (its device state
      is lost; host-tier state survives per domain).
    * ``disk_read_error_rate`` / ``disk_write_error_rate`` — per-op
      probability of a *transient* :class:`SpillIOError` (drawn from
      the seeded RNG, so the same ops fail across runs).
    * ``permanent_read_frames`` — frames whose reads always fail
      permanently (bad sector).
    * ``corrupt_write_rate`` — per-frame probability that a spill
      write lands on disk with a flipped bit (the checksum recorded is
      of the *true* bytes, so verification must catch it).
    * ``corrupt_frames`` — frames corrupted unconditionally.
    * ``dma_stall_every`` / ``dma_stall_us`` — every Nth enqueued DMA
      job (per direction) is stalled by ``dma_stall_us`` extra µs on
      its lane (a throttled channel), 0 disables.
    """

    seed: int = 0
    engine_crashes: Tuple[Tuple[int, int], ...] = ()
    disk_read_error_rate: float = 0.0
    disk_write_error_rate: float = 0.0
    max_transient_failures: int = 2     # per frame+op: then reads succeed
    permanent_read_frames: Tuple[int, ...] = ()
    corrupt_write_rate: float = 0.0
    corrupt_frames: Tuple[int, ...] = ()
    dma_stall_every: int = 0
    dma_stall_us: float = 0.0


class FaultInjector:
    """Runtime fault oracle: components ask it whether to fail.

    Deterministic: decisions come from a ``numpy`` RNG seeded by the
    plan, advanced only by the hook calls themselves — identical call
    sequences (which deterministic engines produce) yield identical
    fault sequences.  Transient errors are bounded per ``(frame, op)``
    by ``max_transient_failures`` so retry loops provably terminate in
    tests while still exercising the backoff path.
    """

    def __init__(self, plan: Optional[FaultPlan] = None) -> None:
        self.plan = plan or FaultPlan()
        self._rng = np.random.default_rng(self.plan.seed)
        self._crashed: set = set()
        self._transients: Dict[Tuple[int, str], int] = {}
        self._dma_jobs = 0
        self.log: List[Tuple[str, tuple]] = []
        self.stats = {
            "engine_crashes": 0, "disk_read_errors": 0,
            "disk_write_errors": 0, "permanent_read_errors": 0,
            "corrupted_frames": 0, "dma_stalls": 0,
            "dma_stall_us": 0.0,
        }

    def _note(self, kind: str, *detail) -> None:
        self.log.append((kind, detail))

    # ------------------------------------------------------------- crashes

    def crashes_due(self, step: int) -> List[int]:
        """Engine ids scheduled to die at (or before) ``step`` that have
        not fired yet — the router calls this at each step start."""
        due = []
        for at, eng in self.plan.engine_crashes:
            if at <= step and (at, eng) not in self._crashed:
                self._crashed.add((at, eng))
                due.append(eng)
                self.stats["engine_crashes"] += 1
                self._note("engine_crash", step, eng)
        return due

    # ---------------------------------------------------------------- disk

    def _transient_ok(self, frame: int, op: str) -> bool:
        """True if this (frame, op) may still fail transiently."""
        n = self._transients.get((frame, op), 0)
        if n >= self.plan.max_transient_failures:
            return False
        self._transients[(frame, op)] = n + 1
        return True

    def disk_write_fault(self, frame: int) -> None:
        """Called before a spill-frame write; raises to fail it."""
        rate = self.plan.disk_write_error_rate
        if rate > 0.0 and self._rng.random() < rate \
                and self._transient_ok(frame, "write"):
            self.stats["disk_write_errors"] += 1
            self._note("disk_write_error", frame)
            raise SpillIOError(frame, "write", transient=True)

    def disk_read_fault(self, frame: int) -> None:
        """Called before a spill-frame read; raises to fail it."""
        if frame in self.plan.permanent_read_frames:
            self.stats["permanent_read_errors"] += 1
            self._note("disk_read_permanent", frame)
            raise SpillIOError(frame, "read", transient=False)
        rate = self.plan.disk_read_error_rate
        if rate > 0.0 and self._rng.random() < rate \
                and self._transient_ok(frame, "read"):
            self.stats["disk_read_errors"] += 1
            self._note("disk_read_error", frame)
            raise SpillIOError(frame, "read", transient=True)

    def corrupt_written(self, frame: int, blob: bytes) -> Optional[bytes]:
        """Maybe bit-flip a frame's payload bytes as they land on disk.

        Returns the corrupted copy, or None to write faithfully.  The
        flipped bit position is drawn from the seeded RNG, so the same
        byte breaks across runs."""
        hit = frame in self.plan.corrupt_frames
        if not hit and self.plan.corrupt_write_rate > 0.0:
            hit = self._rng.random() < self.plan.corrupt_write_rate
        if not hit or not blob:
            return None
        pos = int(self._rng.integers(0, len(blob)))
        bit = 1 << int(self._rng.integers(0, 8))
        out = bytearray(blob)
        out[pos] ^= bit
        self.stats["corrupted_frames"] += 1
        self._note("frame_corruption", frame, pos)
        return bytes(out)

    # ----------------------------------------------------------------- dma

    def dma_stall(self, kind: str, direction: str) -> float:
        """Extra µs to add to the job being enqueued (lane stall)."""
        every = self.plan.dma_stall_every
        if every <= 0 or self.plan.dma_stall_us <= 0.0:
            return 0.0
        self._dma_jobs += 1
        if self._dma_jobs % every:
            return 0.0
        self.stats["dma_stalls"] += 1
        self.stats["dma_stall_us"] += self.plan.dma_stall_us
        self._note("dma_stall", kind, direction)
        return self.plan.dma_stall_us
