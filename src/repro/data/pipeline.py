"""Deterministic, shardable, checkpointable token pipeline.

Two sources:
  * :class:`SyntheticLM` — counter-based (stateless) generation: batch for
    step ``s``, data-parallel rank ``r`` is a pure function of
    ``(seed, s, r)``.  Restart at any step reproduces the exact stream with
    zero state — the strongest checkpointability you can have.
  * :class:`MemmapCorpus` — fixed token file (np.memmap), deterministic
    strided reads per (step, rank); state is just the step counter.

Both emit ``{"tokens": int32 [per_rank_batch, seq_len+?]}``; a background
prefetch thread keeps ``depth`` batches ready (overlap host data work with
device compute).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    """Markov-ish synthetic tokens with enough structure for loss to fall."""

    def __init__(self, vocab: int, seq_len: int, batch_per_rank: int,
                 seed: int = 0, rank: int = 0, num_ranks: int = 1):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch_per_rank
        self.seed = seed
        self.rank = rank
        self.num_ranks = num_ranks

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.rank]))
        B, T, V = self.batch, self.seq_len, self.vocab
        # structured stream: random walk over the vocab with repetitions —
        # learnable short-range correlations.
        start = rng.integers(0, V, size=(B, 1))
        steps = rng.integers(-3, 4, size=(B, T - 1))
        toks = np.concatenate([start, steps], axis=1).cumsum(axis=1) % V
        return {"tokens": toks.astype(np.int32)}

    def state(self, step: int) -> Dict:
        return {"step": step, "seed": self.seed}


class MemmapCorpus:
    def __init__(self, path: str, seq_len: int, batch_per_rank: int,
                 rank: int = 0, num_ranks: int = 1):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.batch = batch_per_rank
        self.rank = rank
        self.num_ranks = num_ranks
        self.n_seq = len(self.data) // seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B, T = self.batch, self.seq_len
        base = (step * self.num_ranks + self.rank) * B
        idx = (base + np.arange(B)) % self.n_seq
        toks = np.stack([self.data[i * T:(i + 1) * T] for i in idx])
        return {"tokens": toks.astype(np.int32)}

    def state(self, step: int) -> Dict:
        return {"step": step}


class Prefetcher:
    """Background thread keeping ``depth`` upcoming batches ready."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
