"""Jit'd public wrappers for the Pallas kernels.

``use_pallas`` selects the kernel path (interpret=True on CPU — executes
the kernel body in Python for correctness; on real TPUs pass
``interpret=False``).  The default is the pure-JAX path from
:mod:`repro.models`, which is what the dry-run lowers (Pallas cannot
compile for the CPU placeholder devices).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.page_compact import (
    page_compact as _compact_kernel,
    page_gather as _gather_kernel,
    page_scatter as _scatter_kernel,
)
from repro.kernels.paged_attention import (
    combine_granularities,
    paged_attention_kernel,
)


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas",
                                             "interpret", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True,
                    use_pallas: bool = False, interpret: bool = True,
                    bq: int = 128, bk: int = 512):
    if use_pallas:
        return _flash_kernel(q, k, v, causal=causal, bq=bq, bk=bk,
                             interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("frame_pages", "scale",
                                             "use_pallas", "interpret"))
def paged_attention_dual(q, pool_k, pool_v, frame_tables, frame_ntok,
                         page_tables, page_ntok, *, frame_pages: int,
                         scale: float, use_pallas: bool = False,
                         interpret: bool = True):
    """Dual-granularity paged attention over one shard's pool.

    Coalesced frames go down the frame fast path; splintered pages down the
    page path; partials flash-combined.  Returns normalized [B, H, dh_v].
    """
    if use_pallas:
        parts = [
            paged_attention_kernel(q, pool_k, pool_v, frame_tables,
                                   frame_ntok, granularity="frame",
                                   frame_pages=frame_pages, scale=scale,
                                   interpret=interpret),
            paged_attention_kernel(q, pool_k, pool_v, page_tables,
                                   page_ntok, granularity="page",
                                   scale=scale, interpret=interpret),
        ]
        o, m, l = combine_granularities(parts)
        return o / jnp.maximum(l[..., None], 1e-30)
    # Oracle path: frames expanded to pages.
    B, nf = frame_tables.shape
    fp = frame_pages
    ptok = pool_k.shape[1]
    pages_of_frames = (frame_tables[..., None] * fp
                       + jnp.arange(fp)[None, None, :])
    pages_of_frames = jnp.where(frame_tables[..., None] >= 0,
                                pages_of_frames, -1).reshape(B, nf * fp)
    slot0 = jnp.arange(fp)[None, None, :] * ptok
    ntok_pages = jnp.clip(frame_ntok[..., None] - slot0, 0, ptok)
    ntok_pages = ntok_pages.reshape(B, nf * fp)
    all_tables = jnp.concatenate([pages_of_frames, page_tables], axis=1)
    all_ntok = jnp.concatenate([ntok_pages, page_ntok], axis=1)
    return ref.paged_attention_full_ref(q, pool_k, pool_v, all_tables,
                                        all_ntok, scale=scale)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def page_compact(pool, src, dst, *, use_pallas: bool = True,
                 interpret: bool = True):
    if use_pallas:
        return _compact_kernel(pool, src, dst, interpret=interpret)
    return ref.page_compact_ref(pool, src, dst)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def page_gather(pool, idx, *, use_pallas: bool = True,
                interpret: bool = True):
    """Host-tier eviction gather: pages[i] = pool[idx[i]] (DESIGN.md §6)."""
    if use_pallas:
        return _gather_kernel(pool, idx, interpret=interpret)
    return ref.page_gather_ref(pool, idx)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def page_scatter(pool, idx, pages, *, use_pallas: bool = True,
                 interpret: bool = True):
    """Host-tier fault-in scatter: pool[idx[i]] = pages[i] (DESIGN.md §6)."""
    if use_pallas:
        return _scatter_kernel(pool, idx, pages, interpret=interpret)
    return ref.page_scatter_ref(pool, idx, pages)


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int, h0=None,
             use_pallas: bool = False, interpret: bool = True):
    """Mamba-2 SSD chunked scan (see kernels/ssd_scan.py)."""
    if use_pallas:
        from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel
        return _ssd_kernel(x, dt, A, Bm, Cm, chunk=chunk, h0=h0,
                           interpret=interpret)
    return ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
