"""CAC page-copy kernel: batched on-device base-page migration.

Executes a compaction plan's ``CopyOp`` list in one launch: grid over the
copy list; each step DMAs one base page pool[src[i]] → pool[dst[i]] through
VMEM, with both sides addressed via scalar-prefetched index maps.  Holes
(src/dst = -1) are rewritten to a *duplicate* of the first valid copy op —
duplicates are idempotent because CAC only copies live pages into free
slots (a src page is never also a dst page), so the kernel stays total
without the hole ever clobbering a real destination.  If every op is a
hole, the plan degenerates to copying page 0 onto itself, which is safe
precisely because then nothing else writes.

The paper models compaction as a whole-GPU stall (worst case); this kernel
is the real cost: len(plan) page-sized DMAs, overlappable between decode
steps.  ``benchmarks/kernel_bench.py`` measures it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(src_ref, dst_ref, pool_in_ref, pool_out_ref):
    pool_out_ref[...] = pool_in_ref[...]


def page_compact(pool, src, dst, *, interpret: bool = True):
    """pool [NP, ptok, kv, dh]; src/dst int32 [n].  Returns updated pool.

    input_output_aliasing keeps this in-place on the real device: only the
    touched pages move.
    """
    n = src.shape[0]
    if n == 0:
        return pool
    NP = pool.shape[0]
    blk = (1, *pool.shape[1:])

    # Rewrite holes to duplicates of the first valid op (see module doc).
    valid = (src >= 0) & (dst >= 0)
    first = jnp.argmax(valid)                      # 0 when no valid op
    any_valid = jnp.any(valid)
    src = jnp.where(valid, src, jnp.where(any_valid, src[first], 0))
    dst = jnp.where(valid, dst, jnp.where(any_valid, dst[first], 0))

    def in_index(i, src, dst):
        return (src[i], *([0] * (len(blk) - 1)))

    def out_index(i, src, dst):
        return (dst[i], *([0] * (len(blk) - 1)))

    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n,),
            in_specs=[pl.BlockSpec(blk, in_index)],
            out_specs=pl.BlockSpec(blk, out_index),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(src, dst, pool)
    if interpret:
        # The interpreter does not emulate in-place aliasing of unwritten
        # output blocks; merge untouched pages back (TPU path skips this).
        touched = (jnp.zeros((NP,), jnp.int32).at[jnp.maximum(dst, 0)]
                   .add((dst >= 0).astype(jnp.int32))) > 0
        out = jnp.where(touched[:, None, None, None], out, pool)
    return out
