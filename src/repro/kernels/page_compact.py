"""On-device base-page movement kernels: CAC compaction + host-tier paging.

``page_compact`` executes a compaction plan (pool-internal copies);
``page_gather``/``page_scatter`` are the device halves of demand paging
(DESIGN.md §6): gather packs the evicted pages of a preempted request into
a dense staging block the host reads back, scatter lands a fault batch's
payload at the faulted pages' physical locations.

CAC page-copy kernel: batched on-device base-page migration.

Executes a compaction plan's ``CopyOp`` list in one launch: grid over the
copy list; each step DMAs one base page pool[src[i]] → pool[dst[i]] through
VMEM, with both sides addressed via scalar-prefetched index maps.  Holes
(src/dst = -1) are rewritten to a *duplicate* of the first valid copy op —
duplicates are idempotent because CAC only copies live pages into free
slots (a src page is never also a dst page), so the kernel stays total
without the hole ever clobbering a real destination.  If every op is a
hole, the plan degenerates to copying page 0 onto itself, which is safe
precisely because then nothing else writes.

The paper models compaction as a whole-GPU stall (worst case); this kernel
is the real cost: len(plan) page-sized DMAs, overlappable between decode
steps.  ``benchmarks/kernel_bench.py`` measures it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _copy_kernel(src_ref, dst_ref, pool_in_ref, pool_out_ref):
    pool_out_ref[...] = pool_in_ref[...]


def page_compact(pool, src, dst, *, interpret: bool = True):
    """pool [NP, ptok, kv, dh]; src/dst int32 [n].  Returns updated pool.

    input_output_aliasing keeps this in-place on the real device: only the
    touched pages move.
    """
    n = src.shape[0]
    if n == 0:
        return pool
    NP = pool.shape[0]
    blk = (1, *pool.shape[1:])

    # Rewrite holes to duplicates of the first valid op (see module doc).
    valid = (src >= 0) & (dst >= 0)
    first = jnp.argmax(valid)                      # 0 when no valid op
    any_valid = jnp.any(valid)
    src = jnp.where(valid, src, jnp.where(any_valid, src[first], 0))
    dst = jnp.where(valid, dst, jnp.where(any_valid, dst[first], 0))

    def in_index(i, src, dst):
        return (src[i], *([0] * (len(blk) - 1)))

    def out_index(i, src, dst):
        return (dst[i], *([0] * (len(blk) - 1)))

    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n,),
            in_specs=[pl.BlockSpec(blk, in_index)],
            out_specs=pl.BlockSpec(blk, out_index),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(src, dst, pool)
    if interpret:
        # The interpreter does not emulate in-place aliasing of unwritten
        # output blocks; merge untouched pages back (TPU path skips this).
        touched = (jnp.zeros((NP,), jnp.int32).at[jnp.maximum(dst, 0)]
                   .add((dst >= 0).astype(jnp.int32))) > 0
        out = jnp.where(touched[:, None, None, None], out, pool)
    return out


def _gather_kernel(idx_ref, pool_in_ref, out_ref):
    out_ref[...] = pool_in_ref[...]


def page_gather(pool, idx, *, interpret: bool = True):
    """pool [NP, ptok, kv, dh]; idx int32 [n] → pages [n, ptok, kv, dh].

    One page-sized DMA per grid step, both sides scalar-prefetch-addressed;
    holes (idx = -1) read page 0 (caller masks them out).  The dense output
    block is what the host copies back over the I/O link at eviction time.
    """
    n = idx.shape[0]
    blk = (1, *pool.shape[1:])
    idx = jnp.maximum(idx, 0)

    def in_index(i, idx):
        return (idx[i], *([0] * (len(blk) - 1)))

    def out_index(i, idx):
        return (i, *([0] * (len(blk) - 1)))

    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[pl.BlockSpec(blk, in_index)],
            out_specs=pl.BlockSpec(blk, out_index),
        ),
        out_shape=jax.ShapeDtypeStruct((n, *pool.shape[1:]), pool.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx, pool)


def _scatter_kernel(idx_ref, row_ref, pages_ref, pool_in_ref, pool_out_ref):
    pool_out_ref[...] = pages_ref[...]


def page_scatter(pool, idx, pages, *, interpret: bool = True):
    """pool [NP, ...]; idx int32 [n]; pages [n, ...] → pool'.

    pool'[idx[i]] = pages[i].  Holes (idx = -1) are rewritten to duplicates
    of the first valid entry — idempotent because duplicates write the same
    payload to the same destination.  Aliased in-place on the real device.
    """
    n = idx.shape[0]
    if n == 0:
        return pool
    NP = pool.shape[0]
    blk = (1, *pool.shape[1:])

    valid = idx >= 0
    first = jnp.argmax(valid)
    any_valid = jnp.any(valid)
    safe_idx = jnp.where(valid, idx, jnp.where(any_valid, idx[first], 0))
    src_row = jnp.where(valid, jnp.arange(n),
                        jnp.where(any_valid, first, 0))

    def pages_index(i, safe_idx, src_row):
        return (src_row[i], *([0] * (len(blk) - 1)))

    def out_index(i, safe_idx, src_row):
        return (safe_idx[i], *([0] * (len(blk) - 1)))

    out = pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n,),
            # The aliased pool input still needs a spec; its block is the
            # destination page the kernel overwrites, never read.
            in_specs=[pl.BlockSpec(blk, pages_index),
                      pl.BlockSpec(blk, out_index)],
            out_specs=pl.BlockSpec(blk, out_index),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={3: 0},
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(safe_idx, src_row, pages, pool)
    if interpret:
        # Same interpreter aliasing caveat as page_compact.
        touched = (jnp.zeros((NP,), jnp.int32).at[jnp.maximum(idx, 0)]
                   .add(valid.astype(jnp.int32))) > 0
        extra = (1,) * (pool.ndim - 1)
        out = jnp.where(touched.reshape(-1, *extra), out, pool)
    else:
        # All-holes degenerate case: the rewrite above aimed every write at
        # page 0, which must then be restored (the oracle treats holes as
        # no-ops).  One-page fixup, traceable under jit.
        out = out.at[0].set(jnp.where(any_valid, out[0], pool[0]))
    return out
