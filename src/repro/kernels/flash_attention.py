"""Causal flash attention (train/prefill) — Pallas TPU kernel.

Standard two-level blocking: grid = (B·Hkv·g, Tq/bq, Tk/bk) with the KV
axis innermost ("arbitrary": sequential per core, accumulator in VMEM
scratch).  Causal blocks above the diagonal are skipped entirely via
``pl.when`` (the index map still loads, but no FLOPs are spent — on real
TPUs the Mosaic compiler elides the DMA for fully-masked blocks when the
bound is static; we keep the simple form).

Block sizes default to (bq, bk) = (128, 512): MXU-aligned, and the working
set per step — q 128×dh + k/v 2×512×dh + acc 128×dh fp32 — stays well under
VMEM for dh ≤ 256.

GQA is handled by flattening (B, Hkv) into the grid's batch axis and
carrying the g query heads of the group in the q block: q block is
[1, g·bq, dh] so group heads share the K/V DMA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  bq: int, bk: int, g: int, causal: bool, scale: float,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG_INF)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    q_start = qi * bq
    k_start = ki * bk
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale       # [g*bq, dh]
        k = k_ref[0].astype(jnp.float32)               # [bk, dh]
        v = v_ref[0].astype(jnp.float32)               # [bk, dh_v]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # [g*bq, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = kpos < kv_len
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (g * bq, 1), 0) % bq
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_s[...], s.max(axis=-1))
        alpha = jnp.exp(m_s[...] - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_s[...] = l_s[...] * alpha + p.sum(axis=-1)
        acc_s[...] = acc_s[...] * alpha[..., None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_s[...] /
                    jnp.maximum(l_s[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    bq: int = 128, bk: int = 512, interpret: bool = True):
    """q [B,T,H,dh]; k/v [B,T,Hkv,dh{,_v}] -> [B,T,H,dh_v].

    T must be a multiple of bq and bk (pad upstream; the model path pads).
    """
    B, T, H, dh = q.shape
    Hkv = k.shape[2]
    dh_v = v.shape[-1]
    g = H // Hkv
    bq = min(bq, T)
    bk = min(bk, T)
    assert T % bq == 0 and T % bk == 0, (T, bq, bk)
    scale = dh ** -0.5 if scale is None else scale

    # [B*Hkv, g*T, dh] layout: group heads ride along the q row-block.
    qr = (q.reshape(B, T, Hkv, g, dh).transpose(0, 2, 3, 1, 4)
          .reshape(B * Hkv, g * T, dh))
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, dh_v)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        return (bh, ki, 0)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, g=g, causal=causal, scale=scale,
        kv_len=T)
    # q block carries the g heads of the group: rows [g, bq] flattened.
    # We lay q as [B*Hkv, g*T, dh] with head-major rows, so the q block for
    # (qi) must gather g strided row-slices — instead use block = g*bq rows
    # at stride T: reorder to [B*Hkv, T/bq, g*bq, dh] host-side.
    qb = (qr.reshape(B * Hkv, g, T // bq, bq, dh).transpose(0, 2, 1, 3, 4)
          .reshape(B * Hkv, T // bq * g * bq, dh))

    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, T // bq, T // bk),
        in_specs=[
            pl.BlockSpec((1, g * bq, dh), q_index),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh_v), kv_index),
        ],
        out_specs=pl.BlockSpec((1, g * bq, dh_v), q_index),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, T // bq * g * bq, dh_v),
                                       q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq, dh_v), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qb, kr, vr)
    out = (out.reshape(B, Hkv, T // bq, g, bq, dh_v)
           .transpose(0, 2, 4, 1, 3, 5)
           .reshape(B, T, H, dh_v))
    return out
