"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (arXiv:2405.21060 §6).

Grid: (batch, heads, n_chunks) — batch/head blocks are parallel, the
chunk axis is sequential ("arbitrary") and carries the running
[head_dim, d_state] recurrent state in a VMEM scratch accumulator, so the
inter-chunk linear recurrence never round-trips HBM.  Per step the kernel
does three MXU matmuls on one chunk:

    scores = (C B^T) ⊙ exp(segsum)        [Q, Q]   (the "duality" term)
    y      = scores · (dt x) + (C h^T) ⊙ exp(cum)  [Q, hd]
    h'     = diag(exp(cum_last)) h + (dt x ⊙ decay)^T B   [hd, N]

Shapes are chosen MXU-friendly by the model (Q = chunk = 256, hd = 64,
N = 128).  ``repro.models.mamba2.ssd_chunked`` is the pure-JNP oracle;
``tests/test_kernels.py`` sweeps shapes/dtypes against it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hout_ref, h_acc):
    c_idx = pl.program_id(2)
    Q = x_ref.shape[1]

    @pl.when(c_idx == 0)
    def _init():
        h_acc[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [Q, hd]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    A = a_ref[0].astype(jnp.float32)                 # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]

    xdt = x * dt[:, None]
    cum = jnp.cumsum(dt * A)                         # [Q]
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Ldec = jnp.exp(jnp.where(ii >= jj, seg, NEG_INF))
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * Ldec   # [Q, Q]
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # Off-diagonal (carried-state) term.
    h = h_acc[...]                                   # [hd, N]
    y = y + jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # State update.
    decay = jnp.exp(cum[-1] - cum)                   # [Q]
    h_new = h * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xdt * decay[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h_acc[...] = h_new
    hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int, h0=None,
             interpret: bool = True):
    """Chunked SSD scan. x [B,T,nh,hd]; dt [B,T,nh] (post-softplus);
    A [nh] (negative); Bm/Cm [B,T,nh,N]; h0 [B,nh,hd,N] or None.
    Returns (y [B,T,nh,hd] f32, h_final [B,nh,hd,N] f32).
    """
    Bsz, T, nh, hd = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, hd, N), jnp.float32)

    grid = (Bsz, nh, nc)
    y, h_last = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, T, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nh, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, h0)
    return y, h_last
