"""Pure-JNP oracles for every Pallas kernel in this package.

The model code in :mod:`repro.models` *is* the production pure-JAX path
(used by the CPU dry-run); these wrappers expose the exact same math with
kernel-shaped signatures so tests can sweep shapes/dtypes and
``assert_allclose`` kernel vs. oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention as _attention
from repro.models.paged import (
    combine_partials,
    paged_attention_local,
)


def paged_attention_ref(q, pool_k, pool_v, tables, ntok, *, scale):
    """Unnormalized (o, m, l) over a set of pages — oracle for both
    granularities of :mod:`repro.kernels.paged_attention` (a frame is just
    its constituent pages)."""
    return paged_attention_local(q, pool_k, pool_v, tables, ntok,
                                 scale=scale)


def paged_attention_full_ref(q, pool_k, pool_v, tables, ntok, *, scale):
    """Normalized single-shard paged attention."""
    o, m, l = paged_attention_local(q, pool_k, pool_v, tables, ntok,
                                    scale=scale)
    return combine_partials(o, m, l, ())


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                        scale=None):
    """Oracle for the training flash-attention kernel."""
    return _attention(q, k, v, causal=causal, q_offset=q_offset,
                      kv_len=kv_len, scale=scale)


def ssd_scan_ref(x, dt, A, Bm, Cm, *, chunk, h0=None):
    """Oracle for the Mamba-2 SSD chunked-scan kernel."""
    from repro.models.mamba2 import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, chunk, h0=h0)


def page_gather_ref(pool, idx):
    """Oracle for the host-tier gather kernel: pages[i] = pool[idx[i]].

    Holes (idx == -1) return page 0 (callers mask them out).
    """
    return pool[jnp.maximum(idx, 0)]


def page_scatter_ref(pool, idx, pages):
    """Oracle for the host-tier scatter kernel: pool[idx[i]] = pages[i].

    Entries with idx == -1 are no-ops (scatter-dropped past the pool end).
    """
    d = jnp.where(idx >= 0, idx, pool.shape[0])
    padded = jnp.concatenate(
        [pool, jnp.zeros((1, *pool.shape[1:]), pool.dtype)], axis=0)
    return padded.at[d].set(pages.astype(pool.dtype))[:-1]


def page_compact_ref(pool, src, dst):
    """Oracle for the CAC page-copy kernel: pool[dst[i]] = pool[src[i]].

    Entries with src or dst == -1 are no-ops.
    """
    valid = (src >= 0) & (dst >= 0)
    s = jnp.maximum(src, 0)
    d = jnp.where(valid, dst, pool.shape[0])      # scatter-drop for holes
    moved = pool[s]
    padded = jnp.concatenate(
        [pool, jnp.zeros((1, *pool.shape[1:]), pool.dtype)], axis=0)
    out = padded.at[d].set(moved)
    return out[:-1]


class _ScratchCell:
    """Minimal stand-in for a pallas scratch ref: `cell[...]` reads the
    held array, `cell[...] = x` replaces it.  Lets the reference reuse
    the kernel module's `_flash_step` verbatim so the fused reference is
    op-for-op (and therefore bitwise) identical to interpret mode."""

    def __init__(self):
        self.val = None

    def __getitem__(self, _):
        return self.val

    def __setitem__(self, _, v):
        self.val = v


def fused_gather_attend_ref(q, pool_k, pool_v, stage_k, stage_v,
                            tables, slots, ntok, *, scale):
    """Oracle for the fused gather-attend kernel (DESIGN.md §13).

    Mirrors `_fused_kernel` exactly: per batch row, walk blocks in
    canonical order, folding pool-resident pages (slot == -1) into the
    *ready* accumulator and staged pages (slot >= 0) into the *late*
    accumulator via the same `_flash_step`, then combine the two in
    fixed (ready, late) order.  Returns unnormalized (o, m, l).
    """
    from repro.kernels.paged_attention import _flash_step

    B, H, dh = q.shape
    _np, ptok, n_kv, _ = pool_k.shape
    dh_v = pool_v.shape[-1]
    g = H // n_kv
    nblk = tables.shape[1]
    os, ms, ls = [], [], []
    for b in range(B):
        qb = q[b].reshape(n_kv, g, dh).astype(jnp.float32) * scale
        acc = {False: None, True: None}      # late? -> (m, l, o) cells
        for blk in range(nblk):
            late = bool(slots[b, blk] >= 0)
            if late:
                k = stage_k[max(int(slots[b, blk]), 0)]
                v = stage_v[max(int(slots[b, blk]), 0)]
            else:
                k = pool_k[max(int(tables[b, blk]), 0)]
                v = pool_v[max(int(tables[b, blk]), 0)]
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
            nt = int(ntok[b, blk])
            valid = jnp.arange(ptok, dtype=jnp.int32) < nt
            first = acc[late] is None
            if first:
                acc[late] = (_ScratchCell(), _ScratchCell(), _ScratchCell())
            m_s, l_s, o_s = acc[late]
            _flash_step(qb, k, v, valid, m_s, l_s, o_s, first=first)
        if acc[True] is None:                # all-ready fast path
            m_s, l_s, o_s = acc[False]
            o_b, m_b, l_b = o_s.val, m_s.val, l_s.val
        elif acc[False] is None:             # nothing resident
            m_s, l_s, o_s = acc[True]
            o_b, m_b, l_b = o_s.val, m_s.val, l_s.val
        else:                                # fixed-order combine
            m_r, l_r, o_r = (c.val for c in acc[False])
            m_t, l_t, o_t = (c.val for c in acc[True])
            m_b = jnp.maximum(m_r, m_t)
            a_r = jnp.exp(m_r - m_b)
            a_t = jnp.exp(m_t - m_b)
            o_b = o_r * a_r[..., None] + o_t * a_t[..., None]
            l_b = l_r * a_r + l_t * a_t
        os.append(o_b.reshape(H, dh_v))
        ms.append(m_b.reshape(H))
        ls.append(l_b.reshape(H))
    return jnp.stack(os), jnp.stack(ms), jnp.stack(ls)
