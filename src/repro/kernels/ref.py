"""Pure-JNP oracles for every Pallas kernel in this package.

The model code in :mod:`repro.models` *is* the production pure-JAX path
(used by the CPU dry-run); these wrappers expose the exact same math with
kernel-shaped signatures so tests can sweep shapes/dtypes and
``assert_allclose`` kernel vs. oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention as _attention
from repro.models.paged import (
    combine_partials,
    paged_attention_local,
)


def paged_attention_ref(q, pool_k, pool_v, tables, ntok, *, scale):
    """Unnormalized (o, m, l) over a set of pages — oracle for both
    granularities of :mod:`repro.kernels.paged_attention` (a frame is just
    its constituent pages)."""
    return paged_attention_local(q, pool_k, pool_v, tables, ntok,
                                 scale=scale)


def paged_attention_full_ref(q, pool_k, pool_v, tables, ntok, *, scale):
    """Normalized single-shard paged attention."""
    o, m, l = paged_attention_local(q, pool_k, pool_v, tables, ntok,
                                    scale=scale)
    return combine_partials(o, m, l, ())


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                        scale=None):
    """Oracle for the training flash-attention kernel."""
    return _attention(q, k, v, causal=causal, q_offset=q_offset,
                      kv_len=kv_len, scale=scale)


def ssd_scan_ref(x, dt, A, Bm, Cm, *, chunk, h0=None):
    """Oracle for the Mamba-2 SSD chunked-scan kernel."""
    from repro.models.mamba2 import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, chunk, h0=h0)


def page_gather_ref(pool, idx):
    """Oracle for the host-tier gather kernel: pages[i] = pool[idx[i]].

    Holes (idx == -1) return page 0 (callers mask them out).
    """
    return pool[jnp.maximum(idx, 0)]


def page_scatter_ref(pool, idx, pages):
    """Oracle for the host-tier scatter kernel: pool[idx[i]] = pages[i].

    Entries with idx == -1 are no-ops (scatter-dropped past the pool end).
    """
    d = jnp.where(idx >= 0, idx, pool.shape[0])
    padded = jnp.concatenate(
        [pool, jnp.zeros((1, *pool.shape[1:]), pool.dtype)], axis=0)
    return padded.at[d].set(pages.astype(pool.dtype))[:-1]


def page_compact_ref(pool, src, dst):
    """Oracle for the CAC page-copy kernel: pool[dst[i]] = pool[src[i]].

    Entries with src or dst == -1 are no-ops.
    """
    valid = (src >= 0) & (dst >= 0)
    s = jnp.maximum(src, 0)
    d = jnp.where(valid, dst, pool.shape[0])      # scatter-drop for holes
    moved = pool[s]
    padded = jnp.concatenate(
        [pool, jnp.zeros((1, *pool.shape[1:]), pool.dtype)], axis=0)
    out = padded.at[d].set(moved)
    return out[:-1]
