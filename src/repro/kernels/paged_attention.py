"""Dual-granularity paged decode attention — the Mosaic "hardware" half.

Two Pallas TPU kernels share one flash-accumulator structure; both emit
*unnormalized* (o, m, l) partials so their results can be flash-combined
with each other and across page shards:

  * ``frames`` kernel — the **coalesced fast path** (paper: the 2MB TLB
    entry).  A coalesced large frame is ``frame_pages`` physically
    contiguous, aligned base pages, so the whole frame streams HBM→VMEM as
    ONE BlockSpec block per grid step via ONE scalar-prefetched index
    (frame table).  16× fewer table lookups and long contiguous DMAs.

  * ``pages`` kernel — the **splintered path** (the 4KB base-page walk).
    One base page per grid step, one table lookup per page, short
    scattered DMAs.  This is what 100% of traffic pays under the
    GPU-MMU baseline; under Mosaic only the un-coalesced tail pays it.

Both use ``PrefetchScalarGridSpec`` so the page/frame table drives the
BlockSpec ``index_map`` — the TPU-native analogue of the paper's
hardware page-table walk: translation happens in the DMA descriptor
stream, and its *cost* is the number of descriptors (table entries)
consumed per KV byte.

Grid: (batch, n_blocks) with the KV axis iterated sequentially
("arbitrary") per sequence; the flash accumulator lives in VMEM scratch
and is flushed on the last block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_step(q, k, v, valid, m_s, l_s, o_s, *, first: bool):
    """One flash-accumulation step over a KV slab.

    q [kv, g, dh]; k [t, kv, dh]; v [t, kv, dh_v]; valid [t] bool.
    Scratch m_s/l_s [kv, g]; o_s [kv, g, dh_v]  (all fp32).
    """
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)            # [kv, g, t]
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m_blk = s.max(axis=-1)
    if first:
        m_new = m_blk
        alpha = jnp.zeros_like(m_blk)                  # kill stale scratch
    else:
        m_new = jnp.maximum(m_s[...], m_blk)
        alpha = jnp.exp(m_s[...] - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid[None, None, :], p, 0.0)
    l_new = (0.0 if first else l_s[...] * alpha) + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)            # [kv, g, dh_v]
    o_new = (0.0 if first else o_s[...] * alpha[..., None]) + pv
    m_s[...] = m_new
    l_s[...] = l_new
    o_s[...] = o_new


def _paged_kernel(tables_ref, ntok_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, m_s, l_s, o_s, *,
                  tokens_per_block: int, scale: float):
    """Shared body for both granularities.

    Block shapes (leading batch block of 1 squeezed by indexing):
      q_ref [1, kv, g, dh]; k_ref [1, T, kv, dh]; v_ref [1, T, kv, dh_v]
      (T = tokens_per_block: one page or one whole frame)
      outputs: o_ref [1, kv, g, dh_v]; m_ref/l_ref [1, kv, g]
    """
    blk = pl.program_id(1)
    nblk = pl.num_programs(1)
    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    nt = ntok_ref[pl.program_id(0), blk]
    valid = jax.lax.broadcasted_iota(
        jnp.int32, (tokens_per_block,), 0) < nt

    @pl.when(blk == 0)
    def _init():
        _flash_step(q, k, v, valid, m_s, l_s, o_s, first=True)

    @pl.when(blk != 0)
    def _acc():
        _flash_step(q, k, v, valid, m_s, l_s, o_s, first=False)

    @pl.when(blk == nblk - 1)
    def _flush():
        o_ref[0] = o_s[...]
        m_ref[0] = m_s[...]
        l_ref[0] = l_s[...]


def paged_attention_kernel(
    q, pool_k, pool_v, tables, ntok, *,
    granularity: str,            # 'page' | 'frame'
    frame_pages: int = 16,
    scale: float = 1.0,
    interpret: bool = True,
):
    """Launch one granularity's kernel.

    q [B, H, dh]; pool_k/v [NP, ptok, kv, dh{,_v}];
    tables [B, n_blocks] (page ids or frame ids; -1 holes);
    ntok [B, n_blocks] valid tokens per block.
    Returns unnormalized (o [B,H,dh_v] f32, m [B,H] f32, l [B,H] f32).
    """
    B, H, dh = q.shape
    NP, ptok, n_kv, _ = pool_k.shape
    dh_v = pool_v.shape[-1]
    g = H // n_kv
    nblocks = tables.shape[1]
    if granularity == "frame":
        pages_per_block = frame_pages
    else:
        pages_per_block = 1
    tpb = pages_per_block * ptok

    # View pools as [NP // pages_per_block, tpb, kv, dh]: one block = one
    # page or one aligned frame (contiguous slab — the Mosaic fast path).
    pk = pool_k.reshape(NP // pages_per_block, tpb, n_kv, dh)
    pv = pool_v.reshape(NP // pages_per_block, tpb, n_kv, dh_v)
    qg = q.reshape(B, n_kv, g, dh)

    def q_index(b, blk, tables, ntok):
        return (b, 0, 0, 0)

    def kv_index(b, blk, tables, ntok):
        return (jnp.maximum(tables[b, blk], 0), 0, 0, 0)

    def out_index(b, blk, tables, ntok):
        return (b, 0, 0)

    def out_index4(b, blk, tables, ntok):
        return (b, 0, 0, 0)

    grid = (B, nblocks)
    kernel = functools.partial(
        _paged_kernel, tokens_per_block=tpb, scale=scale)
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, n_kv, g, dh), q_index),
                pl.BlockSpec((1, tpb, n_kv, dh), kv_index),
                pl.BlockSpec((1, tpb, n_kv, dh_v), kv_index),
            ],
            out_specs=[
                pl.BlockSpec((1, n_kv, g, dh_v), out_index4),
                pl.BlockSpec((1, n_kv, g), out_index),
                pl.BlockSpec((1, n_kv, g), out_index),
            ],
            scratch_shapes=[
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g, dh_v), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, g, dh_v), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, g), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, ntok, qg, pk, pv)
    return (o.reshape(B, H, dh_v), m.reshape(B, H), l.reshape(B, H))


def combine_granularities(parts):
    """Flash-combine [(o, m, l), ...] partials from both kernels."""
    os, ms, ls = zip(*parts)
    m_g = functools.reduce(jnp.maximum, ms)
    l_g = sum(l * jnp.exp(m - m_g) for m, l in zip(ms, ls))
    o_g = sum(o * jnp.exp(m - m_g)[..., None] for m, o in zip(ms, os))
    return o_g, m_g, l_g
