"""Dual-granularity paged decode attention — the Mosaic "hardware" half.

Two Pallas TPU kernels share one flash-accumulator structure; both emit
*unnormalized* (o, m, l) partials so their results can be flash-combined
with each other and across page shards:

  * ``frames`` kernel — the **coalesced fast path** (paper: the 2MB TLB
    entry).  A coalesced large frame is ``frame_pages`` physically
    contiguous, aligned base pages, so the whole frame streams HBM→VMEM as
    ONE BlockSpec block per grid step via ONE scalar-prefetched index
    (frame table).  16× fewer table lookups and long contiguous DMAs.

  * ``pages`` kernel — the **splintered path** (the 4KB base-page walk).
    One base page per grid step, one table lookup per page, short
    scattered DMAs.  This is what 100% of traffic pays under the
    GPU-MMU baseline; under Mosaic only the un-coalesced tail pays it.

Both use ``PrefetchScalarGridSpec`` so the page/frame table drives the
BlockSpec ``index_map`` — the TPU-native analogue of the paper's
hardware page-table walk: translation happens in the DMA descriptor
stream, and its *cost* is the number of descriptors (table entries)
consumed per KV byte.

Grid: (batch, n_blocks) with the KV axis iterated sequentially
("arbitrary") per sequence; the flash accumulator lives in VMEM scratch
and is flushed on the last block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_step(q, k, v, valid, m_s, l_s, o_s, *, first: bool):
    """One flash-accumulation step over a KV slab.

    q [kv, g, dh]; k [t, kv, dh]; v [t, kv, dh_v]; valid [t] bool.
    Scratch m_s/l_s [kv, g]; o_s [kv, g, dh_v]  (all fp32).
    """
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)            # [kv, g, t]
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m_blk = s.max(axis=-1)
    if first:
        m_new = m_blk
        alpha = jnp.zeros_like(m_blk)                  # kill stale scratch
    else:
        m_new = jnp.maximum(m_s[...], m_blk)
        alpha = jnp.exp(m_s[...] - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid[None, None, :], p, 0.0)
    l_new = (0.0 if first else l_s[...] * alpha) + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)            # [kv, g, dh_v]
    o_new = (0.0 if first else o_s[...] * alpha[..., None]) + pv
    m_s[...] = m_new
    l_s[...] = l_new
    o_s[...] = o_new


def _paged_kernel(tables_ref, ntok_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, m_s, l_s, o_s, *,
                  tokens_per_block: int, scale: float):
    """Shared body for both granularities.

    Block shapes (leading batch block of 1 squeezed by indexing):
      q_ref [1, kv, g, dh]; k_ref [1, T, kv, dh]; v_ref [1, T, kv, dh_v]
      (T = tokens_per_block: one page or one whole frame)
      outputs: o_ref [1, kv, g, dh_v]; m_ref/l_ref [1, kv, g]
    """
    blk = pl.program_id(1)
    nblk = pl.num_programs(1)
    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    nt = ntok_ref[pl.program_id(0), blk]
    valid = jax.lax.broadcasted_iota(
        jnp.int32, (tokens_per_block,), 0) < nt

    @pl.when(blk == 0)
    def _init():
        _flash_step(q, k, v, valid, m_s, l_s, o_s, first=True)

    @pl.when(blk != 0)
    def _acc():
        _flash_step(q, k, v, valid, m_s, l_s, o_s, first=False)

    @pl.when(blk == nblk - 1)
    def _flush():
        o_ref[0] = o_s[...]
        m_ref[0] = m_s[...]
        l_ref[0] = l_s[...]


def paged_attention_kernel(
    q, pool_k, pool_v, tables, ntok, *,
    granularity: str,            # 'page' | 'frame'
    frame_pages: int = 16,
    scale: float = 1.0,
    interpret: bool = True,
):
    """Launch one granularity's kernel.

    q [B, H, dh]; pool_k/v [NP, ptok, kv, dh{,_v}];
    tables [B, n_blocks] (page ids or frame ids; -1 holes);
    ntok [B, n_blocks] valid tokens per block.
    Returns unnormalized (o [B,H,dh_v] f32, m [B,H] f32, l [B,H] f32).
    """
    B, H, dh = q.shape
    NP, ptok, n_kv, _ = pool_k.shape
    dh_v = pool_v.shape[-1]
    g = H // n_kv
    nblocks = tables.shape[1]
    if granularity == "frame":
        pages_per_block = frame_pages
    else:
        pages_per_block = 1
    tpb = pages_per_block * ptok

    # View pools as [NP // pages_per_block, tpb, kv, dh]: one block = one
    # page or one aligned frame (contiguous slab — the Mosaic fast path).
    pk = pool_k.reshape(NP // pages_per_block, tpb, n_kv, dh)
    pv = pool_v.reshape(NP // pages_per_block, tpb, n_kv, dh_v)
    qg = q.reshape(B, n_kv, g, dh)

    def q_index(b, blk, tables, ntok):
        return (b, 0, 0, 0)

    def kv_index(b, blk, tables, ntok):
        return (jnp.maximum(tables[b, blk], 0), 0, 0, 0)

    def out_index(b, blk, tables, ntok):
        return (b, 0, 0)

    def out_index4(b, blk, tables, ntok):
        return (b, 0, 0, 0)

    grid = (B, nblocks)
    kernel = functools.partial(
        _paged_kernel, tokens_per_block=tpb, scale=scale)
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, n_kv, g, dh), q_index),
                pl.BlockSpec((1, tpb, n_kv, dh), kv_index),
                pl.BlockSpec((1, tpb, n_kv, dh_v), kv_index),
            ],
            out_specs=[
                pl.BlockSpec((1, n_kv, g, dh_v), out_index4),
                pl.BlockSpec((1, n_kv, g), out_index),
                pl.BlockSpec((1, n_kv, g), out_index),
            ],
            scratch_shapes=[
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g, dh_v), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, g, dh_v), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, g), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, ntok, qg, pk, pv)
    return (o.reshape(B, H, dh_v), m.reshape(B, H), l.reshape(B, H))


def combine_granularities(parts):
    """Flash-combine [(o, m, l), ...] partials from both kernels."""
    os, ms, ls = zip(*parts)
    m_g = functools.reduce(jnp.maximum, ms)
    l_g = sum(l * jnp.exp(m - m_g) for m, l in zip(ms, ls))
    o_g = sum(o * jnp.exp(m - m_g)[..., None] for m, o in zip(ms, os))
    return o_g, m_g, l_g


# -------------------------------------------------------- fused gather-attend


def _fused_kernel(tables_ref, ntok_ref, slots_ref, meta_ref,
                  q_ref, kp_ref, vp_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref,
                  m_r, l_r, o_r, m_t, l_t, o_t, *,
                  tokens_per_block: int, scale: float):
    """Fused gather-attend body (DESIGN.md §13).

    Each grid step reads one page from EITHER the resident pool (slot ==
    -1, via the page table) OR the staging region (slot >= 0, via the
    slot table) — arriving pages are consumed where the DMA landed them,
    no second copy.  Two flash accumulators run in canonical block
    order: the *ready* set (pool-resident pages) and the *late* set
    (staging-slot pages); the flush combines them in fixed (ready, late)
    order.  With every page ready this executes exactly the baseline
    ``_paged_kernel`` accumulate sequence — bitwise-equal fast path —
    and once all pages have landed the staged bytes equal what a
    gather-then-scatter would have written, so the fused result matches
    gather-then-attend.

    ``meta_ref[b] = (n_late, first_ready, first_late)`` (first_* = -1
    when that set is empty) tells each row which block initializes which
    accumulator and which flush case applies.
    """
    b = pl.program_id(0)
    blk = pl.program_id(1)
    nblk = pl.num_programs(1)
    late = slots_ref[b, blk] >= 0
    q = q_ref[0].astype(jnp.float32) * scale
    k = jnp.where(late, ks_ref[0].astype(jnp.float32),
                  kp_ref[0].astype(jnp.float32))
    v = jnp.where(late, vs_ref[0].astype(jnp.float32),
                  vp_ref[0].astype(jnp.float32))
    nt = ntok_ref[b, blk]
    valid = jax.lax.broadcasted_iota(
        jnp.int32, (tokens_per_block,), 0) < nt
    n_late = meta_ref[b, 0]
    first_ready = meta_ref[b, 1]
    first_late = meta_ref[b, 2]
    ready = jnp.logical_not(late)

    @pl.when(ready & (blk == first_ready))
    def _init_ready():
        _flash_step(q, k, v, valid, m_r, l_r, o_r, first=True)

    @pl.when(ready & (blk != first_ready))
    def _acc_ready():
        _flash_step(q, k, v, valid, m_r, l_r, o_r, first=False)

    @pl.when(late & (blk == first_late))
    def _init_late():
        _flash_step(q, k, v, valid, m_t, l_t, o_t, first=True)

    @pl.when(late & (blk != first_late))
    def _acc_late():
        _flash_step(q, k, v, valid, m_t, l_t, o_t, first=False)

    last = blk == nblk - 1

    @pl.when(last & (n_late == 0))
    def _flush_all_ready():
        # Late scratch was never written: emit the ready accumulator
        # untouched — bit-for-bit the baseline kernel's flush.
        o_ref[0] = o_r[...]
        m_ref[0] = m_r[...]
        l_ref[0] = l_r[...]

    @pl.when(last & (n_late == nblk))
    def _flush_all_late():
        o_ref[0] = o_t[...]
        m_ref[0] = m_t[...]
        l_ref[0] = l_t[...]

    @pl.when(last & (n_late > 0) & (n_late < nblk))
    def _flush_combined():
        m_g = jnp.maximum(m_r[...], m_t[...])
        a_r = jnp.exp(m_r[...] - m_g)
        a_t = jnp.exp(m_t[...] - m_g)
        o_ref[0] = o_r[...] * a_r[..., None] + o_t[...] * a_t[..., None]
        m_ref[0] = m_g
        l_ref[0] = l_r[...] * a_r + l_t[...] * a_t


def readiness_meta(slots):
    """Per-row readiness summary for the fused kernel's scalar prefetch:
    ``[B, 3]`` int32 of (n_late, first_ready, first_late), where first_*
    is the lowest block index in that set or -1 when the set is empty."""
    late = slots >= 0
    ready = jnp.logical_not(late)
    n_late = late.sum(axis=1).astype(jnp.int32)
    first_late = jnp.where(late.any(axis=1),
                           jnp.argmax(late, axis=1), -1).astype(jnp.int32)
    first_ready = jnp.where(ready.any(axis=1),
                            jnp.argmax(ready, axis=1), -1).astype(jnp.int32)
    return jnp.stack([n_late, first_ready, first_late], axis=1)


def fused_paged_attention_kernel(
    q, pool_k, pool_v, stage_k, stage_v, tables, slots, ntok, *,
    scale: float = 1.0,
    interpret: bool = True,
):
    """Decode attention over partially-resident KV (DESIGN.md §13).

    q [B, H, dh]; pool_k/v [NP, ptok, kv, dh{,_v}] the resident pool;
    stage_k/v [NS, ptok, kv, dh{,_v}] the staging region late arrivals
    landed in; tables [B, n_blocks] pool page ids (-1 holes);
    slots [B, n_blocks] staging slot per page (-1 = read the pool —
    the per-page readiness mask); ntok [B, n_blocks].
    Returns unnormalized (o, m, l) like :func:`paged_attention_kernel`;
    page granularity (staging slots are page-sized).
    """
    B, H, dh = q.shape
    NP, ptok, n_kv, _ = pool_k.shape
    dh_v = pool_v.shape[-1]
    g = H // n_kv
    nblocks = tables.shape[1]
    if stage_k.shape[0] == 0:       # all-resident caller: keep specs legal
        stage_k = jnp.zeros((1, ptok, n_kv, dh), pool_k.dtype)
        stage_v = jnp.zeros((1, ptok, n_kv, dh_v), pool_v.dtype)
    qg = q.reshape(B, n_kv, g, dh)
    meta = readiness_meta(slots)

    def q_index(b, blk, tables, ntok, slots, meta):
        return (b, 0, 0, 0)

    def kv_pool_index(b, blk, tables, ntok, slots, meta):
        return (jnp.maximum(tables[b, blk], 0), 0, 0, 0)

    def kv_stage_index(b, blk, tables, ntok, slots, meta):
        return (jnp.maximum(slots[b, blk], 0), 0, 0, 0)

    def out_index(b, blk, tables, ntok, slots, meta):
        return (b, 0, 0)

    def out_index4(b, blk, tables, ntok, slots, meta):
        return (b, 0, 0, 0)

    kernel = functools.partial(
        _fused_kernel, tokens_per_block=ptok, scale=scale)
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(B, nblocks),
            in_specs=[
                pl.BlockSpec((1, n_kv, g, dh), q_index),
                pl.BlockSpec((1, ptok, n_kv, dh), kv_pool_index),
                pl.BlockSpec((1, ptok, n_kv, dh_v), kv_pool_index),
                pl.BlockSpec((1, ptok, n_kv, dh), kv_stage_index),
                pl.BlockSpec((1, ptok, n_kv, dh_v), kv_stage_index),
            ],
            out_specs=[
                pl.BlockSpec((1, n_kv, g, dh_v), out_index4),
                pl.BlockSpec((1, n_kv, g), out_index),
                pl.BlockSpec((1, n_kv, g), out_index),
            ],
            scratch_shapes=[
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g, dh_v), jnp.float32),
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g), jnp.float32),
                pltpu.VMEM((n_kv, g, dh_v), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, g, dh_v), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, g), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, ntok, slots, meta, qg, pool_k, pool_v, stage_k, stage_v)
    return (o.reshape(B, H, dh_v), m.reshape(B, H), l.reshape(B, H))
