"""Pallas TPU kernels for the paper's compute hot-spots.

One module per kernel (``paged_attention``, ``page_compact``,
``flash_attention``, ``ssd_scan``) plus ``ops.py`` — the dispatch layer
the engine calls (``use_pallas`` flips Pallas vs the pure-JAX oracles in
``ref.py``).  Kernels exist ONLY for hot-spots the paper itself
optimizes; everything else stays plain jax.
"""
