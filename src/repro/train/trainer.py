"""Training engine: pjit train_step builder + fault-tolerant loop.

``make_train_step`` assembles the jitted step for a given (LM, mesh, hp):
  * gradient accumulation (``hp.microbatch``) via ``lax.scan`` over
    microbatches (sequential, activation memory = one microbatch);
  * per-block remat (``hp.remat``);
  * ZeRO-1: optimizer moments sharded with data-extended specs — XLA
    inserts the reduce-scatter / all-gather pair around the update;
  * optional int8 ring all-reduce of gradients with error feedback
    (``hp.grad_compress``) via shard_map over the data axes;
  * donation of params/opt state (in-place update at scale).

``Trainer`` runs the loop with checkpoint/restart (atomic, elastic),
SIGTERM-safe preemption handling, and step-time stats.
"""

from __future__ import annotations

import signal
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.compat import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, TrainHParams
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.sharding import fsdp_specs, param_specs, zero1_specs
from repro.models.common import batch_axes, set_batch_axes
from repro.models.lm import LM
from repro.train import checkpoint as ckpt
from repro.train.grad_compress import (
    BLOCK,
    compressed_allreduce_flat,
    pad_to_block,
)
from repro.train.optimizer import adamw_init, adamw_update


def configure_parallelism(hp: TrainHParams) -> None:
    """Set batch-axes + TP-mode contexts for this run.

    'megatron': explicit shard_map TP blocks (bf16 psums).
    'auto':     GSPMD auto-sharding from shd() hints (the naive baseline
                kept for §Perf before/after).
    'fsdp':     every axis data-parallel, ZeRO-3 weight streaming.
    """
    from repro.models.common import set_tp_mode
    set_batch_axes(("pod", "data", "model") if hp.parallelism == "fsdp"
                   else ("pod", "data"))
    set_tp_mode("auto" if hp.parallelism == "auto" else "explicit")


def batch_spec(mesh) -> P:
    dp = tuple(a for a in batch_axes() if a in mesh.axis_names)
    return P(dp if dp else None)


def state_specs(params, hp: TrainHParams, mesh):
    """(param specs, optimizer-moment specs) for the chosen parallelism."""
    if hp.parallelism == "fsdp":
        f = fsdp_specs(params, mesh)
        return f, f
    return zero1_specs(params, mesh), zero1_specs(params, mesh)


def _accum_grads(loss_fn, params, batch, n_micro: int, accum_specs=None):
    """Gradient accumulation over ``n_micro`` sequential microbatches.

    ``accum_specs``: sharding for the running gradient sum.  Must NOT be
    data-extended (ZeRO-1) — that would force a cross-data reduce-scatter
    *per microbatch*; with TP-only specs each iteration adds local
    partial grads and the data reduction happens once, at the optimizer
    (EXPERIMENTS.md §Perf iteration 5).
    """
    B = batch["tokens"].shape[0]
    assert B % n_micro == 0
    mb = B // n_micro
    sliced = {k: v.reshape(n_micro, mb, *v.shape[1:])
              for k, v in batch.items()}

    def pin(tree):
        if accum_specs is None:
            return tree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            tree, accum_specs, is_leaf=lambda x: not isinstance(
                x, (dict, list, tuple)))

    def body(carry, micro):
        gsum, lsum = carry
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, micro)
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        return (pin(gsum), lsum + loss), aux

    g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params))
    (gsum, lsum), auxs = jax.lax.scan(body, (g0, jnp.float32(0.0)), sliced)
    g = jax.tree.map(lambda a: a / n_micro, gsum)
    return lsum / n_micro, g, jax.tree.map(lambda a: a[-1], auxs)


def make_train_step(lm: LM, hp: TrainHParams, mesh):
    """Returns (step_fn, init_fn, shardings dict)."""
    configure_parallelism(hp)
    remat_arg = {"none": False, "block": True}.get(hp.remat, hp.remat)

    # Compute-layout pins (EXPERIMENTS.md §Perf MoE iterations 2-3):
    #  * params are cast to bf16 ONCE per step, pinned to the TP-only
    #    layout — the ZeRO'd master copy is then gathered over data a
    #    single time outside the layer scan instead of per layer (and
    #    again per remat recompute);
    #  * grads are pinned to the ZeRO layout so the data-axis reduction
    #    lowers as a reduce-scatter (half the wire of the all-reduce XLA
    #    otherwise picks).
    compute_shardings = grad_shardings = None
    if mesh is not None and hp.parallelism != "fsdp":
        from jax.sharding import NamedSharding as _NS
        abs_params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
        compute_shardings = jax.tree.map(
            lambda s: _NS(mesh, s), param_specs(abs_params, mesh),
            is_leaf=lambda x: isinstance(x, P))
        grad_shardings = jax.tree.map(
            lambda s: _NS(mesh, s), zero1_specs(abs_params, mesh),
            is_leaf=lambda x: isinstance(x, P))

    def loss_fn(params, batch):
        if compute_shardings is not None:
            from repro.models.common import cast as _cast
            params = _cast(params, jnp.dtype(lm.cfg.dtype))
            params = jax.tree.map(jax.lax.with_sharding_constraint,
                                  params, compute_shardings)
        return lm.loss(params, batch, remat=remat_arg)

    dp_axes = tuple(a for a in batch_axes() if a in mesh.axis_names)

    # NOTE: pinning the accumulator to TP-only specs was measured WORSE
    # (a replicated-over-data constraint all-reduces every microbatch's
    # grads; GSPMD cannot carry pending-reduction partials across scan
    # iterations) — see §Perf iteration 5 (refuted).  The accumulator
    # inherits the optimizer sharding; prefer microbatch=0 when HBM
    # allows.
    def train_step(params, opt_state, ef, batch):
        if hp.microbatch and hp.microbatch > 1:
            loss, grads, aux = _accum_grads(loss_fn, params, batch,
                                            hp.microbatch)
        else:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if hp.grad_compress and dp_axes and ef is not None:
            grads, ef = _compress_grads(grads, ef, mesh, dp_axes)
        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, grad_shardings)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, hp)
        metrics = {"loss": loss, **{k: aux[k] for k in ("nll", "aux")},
                   **om}
        return new_params, new_opt, ef, metrics

    def init_fn(key):
        params = lm.init(key)
        opt = adamw_init(params)
        ef = None
        if hp.grad_compress and dp_axes:
            from repro.train.grad_compress import padded_size
            n_dev = 1
            for a in dp_axes:
                n_dev *= mesh.shape[a]
            ef = jax.tree.map(
                lambda p: jnp.zeros((padded_size(p.size, n_dev),),
                                    jnp.float32), params)
        return params, opt, ef

    return train_step, init_fn


def _compress_grads(grads, ef, mesh, dp_axes):
    """int8 ring all-reduce over the data axes, per leaf, error feedback."""
    axis = dp_axes[0] if len(dp_axes) == 1 else dp_axes
    n_dev = 1
    for a in dp_axes:
        n_dev *= mesh.shape[a]

    def one(g, e):
        flat, n = pad_to_block(g.astype(jnp.float32), BLOCK * n_dev)

        def local(fl, el):
            red, e_new = compressed_allreduce_flat(
                fl, el, axis if isinstance(axis, str) else axis[0])
            return red, e_new

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False)
        red, e_new = fn(flat, e)
        return red[:n].reshape(g.shape), e_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


class Trainer:
    """Fault-tolerant training loop.

    * checkpoint every ``ckpt_every`` steps (atomic; pruned to 3);
    * SIGTERM/SIGINT → finish current step, checkpoint, exit cleanly
      (preemption handling for spot/maintenance events);
    * restart: ``Trainer(..., resume=True)`` restores the newest complete
      checkpoint, re-sharding onto the current mesh (elastic).
    """

    def __init__(self, cfg: ModelConfig, hp: TrainHParams, mesh,
                 batch_per_step: int, seq_len: int,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 resume: bool = False, seed: int = 0):
        self.cfg, self.hp, self.mesh = cfg, hp, mesh
        self.lm = LM(cfg)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self._preempted = False
        self.data = SyntheticLM(cfg.vocab_size, seq_len, batch_per_step,
                                seed=seed)

        step_fn, init_fn = make_train_step(self.lm, hp, mesh)
        with compat.set_mesh(mesh):
            params, opt, ef = init_fn(jax.random.PRNGKey(seed))
            pspec, mspec = state_specs(params, hp, mesh)
            ospec = {"step": P(), "mu": mspec, "nu": mspec}
            pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
            oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospec,
                                  is_leaf=lambda x: isinstance(x, P))
            self.params = jax.device_put(params, pshard)
            self.opt = jax.device_put(opt, oshard)
            self.ef = ef
            bs = NamedSharding(mesh, batch_spec(mesh))
            self._bs = bs
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, None, bs),
                out_shardings=(pshard, oshard, None, None),
                donate_argnums=(0, 1),
            )
        self.start_step = 0
        if resume and ckpt_dir:
            s = ckpt.latest_step(ckpt_dir)
            if s is not None:
                state, extra = ckpt.restore(
                    ckpt_dir, s, {"params": self.params, "opt": self.opt},
                    shardings={"params": pshard, "opt": oshard})
                self.params, self.opt = state["params"], state["opt"]
                self.start_step = s
        signal.signal(signal.SIGTERM, self._on_preempt)

    def _on_preempt(self, *_):
        self._preempted = True

    def save(self, step: int):
        if self.ckpt_dir:
            ckpt.save(self.ckpt_dir, step,
                      {"params": self.params, "opt": self.opt},
                      extra={"data": self.data.state(step)})
            ckpt.prune(self.ckpt_dir)

    def run(self, n_steps: int, log_every: int = 10):
        history = []
        pf = Prefetcher(self.data, start_step=self.start_step)
        try:
            with compat.set_mesh(self.mesh):
                t0 = time.time()
                for i in range(self.start_step, self.start_step + n_steps):
                    step, batch = next(pf)
                    batch = {k: jax.device_put(v, self._bs)
                             for k, v in batch.items()}
                    self.params, self.opt, self.ef, m = self.step_fn(
                        self.params, self.opt, self.ef, batch)
                    if (i + 1) % log_every == 0 or i == self.start_step:
                        loss = float(m["loss"])
                        dt = (time.time() - t0) / max(
                            1, i + 1 - self.start_step)
                        history.append((i + 1, loss))
                        print(f"step {i+1}: loss={loss:.4f} "
                              f"gnorm={float(m['grad_norm']):.3f} "
                              f"{dt*1e3:.0f} ms/step", flush=True)
                    if self.ckpt_every and (i + 1) % self.ckpt_every == 0:
                        self.save(i + 1)
                    if self._preempted:
                        self.save(i + 1)
                        print(f"preempted at step {i+1}; checkpointed.",
                              flush=True)
                        break
        finally:
            pf.stop()
        return history
