"""Sharded, atomic, elastic checkpointing (no external deps).

Layout::

    <dir>/step_000123/
        meta.json            # step, tree structure, shapes/dtypes, mesh info
        arrays/<idx>.npy     # one file per leaf (addressable data)
        _COMPLETE            # commit marker (atomic rename of tmp dir)

Fault-tolerance properties:
  * atomic: written to ``step_X.tmp`` then renamed; readers only trust
    directories containing ``_COMPLETE`` — a preempted writer never
    corrupts the latest checkpoint;
  * elastic: arrays are saved as full logical values; ``restore`` re-shards
    onto whatever mesh/sharding the restarted job provides (device count
    may differ — the mesh is rebuilt by ``make_elastic_mesh``);
  * self-describing: tree structure serialized with string paths, so a
    restart can validate compatibility and surface mismatches early.

On multi-host deployments each host saves only addressable shards of its
jax.Array; here (single-host CI) that equals the full value.  The file
format keeps a ``shard_of`` field so the multi-host writer can extend it
without changing readers.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", k)) for k in path)
             for path, _ in leaves]
    vals = [v for _, v in leaves]
    return paths, vals, jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None):
    """Atomically save a pytree checkpoint."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
    paths, vals, _ = _flatten(tree)
    meta = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (p, v) in enumerate(zip(paths, vals)):
        arr = np.asarray(jax.device_get(v))
        np.save(os.path.join(tmp, "arrays", f"{i}.npy"), arr)
        meta["leaves"].append({
            "path": p, "idx": i, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "shard_of": None,
        })
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "_COMPLETE")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like_tree``; re-shard if given.

    ``shardings``: optional pytree of NamedSharding (elastic restore onto a
    new mesh).  Raises with a clear message on structural mismatch.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    want_paths, want_vals, treedef = _flatten(like_tree)
    by_path = {l["path"]: l for l in meta["leaves"]}
    missing = [p for p in want_paths if p not in by_path]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")
    shard_list = (None if shardings is None
                  else _flatten(shardings)[1])
    out = []
    for i, (p, like) in enumerate(zip(want_paths, want_vals)):
        leaf = by_path[p]
        arr = np.load(os.path.join(d, "arrays", f"{leaf['idx']}.npy"))
        if list(arr.shape) != list(like.shape):
            raise ValueError(
                f"{p}: shape {arr.shape} != expected {like.shape}")
        if shard_list is not None:
            out.append(jax.device_put(arr, shard_list[i]))
        else:
            out.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, meta["extra"]


def prune(ckpt_dir: str, keep: int = 3):
    """Keep the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "_COMPLETE")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
