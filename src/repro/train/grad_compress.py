"""Int8 block-quantized ring all-reduce with error feedback.

Distributed-optimization trick for the multi-pod mesh: cross-pod (DCN)
gradient reduction is bandwidth-bound, so we reduce in int8 (+fp32
per-block scales, 1/256 overhead) instead of bf16 — ~2× wire bytes saved —
with per-step quantization error carried in an *error-feedback* buffer so
the optimizer sees an unbiased long-run gradient (Seide et al. 1-bit SGD /
EF-SGD line of work).

Implementation: shard_map over the reduction axes; a ring reduce-scatter of
quantized chunks via ``lax.ppermute`` (each hop dequantizes, accumulates in
fp32, requantizes), then a ring all-gather of the final quantized chunks.
On the wire every hop carries int8 payload + fp32 scales.

``compressed_psum_mean`` is a drop-in for ``psum/axis-mean`` on a pytree.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat

BLOCK = 256


def _quant(x32: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """fp32 [n] -> (int8 [n], scales fp32 [n/BLOCK])."""
    xb = x32.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32).reshape(-1, BLOCK)
            * scale[:, None]).reshape(-1)


def _ring_allreduce_q(x32: jax.Array, axis: str) -> jax.Array:
    """In-shard_map int8 ring all-reduce of a flat fp32 vector."""
    n_dev = compat.axis_size(axis)
    if n_dev == 1:
        return x32
    me = jax.lax.axis_index(axis)
    n = x32.shape[0]
    chunk = n // n_dev
    xs = x32.reshape(n_dev, chunk)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    # Reduce-scatter: after D-1 hops, shard `me` holds the full sum of
    # chunk (me+1) % D.
    acc = xs
    send_idx = me

    def rs_hop(i, carry):
        acc, send_idx = carry
        payload = jax.lax.dynamic_index_in_dim(acc, send_idx, 0,
                                               keepdims=False)
        q, s = _quant(payload)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv_idx = (send_idx - 1) % n_dev
        inc = _dequant(q, s)
        acc = acc.at[recv_idx].add(inc)
        return acc, recv_idx

    acc, hold_idx = jax.lax.fori_loop(0, n_dev - 1, rs_hop, (acc, send_idx))

    # All-gather: circulate the reduced chunk D-1 hops, quantized.
    def ag_hop(i, carry):
        acc, send_idx = carry
        payload = jax.lax.dynamic_index_in_dim(acc, send_idx, 0,
                                               keepdims=False)
        q, s = _quant(payload)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv_idx = (send_idx - 1) % n_dev
        acc = acc.at[recv_idx].set(_dequant(q, s))
        return acc, recv_idx

    acc, _ = jax.lax.fori_loop(0, n_dev - 1, ag_hop, (acc, hold_idx))
    return acc.reshape(n)


def compressed_allreduce_flat(g32: jax.Array, err: jax.Array, axis: str):
    """One flat fp32 vector: returns (mean-reduced g, new error feedback).

    Error feedback: e' = (g + e) - Q(g + e) accumulated locally.
    """
    n_dev = compat.axis_size(axis)
    x = g32 + err
    q, s = _quant(x)
    xq = _dequant(q, s)
    new_err = x - xq
    total = _ring_allreduce_q(xq, axis)
    return total / n_dev, new_err


def pad_to_block(x: jax.Array, block: int = BLOCK):
    n = x.size
    pad = (-n) % block
    return jnp.pad(x.reshape(-1), (0, pad)), n


def padded_size(n_elems: int, n_dev: int = 1) -> int:
    """Length after padding for an n_dev-ring of BLOCK-quantized chunks.

    Each ring chunk (1/n_dev of the vector) must itself be a whole number
    of quantization blocks, so the vector pads to BLOCK * n_dev.
    """
    block = BLOCK * max(1, n_dev)
    return n_elems + (-n_elems) % block
