"""AdamW with warmup+cosine schedule and global-norm clipping (from scratch).

State is a plain pytree so the trainer can shard it with ZeRO-1 specs:
moments live in fp32 at the params' shapes; master params are the fp32
params themselves (models cast to bf16 at entry).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainHParams


def lr_schedule(hp: TrainHParams, step):
    warm = jnp.minimum(step / jnp.maximum(hp.warmup_steps, 1), 1.0)
    t = jnp.clip((step - hp.warmup_steps)
                 / jnp.maximum(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return hp.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"step": jnp.zeros((), jnp.int32), "mu": zeros(), "nu": zeros()}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, hp: TrainHParams):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gn, 1e-12))
    lr = lr_schedule(hp, step)
    b1, b2 = hp.b1, hp.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / c1
        vhat = nu / c2
        step_ = mhat / (jnp.sqrt(vhat) + hp.eps)
        newp = p.astype(jnp.float32) * (1 - lr * hp.weight_decay) - lr * step_
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}, {
        "grad_norm": gn, "lr": lr}
