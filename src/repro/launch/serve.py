"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Stands up the multi-tenant continuous-batching engine on the Mosaic pool
and replays a synthetic request stream (or reads prompts from a token
file). ``--manager gpu-mmu`` flips to the baseline allocator for A/B.
``--engines N`` serves the stream from a cluster of N engine replicas
over one shared host tier, with the deadline-aware router dispatching
(and, unless ``--no-migrate``, work-stealing) across them — DESIGN.md
§10; outputs are byte-identical to the single-engine run.

CPU example (smoke-scale):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --smoke --requests 8 --max-new 8
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --smoke --requests 8 --max-new 8 --engines 2
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.configs.base import PoolGeometry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--manager", default="mosaic",
                    choices=["mosaic", "gpu-mmu"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-tokens", type=int, default=None,
                    help="default: 8 for --smoke, 64 otherwise")
    ap.add_argument("--frame-pages", type=int, default=None,
                    help="default: 4 for --smoke, 16 otherwise")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engines", type=int, default=1,
                    help="engine replicas over one shared host tier "
                         "(cluster tier + deadline router, DESIGN.md §10)")
    ap.add_argument("--router", default="slack",
                    choices=["slack", "fifo"],
                    help="cluster dispatch policy (with --engines > 1)")
    ap.add_argument("--no-migrate", action="store_true",
                    help="disable work-stealing migration between "
                         "replicas (with --engines > 1)")
    ap.add_argument("--translation", default="off",
                    choices=["off", "flat", "radix"],
                    help="meter KV page translations through the "
                         "coalesced-TLB + radix-walker model "
                         "(DESIGN.md §15); prints a per-app "
                         "translation-cycle summary line")
    args = ap.parse_args()

    from repro.serving.cluster import ServingCluster
    from repro.serving.engine import Request, ServingEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    geo = PoolGeometry(
        page_tokens=args.page_tokens or (8 if args.smoke else 64),
        frame_pages=args.frame_pages or (4 if args.smoke else 16))
    if args.engines > 1:
        eng = ServingCluster(cfg, geometry=geo, n_engines=args.engines,
                             max_batch=args.max_batch,
                             max_seq=args.max_seq,
                             manager_kind=args.manager, seed=args.seed,
                             router_policy=args.router,
                             migrate=not args.no_migrate,
                             translation=args.translation)
    else:
        eng = ServingEngine(cfg, geometry=geo, max_batch=args.max_batch,
                            max_seq=args.max_seq,
                            manager_kind=args.manager, seed=args.seed,
                            translation=args.translation)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        T = int(rng.integers(8, max(9, args.max_seq // 2)))
        r = Request(rid=i, tenant=i % 3,
                    prompt=rng.integers(0, cfg.vocab_size, T).astype(
                        np.int32),
                    max_new=args.max_new)
        reqs.append(r)
        eng.submit(r)
    steps = eng.run_until_drained()
    if args.engines > 1:
        print(f"[{args.manager}] {len(reqs)} requests in {steps} "
              f"cluster steps")
        print(eng.stats().summary())
    else:
        st = eng.cache.stats()
        print(f"[{args.manager}] {len(reqs)} requests in {steps} steps | "
              f"{eng.stats.tok_per_s():.1f} tok/s (this host) | "
              f"coalesced {eng.stats.coalesced_mean:.1%} | "
              f"CAC copies {eng.stats.compaction_copies} | "
              f"bloat {st.get('memory_bloat', 1.0):.2f}")
    if args.translation != "off":
        engines = eng.engines if args.engines > 1 else [eng]
        for e in engines:
            print(f"  engine[{e.engine_id}] "
                  f"{e.translation_meter.summary()}")
    for r in reqs[:4]:
        print(f"  rid={r.rid} tenant={r.tenant} -> {r.out[:10]}")


if __name__ == "__main__":
    main()
