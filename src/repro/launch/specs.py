"""Input/parameter ShapeDtypeStruct builders for the dry-run and launchers.

``input_specs(arch, shape, mesh)`` returns everything needed to lower the
cell: abstract params, abstract inputs, in/out shardings — no device
allocation (weak-type-correct SDS stand-ins only).

Serving geometry (DESIGN.md §5): page_tokens=64, frame_pages=16.  A
sequence's frames are striped over the page shards (``model`` axis when the
batch is data-sharded; every mesh axis for the single-sequence long-context
shape), so ``S`` and ``mpps`` below are mesh-dependent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, PoolGeometry, ShapeConfig
from repro.models.lm import LM
from repro.models.transformer import PageCtx
from repro.launch.sharding import param_specs, zero1_specs

GEO = PoolGeometry(page_tokens=64, frame_pages=16, headroom=1.25)


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp_axes(mesh)]) or 1)


def abstract_params(lm: LM):
    return jax.eval_shape(lambda k: lm.init(k), jax.random.PRNGKey(0))


@dataclasses.dataclass
class ServePlan:
    """Mesh-dependent paging geometry for one decode/prefill cell."""

    batch_sharded: bool
    S: int                 # page shards a sequence stripes over (tables dim)
    mpps: int              # max pages per (sequence, shard)
    np_global: int         # total pool pages (all pool shards)
    page_axes: Tuple[str, ...]   # table stripe / combine axes
    pool_axes: Tuple[str, ...]   # physical pool page-dim sharding


def serve_plan(shape: ShapeConfig, mesh) -> ServePlan:
    geo = GEO
    ftok = geo.frame_pages * geo.page_tokens
    model = mesh.shape.get("model", 1)
    dp = _dp_size(mesh)
    pool_axes = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)
    n_pool_shards = int(np.prod([mesh.shape[a] for a in pool_axes]))
    batch_sharded = shape.global_batch % dp == 0 and shape.global_batch >= dp
    if batch_sharded:
        page_axes = tuple(a for a in ("model",) if a in mesh.axis_names)
        S = model
        n_cells = dp              # independent (data-shard) sub-pools
        seqs_per_cell = shape.global_batch // dp
    else:
        page_axes = pool_axes
        S = n_pool_shards
        n_cells = 1
        seqs_per_cell = shape.global_batch
    # +1 token for the in-flight decode position.
    frames_per_seq = math.ceil((shape.seq_len + 1) / ftok)
    mpps = math.ceil(frames_per_seq / S) * geo.frame_pages
    # Capacity per (cell, model-stripe): worst-stripe frames per sequence
    # x sequences in the cell x headroom.
    frames_per_stripe = math.ceil(
        math.ceil(frames_per_seq / S) * seqs_per_cell * geo.headroom)
    np_global = frames_per_stripe * geo.frame_pages * S * n_cells
    return ServePlan(batch_sharded, S, mpps, np_global, page_axes,
                     pool_axes)


def _frontend_inputs(cfg: ModelConfig, B: int, T_src: Optional[int] = None):
    out = {}
    if cfg.family == "vlm":
        out["patch_embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model),
                                  jnp.bfloat16)
    if cfg.family == "encdec":
        out["src_embeds"] = sds((B, T_src or cfg.encdec.source_len,
                                 cfg.d_model), jnp.bfloat16)
    return out


def _ctx_specs(B: int, plan: ServePlan):
    i32 = jnp.int32
    return PageCtx(
        tables=sds((B, plan.S, plan.mpps), i32),
        ntok=sds((B, plan.S, plan.mpps), i32),
        wpage=sds((B, plan.S), i32),
        wslot=sds((B,), i32),
        batch_sharded=plan.batch_sharded,
    )


def _ctx_shardings(mesh, plan: ServePlan, bs):
    pa = plan.page_axes if plan.page_axes else None
    return PageCtx(
        tables=NamedSharding(mesh, P(bs, pa, None)),
        ntok=NamedSharding(mesh, P(bs, pa, None)),
        wpage=NamedSharding(mesh, P(bs, pa)),
        wslot=NamedSharding(mesh, P(bs)),
        batch_sharded=plan.batch_sharded,
    )


def _pool_shardings(mesh, plan: ServePlan, pools_sds):
    pa = plan.pool_axes if plan.pool_axes else None

    def shard_one(s):
        # [L, NP, ptok, kv, dh] → pages over every mesh axis (each
        # (data, model) cell owns a private sub-pool; see PageCtx.pool_axes).
        return NamedSharding(mesh, P(None, pa, *([None] * (len(s.shape) - 2))))

    return tuple(shard_one(s) for s in pools_sds)


def _state_shardings(cfg, mesh, state_sds, bs):
    out = {}
    for k, s in state_sds.items():
        if k in ("ssm", "conv"):
            out[k] = NamedSharding(mesh, P(None, bs,
                                           *([None] * (len(s.shape) - 2))))
        elif k in ("cross_k", "cross_v"):
            # [L, B, src, kv, dh]: batch over dp, kv heads over model.
            kv_ax = ("model" if s.shape[3] % mesh.shape.get("model", 1) == 0
                     else None)
            out[k] = NamedSharding(mesh, P(None, bs, None, kv_ax, None))
    return out


def build_cell(arch: str, shape_name: str, mesh,
               hp=None) -> Dict[str, Any]:
    """Everything needed to lower one (arch × shape × mesh) cell.

    Returns dict with: kind, fn (to jit), args (SDS tree),
    in_shardings, out_shardings (or None), donate.
    """
    from repro.configs.base import TrainHParams
    from repro.models.common import set_batch_axes
    from repro.train.trainer import (
        configure_parallelism,
        make_train_step,
        state_specs,
    )

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    lm = LM(cfg)
    hp = hp or TrainHParams(remat="block")
    params_sds = abstract_params(lm)

    if shape.kind == "train":
        from repro.train.optimizer import adamw_init
        from repro.models.common import set_serving_mode

        set_serving_mode(False)
        configure_parallelism(hp)
        bdp = tuple(a for a in (("pod", "data", "model")
                                if hp.parallelism == "fsdp"
                                else ("pod", "data"))
                    if a in mesh.axis_names)
        bs = bdp if bdp else None
        pspec, mspec = state_specs(params_sds, hp, mesh)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                              is_leaf=lambda x: isinstance(x, P))
        mshard = jax.tree.map(lambda s: NamedSharding(mesh, s), mspec,
                              is_leaf=lambda x: isinstance(x, P))

        B, T = shape.global_batch, shape.seq_len
        step_fn, _ = make_train_step(lm, hp, mesh)
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        oshard = {"step": NamedSharding(mesh, P()),
                  "mu": mshard, "nu": mshard}
        batch = {"tokens": sds((B, T), jnp.int32),
                 **_frontend_inputs(cfg, B)}
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, P(bs, *([None] * (len(s.shape) - 1)))),
            batch)
        ef_sds = None
        if hp.grad_compress:
            from repro.train.grad_compress import padded_size
            ef_sds = jax.tree.map(
                lambda q: sds((padded_size(int(np.prod(q.shape)),
                                           _dp_size(mesh)),),
                              jnp.float32), params_sds)

        def train_fn(p, o, ef, b):
            new_p, new_o, _, m = step_fn(p, o, ef, b)
            return new_p, new_o, m["loss"]

        ef_shard = (jax.tree.map(lambda s: NamedSharding(mesh, P()), ef_sds)
                    if ef_sds is not None else None)
        return dict(
            kind="train",
            fn=train_fn,
            args=(params_sds, opt_sds, ef_sds, batch),
            in_shardings=(pshard, oshard, ef_shard, bshard),
            donate=(0, 1),
        )

    # Serving shapes (always megatron-style: model axis = page stripes/TP).
    # Params: bf16, TP-sharded, REPLICATED over data — never ZeRO-extended.
    # A data-extended layout would re-gather every layer's weights every
    # decode step (measured 42 GB wire/step on llama3 decode_32k —
    # EXPERIMENTS.md §Perf decode iteration 1); inference reads weights
    # once per token, so they must live resident per TP shard.  MoE
    # expert tensors use the 2D-EP layout when it applies (dbrx's 254 GB
    # of experts cannot replicate over data; models/moe.py).
    from repro.launch.sharding import serving_param_specs
    from repro.models.common import set_serving_mode
    from repro.models.moe import ep2d_geometry

    set_batch_axes(("pod", "data"))
    set_serving_mode(True)
    dp = _dp_axes(mesh)
    bs = dp if dp else None
    params_sds = jax.tree.map(
        lambda s: sds(s.shape, jnp.bfloat16
                      if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        params_sds)
    ep2d = ep2d_geometry(cfg, mesh) is not None
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          serving_param_specs(params_sds, mesh, ep2d),
                          is_leaf=lambda x: isinstance(x, P))
    plan = serve_plan(shape, mesh)
    B = shape.global_batch
    pools_sds = lm.pool_shapes(plan.np_global, GEO.page_tokens)
    pool_shard = (_pool_shardings(mesh, plan, pools_sds)
                  if pools_sds else None)
    ctx = _ctx_specs(B, plan)
    ctx_shard = _ctx_shardings(mesh, plan, bs if plan.batch_sharded else None)

    if shape.kind == "prefill":
        T = shape.seq_len
        batch = {"tokens": sds((B, T), jnp.int32),
                 **_frontend_inputs(cfg, B)}
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, P(bs, *([None] * (len(s.shape) - 1)))),
            batch)
        last_pos = sds((B,), jnp.int32)

        def prefill_fn(p, b, pools, ctx, last_pos):
            return lm.prefill(p, b, pools, ctx, last_pos)

        return dict(
            kind="prefill",
            fn=prefill_fn,
            args=(params_sds, batch, pools_sds, ctx, last_pos),
            in_shardings=(pshard, bshard, pool_shard, ctx_shard,
                          NamedSharding(mesh, P(bs))),
            donate=(2,),
            plan=plan,
        )

    # decode
    bsd = bs if plan.batch_sharded else None
    state_sds = lm.init_state_shapes(
        B, src_len=(cfg.encdec.source_len if cfg.encdec else 0))
    st_shard = _state_shardings(cfg, mesh, state_sds, bsd)
    tokens = sds((B,), jnp.int32)
    pos = sds((B,), jnp.int32)

    def decode_fn(p, t, pos, pools, ctx, st):
        return lm.decode_step(p, t, pos, pools, ctx, st)

    return dict(
        kind="decode",
        fn=decode_fn,
        args=(params_sds, tokens, pos, pools_sds, ctx, state_sds),
        in_shardings=(pshard, NamedSharding(mesh, P(bsd)),
                      NamedSharding(mesh, P(bsd)), pool_shard, ctx_shard,
                      st_shard),
        donate=(3,),
        plan=plan,
    )
