"""Fleet supervision: heartbeats, straggler mitigation, elastic restart.

On a real multi-pod deployment each host runs a ``WorkerAgent`` (heartbeat
writer) and rank 0 runs the ``FleetMonitor``.  Policy:

  * missed heartbeat > ``dead_after_s``      → worker DEAD → elastic
    restart: rebuild the mesh from survivors (``make_elastic_mesh``),
    restore the newest complete checkpoint re-sharded onto it;
  * step time > ``straggle_factor`` × median → worker STRAGGLING → first
    soft-mitigate (re-dispatch its input shard / drop to best-effort
    collectives), escalate to DEAD after ``straggle_patience`` repeats.

The control logic is deliberately transport-agnostic (heartbeats are a
dict the tests drive directly; production wires it to GCS/etcd), so the
decision engine itself is unit-tested — the part that actually must be
correct when a pod vanishes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    last_step: int = 0
    step_times: List[float] = dataclasses.field(default_factory=list)
    straggle_strikes: int = 0
    alive: bool = True


@dataclasses.dataclass
class FleetDecision:
    kind: str                 # 'ok' | 'mitigate' | 'restart'
    dead: Tuple[int, ...] = ()
    stragglers: Tuple[int, ...] = ()
    new_world_size: Optional[int] = None


class FleetMonitor:
    def __init__(self, n_workers: int, *, dead_after_s: float = 60.0,
                 straggle_factor: float = 2.0, straggle_patience: int = 3,
                 devices_per_worker: int = 8,
                 now: Callable[[], float] = time.monotonic):
        self.now = now
        self.dead_after_s = dead_after_s
        self.straggle_factor = straggle_factor
        self.straggle_patience = straggle_patience
        self.devices_per_worker = devices_per_worker
        t = now()
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(last_heartbeat=t) for i in range(n_workers)}

    # ------------------------------------------------------------ inputs

    def heartbeat(self, worker: int, step: int, step_time_s: float):
        w = self.workers[worker]
        w.last_heartbeat = self.now()
        w.last_step = step
        w.step_times.append(step_time_s)
        if len(w.step_times) > 20:
            w.step_times.pop(0)

    # ------------------------------------------------------------ policy

    def _median_step(self) -> float:
        times = sorted(
            t for w in self.workers.values() if w.alive and w.step_times
            for t in w.step_times[-5:])
        return times[len(times) // 2] if times else 0.0

    def assess(self) -> FleetDecision:
        t = self.now()
        dead, stragglers = [], []
        med = self._median_step()
        for i, w in self.workers.items():
            if not w.alive:
                continue
            if t - w.last_heartbeat > self.dead_after_s:
                w.alive = False
                dead.append(i)
                continue
            if med > 0 and w.step_times and \
                    w.step_times[-1] > self.straggle_factor * med:
                w.straggle_strikes += 1
                if w.straggle_strikes >= self.straggle_patience:
                    w.alive = False
                    dead.append(i)
                else:
                    stragglers.append(i)
            else:
                w.straggle_strikes = 0
        if dead:
            alive = sum(w.alive for w in self.workers.values())
            return FleetDecision(
                "restart", dead=tuple(dead), stragglers=tuple(stragglers),
                new_world_size=alive * self.devices_per_worker)
        if stragglers:
            return FleetDecision("mitigate", stragglers=tuple(stragglers))
        return FleetDecision("ok")

    def alive_workers(self) -> List[int]:
        return [i for i, w in self.workers.items() if w.alive]


def elastic_restart_plan(n_devices_left: int, *, model_axis: int = 16):
    """What a restart does: new mesh + which checkpoint artifacts to load.

    Returns (mesh_shape, mesh_axes).  Training resumes from the newest
    complete checkpoint; ``repro.train.checkpoint.restore`` re-shards onto
    the new mesh (full logical arrays → any device count).
    """
    m = model_axis
    while m > 1 and n_devices_left % m:
        m //= 2
    return (n_devices_left // m, m), ("data", "model")
