"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Builds the mesh for whatever devices this process has (or the production
mesh under a TPU runtime), instantiates the fault-tolerant Trainer, and
runs. Restart the same command after a failure/preemption: ``--resume``
restores the newest complete checkpoint and re-shards it onto the
surviving device count (elastic restart; see train/checkpoint.py).

CPU example (smoke-scale):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --smoke --steps 50 --batch 4 --seq 64 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse

from repro.configs import get_config, get_smoke_config, list_archs
from repro.configs.base import TrainHParams
from repro.launch.mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default="save_collectives",
                    choices=["none", "block", "save_collectives"])
    ap.add_argument("--parallelism", default="megatron",
                    choices=["megatron", "auto", "fsdp"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 (requires 256 devices)")
    args = ap.parse_args()

    from repro.train.trainer import Trainer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    hp = TrainHParams(lr=args.lr, total_steps=args.steps,
                      microbatch=args.microbatch, remat=args.remat,
                      parallelism=args.parallelism)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    print(f"arch={cfg.name} params~{cfg.param_count() / 1e6:.1f}M "
          f"mesh={dict(mesh.shape)} parallelism={hp.parallelism}")
    tr = Trainer(cfg, hp, mesh, batch_per_step=args.batch,
                 seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                 ckpt_every=args.ckpt_every, resume=args.resume)
    tr.run(args.steps)


if __name__ == "__main__":
    main()
