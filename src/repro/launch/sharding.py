"""Parameter/optimizer sharding rules (TP + EP + ZeRO-1).

Specs are matched by parameter *name* against trailing dimensions, so the
same rule covers a stacked ``[L, ...]`` tensor, a hybrid's ``[G, P, ...]``
grouping, or an unstacked shared block.  Megatron-style pairing: column
-parallel (heads / ffn-hidden / experts) then row-parallel back, one
all-reduce per pair; embeddings are vocab-sharded.

``zero1_specs`` extends each param's spec with the data axes on the
largest still-unsharded (and divisible) dim — applied to optimizer moments
and used by the trainer for ZeRO-1 state partitioning.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# name -> trailing-dims spec (leading dims padded with None).
_TRAILING_RULES: Dict[str, Tuple] = {
    # embeddings
    "embed": ("model", None),
    "unembed": (None, "model"),
    "frontend_proj": (None, None),
    # attention (GQA): column-parallel QKV, row-parallel O
    "wq": (None, "model", None),
    "wk": (None, "model", None),
    "wv": (None, "model", None),
    "wo": ("model", None, None),
    "bq": ("model", None),
    "bk": ("model", None),
    "bv": ("model", None),
    "q_norm": (None,),
    "k_norm": (None,),
    # MLA
    "w_dkv": (None, None),
    "w_kr": (None, None),
    "kv_norm": (None,),
    "w_uk": (None, "model", None),
    "w_uv": (None, "model", None),
    # dense FFN
    "w_gate": (None, "model"),
    "w_up": (None, "model"),
    "w_down": ("model", None),
    # MoE (EP: experts over model axis; router replicated)
    "router": (None, None),
    "ws_gate": (None, "model"),
    "ws_up": (None, "model"),
    "ws_down": ("model", None),
    # Mamba2 (head-major inner dim sharded; scalars replicated)
    "w_in": (None, "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "norm_w": ("model",),
    "w_out": ("model", None),
}

# MoE expert tensors carry an extra leading E dim that is itself sharded.
_MOE_EXPERT_RULES: Dict[str, Tuple] = {
    "w_gate": ("model", None, None),
    "w_up": ("model", None, None),
    "w_down": ("model", None, None),
}


def _leaf_name(path) -> Tuple[str, Tuple[str, ...]]:
    keys = tuple(
        k.key if hasattr(k, "key") else str(k) for k in path)
    return keys[-1], keys


def spec_for(path, leaf, mesh) -> P:
    name, keys = _leaf_name(path)
    names = set(mesh.axis_names)
    in_moe = "moe" in keys
    rule = None
    if in_moe and name in _MOE_EXPERT_RULES:
        rule = _MOE_EXPERT_RULES[name]
    elif name in _TRAILING_RULES:
        rule = _TRAILING_RULES[name]
    if rule is None:
        return P()
    nd = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    if nd < len(rule):
        return P()
    full = (None,) * (nd - len(rule)) + tuple(rule)
    # Drop axes absent from the mesh or non-divisible dims.
    shape = leaf.shape
    out = []
    for dim, ax in zip(shape, full):
        if ax is None or ax not in names or dim % mesh.shape[ax] != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def param_specs(params, mesh):
    """Pytree of PartitionSpec matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(path, leaf, mesh), params)


def zero1_extend(spec: P, shape, mesh) -> P:
    """Add the data axes to the largest unsharded divisible dim (ZeRO-1)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not dp:
        return spec
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # Prefer the largest dim with no axis yet.
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if entries[i] is None and shape[i] % dp_size == 0:
            entries[i] = dp if len(dp) > 1 else dp[0]
            return P(*entries)
        if entries[i] is not None and not isinstance(entries[i], tuple):
            ax = entries[i]
            if shape[i] % (mesh.shape[ax] * dp_size) == 0:
                entries[i] = (ax, *dp)
                return P(*entries)
    return spec


def zero1_specs(params, mesh):
    base = param_specs(params, mesh)
    return jax.tree.map(
        lambda leaf, sp: zero1_extend(sp, leaf.shape, mesh), params, base)


def fsdp_spec_for(shape, mesh) -> P:
    """ZeRO-3: shard the largest divisible dim over every mesh axis.

    Falls back to progressively smaller axis subsets (drop 'pod', then
    'data') so awkward dims (e.g. vocab not divisible by 512) still shard
    as much as possible; fully replicated only as a last resort.
    """
    axes_all = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    for drop in range(len(axes_all)):
        axes = axes_all[drop:]
        n = int(np.prod([mesh.shape[a] for a in axes]))
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % n == 0 and shape[i] >= n:
                entries = [None] * len(shape)
                entries[i] = axes if len(axes) > 1 else axes[0]
                return P(*entries)
    return P()


def fsdp_specs(params, mesh):
    """Pytree of fully-sharded (ZeRO-3) PartitionSpecs."""
    return jax.tree.map(lambda leaf: fsdp_spec_for(leaf.shape, mesh),
                        params)


# Serving layout for MoE expert tensors: 2D EP — experts over 'data',
# expert-hidden over 'model' (see models/moe.py::ep2d_geometry).  All
# other params keep the TP rules (bf16, replicated over data).
_MOE_EXPERT_SERVING_RULES: Dict[str, Tuple] = {
    "w_gate": ("data", None, "model"),
    "w_up": ("data", None, "model"),
    "w_down": ("data", "model", None),
}


def serving_param_specs(params, mesh, ep2d: bool):
    """Param specs for inference; ``ep2d`` switches expert tensors to the
    2D expert-parallel layout."""
    base = param_specs(params, mesh)
    if not ep2d:
        return base

    def override(path, leaf, spec):
        name, keys = _leaf_name(path)
        if "moe" in keys and name in _MOE_EXPERT_SERVING_RULES:
            rule = _MOE_EXPERT_SERVING_RULES[name]
            nd = leaf.ndim
            full = (None,) * (nd - len(rule)) + tuple(rule)
            names = set(mesh.axis_names)
            out = []
            for dim, ax in zip(leaf.shape, full):
                ok = (ax is not None and ax in names
                      and dim % mesh.shape[ax] == 0)
                out.append(ax if ok else None)
            return P(*out)
        return spec

    return jax.tree_util.tree_map_with_path(override, params, base)


def shardings_of(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
