"""Mesh construction for single-pod / multi-pod deployments.

``make_production_mesh`` is the contract required by the dry-run: a
function (never a module-level constant — importing this module must not
touch jax device state).

Production target: TPU v5e pods, 256 chips each (16×16), ICI ~50 GB/s/link,
197 bf16 TFLOP/s + 16 GB HBM @ 819 GB/s per chip.  The ``pod`` axis of the
multi-pod mesh is pure data parallelism over DCN (gradient all-reduce
crosses pods once per step); ``data`` is in-pod data parallel; ``model`` is
the tensor/expert-parallel axis kept inside an ICI-adjacent 16-chip ring.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh as _mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_elastic_mesh(n_devices: int, *, model: int = 16):
    """Best mesh for an arbitrary surviving-device count (elastic restart).

    Keeps the model axis at the largest power-of-two divisor ≤ ``model`` so
    TP weight shards stay ICI-local; the rest becomes data parallelism.
    """
    m = model
    while m > 1 and n_devices % m:
        m //= 2
    return _mesh((n_devices // m, m), ("data", "model"))


def make_host_mesh():
    """Whatever this process actually has (tests / examples)."""
    n = len(jax.devices())
    return make_elastic_mesh(n, model=min(4, n))
