"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (the XLA_FLAGS line below must execute
before any jax import anywhere — jax locks the device count at first
init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape decode_32k --mesh pod          # 16x16 (256 chips)
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod

For each cell it prints (and appends to --out as JSON lines):
  * compiled.memory_analysis()  — proves the cell fits per-device HBM;
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline;
  * collective bytes parsed from the compiled HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute);
  * the three roofline terms vs. TPU v5e peaks (DESIGN/EXPERIMENTS).
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import sys
import time
import warnings

warnings.filterwarnings("ignore")


# v5e hardware constants (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (~per-direction useful)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2,
}

_COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\w+\[[^\]]*\](?:\([^)]*\))?[^=]*?)"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)


def parse_collective_bytes(hlo_text: str):
    """Sum operand bytes of every collective op in (sharded) HLO text."""
    totals = {}
    # Match lines like: %x = bf16[8,128,512]{...} all-gather(...)
    line_re = re.compile(
        r"=\s*(?:\()?\s*((?:\w+\[[^\]]*\][,\s]*)+)[^=]*?"
        r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)")
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(shapes):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[kind] = totals.get(kind, 0) + nbytes
        totals["total"] = totals.get("total", 0) + nbytes
    return totals


def roofline_terms(flops, hbm_bytes, coll_bytes, n_chips):
    return {
        "compute_s": flops / (n_chips * PEAK_FLOPS),
        "memory_s": hbm_bytes / (n_chips * HBM_BW),
        "collective_s": coll_bytes / (n_chips * ICI_BW),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             skip_hlo_text: bool = False, parallelism: str = "megatron",
             remat: str = "block", tp: int = 0,
             microbatch: int = 0, grad_compress: bool = False):
    import jax
    from repro import compat
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.configs import get_config, SHAPES
    from repro.configs.base import TrainHParams
    from benchmarks.hlo_analysis import analyze_hlo
    from benchmarks.analytic import memory_bytes

    hp = TrainHParams(remat=remat, parallelism=parallelism,
                      microbatch=microbatch, grad_compress=grad_compress)
    if tp:
        # TP-degree re-factoring (EXPERIMENTS.md §Perf iteration 4): same
        # chip count and physical topology, model axis of size `tp`
        # (ICI-contiguous), the rest data parallel.
        per_pod = 256 // tp
        shape_axes = ((2, per_pod, tp) if multi_pod else (per_pod, tp))
        names = (("pod", "data", "model") if multi_pod
                 else ("data", "model"))
        from repro.compat import make_mesh
        mesh = make_mesh(shape_axes, names)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    with compat.set_mesh(mesh):
        cell = build_cell(arch, shape_name, mesh, hp=hp)
        jitted = jax.jit(
            cell["fn"],
            in_shardings=cell["in_shardings"],
            donate_argnums=cell.get("donate", ()),
        )
        lowered = jitted.lower(*cell["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # --- per-device memory: XLA buffer assignment (proves the cell fits).
    mem = compiled.memory_analysis()
    peak = getattr(mem, "peak_memory_in_bytes", None)
    peak_is_estimate = peak is None
    if peak is None:
        # Older jax exposes no true peak; temp+args+out is a loose upper
        # bound (no liveness/buffer-sharing), flagged so consumers don't
        # treat it as the XLA buffer-assignment peak.
        peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                + mem.output_size_in_bytes)
    mem_rec = {
        "peak": int(peak),
        "peak_is_estimate": peak_is_estimate,
        "args": int(mem.argument_size_in_bytes),
        "out": int(mem.output_size_in_bytes),
        "alias": int(mem.alias_size_in_bytes),
    }

    # --- FLOPs / collective bytes, per chip.
    # XLA's cost_analysis() counts a lax.scan body ONCE (trip count
    # ignored), silently under-reporting scanned stacks by ~n_layers x.
    # hlo_analysis walks the compiled (post-SPMD) HLO with while
    # trip-count multipliers instead; the raw XLA numbers are recorded
    # alongside for reference.
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax wraps it in a list
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    colls = {}
    hlo_flops = xla_flops
    dcn_bytes = 0.0
    if not skip_hlo_text:
        hlo = compiled.as_text()
        # Replica-group sizes that span the pod (DCN) boundary on this
        # mesh: any group factorization using the 'pod' axis.  Size-based
        # heuristic — exact for the axis factorizations we lower.
        pod_sizes = ()
        if multi_pod:
            dp_in_pod = mesh.shape.get("data", 1)
            pod_sizes = (2, 2 * dp_in_pod, n_chips)
        res = analyze_hlo(hlo, pod_group_sizes=pod_sizes)
        hlo_flops = res["flops"]
        dcn_bytes = res.get("dcn_bytes", 0.0)
        colls = {k: v for k, v in res["collectives"].items() if v}
        colls["total"] = res["collective_bytes"]

    # --- HBM traffic, per chip: analytic model (cost_analysis 'bytes
    # accessed' has the same scan defect and also counts VMEM-resident
    # reuse; see benchmarks/analytic.py for the derivation).
    mem_model = memory_bytes(arch, shape_name, mesh)

    terms = roofline_terms(hlo_flops * n_chips, mem_model["total"] * n_chips,
                           colls.get("total", 0) * n_chips, n_chips)

    cfg = get_config(arch)
    n_active = cfg.active_param_count()
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "parallelism": parallelism,
        "remat": remat,
        "tp": tp or mesh.shape.get("model", 0),
        "microbatch": microbatch,
        "grad_compress": grad_compress,
        "chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device_bytes": mem_rec,
        "hlo_flops_per_chip": hlo_flops,
        "xla_flops_per_chip": xla_flops,
        "hbm_bytes_per_chip": mem_model["total"],
        "collective_bytes_per_chip": colls,
        "dcn_bytes_per_chip": dcn_bytes,
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / (hlo_flops * n_chips))
                             if hlo_flops else None,
        **terms,
    }
    terms_only = {k: rec[k] for k in
                  ("compute_s", "memory_s", "collective_s")}
    rec["bottleneck"] = max(terms_only, key=terms_only.get)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--parallelism", choices=["megatron", "auto", "fsdp"],
                    default="megatron")
    ap.add_argument("--remat", choices=["none", "block", "save_collectives"],
                    default="save_collectives")
    ap.add_argument("--tp", type=int, default=0,
                    help="re-factor the 256-chip pod as (256/tp) x tp")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import SHAPES, list_archs

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = (f"{arch} × {shape} × "
                         f"{'2x16x16' if mp else '16x16'}"
                         f"{' × fsdp' if args.parallelism == 'fsdp' else ''}")
                print(f"=== {label}", flush=True)
                try:
                    rec = run_cell(arch, shape, mp,
                                   parallelism=args.parallelism,
                                   remat=args.remat, tp=args.tp,
                                   microbatch=args.microbatch,
                                   grad_compress=args.grad_compress)
                    print(json.dumps(rec, default=str), flush=True)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(rec, default=str) + "\n")
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((label, repr(e)))
                    print(f"FAILED {label}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for lab, err in failures:
            print(" ", lab, err[:200])
        sys.exit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
