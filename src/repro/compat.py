"""Version-compat shims for the installed jax.

Three APIs this repo relies on moved (or appeared) across recent jax
releases; import them from here so the repo runs on either side:

* ``shard_map``: ``jax.experimental.shard_map`` → top-level ``jax``;
* ``jax.sharding.AxisType`` (and ``jax.make_mesh(..., axis_types=...)``):
  new in jax 0.5-era releases — older jax has ``jax.make_mesh`` without
  the ``axis_types`` kwarg, which is equivalent to all-Auto;
* ``jax.sharding.set_mesh``: older jax spells the ambient-mesh context as
  ``with mesh:`` (Mesh is itself a context manager).
"""

from __future__ import annotations

import contextlib
import functools
import inspect

import jax

try:  # jax >= 0.5 (also present in some late 0.4.x releases)
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


@functools.wraps(_shard_map)
def shard_map(f, *args, **kwargs):
    # New jax renamed check_rep -> check_vma; accept either spelling.
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, *args, **kwargs)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with all-Auto axis types on any jax version."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):  # pragma: no cover - version dependent
        return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:  # pragma: no cover - version dependent
        return setter(mesh)
    # Older jax: Mesh is a context manager establishing the resource env.
    return contextlib.nullcontext(mesh) if mesh is None else mesh


def get_abstract_mesh():
    """Ambient mesh, or None — older jax lacks ``get_abstract_mesh``."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:  # pragma: no cover - version dependent
        return getter()
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover - private-API drift
        return None


def axis_size(axis_name):
    """Size of a mapped mesh axis — ``jax.lax.axis_size`` is new-jax only."""
    if hasattr(jax.lax, "axis_size"):  # pragma: no cover - version dependent
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the TPUCompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:  # pragma: no cover - version dependent
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def _native_barrier_differentiable() -> bool:
    try:
        jax.grad(lambda x: jax.lax.optimization_barrier(x))(0.0)
        return True
    except NotImplementedError:  # pragma: no cover - version dependent
        return False


if _native_barrier_differentiable():  # pragma: no cover - version dependent
    optimization_barrier = jax.lax.optimization_barrier
else:
    # Older jax has no differentiation rule for the primitive; supply the
    # one new jax ships (barrier forward, barrier on the cotangent).
    @jax.custom_vjp
    def optimization_barrier(x):
        return jax.lax.optimization_barrier(x)

    def _barrier_fwd(x):
        return jax.lax.optimization_barrier(x), None

    def _barrier_bwd(_, g):
        return (jax.lax.optimization_barrier(g),)

    optimization_barrier.defvjp(_barrier_fwd, _barrier_bwd)


__all__ = ["shard_map", "make_mesh", "set_mesh", "get_abstract_mesh",
           "optimization_barrier"]
