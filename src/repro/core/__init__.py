"""Mosaic core: the paper's contribution as a composable library.

Components (paper §2):
  * :class:`~repro.core.pagepool.PagePool`       — physical pages/frames
  * :class:`~repro.core.cocoa.CoCoA`             — contiguity-conserving allocation
  * :class:`~repro.core.coalescer.InPlaceCoalescer` — metadata-only promotion
  * :class:`~repro.core.compaction.CAC`          — contiguity-aware compaction
  * :class:`~repro.core.manager.MosaicManager`   — facade wiring the above
  * :class:`~repro.core.baseline_mmu.BaselineMMU`— GPU-MMU baseline (Power et al.)
  * :mod:`~repro.core.tlb_sim`                   — paper-faithful TLB timing model
  * :mod:`~repro.core.demand_paging`             — host↔HBM base-page transfers
"""

from repro.core.pagepool import PagePool, PoolConfig
from repro.core.page_table import PageTable, pack_batch_tables, UNMAPPED
from repro.core.cocoa import CoCoA, OutOfMemory
from repro.core.coalescer import InPlaceCoalescer
from repro.core.compaction import CAC, CompactionPlan, CopyOp
from repro.core.manager import MosaicManager, pages_for_tokens
from repro.core.baseline_mmu import BaselineMMU
from repro.core.demand_paging import (
    FaultBatch,
    LinkModel,
    ResidencyTracker,
    contiguous_runs,
)

MANAGERS = {"mosaic": MosaicManager, "gpu-mmu": BaselineMMU}


def make_manager(kind: str, config: PoolConfig, *, link=None,
                 page_bytes: int = 0):
    return MANAGERS[kind](config, link=link, page_bytes=page_bytes)


__all__ = [
    "PagePool", "PoolConfig", "PageTable", "pack_batch_tables", "UNMAPPED",
    "CoCoA", "OutOfMemory", "InPlaceCoalescer", "CAC", "CompactionPlan",
    "CopyOp", "MosaicManager", "BaselineMMU", "MANAGERS", "make_manager",
    "LinkModel", "ResidencyTracker", "FaultBatch", "contiguous_runs",
    "pages_for_tokens",
]
