"""CoCoA — Contiguity-Conserving Allocation (paper §2; DESIGN.md §1).

The first of Mosaic's three cooperating mechanisms (CoCoA allocates,
the :mod:`In-Place Coalescer <repro.core.coalescer>` promotes, :mod:`CAC
<repro.core.compaction>` repairs): the *allocation-time* half of the
paper's argument that contiguity is nearly free to **conserve** if you
never break it, whereas recovering it later costs data migration.

Allocation policy:

* **En-masse allocations** (a prefill allocating a whole sequence's KV at
  once — the paper's key observation about GPGPU allocation behaviour) take
  whole free frames so that virtually-contiguous base pages are physically
  contiguous *and aligned* within large-page frames.  Every fully covered
  frame is immediately coalescible with zero copies.
* **Soft guarantee**: a large-page frame only ever holds base pages of a
  single owner.  Under memory pressure we fall back to free slots in
  *this owner's* partial frames (conserving the guarantee) before failing;
  the caller then runs CAC compaction or evicts.
* **Appends** (decode-time growth, one page per ``page_tokens`` tokens) fill
  the owner's active frame slot-by-slot in alignment order, so a frame
  coalesces the moment its last slot fills.

Alignment invariant maintained throughout: a page mapped at virtual page
number ``vpn`` is placed at slot ``vpn % frame_pages`` of its frame whenever
possible, which is exactly the In-Place Coalescer's promotion condition.

What conserved contiguity buys downstream (the claims the benches pin):

* *translation reach* — coalesced frames translate as large pages in the
  TLB-timing simulator (:mod:`repro.core.tlb_sim`, paper Figs. 1/5/6) and
  take the frame-granular fast path of the dual-granularity Pallas
  paged-attention kernel (DESIGN.md §4);
* *transfer merging* — physically-contiguous base pages merge into single
  DMA descriptors on the host↔device link (one setup cost per run, not
  per page), which is why the serving engine's swap/fault batches and the
  prefix cache's admission fault-ins are cheap under Mosaic
  (:class:`repro.core.demand_paging.FaultBatch`, DESIGN.md §6/§8);
* *whole-frame return* — the soft guarantee means a finished sequence
  hands back intact frames, so multi-tenant churn does not splinter the
  pool (the ``memory_bloat``/fragmentation comparisons vs ``gpu-mmu``).

``OutOfMemory`` raised here is a *scheduling* signal, not a failure: the
serving engine responds with CAC compaction, then cost-aware preemption
to the host tier (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.coalescer import InPlaceCoalescer
from repro.core.page_table import UNMAPPED, PageTable
from repro.core.pagepool import FREE, PagePool


class OutOfMemory(Exception):
    """Pool cannot satisfy the request; caller should compact or evict."""


class CoCoA:
    def __init__(self, pool: PagePool, coalescer: Optional[InPlaceCoalescer] = None):
        self.pool = pool
        self.coalescer = coalescer or InPlaceCoalescer(pool)
        # owner -> active (tail) frame being filled by appends, if any.
        self._active_frame: Dict[int, int] = {}
        # owner -> frames owned with ≥1 free slot (pressure fallback pool).
        self._partial_frames: Dict[int, List[int]] = {}

    # -- internal helpers --------------------------------------------------------

    def _note_partial(self, owner: int, frame: int) -> None:
        fp = self.pool.config.frame_pages
        lst = self._partial_frames.setdefault(owner, [])
        if self.pool.frame_used[frame] < fp and frame not in lst:
            lst.append(frame)

    def _unnote_if_full_or_released(self, owner: int, frame: int) -> None:
        lst = self._partial_frames.get(owner, [])
        fp = self.pool.config.frame_pages
        if frame in lst and (
            self.pool.frame_used[frame] == fp or self.pool.frame_owner[frame] == FREE
        ):
            lst.remove(frame)

    def _alloc_slot(
        self, owner: int, table: PageTable, want_slot: int
    ) -> Tuple[int, bool]:
        """Allocate one page for the owner's tail, preferring alignment.

        Returns (ppn, aligned) where ``aligned`` is True when the page landed
        at its alignment-preserving slot in a frame whose earlier slots hold
        the preceding vpns (i.e. the frame can still coalesce).
        """
        pool = self.pool
        # 1. Active frame with the aligned slot free → contiguity conserved.
        af = self._active_frame.get(owner)
        if af is not None and pool.frame_owner[af] == owner:
            ppn = pool.page_of(af, want_slot)
            if not pool.page_allocated[ppn]:
                pool.alloc_page(af, want_slot)
                self._unnote_if_full_or_released(owner, af)
                return ppn, True
        # 2. Start a new frame (only makes sense at slot 0 for alignment).
        if want_slot == 0:
            f = pool.take_free_frame(owner)
            if f is not None:
                self._active_frame[owner] = f
                pool.alloc_page(f, 0)
                self._note_partial(owner, f)
                self._unnote_if_full_or_released(owner, f)
                return pool.page_of(f, 0), True
        elif af is None or pool.frame_owner[af] != owner:
            # Lost our active frame mid-sequence (restore path): try a fresh
            # frame and keep alignment by landing at want_slot.
            f = pool.take_free_frame(owner)
            if f is not None:
                self._active_frame[owner] = f
                pool.alloc_page(f, want_slot)
                self._note_partial(owner, f)
                self._unnote_if_full_or_released(owner, f)
                return pool.page_of(f, want_slot), True
        # 3. Pressure fallback: any free slot in this owner's partial frames
        #    (soft guarantee conserved; contiguity sacrificed).
        for f in list(self._partial_frames.get(owner, [])):
            if pool.frame_owner[f] != owner:
                self._partial_frames[owner].remove(f)
                continue
            slots = pool.free_slots(f)
            if slots:
                # Prefer the aligned slot if free, else any.
                s = want_slot if want_slot in slots else slots[0]
                pool.alloc_page(f, s)
                self._unnote_if_full_or_released(owner, f)
                return pool.page_of(f, s), s == want_slot
        # 4. Last resort even at slot != 0: brand-new frame, aligned slot.
        f = pool.take_free_frame(owner)
        if f is not None:
            self._active_frame[owner] = f
            pool.alloc_page(f, want_slot)
            self._note_partial(owner, f)
            self._unnote_if_full_or_released(owner, f)
            return pool.page_of(f, want_slot), True
        raise OutOfMemory(
            f"owner {owner}: no free frame and no partial-frame slot "
            f"(pool occupancy {pool.occupancy():.1%})"
        )

    # -- public API ---------------------------------------------------------------

    def alloc_en_masse(self, owner: int, table: PageTable, n_pages: int) -> List[int]:
        """Allocate ``n_pages`` new tail pages at once (prefill path).

        Fully covered virtual frames are coalesced immediately (paper steps
        5–6: CoCoA sends the frame list to the In-Place Coalescer).
        """
        fp = self.pool.config.frame_pages
        vpns: List[int] = []
        touched_vframes = set()
        for _ in range(n_pages):
            vpn = table.num_pages
            ppn, _ = self._alloc_slot(owner, table, vpn % fp)
            table.append(ppn)
            vpns.append(vpn)
            touched_vframes.add(table.vframe_of(vpn))
        self.coalescer.coalesce_all(table, touched_vframes)
        return vpns

    def append_page(self, owner: int, table: PageTable) -> int:
        """Allocate one tail page (decode growth path)."""
        fp = self.pool.config.frame_pages
        vpn = table.num_pages
        ppn, _ = self._alloc_slot(owner, table, vpn % fp)
        table.append(ppn)
        self.coalescer.maybe_coalesce(table, table.vframe_of(vpn))
        return vpn

    def forget_owner(self, owner: int) -> None:
        self._active_frame.pop(owner, None)
        self._partial_frames.pop(owner, None)

    def partial_frames(self, owner: int) -> List[int]:
        pool = self.pool
        return [
            f
            for f in self._partial_frames.get(owner, [])
            if pool.frame_owner[f] == owner
            and pool.frame_used[f] < pool.config.frame_pages
        ]
