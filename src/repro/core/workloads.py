"""Multi-application GPGPU workload generator for the TLB/paging simulator.

The paper evaluates 235 workloads built from 27 applications (Parboil, SHOC,
LULESH, Rodinia, CUDA SDK).  We cannot execute CUDA binaries; instead each
application is a *synthetic profile* — working-set size, access-pattern mix
(streaming / strided / hotspot), and memory intensity — chosen to span the
paper's range from TLB-friendly (high locality, small footprint) to
TLB-thrashing (large footprint, low locality).  Names mirror the suites for
readability; parameters are synthetic (disclosed in DESIGN.md §2).

Crucially, *allocation behaviour* is not synthetic: every workload allocates
its buffers through a real manager (:class:`MosaicManager` or
:class:`BaselineMMU`) with en-masse, per-buffer mallocs interleaved across
the concurrently-running applications — reproducing the paper's Fig. 2
setting where frame interleaving is what denies the baseline any coalescing
opportunity.  The resulting vpn→(ppn, frame, coalesced-bit) mapping is what
the TLB simulator translates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.manager import MosaicManager
from repro.core.baseline_mmu import BaselineMMU
from repro.core.pagepool import PoolConfig
from repro.core.tlb_sim import AppTrace

# Paper geometry: 4KB base pages, 2MB frames → 512 pages/frame.
PAPER_FRAME_PAGES = 512


@dataclasses.dataclass(frozen=True)
class AppProfile:
    """One application, at *macro-access* (page-dwell) granularity.

    A trace entry is one warp-dwell on one 4KB page; the warp issues
    ``page_repeat`` memory instructions into that page (cache-line
    iteration) taking ``gap_cycles`` of compute.  The TLB is consulted once
    per dwell — dwell-internal instructions hit the just-filled entry.
    """

    name: str
    ws_pages: int          # working set, in 4KB base pages
    n_access: int          # trace length (page dwells simulated)
    gap_cycles: int        # compute cycles per dwell (arithmetic intensity)
    p_stream: float        # fraction of sequential-scan dwells
    p_hot: float           # fraction of hotspot (reuse-heavy) dwells
    zipf_a: float = 1.2    # hotspot skew
    stride: int = 7        # page stride of the remaining dwells
    buffers: int = 6       # number of en-masse mallocs the app performs
    page_repeat: int = 24  # memory instructions per dwell (for reporting)


# 27 application profiles spanning the paper's suites (synthetic parameters:
# working sets 10MB–64MB, i.e. 5–32× the 128-entry L1 TLB reach and up to
# 8× the 512-entry L2 reach, matching the paper's "poor TLB reach" regime).
APP_PROFILES: Dict[str, AppProfile] = {
    p.name: p
    for p in [
        # Parboil
        AppProfile("sad",        8192, 24000, 420, 0.70, 0.15),
        AppProfile("histo",      4096, 24000, 520, 0.30, 0.55, 1.4),
        AppProfile("bfs",       16384, 24000, 300, 0.10, 0.35, 1.1),
        AppProfile("mri-q",      2560, 24000, 900, 0.80, 0.10),
        AppProfile("sgemm",      6144, 24000, 1100, 0.85, 0.10),
        AppProfile("spmv",      12288, 24000, 340, 0.25, 0.30, 1.1),
        AppProfile("stencil",    8192, 24000, 600, 0.90, 0.05),
        AppProfile("tpacf",      3072, 24000, 850, 0.50, 0.40, 1.5),
        AppProfile("lbm",       16384, 24000, 460, 0.92, 0.03),
        AppProfile("cutcp",      4096, 24000, 700, 0.60, 0.30, 1.3),
        # SHOC
        AppProfile("shoc-md",    6144, 24000, 640, 0.40, 0.40, 1.3),
        AppProfile("shoc-fft",   8192, 24000, 800, 0.75, 0.15),
        AppProfile("shoc-scan", 12288, 24000, 480, 0.95, 0.02),
        AppProfile("shoc-sort", 12288, 24000, 400, 0.55, 0.20),
        AppProfile("shoc-spmv", 16384, 24000, 320, 0.25, 0.30, 1.1),
        # LULESH
        AppProfile("lulesh",    16384, 24000, 540, 0.45, 0.25, 1.2),
        # Rodinia
        AppProfile("backprop",   4096, 24000, 680, 0.70, 0.20),
        AppProfile("gaussian",   2560, 24000, 760, 0.75, 0.20),
        AppProfile("hotspot",    4096, 24000, 720, 0.85, 0.10),
        AppProfile("kmeans",     8192, 24000, 440, 0.50, 0.35, 1.4),
        AppProfile("lud",        3072, 24000, 860, 0.80, 0.12),
        AppProfile("nw",         6144, 24000, 580, 0.88, 0.06),
        AppProfile("pathfinder", 8192, 24000, 620, 0.90, 0.05),
        AppProfile("srad",       8192, 24000, 560, 0.86, 0.08),
        # CUDA SDK
        AppProfile("blackscholes", 6144, 24000, 740, 0.95, 0.02),
        AppProfile("dct",        2560, 24000, 880, 0.80, 0.12),
        AppProfile("reduction", 12288, 24000, 500, 0.97, 0.01),
    ]
}

APP_NAMES: List[str] = sorted(APP_PROFILES)


def _gen_vpns(p: AppProfile, rng: np.random.Generator) -> np.ndarray:
    """Synthesize the virtual page access stream for one app."""
    n, ws = p.n_access, p.ws_pages
    kinds = rng.choice(
        3, size=n, p=[p.p_stream, p.p_hot, max(0.0, 1 - p.p_stream - p.p_hot)]
    )
    idx = np.arange(n)
    # Streaming: piecewise-sequential page sweeps, mean run length 64 pages.
    new_run = rng.random(n) < 1.0 / 64
    new_run[0] = True
    run_id = np.cumsum(new_run) - 1
    run_starts = rng.integers(0, ws, size=int(run_id[-1]) + 1)
    first_idx = np.maximum.accumulate(np.where(new_run, idx, 0))
    offset = idx - first_idx
    seq = (run_starts[run_id] + offset) % ws
    # Hotspot: zipf-ranked over a random permutation of the working set.
    ranks = rng.zipf(p.zipf_a, size=n) - 1
    perm = rng.permutation(ws)
    hot = perm[np.minimum(ranks, ws - 1)]
    # Strided: same run structure, wider page steps.
    strided = (run_starts[run_id] + p.stride * offset) % ws
    vpn = np.where(kinds == 0, seq, np.where(kinds == 1, hot, strided))
    return vpn.astype(np.int32)


def _manager(kind: str, total_pages: int) -> MosaicManager | BaselineMMU:
    cfg = PoolConfig(
        num_pages=total_pages,
        frame_pages=PAPER_FRAME_PAGES,
        page_tokens=1,  # 1 "token" == 1 base page for the simulator
    )
    return MosaicManager(cfg) if kind == "mosaic" else BaselineMMU(cfg)


def build_workload(
    names: Sequence[str],
    manager_kind: str,
    seed: int = 0,
    n_access: int | None = None,
) -> Tuple[List[AppTrace], object]:
    """Allocate + trace a multi-application workload through a real manager.

    Buffers are allocated round-robin across the applications (per-buffer
    en-masse mallocs) — the interleaving that defeats the baseline GPU-MMU's
    coalescing opportunities in the paper's Fig. 2.
    """
    rng = np.random.default_rng(seed)
    profiles = [APP_PROFILES[n] for n in names]
    total = sum(p.ws_pages for p in profiles)
    # Pool sized with 25% headroom, frame-aligned.
    pool_pages = int(np.ceil(total * 1.25 / PAPER_FRAME_PAGES)) * PAPER_FRAME_PAGES
    mgr = _manager(manager_kind, pool_pages)
    # Round-robin per-buffer allocation.  CUDA mallocs are base-page- but not
    # frame-aligned: jitter buffer sizes so they do not divide into 2MB
    # frames — the interleaving of paper Fig. 2 that denies the baseline any
    # coalescing opportunity (CoCoA is immune: it re-packs per owner).
    remaining = {i: p.ws_pages for i, p in enumerate(profiles)}
    chunk = {
        i: max(1, p.ws_pages // p.buffers) for i, p in enumerate(profiles)
    }
    live = set(remaining)
    while live:
        for i in sorted(live):
            jitter = int(rng.integers(-PAPER_FRAME_PAGES // 8,
                                      PAPER_FRAME_PAGES // 8))
            take = min(max(1, chunk[i] + jitter), remaining[i])
            mgr.allocate_tokens(i, take)
            remaining[i] -= take
            if remaining[i] == 0:
                live.discard(i)
    # Translate traces through each app's page table.
    traces = []
    for i, p in enumerate(profiles):
        table = mgr.table(i)
        ppn_of_vpn = np.asarray(table.ppn, dtype=np.int32)
        coalesced_of_vframe = np.asarray(table.coalesced, dtype=np.int8)
        prof = (
            p if n_access is None else dataclasses.replace(p, n_access=n_access)
        )
        vpn = _gen_vpns(prof, rng)
        ppn = ppn_of_vpn[vpn]
        frame = ppn // PAPER_FRAME_PAGES
        coalesced = coalesced_of_vframe[vpn // PAPER_FRAME_PAGES]
        traces.append(
            AppTrace(
                vpn=vpn,
                ppn=ppn,
                frame=frame,
                coalesced=coalesced,
                gap_cycles=p.gap_cycles,
                name=p.name,
                # The allocator's actual vpn→ppn map: the radix model
                # derives coalesced-entry coverage from it (DESIGN.md §15).
                ppn_map=ppn_of_vpn,
            )
        )
    return traces, mgr


def homogeneous_names(app: str, n: int) -> List[str]:
    return [app] * n


def heterogeneous_names(k: int, seed: int) -> List[str]:
    rng = np.random.default_rng(1000 + seed)
    return list(rng.choice(APP_NAMES, size=k, replace=False))
