"""GPU-MMU baseline memory manager (Power et al., HPCA 2014 analogue).

The paper's baseline (its Fig. 2): base pages are allocated from a global
free list with **no frame awareness** — pages of different applications
interleave inside large-page frames, so fully-mapped frames virtually always
contain pages from multiple protection domains and can never be coalesced
without mass migration.  We reproduce that policy faithfully:

* allocation = pop the next free base page (lowest physical address first),
  regardless of frame ownership or alignment;
* no soft guarantee, no in-place coalescer (it would simply never fire —
  which we *measure* rather than assume: the coalescer check is run and its
  ~0% success rate is reported), no CAC.

Implements the same interface as :class:`repro.core.manager.MosaicManager`
so every engine/benchmark can flip between managers.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

from repro.core import page_table as pt
from repro.core.compaction import CompactionPlan, CopyOp
from repro.core.cocoa import OutOfMemory
from repro.core.coalescer import InPlaceCoalescer
from repro.core.demand_paging import (
    DEFAULT_PAGE_BYTES,
    LinkModel,
    ResidencyTracker,
)
from repro.core.pagepool import FREE, PagePool, PoolConfig

_POOL_OWNER = 0  # PagePool sees one pseudo-owner; real owners tracked here.


class BaselineMMU:
    name = "gpu-mmu"

    def __init__(self, config: PoolConfig, *,
                 link: "LinkModel | None" = None, page_bytes: int = 0):
        self.config = config
        self.pool = PagePool(config)
        self.coalescer = InPlaceCoalescer(self.pool)
        # Same residency hooks as MosaicManager (DESIGN.md §6): demand
        # paging is manager-agnostic; only page *placement* differs, which
        # is exactly what the fault-DMA accounting measures.
        self.residency = ResidencyTracker(
            config.num_pages, page_bytes or DEFAULT_PAGE_BYTES, link)
        self.tables: Dict[int, pt.PageTable] = {}
        self.seq_tokens: Dict[int, int] = {}
        self.rmap: Dict[int, Tuple[int, int]] = {}
        self._free_pages: List[int] = list(range(config.num_pages))
        heapq.heapify(self._free_pages)
        # Which real owners have pages in each frame (paper Fig. 2 metric).
        self.frame_owner_sets: List[Set[int]] = [
            set() for _ in range(config.num_frames)
        ]
        # GPU-MMU is a 4KB-only design: it never *uses* large pages.  We
        # still count how often a frame happens to end up coalesceable, to
        # quantify the paper's "no opportunities without migration" claim.
        self.coalesce_opportunities = 0

    # -- owner lifecycle ---------------------------------------------------------

    def _table(self, owner: int) -> pt.PageTable:
        if owner not in self.tables:
            self.tables[owner] = pt.PageTable(self.config.frame_pages)
            self.seq_tokens[owner] = 0
        return self.tables[owner]

    def owners(self) -> List[int]:
        return sorted(self.tables)

    def table(self, owner: int) -> pt.PageTable:
        return self.tables[owner]

    # -- allocation ----------------------------------------------------------------

    def _alloc_page(self, owner: int) -> int:
        if not self._free_pages:
            raise OutOfMemory(f"baseline pool exhausted (owner {owner})")
        ppn = heapq.heappop(self._free_pages)
        f = self.pool.frame_of(ppn)
        if self.pool.frame_owner[f] == FREE:
            self.pool.take_specific_frame(f, _POOL_OWNER)
        self.pool.alloc_page(f, self.pool.slot_of(ppn))
        self.frame_owner_sets[f].add(owner)
        self.residency.mark_resident([ppn])
        return ppn

    def allocate_tokens(self, owner: int, n_tokens: int) -> List[int]:
        table = self._table(owner)
        have = (self.seq_tokens[owner] + self.config.page_tokens - 1) // self.config.page_tokens
        total = self.seq_tokens[owner] + n_tokens
        need = (total + self.config.page_tokens - 1) // self.config.page_tokens - have
        vpns = []
        for _ in range(need):
            ppn = self._alloc_page(owner)
            vpn = table.append(ppn)
            self.rmap[ppn] = (owner, vpn)
            vpns.append(vpn)
            # 4KB-only design: check (but never use) coalesceability, to
            # measure the paper's Fig. 2 claim that opportunities ~never arise.
            ok, _ = table.vframe_contiguous_aligned(table.vframe_of(vpn))
            self.coalesce_opportunities += int(ok)
        self.seq_tokens[owner] = total
        return vpns

    def append_tokens(self, owner: int, n_tokens: int = 1) -> List[int]:
        table = self._table(owner)
        new_vpns = []
        for _ in range(n_tokens):
            tok = self.seq_tokens[owner]
            if tok % self.config.page_tokens == 0:
                ppn = self._alloc_page(owner)
                vpn = table.append(ppn)
                self.rmap[ppn] = (owner, vpn)
                new_vpns.append(vpn)
                ok, _ = table.vframe_contiguous_aligned(table.vframe_of(vpn))
                self.coalesce_opportunities += int(ok)
            self.seq_tokens[owner] = tok + 1
        return new_vpns

    # -- deallocation -----------------------------------------------------------------

    def _free_ppn(self, owner: int, ppn: int) -> None:
        f = self.pool.frame_of(ppn)
        self.pool.free_page(ppn)  # releases the frame if it empties
        self.rmap.pop(ppn, None)
        self.residency.release([ppn])
        heapq.heappush(self._free_pages, ppn)
        owners_left = {
            self.rmap[p][0]
            for p in range(f * self.config.frame_pages,
                           (f + 1) * self.config.frame_pages)
            if p in self.rmap
        }
        self.frame_owner_sets[f] = owners_left

    def free_pages(self, owner: int, vpns: Sequence[int]) -> None:
        table = self.tables[owner]
        for vf in {table.vframe_of(v) for v in vpns}:
            self.coalescer.splinter(table, vf)
        for vpn in vpns:
            self._free_ppn(owner, table.unmap(vpn))

    def deallocate(self, owner: int) -> None:
        table = self.tables.pop(owner)
        for vf in range(table.num_vframes):
            self.coalescer.splinter(table, vf)
        for vpn in table.mapped_vpns():
            self._free_ppn(owner, table.unmap(vpn))
        self.seq_tokens.pop(owner, None)

    # -- compaction: the baseline has none ------------------------------------------------

    def compact(self, owner: int) -> CompactionPlan:
        return CompactionPlan([], [])

    def drain_copy_ops(self) -> List[CopyOp]:
        return []

    # -- kernel-facing views ----------------------------------------------------------------

    def pack(self, owners: Sequence[int], max_pages: int) -> Dict[str, np.ndarray]:
        packed = pt.pack_batch_tables(
            [self.tables[o] for o in owners], max_pages, self.config.frame_pages
        )
        packed["seq_tokens"] = np.asarray(
            [self.seq_tokens[o] for o in owners], dtype=np.int32
        )
        return packed

    # -- stats ----------------------------------------------------------------------------------

    def multi_owner_frames(self) -> int:
        return sum(len(s) > 1 for s in self.frame_owner_sets)

    def stats(self) -> Dict[str, float]:
        s = dict(self.pool.stats)
        s.update(
            occupancy=self.pool.occupancy(),
            coalesced_fraction=self.pool.coalesced_fraction(),
            memory_bloat=1.0,  # the baseline reserves nothing beyond use
            owners=len(self.tables),
            multi_owner_frames=self.multi_owner_frames(),
            coalesce_opportunities=self.coalesce_opportunities,
        )
        s.update(self.residency.stats)
        return s

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        seen = set()
        for owner, table in self.tables.items():
            for vpn in table.mapped_vpns():
                ppn = table.ppn[vpn]
                assert ppn not in seen, "page mapped twice"
                seen.add(ppn)
                assert self.rmap.get(ppn) == (owner, vpn)
                assert self.pool.page_allocated[ppn]
        assert len(seen) == len(self.rmap)
        assert not (self.residency.resident
                    & ~self.pool.page_allocated).any(), \
            "resident bit on unallocated page"
