"""Mosaic memory manager facade: CoCoA + In-Place Coalescer + CAC.

This is the object the serving engine talks to.  It tracks one
:class:`PageTable` per owner (request / protection domain), the global
reverse map ppn→(owner, vpn) needed by compaction, and token-level sizes.

The same interface is implemented by
:class:`repro.core.baseline_mmu.BaselineMMU` (the GPU-MMU baseline of
Power et al. used throughout the paper's evaluation), so engines and
benchmarks can swap managers with one flag.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import page_table as pt
from repro.core.coalescer import InPlaceCoalescer
from repro.core.cocoa import CoCoA, OutOfMemory
from repro.core.compaction import CAC, CompactionPlan, CopyOp
from repro.core.demand_paging import (
    DEFAULT_PAGE_BYTES,
    LinkModel,
    ResidencyTracker,
)
from repro.core.pagepool import PagePool, PoolConfig


def pages_for_tokens(n_tokens: int, page_tokens: int) -> int:
    return (n_tokens + page_tokens - 1) // page_tokens


class MosaicManager:
    name = "mosaic"

    def __init__(self, config: PoolConfig, *,
                 link: Optional[LinkModel] = None, page_bytes: int = 0):
        self.config = config
        self.pool = PagePool(config)
        self.coalescer = InPlaceCoalescer(self.pool)
        self.cocoa = CoCoA(self.pool, self.coalescer)
        self.cac = CAC(self.pool, self.coalescer)
        self.tables: Dict[int, pt.PageTable] = {}
        self.seq_tokens: Dict[int, int] = {}
        self.rmap: Dict[int, Tuple[int, int]] = {}
        self._pending_copies: List[CopyOp] = []
        # Host-tier residency (DESIGN.md §6): same hooks as BaselineMMU so
        # engines/benchmarks measure demand paging under either manager.
        self.residency = ResidencyTracker(
            config.num_pages, page_bytes or DEFAULT_PAGE_BYTES, link)

    # -- owner lifecycle ---------------------------------------------------------

    def _table(self, owner: int) -> pt.PageTable:
        if owner not in self.tables:
            self.tables[owner] = pt.PageTable(self.config.frame_pages)
            self.seq_tokens[owner] = 0
        return self.tables[owner]

    def owners(self) -> List[int]:
        return sorted(self.tables)

    def table(self, owner: int) -> pt.PageTable:
        return self.tables[owner]

    # -- allocation --------------------------------------------------------------

    def allocate_tokens(self, owner: int, n_tokens: int) -> List[int]:
        """En-masse allocation for ``n_tokens`` (prefill).  Returns new vpns."""
        table = self._table(owner)
        have = pages_for_tokens(self.seq_tokens[owner], self.config.page_tokens)
        need = pages_for_tokens(self.seq_tokens[owner] + n_tokens,
                                self.config.page_tokens) - have
        vpns = self._with_compaction_retry(
            owner, lambda: self.cocoa.alloc_en_masse(owner, table, need)
        )
        for vpn in vpns:
            self.rmap[table.ppn[vpn]] = (owner, vpn)
        self.residency.mark_resident([table.ppn[v] for v in vpns])
        self.seq_tokens[owner] += n_tokens
        return vpns

    def append_tokens(self, owner: int, n_tokens: int = 1) -> List[int]:
        """Decode-time growth; allocates pages lazily at page boundaries."""
        table = self._table(owner)
        new_vpns: List[int] = []
        for _ in range(n_tokens):
            tok = self.seq_tokens[owner]
            if tok % self.config.page_tokens == 0:
                vpn = self._with_compaction_retry(
                    owner, lambda: self.cocoa.append_page(owner, table)
                )
                self.rmap[table.ppn[vpn]] = (owner, vpn)
                self.residency.mark_resident([table.ppn[vpn]])
                new_vpns.append(vpn)
            self.seq_tokens[owner] = tok + 1
        return new_vpns

    def _with_compaction_retry(self, owner: int, fn):
        try:
            return fn()
        except OutOfMemory:
            # Paper step 9–10: compaction frees frames for future allocations.
            for o in self.owners():
                self.compact(o)
            return fn()

    # -- deallocation --------------------------------------------------------------

    def free_pages(self, owner: int, vpns: Sequence[int]) -> None:
        """Partial dealloc (eviction/trim): splinter + unmap + CAC check."""
        table = self.tables[owner]
        self.cac.splinter_for_dealloc(table, vpns)
        for vpn in vpns:
            ppn = table.unmap(vpn)
            self.rmap.pop(ppn, None)
            self.pool.free_page(ppn)
            self.residency.release([ppn])
        self.compact(owner)

    def deallocate(self, owner: int) -> None:
        """Full owner teardown (kernel/request completion)."""
        table = self.tables.pop(owner)
        for vf in range(table.num_vframes):
            self.coalescer.splinter(table, vf)
        for vpn in table.mapped_vpns():
            ppn = table.unmap(vpn)
            self.rmap.pop(ppn, None)
            self.pool.free_page(ppn)
            self.residency.release([ppn])
        self.seq_tokens.pop(owner, None)
        self.cocoa.forget_owner(owner)

    # -- compaction ---------------------------------------------------------------

    def compact(self, owner: int) -> CompactionPlan:
        if owner not in self.tables:
            return CompactionPlan([], [])
        plan = self.cac.compact_owner(owner, self.tables[owner], self.rmap)
        for op in plan.copies:
            # Residency moves with the payload: a host-backed (non-resident)
            # page stays host-backed at its new physical location.
            self.residency.on_copy(op.src_ppn, op.dst_ppn)
        self._pending_copies.extend(plan.copies)
        return plan

    def drain_copy_ops(self) -> List[CopyOp]:
        """Device copies the engine must execute (page_compact kernel)."""
        ops, self._pending_copies = self._pending_copies, []
        return ops

    # -- kernel-facing views ---------------------------------------------------------

    def pack(self, owners: Sequence[int], max_pages: int) -> Dict[str, np.ndarray]:
        packed = pt.pack_batch_tables(
            [self.tables[o] for o in owners], max_pages, self.config.frame_pages
        )
        packed["seq_tokens"] = np.asarray(
            [self.seq_tokens[o] for o in owners], dtype=np.int32
        )
        return packed

    # -- stats -------------------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        s = dict(self.pool.stats)
        s.update(
            occupancy=self.pool.occupancy(),
            coalesced_fraction=self.pool.coalesced_fraction(),
            memory_bloat=self.pool.memory_bloat(),
            owners=len(self.tables),
        )
        s.update(self.residency.stats)
        return s

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        # Cross-structure: every mapped page appears in rmap exactly once and
        # coalesced bits imply contiguity+alignment (I6/I7 in tests).
        seen = set()
        for owner, table in self.tables.items():
            for vpn in table.mapped_vpns():
                ppn = table.ppn[vpn]
                assert ppn not in seen, "page mapped twice"
                seen.add(ppn)
                assert self.rmap.get(ppn) == (owner, vpn), "rmap mismatch"
                assert self.pool.page_allocated[ppn], "mapped page not allocated"
                f = self.pool.frame_of(ppn)
                assert self.pool.frame_owner[f] == owner, "soft guarantee violated"
            for vf, c in enumerate(table.coalesced):
                if c:
                    ok, _ = table.vframe_contiguous_aligned(vf)
                    assert ok, "coalesced bit on non-contiguous vframe"
        assert len(seen) == len(self.rmap), "stale rmap entries"
        # Residency ⊆ allocation: a free page never claims a device payload.
        assert not (self.residency.resident
                    & ~self.pool.page_allocated).any(), \
            "resident bit on unallocated page"
