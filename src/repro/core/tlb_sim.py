"""Paper-faithful TLB + page-table-walk + demand-paging timing simulator.

This module reproduces the *evaluation apparatus* of the MICRO'17 paper
(§3, Table 1) so that our CoCoA/coalescer/CAC implementations can be
validated against the paper's own claims (Figs. 1, 5, 6, 7, 8):

  * per-core L1 TLB: 128 base-page + 16 large-page entries, LRU, 1 cycle;
  * shared L2 TLB: 512 base + 256 large entries, LRU, 10-cycle latency;
  * shared page-table walker, 64 concurrent walks, each walk = 4 serialized
    memory accesses (x86-64 radix table, as in Power et al.);
  * MSHRs merging duplicate in-flight walks;
  * demand paging over the system I/O bus (PCIe model: setup + per-byte);
  * GTO-style warp issue: W warps per app round-robin their memory trace;
    a warp blocks until translation + fault resolve — so one miss stalls
    every warp that touches the page, the paper's core TLP argument.

Deliberate simplifications (disclosed; see DESIGN.md §2):
  * one aggregate L1 TLB per application instead of one per SM (warps of an
    app see the same working set; per-SM replication changes constants, not
    trends);
  * TLB set-associativity modeled as full-LRU;
  * compute between memory ops collapses to a fixed ``gap_cycles`` drawn
    per app profile (paper's IPC differences across apps live here);
  * DRAM bandwidth contention beyond the walker queue is not modeled.

Performance metric: retired accesses / cycle ("IPC" up to the constant
instructions-per-access factor), and the paper's weighted speedup
``Σ IPC_shared / IPC_alone`` with IPC_alone measured on the baseline
GPU-MMU manager with the same core count (paper §3).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.demand_paging import LinkModel
from repro.core.pagepool import PoolConfig


# --------------------------------------------------------------------------- config


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Table 1 of the paper, plus trace/issue parameters."""

    # TLB hierarchy (entries).
    l1_base_entries: int = 128
    l1_large_entries: int = 16
    l2_base_entries: int = 512
    l2_large_entries: int = 256
    l1_latency: int = 1
    l2_latency: int = 10
    # Page table walker.
    walker_slots: int = 64
    walk_levels: int = 4
    dram_latency: int = 160          # cycles per serialized walk access
    # Translation model (DESIGN.md §15).  "flat" charges every TLB miss a
    # constant ``walk_levels × dram_latency`` (the pre-§15 model, kept
    # verbatim — bitwise-identical timings).  "radix" routes misses
    # through :class:`repro.core.ptw.RadixWalker` — per-level page-walk
    # caches skip already-cached upper levels — and replaces per-page TLB
    # entries with subregion-coalesced ones whose reach is derived from
    # the actual frame map the allocator produced (CoCoA's contiguity ⇒
    # one entry covers a run of pages; the oracle ``coalesced`` bit is
    # ignored).  With ``pwc_entries=0`` and ``coalesce_span=1`` radix is
    # cycle-identical to flat (the parity the ptw tests pin).
    translation: str = "flat"
    pwc_entries: int = 64            # per-level walk-cache entries (0 = off)
    pwc_latency: int = 2             # charged once when a PWC skips levels
    coalesce_span: int = 32          # subregion size in base pages (1 = flat
    #                                  per-page entries)
    radix_bits: int = 9              # index bits per radix level
    # Issue model.  One trace access is a *macro-access*: a warp's full dwell
    # on one 4KB page (it issues `page_repeat` memory instructions into that
    # page — cache-line iteration).  ``AppTrace.gap_cycles`` is the dwell
    # time; translation is looked up once per dwell, which is exactly how a
    # TLB behaves (the dwell's remaining accesses hit the same entry).
    warps_per_app: int = 32
    # Demand paging.
    paging: bool = True
    warm: bool = False               # True: working set pre-resident (steady state)
    page_bytes: int = 4096           # paper's base page
    # Trace-scale amortization: our simulated window is ~1/K of the interval
    # between kernel launches in the paper's billion-cycle runs, but cold
    # faults all land inside it.  Dividing fault cost by K restores the
    # fault-to-compute ratio of the full-length run (disclosed; swept in the
    # Fig. 7 benchmark with K=1 as the worst case).
    fault_amortize: int = 16
    # Host↔device DMA channels on the shared link (serving/dma.py's overlap
    # model, transplanted): 1 = the paper's single serialized bus; >1 lets
    # transfers of different apps proceed concurrently, shrinking the
    # cross-app interference the contention stats measure.
    dma_channels: int = 1
    # Full-duplex link (serving/dma.py's model, DESIGN.md §8): inbound
    # faults and outbound writebacks get independent per-channel
    # timelines; False degrades to half-duplex, where eviction traffic
    # queues against fault-ins on the same channels.
    duplex: bool = True
    # Device-memory cap per app in resident base pages (None = unbounded,
    # the paper's cold-fault-only model).  With a cap, a fault past the
    # cap evicts the app's LRU page first — an outbound writeback on the
    # link — so the sim generates the two-direction traffic the duplex
    # model distinguishes.
    hbm_pages_per_app: Optional[int] = None
    # Cluster tier (DESIGN.md §10): engines each own `dma_channels`
    # host↔device lanes (apps are striped app % n_engines), so per-engine
    # links remove cross-engine *link* contention — but with a shared
    # host tier every transfer must also occupy one of `host_lanes` host
    # DRAM lanes, the new shared bottleneck.  host_lanes=0 leaves the
    # host store unmodeled (pre-cluster behavior, and the default).
    n_engines: int = 1
    host_lanes: int = 0
    # Disk spill tier under the host store (DESIGN.md §11): each outbound
    # writeback, after its link transfer, streams from host DRAM to disk
    # on one of `disk_lanes` lanes at `disk_cycles_per_page` occupancy
    # (amortized by fault_amortize like every other per-page cost).
    # Disk is ~an order of magnitude slower than the link, so a burst of
    # evictions queues at the disk — the write-back back-pressure the
    # serving tier's bounded buffer models; `disk_contention_cycles`
    # measures exactly that queueing.  disk_lanes=0 leaves the disk
    # unmodeled (the default, pre-§11 behavior).
    disk_lanes: int = 0
    disk_cycles_per_page: float = 4000.0
    clock_ghz: float = 1.02          # shader clock (Table 1: 1020 MHz)
    link: LinkModel = dataclasses.field(default_factory=LinkModel)
    # Page-size mode: "mosaic" uses per-frame coalesced bits from the
    # allocator; "base" forces 4KB-only; "large" forces 2MB-only (Fig. 1's
    # GPU-MMU-2MB design: same entry *counts* as the 4KB design).
    mode: str = "mosaic"
    ideal: bool = False              # ideal TLB: every lookup hits in L1

    @property
    def walk_latency(self) -> int:
        return self.walk_levels * self.dram_latency

    def fault_cycles(self, nbytes: int) -> float:
        return self.link.transfer_us(nbytes) * self.clock_ghz * 1e3


# --------------------------------------------------------------------------- pieces


class LRU:
    """Fully-associative LRU cache of hashable tags."""

    __slots__ = ("cap", "d", "hits", "misses")

    def __init__(self, cap: int):
        self.cap = cap
        self.d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, tag) -> bool:
        if tag in self.d:
            self.d.move_to_end(tag)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert(self, tag) -> None:
        if tag in self.d:
            self.d.move_to_end(tag)
            return
        if len(self.d) >= self.cap and self.cap > 0:
            self.d.popitem(last=False)
        if self.cap > 0:
            self.d[tag] = True

    @property
    def rate(self) -> float:
        n = self.hits + self.misses
        # A never-touched cache has no hit rate; nan (not 1.0) keeps it
        # from reading as a perfect cache in bench tables.
        return self.hits / n if n else float("nan")


class Walker:
    """Shared page-table walker: ``slots`` concurrent walks, FIFO overflow."""

    def __init__(self, slots: int, walk_latency: int):
        self.slots = slots
        self.walk_latency = walk_latency
        self._busy: List[float] = []   # heap of finish times
        self.walks = 0
        self.stall_cycles = 0.0

    def start(self, now: float) -> float:
        """Returns the completion time of a walk requested at ``now``."""
        while self._busy and self._busy[0] <= now:
            heapq.heappop(self._busy)
        if len(self._busy) < self.slots:
            begin = now
        else:
            begin = heapq.heappop(self._busy)   # wait for a slot
            self.stall_cycles += begin - now
        finish = begin + self.walk_latency
        heapq.heappush(self._busy, finish)
        self.walks += 1
        return finish


class Link:
    """System I/O bus: bandwidth-serialized, setup-pipelined (demand paging).

    DMA setup overlaps with in-flight transfers (real PCIe queues many
    descriptors), so the bus *occupancy* per fault is bytes/bandwidth, while
    the faulting warp's *latency* additionally pays the setup cost.

    ``cfg.dma_channels`` transplants the serving engine's overlap model
    (:mod:`repro.serving.dma`): each transfer rides the earliest-free
    channel, so with one channel the bus serializes exactly as in the
    paper, while extra channels let different apps' faults overlap.  The
    queueing delay a fault pays because the shared link is busy — almost
    always with *another* app's transfer in a multi-app run — is tracked
    per app in ``contention_cycles``.

    The link is **full-duplex** by default (``cfg.duplex``, DESIGN.md
    §8): outbound writebacks (capacity evictions under
    ``cfg.hbm_pages_per_app``) occupy their own per-channel timelines
    and contend only with each other (``contention_cycles_out``); with
    ``duplex=False`` both directions share one timeline, so eviction
    traffic queues *inbound* faults too — the half-duplex penalty the
    duplex benches measure.
    """

    def __init__(self, cfg: SimConfig, n_apps: int = 1):
        self.cfg = cfg
        n = max(1, cfg.dma_channels)
        E = max(1, cfg.n_engines)
        # Per-engine link lanes (DESIGN.md §10): engine e's inbound lanes
        # are _lanes_in[e]; a single-engine sim degenerates to the
        # pre-cluster model exactly.
        self._lanes_in = [[0.0] * n for _ in range(E)]
        # Half-duplex shares the same list objects (either direction's
        # transfer occupies the single per-channel timeline).
        self._lanes_out = [[0.0] * n for _ in range(E)] if cfg.duplex \
            else self._lanes_in
        # Legacy aliases (engine 0) so existing single-engine callers and
        # tests keep reading the same attributes.
        self.channel_busy = self._lanes_in[0]
        self.channel_busy_out = self._lanes_out[0]
        # Shared host-store DRAM lanes: every transfer of every engine,
        # both directions, must also book one (host DRAM bandwidth is
        # direction-agnostic).  Empty list = unmodeled.
        self._host_lanes = [0.0] * max(0, cfg.host_lanes)
        # Disk spill lanes under the host store (DESIGN.md §11): every
        # writeback streams on to disk after its link transfer.  Empty
        # list = unmodeled.
        self._disk_lanes = [0.0] * max(0, cfg.disk_lanes)
        self.faults = 0
        self.fault_cycles_total = 0.0
        self.contention_cycles = [0.0] * n_apps         # inbound, link
        self.writebacks = 0
        self.writeback_cycles_total = 0.0
        self.contention_cycles_out = [0.0] * n_apps
        # Queueing a transfer pays at the shared host store *after* its
        # link lane is free — the cluster-tier bottleneck stat.
        self.host_contention_cycles = [0.0] * n_apps
        # Writebacks that queued at the (slower) disk after their link
        # transfer — the §11 write-back saturation signal.
        self.disk_writebacks = 0
        self.disk_busy_cycles = 0.0
        self.disk_contention_cycles = [0.0] * n_apps

    @property
    def busy_until(self) -> float:
        return max(max(max(l) for l in self._lanes_in),
                   max(max(l) for l in self._lanes_out))

    def engine_occupancy(self, now: float, engine: int = 0) -> float:
        """Cost-model parity hook (DESIGN.md §14): one engine's modeled
        lane backlog, in cycles — booked time beyond ``now`` on the
        engine's own link lanes plus its share of the host-store and
        disk lanes.  This mirrors the link-/host-/disk-lane occupancy
        terms the serving router's modeled-µs dispatch cost charges, so
        the sim and the router agree (monotonically) on which engine is
        more loaded: booking more traffic on an engine's lanes can only
        raise its occupancy, never lower it.
        """
        e = engine % len(self._lanes_in)
        backlog = sum(max(0.0, t - now) for t in self._lanes_in[e])
        if self._lanes_out[e] is not self._lanes_in[e]:    # duplex only
            backlog += sum(max(0.0, t - now) for t in self._lanes_out[e])
        for shared in (self._host_lanes, self._disk_lanes):
            if shared:
                backlog += sum(max(0.0, t - now) for t in shared)
        return backlog

    def _occupy(self, lanes, now: float, transfer: float):
        ch = min(range(len(lanes)), key=lambda i: lanes[i])
        begin = max(now, lanes[ch])
        lanes[ch] = begin + transfer
        return begin

    def _book(self, lanes, now: float, transfer: float, app: int) -> float:
        """Occupy a link lane and, when modeled, a shared host-store
        lane; the transfer starts when *both* are free.  Returns the
        start time; host-store queueing beyond the link's own is
        attributed to ``host_contention_cycles``."""
        link_begin = self._occupy(lanes, now, transfer)
        if not self._host_lanes:
            return link_begin
        h = min(range(len(self._host_lanes)),
                key=lambda i: self._host_lanes[i])
        begin = max(link_begin, self._host_lanes[h])
        self._host_lanes[h] = begin + transfer
        if begin > link_begin:
            # The link lane sat idle waiting for host DRAM: re-point its
            # busy horizon at the true completion.
            ch = lanes.index(link_begin + transfer)
            lanes[ch] = begin + transfer
        if app < len(self.host_contention_cycles):
            self.host_contention_cycles[app] += begin - link_begin
        return begin

    def _costs(self):
        c = self.cfg
        k = max(1, c.fault_amortize)
        transfer = (c.page_bytes / (c.link.bandwidth_GBps * 1e9)) \
            * c.clock_ghz * 1e9 / k
        setup = c.link.setup_us * c.clock_ghz * 1e3 / k
        return transfer, setup

    def fault(self, now: float, app: int = 0, engine: int = 0) -> float:
        transfer, setup = self._costs()
        lanes = self._lanes_in[engine % len(self._lanes_in)]
        free_at = min(lanes)
        begin = self._book(lanes, now, transfer, app)
        fin = begin + setup + transfer              # faulting warp's latency
        self.faults += 1
        self.fault_cycles_total += fin - now
        if app < len(self.contention_cycles):
            self.contention_cycles[app] += max(free_at - now, 0.0)
        return fin

    def writeback(self, now: float, app: int = 0, engine: int = 0) -> float:
        """Outbound device→host eviction transfer.

        Write-back buffering keeps it off the faulting warp's critical
        path — the return value is the channel-occupancy end, not a warp
        stall — but the transfer occupies an "out" lane (or, when
        half-duplex, the shared lane, where it queues future faults) and,
        in a cluster, a shared host-store lane.
        """
        transfer, _setup = self._costs()
        lanes = self._lanes_out[engine % len(self._lanes_out)]
        free_at = min(lanes)
        begin = self._book(lanes, now, transfer, app)
        self.writebacks += 1
        self.writeback_cycles_total += begin + transfer - now
        if app < len(self.contention_cycles_out):
            self.contention_cycles_out[app] += max(free_at - now, 0.0)
        end = begin + transfer
        if self._disk_lanes:
            # §11 spill: after the link transfer lands in host DRAM the
            # frame streams on to disk.  Disk pages cost far more than
            # link pages, so the lane backlog — not the link — is what
            # stalls further evictions; that wait is the back-pressure
            # the serving tier's bounded write-back queue reacts to.
            disk_cost = self.cfg.disk_cycles_per_page \
                / max(1, self.cfg.fault_amortize)
            lane = min(range(len(self._disk_lanes)),
                       key=self._disk_lanes.__getitem__)
            dbegin = max(end, self._disk_lanes[lane])
            self._disk_lanes[lane] = dbegin + disk_cost
            self.disk_writebacks += 1
            self.disk_busy_cycles += disk_cost
            if app < len(self.disk_contention_cycles):
                self.disk_contention_cycles[app] += dbegin - end
            end = dbegin + disk_cost
        return end

    def contention_total(self) -> float:
        return float(sum(self.contention_cycles))

    def contention_out_total(self) -> float:
        return float(sum(self.contention_cycles_out))

    def host_contention_total(self) -> float:
        return float(sum(self.host_contention_cycles))

    def disk_contention_total(self) -> float:
        return float(sum(self.disk_contention_cycles))


# --------------------------------------------------------------------------- traces


@dataclasses.dataclass
class AppTrace:
    """A translated memory trace: per access, the physical tag info.

    vpn:        virtual page per access            int32[T]
    ppn:        physical page per access           int32[T]
    frame:      physical frame per access          int32[T]
    coalesced:  1 if the page's frame is coalesced int8[T]
    gap_cycles: per-app compute gap between a warp's accesses
    name:       profile name (for reporting)
    """

    vpn: np.ndarray
    ppn: np.ndarray
    frame: np.ndarray
    coalesced: np.ndarray
    gap_cycles: int
    name: str = "app"
    # Full vpn→ppn map of the app's address space (UNMAPPED = -1), as the
    # allocator produced it.  The radix model derives coalesced-entry
    # coverage from it; None falls back to the map induced by the trace
    # pairs themselves (sufficient for synthetic traces).
    ppn_map: Optional[np.ndarray] = None


# --------------------------------------------------------------------------- simulator


@dataclasses.dataclass
class AppResult:
    name: str
    retired: int
    cycles: float
    l1_hit: float
    l2_hit: float
    faults: int
    # Radix-walker accounting (DESIGN.md §15); zeros/nan under the flat
    # model, which only tracks walker-wide totals.
    walks: int = 0
    walk_cycles: float = 0.0         # latency past the L2 miss, summed
    walk_queue_cycles: float = 0.0   # slot-queue wait (walker interference)
    pwc_hit: float = float("nan")    # walk-cache hit rate of the app's walker

    @property
    def ipc(self) -> float:
        return self.retired / max(self.cycles, 1.0)


class TranslationSim:
    """Event-driven multi-application TLB/paging simulator."""

    def __init__(self, cfg: SimConfig, apps: Sequence[AppTrace]):
        if cfg.translation not in ("flat", "radix"):
            raise ValueError(
                f"SimConfig.translation must be 'flat' or 'radix', "
                f"got {cfg.translation!r}")
        self.cfg = cfg
        self.apps = list(apps)
        n = len(self.apps)
        # Private-per-app L1s; shared L2, walker, link (paper Table 1).
        self.l1_base = [LRU(cfg.l1_base_entries) for _ in range(n)]
        self.l1_large = [LRU(cfg.l1_large_entries) for _ in range(n)]
        self.l2_base = LRU(cfg.l2_base_entries)
        self.l2_large = LRU(cfg.l2_large_entries)
        self.walker = Walker(cfg.walker_slots, cfg.walk_latency)
        self.link = Link(cfg, n_apps=n)
        if cfg.translation == "radix":
            from repro.core.ptw import (CoalescedTLB, RadixWalker,
                                        subregion_entry)
            self._mk_entry = subregion_entry
            span = max(1, cfg.coalesce_span)
            # One coalesced entry replaces a base+large entry pair: give
            # the coalesced arrays the combined entry budget so radix
            # isn't quietly handed extra capacity.
            self.l1_co = [
                CoalescedTLB(cfg.l1_base_entries + cfg.l1_large_entries,
                             span)
                for _ in range(n)]
            self.l2_co = CoalescedTLB(
                cfg.l2_base_entries + cfg.l2_large_entries, span)
            # Per-engine walkers (the cluster tier gives each engine its
            # own MMU, like its own link lanes); single-engine degenerates
            # to one shared walker.  ``self.walker`` above still exists
            # but never starts a walk in radix mode; ``self.walkers`` is
            # the accounting surface.
            E = max(1, cfg.n_engines)
            self.walkers = [
                RadixWalker(cfg.walker_slots, cfg.walk_levels,
                            cfg.dram_latency, pwc_entries=cfg.pwc_entries,
                            pwc_latency=cfg.pwc_latency, bits=cfg.radix_bits,
                            n_apps=n)
                for _ in range(E)]
            # Per-app vpn→ppn maps drive coalesced-entry coverage: the
            # allocator's own map when the trace carries one, else the
            # map induced by the trace's (vpn, ppn) pairs.
            self.ppn_maps: List[np.ndarray] = []
            for tr in self.apps:
                if tr.ppn_map is not None:
                    self.ppn_maps.append(
                        np.asarray(tr.ppn_map, dtype=np.int64))
                else:
                    size = int(tr.vpn.max()) + 1 if len(tr.vpn) else 1
                    m = np.full(size, -1, dtype=np.int64)
                    m[tr.vpn] = tr.ppn
                    self.ppn_maps.append(m)
        # Per-app resident pages in LRU order (OrderedDict preserves the
        # set-like membership tests while supporting capacity eviction).
        self.resident: List[OrderedDict] = [OrderedDict() for _ in range(n)]
        self.fault_count = [0] * n
        self.mshr: Dict[Tuple[int, int, bool], float] = {}

    # -- one translation ---------------------------------------------------------

    def _translate_radix(self, now: float, app: int, i: int) -> float:
        """Radix path (DESIGN.md §15): subregion-coalesced L1/L2 lookup,
        then a multi-level walk on the app's engine's walker.  Tags come
        from the *virtual* subregion — the page-size ``mode`` and the
        oracle ``coalesced`` bit are ignored; an entry's reach is however
        much contiguity the allocator actually preserved in the frame
        map."""
        cfg = self.cfg
        tr = self.apps[app]
        vpn = int(tr.vpn[i])
        span = max(1, cfg.coalesce_span)
        sreg, off = divmod(vpn, span)
        l1 = self.l1_co[app]
        if l1.lookup(sreg, off) is not None:
            return now + cfg.l1_latency
        e = self.l2_co.lookup((app, sreg), off)
        if e is not None:
            l1.insert(sreg, e)
            return now + cfg.l1_latency + cfg.l2_latency
        t0 = now + cfg.l1_latency + cfg.l2_latency
        walker = self.walkers[app % len(self.walkers)]
        done = walker.walk(now, t0, app, vpn, (app, sreg))
        entry = self._mk_entry(self.ppn_maps[app], vpn, span)
        self.l2_co.insert((app, sreg), entry)
        l1.insert(sreg, entry)
        return done

    def splinter(self, app: int, vpn: int,
                 new_ppn: Optional[int] = None) -> None:
        """CoCoA splintered/remapped one page: update the app's frame map
        and invalidate only the touched subregion's coalesced entries.
        PWCs are untouched — the upper-level radix entries still point at
        the same intermediate tables (hardware-faithful selectivity the
        ptw property tests pin)."""
        if self.cfg.translation != "radix":
            return
        if new_ppn is not None:
            m = self.ppn_maps[app]
            if vpn >= len(m):
                grown = np.full(vpn + 1, -1, dtype=np.int64)
                grown[: len(m)] = m
                self.ppn_maps[app] = m = grown
            m[vpn] = new_ppn
        sreg = vpn // max(1, self.cfg.coalesce_span)
        self.l1_co[app].invalidate(sreg)
        self.l2_co.invalidate((app, sreg))

    def translate(self, now: float, app: int, i: int) -> float:
        """Returns the cycle at which the translation (and fault) resolves."""
        cfg = self.cfg
        tr = self.apps[app]
        if cfg.translation == "radix" and not cfg.ideal:
            done = self._translate_radix(now, app, i)
            return self._page_in(done, now, app, i)
        if cfg.mode == "large":
            large = True
        elif cfg.mode == "base":
            large = False
        else:
            large = bool(tr.coalesced[i])
        tag = int(tr.frame[i]) if large else int(tr.ppn[i])

        if cfg.ideal:
            done = now + cfg.l1_latency
        else:
            l1 = (self.l1_large if large else self.l1_base)[app]
            l2 = self.l2_large if large else self.l2_base
            if l1.lookup(tag):
                done = now + cfg.l1_latency
            elif l2.lookup((app, tag)):
                l1.insert(tag)
                done = now + cfg.l1_latency + cfg.l2_latency
            else:
                key = (app, tag, large)
                t0 = now + cfg.l1_latency + cfg.l2_latency
                if key in self.mshr and self.mshr[key] > now:
                    done = self.mshr[key]       # merged into in-flight walk
                else:
                    done = self.walker.start(t0)
                    self.mshr[key] = done
                l2.insert((app, tag))
                l1.insert(tag)

        return self._page_in(done, now, app, i)

    def _page_in(self, done: float, now: float, app: int, i: int) -> float:
        """Demand paging: first touch of a base page faults it in.
        (Transfers are always base-page-granular — Mosaic's point; the
        *translation* above may still be large.)  Under an HBM capacity
        cap, faulting past the cap first writes the LRU resident page
        back to host — outbound traffic on the (duplex) link.  Shared
        verbatim by the flat and radix translation paths."""
        cfg = self.cfg
        tr = self.apps[app]
        if cfg.paging and not cfg.warm:
            ppn = int(tr.ppn[i])
            res = self.resident[app]
            # Cluster striping (DESIGN.md §10): app a runs on engine
            # a % n_engines and uses that engine's link lanes.
            engine = app % max(1, cfg.n_engines)
            if ppn in res:
                res.move_to_end(ppn)
            else:
                cap = cfg.hbm_pages_per_app
                if cap is not None and len(res) >= cap:
                    res.popitem(last=False)         # evict LRU
                    self.link.writeback(now, app, engine)
                res[ppn] = True
                self.fault_count[app] += 1
                done = max(done, self.link.fault(now, app, engine))
        return done

    # -- main loop -----------------------------------------------------------------

    def run(self, max_accesses: Optional[int] = None) -> List[AppResult]:
        cfg = self.cfg
        W = cfg.warps_per_app
        events: List[Tuple[float, int, int, int]] = []  # (time, app, warp, idx)
        ptr_step = W
        for a, tr in enumerate(self.apps):
            T = len(tr.vpn) if max_accesses is None else min(len(tr.vpn), max_accesses)
            for w in range(min(W, T)):
                heapq.heappush(events, (float(w % 7), a, w, w))
        retired = [0] * len(self.apps)
        finish_time = [0.0] * len(self.apps)
        lengths = [
            len(tr.vpn) if max_accesses is None else min(len(tr.vpn), max_accesses)
            for tr in self.apps
        ]
        while events:
            now, a, w, i = heapq.heappop(events)
            done = self.translate(now, a, i)
            retired[a] += 1
            finish_time[a] = max(finish_time[a], done)
            nxt = i + ptr_step
            if nxt < lengths[a]:
                heapq.heappush(
                    events, (done + self.apps[a].gap_cycles, a, w, nxt)
                )
        out = []
        radix = cfg.translation == "radix"
        for a, tr in enumerate(self.apps):
            if radix:
                h, m = self.l1_co[a].hits, self.l1_co[a].misses
                wk = self.walkers[a % len(self.walkers)]
                extra = dict(
                    walks=wk.app_walks[a],
                    walk_cycles=wk.app_walk_cycles[a],
                    walk_queue_cycles=wk.app_queue_cycles[a],
                    pwc_hit=wk.pwc_hit_rate(),
                )
            else:
                l1 = self.l1_base[a], self.l1_large[a]
                h = sum(x.hits for x in l1)
                m = sum(x.misses for x in l1)
                extra = {}
            out.append(
                AppResult(
                    name=tr.name,
                    retired=retired[a],
                    cycles=finish_time[a],
                    l1_hit=h / max(h + m, 1),
                    l2_hit=0.0,  # filled by caller from shared L2 (per-sim)
                    # Fault *events* — equals the resident-set size only
                    # while hbm_pages_per_app is uncapped (no re-faults).
                    faults=self.fault_count[a],
                    **extra,
                )
            )
        return out

    def l2_hit_rate(self) -> float:
        if self.cfg.translation == "radix":
            return self.l2_co.hits / max(self.l2_co.hits
                                         + self.l2_co.misses, 1)
        h = self.l2_base.hits + self.l2_large.hits
        m = self.l2_base.misses + self.l2_large.misses
        return h / max(h + m, 1)

    def l1_hit_rate(self) -> float:
        if self.cfg.translation == "radix":
            h = sum(t.hits for t in self.l1_co)
            m = sum(t.misses for t in self.l1_co)
            return h / max(h + m, 1)
        h = sum(x.hits for x in self.l1_base) + sum(x.hits for x in self.l1_large)
        m = sum(x.misses for x in self.l1_base) + sum(x.misses for x in self.l1_large)
        return h / max(h + m, 1)

    # -- radix-only accounting (DESIGN.md §15) -------------------------------

    def total_walks(self) -> int:
        if self.cfg.translation == "radix":
            return sum(w.walks for w in self.walkers)
        return self.walker.walks

    def total_walk_cycles(self) -> float:
        """Summed per-app walk latency past the L2 miss (radix), or the
        flat model's constant-cost equivalent."""
        if self.cfg.translation == "radix":
            return float(sum(sum(w.app_walk_cycles) for w in self.walkers))
        return float(self.walker.walks * self.cfg.walk_latency
                     + self.walker.stall_cycles)

    def walker_queue_cycles(self) -> float:
        if self.cfg.translation == "radix":
            return float(sum(w.stall_cycles for w in self.walkers))
        return float(self.walker.stall_cycles)

    def pwc_hit_rate(self) -> float:
        if self.cfg.translation != "radix":
            return float("nan")
        h = sum(p.hits for w in self.walkers for p in w.pwcs)
        m = sum(p.misses for w in self.walkers for p in w.pwcs)
        return h / (h + m) if h + m else float("nan")

    def walk_dram_accesses(self) -> int:
        if self.cfg.translation == "radix":
            return sum(w.dram_accesses() for w in self.walkers)
        return self.walker.walks * self.cfg.walk_levels

    def l1_hit_rate_micro(self, page_repeat: int = 24) -> float:
        """Per-memory-instruction L1 hit rate.

        The simulator looks up the TLB once per *page dwell*; the remaining
        ``page_repeat - 1`` instructions of the dwell hit the just-filled
        entry by construction.  This converts dwell-level rates to the
        instruction-level rates the paper reports (Fig. 8).
        """
        h = sum(x.hits for x in self.l1_base) + sum(x.hits for x in self.l1_large)
        m = sum(x.misses for x in self.l1_base) + sum(x.misses for x in self.l1_large)
        n = h + m
        if n == 0:
            return 1.0
        return (h + (page_repeat - 1) * n) / (page_repeat * n)

    def l2_hit_rate_micro(self, page_repeat: int = 24) -> float:
        """Per-instruction L2 rate among L2 lookups (L1-dwell misses only).

        L2 is only consulted on an L1 miss, and dwell-internal reuse never
        reaches it, so the dwell-level rate *is* the instruction-level rate.
        Kept as a named helper for symmetry/reporting clarity.
        """
        del page_repeat
        return self.l2_hit_rate()


# --------------------------------------------------------------------------- metrics


def weighted_speedup(
    shared: Sequence[AppResult], alone: Sequence[AppResult]
) -> float:
    """Paper Eq. (1): Σ IPC_shared / IPC_alone."""
    assert len(shared) == len(alone)
    return float(sum(s.ipc / max(al.ipc, 1e-12) for s, al in zip(shared, alone)))
