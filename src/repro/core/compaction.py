"""CAC — Contiguity-Aware Compaction (paper §2, memory deallocation path).

The third of Mosaic's mechanisms: where :mod:`CoCoA <repro.core.cocoa>`
*conserves* contiguity and the :mod:`coalescer <repro.core.coalescer>`
*exploits* it for free, CAC *repairs* it with bounded copies when
deallocation-driven fragmentation finally breaks it — the only point in
the whole design where data actually moves on-device.

When deallocation leaves large pages with high internal fragmentation, the
runtime part of CAC (this module) (1) splinters those large pages back to
base pages (metadata-only, via the In-Place Coalescer) and (2) plans a
compaction: live base pages from multiple splintered frames are migrated
into as few frames as possible; emptied frames return to CoCoA's free pool.

The *data movement* is expressed as a list of :class:`CopyOp`; the serving
engine executes it on-device with the ``page_compact`` Pallas kernel (the
"hardware portion").  The paper models compaction conservatively as a
whole-GPU stall; our TLB-timing simulator (:mod:`repro.core.tlb_sim`) keeps
that conservative model, while the real engine overlaps the batched copy
between decode steps.

The plan is computed greedily per owner (frames hold one owner's pages only
— CoCoA's soft guarantee — so compaction never mixes protection domains):
source frames are the most-fragmented, destinations are the least-fragmented
partial frames; pages move src→dst until sources empty.

Ordering contract with the engine (the subtle part): tables are rewritten
at *plan* time, payloads move at *execution* time — so the engine lands
pending ``CopyOp``s (``_run_compaction``) before anything reads or
gathers through the rewritten tables: before prefill, before decode,
before preemption/parking gathers (DESIGN.md §6/§8).  Residency rides
along via ``ResidencyTracker.on_copy`` — a host-backed (non-resident)
page stays host-backed at its new physical location, which is what lets
compaction run safely under the host tier's demand paging and the
prefix cache's demoted admission pages.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.coalescer import InPlaceCoalescer
from repro.core.page_table import PageTable
from repro.core.pagepool import FREE, PagePool


@dataclasses.dataclass(frozen=True)
class CopyOp:
    """Move one base page's payload ``src_ppn`` → ``dst_ppn`` on device."""

    src_ppn: int
    dst_ppn: int


@dataclasses.dataclass
class CompactionPlan:
    copies: List[CopyOp]
    freed_frames: List[int]

    @property
    def bytes_moved_pages(self) -> int:
        return len(self.copies)


class CAC:
    def __init__(self, pool: PagePool, coalescer: InPlaceCoalescer):
        self.pool = pool
        self.coalescer = coalescer

    # -- fragmentation scan -------------------------------------------------------

    def fragmented_frames(self, owner: int) -> List[int]:
        """Owner's frames whose unallocated fraction exceeds the threshold."""
        pool = self.pool
        thr = pool.config.compact_threshold
        out = []
        for f in range(pool.config.num_frames):
            if pool.frame_owner[f] == owner and 0 < pool.frame_used[f]:
                if pool.frame_frag(f) > thr:
                    out.append(f)
        return out

    # -- splinter on partial dealloc (paper step 8) ---------------------------------

    def splinter_for_dealloc(self, table: PageTable, vpns: Sequence[int]) -> None:
        for vf in {table.vframe_of(v) for v in vpns}:
            self.coalescer.splinter(table, vf)

    # -- compaction (paper step 9) ---------------------------------------------------

    def compact_owner(
        self, owner: int, table: PageTable, rmap: Dict[int, Tuple[int, int]]
    ) -> CompactionPlan:
        """Compact one owner's fragmented frames.

        ``rmap`` maps ppn -> (owner, vpn) and is updated in place, as is the
        owner's page table and the pool's physical state.
        """
        pool = self.pool
        fp = pool.config.frame_pages
        srcs = self.fragmented_frames(owner)
        # Order: emptiest frames are drained first (fewest copies per freed
        # frame — the greedy that maximizes frames freed per byte moved).
        srcs.sort(key=lambda f: pool.frame_used[f])
        copies: List[CopyOp] = []
        freed: List[int] = []
        if not srcs:
            return CompactionPlan(copies, freed)
        # Destinations: fullest-first partial frames not selected as sources.
        dsts = [
            f
            for f in range(pool.config.num_frames)
            if pool.frame_owner[f] == owner
            and 0 < pool.frame_used[f] < fp
            and f not in srcs
        ]
        dsts.sort(key=lambda f: -pool.frame_used[f])
        # Also allow back-filling the fullest source frames with pages drained
        # from the emptiest ones (classic two-pointer compaction).
        dsts = dsts + list(reversed(srcs))

        def dst_slot() -> Tuple[int, int]:
            while dsts:
                f = dsts[0]
                if pool.frame_owner[f] == owner and pool.frame_used[f] < fp:
                    free = pool.free_slots(f)
                    if free:
                        return f, free[0]
                dsts.pop(0)
            return -1, -1

        for src in srcs:
            if pool.frame_owner[src] != owner:
                continue  # already drained & released
            base = src * fp
            for s in range(fp):
                ppn = base + s
                if not pool.page_allocated[ppn]:
                    continue
                df, dslot = dst_slot()
                if df == -1 or df == src:
                    break  # nowhere better to move remaining pages
                # Splinter the destination frame if it was large (it cannot
                # be: coalesced frames are full) — assert instead.
                assert not pool.frame_coalesced[df]
                o, vpn = rmap.pop(ppn)
                assert o == owner, "CAC crossed a protection domain"
                dppn = pool.page_of(df, dslot)
                pool.alloc_page(df, dslot)
                pool.free_page(ppn)  # releases src frame when it empties
                table.set(vpn, dppn)
                rmap[dppn] = (owner, vpn)
                copies.append(CopyOp(ppn, dppn))
                pool.stats["compaction_copies"] += 1
                # A destination frame that just became full+contiguous could
                # re-coalesce; compaction does not guarantee alignment, so we
                # only flip the bit when the coalescer's check passes.
                self.coalescer.maybe_coalesce(table, table.vframe_of(vpn))
            if pool.frame_owner[src] == FREE:
                freed.append(src)
        return CompactionPlan(copies, freed)
