"""Per-owner page tables: the virtual→physical mapping consumed by kernels.

Each *owner* (a serving request / protection domain) has a growable virtual
address space of base pages.  The table stores, per virtual page number
(vpn), the physical page number (ppn) in the pool, plus a per-virtual-frame
``coalesced`` bit maintained by the In-Place Coalescer.

The *hardware-facing* view (:func:`pack_batch_tables`) flattens a batch of
owners into dense int32 arrays that the Pallas paged-attention kernel
scalar-prefetches — this is the TPU analogue of the page table walked by the
GPU MMU in the paper.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

UNMAPPED = -1


class PageTable:
    """Virtual address space of one owner (sequence / app)."""

    def __init__(self, frame_pages: int):
        self.frame_pages = frame_pages
        self.ppn: List[int] = []           # vpn -> ppn (UNMAPPED if hole)
        self.coalesced: List[bool] = []    # per virtual frame

    # -- size helpers ----------------------------------------------------------

    @property
    def num_pages(self) -> int:
        return len(self.ppn)

    @property
    def num_vframes(self) -> int:
        return (len(self.ppn) + self.frame_pages - 1) // self.frame_pages

    def vframe_of(self, vpn: int) -> int:
        return vpn // self.frame_pages

    def vpns_of_vframe(self, vf: int) -> range:
        lo = vf * self.frame_pages
        return range(lo, min(lo + self.frame_pages, len(self.ppn)))

    # -- mutation ---------------------------------------------------------------

    def append(self, ppn: int) -> int:
        """Map the next vpn to ``ppn``; returns the vpn."""
        vpn = len(self.ppn)
        self.ppn.append(ppn)
        while self.num_vframes > len(self.coalesced):
            self.coalesced.append(False)
        return vpn

    def set(self, vpn: int, ppn: int) -> None:
        self.ppn[vpn] = ppn

    def unmap(self, vpn: int) -> int:
        old = self.ppn[vpn]
        assert old != UNMAPPED
        self.ppn[vpn] = UNMAPPED
        return old

    def mapped_vpns(self) -> List[int]:
        return [v for v, p in enumerate(self.ppn) if p != UNMAPPED]

    # -- coalescing queries (In-Place Coalescer conditions, paper §2) -----------

    def vframe_full(self, vf: int) -> bool:
        vpns = self.vpns_of_vframe(vf)
        return len(vpns) == self.frame_pages and all(
            self.ppn[v] != UNMAPPED for v in vpns
        )

    def vframe_contiguous_aligned(self, vf: int) -> Tuple[bool, int]:
        """Is virtual frame ``vf`` backed by one aligned physical frame?

        Returns (ok, physical_frame).  The condition mirrors the paper's
        In-Place Coalescer check: all base pages present, physically
        contiguous, *and* aligned within the large page frame.
        """
        if not self.vframe_full(vf):
            return False, -1
        base_vpn = vf * self.frame_pages
        p0 = self.ppn[base_vpn]
        if p0 % self.frame_pages != 0:
            return False, -1
        for s in range(1, self.frame_pages):
            if self.ppn[base_vpn + s] != p0 + s:
                return False, -1
        return True, p0 // self.frame_pages


def pack_batch_tables(
    tables: Sequence[PageTable],
    max_pages: int,
    frame_pages: int,
) -> Dict[str, np.ndarray]:
    """Flatten a batch of page tables into kernel-facing dense arrays.

    Returns:
      page_tables:  int32[batch, max_pages]      vpn -> ppn (UNMAPPED padding)
      frame_tables: int32[batch, max_vframes]    vframe -> physical frame
                     (UNMAPPED when the vframe is not coalesced)
      coalesced:    int32[batch, max_vframes]    1 if vframe coalesced
      seq_pages:    int32[batch]                 #mapped pages per owner
    """
    batch = len(tables)
    max_vframes = max_pages // frame_pages
    page_tables = np.full((batch, max_pages), UNMAPPED, dtype=np.int32)
    frame_tables = np.full((batch, max_vframes), UNMAPPED, dtype=np.int32)
    coalesced = np.zeros((batch, max_vframes), dtype=np.int32)
    seq_pages = np.zeros((batch,), dtype=np.int32)
    for i, t in enumerate(tables):
        n = min(t.num_pages, max_pages)
        page_tables[i, :n] = np.asarray(t.ppn[:n], dtype=np.int32)
        seq_pages[i] = len(t.mapped_vpns())
        for vf in range(min(t.num_vframes, max_vframes)):
            if vf < len(t.coalesced) and t.coalesced[vf]:
                ok, pf = t.vframe_contiguous_aligned(vf)
                assert ok, "coalesced bit set on non-contiguous vframe"
                frame_tables[i, vf] = pf
                coalesced[i, vf] = 1
    return {
        "page_tables": page_tables,
        "frame_tables": frame_tables,
        "coalesced": coalesced,
        "seq_pages": seq_pages,
    }
