"""Physical page-pool bookkeeping for the Mosaic memory manager.

The pool models a device-resident region of HBM carved into ``num_pages``
*base pages* of ``page_tokens`` tokens each.  Pages are grouped into aligned
*large frames* of ``frame_pages`` consecutive pages (the TPU analogue of the
paper's 2 MB large-page frame; see DESIGN.md §5 for the re-tiling rationale).

This module owns only *physical* state: which pages are allocated, which
frame owns them, and which frames are coalesced.  Virtual-to-physical policy
lives in :mod:`repro.core.cocoa` (Mosaic) and
:mod:`repro.core.baseline_mmu` (the GPU-MMU baseline of Power et al.).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Set

import numpy as np

FREE = -1  # sentinel owner id for unowned frames / unallocated pages


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Geometry of the physical page pool.

    Attributes:
      num_pages:    total base pages in the pool (must be a multiple of
                    ``frame_pages``).
      frame_pages:  base pages per large frame (paper: 512 = 2MB/4KB; TPU
                    default 16, see DESIGN.md §5).
      page_tokens:  tokens of KV state per base page (TPU default 64).
      compact_threshold: CAC fragmentation trigger — a *splintered* frame
                    whose unallocated fraction exceeds this becomes a
                    compaction source (paper §2, "predetermined threshold").
    """

    num_pages: int
    frame_pages: int = 16
    page_tokens: int = 64
    compact_threshold: float = 0.5

    def __post_init__(self) -> None:
        if self.num_pages % self.frame_pages != 0:
            raise ValueError(
                f"num_pages={self.num_pages} not a multiple of "
                f"frame_pages={self.frame_pages}"
            )

    @property
    def num_frames(self) -> int:
        return self.num_pages // self.frame_pages

    @property
    def frame_tokens(self) -> int:
        return self.frame_pages * self.page_tokens


class PagePool:
    """Physical state: page allocation bits, frame ownership, coalesced bits.

    Invariants (checked by :meth:`check_invariants`, property-tested):
      I1  a page is allocated iff its frame has an owner.
      I2  ``frame_used[f]`` == number of allocated pages in frame ``f``.
      I3  a frame with ``frame_used == 0`` is unowned and on the free list.
      I4  a coalesced frame is fully allocated (``frame_used == frame_pages``).
      I5  every frame is either on the free list xor owned.
    """

    def __init__(self, config: PoolConfig):
        self.config = config
        n_f = config.num_frames
        self.page_allocated = np.zeros(config.num_pages, dtype=bool)
        self.frame_owner = np.full(n_f, FREE, dtype=np.int64)
        self.frame_used = np.zeros(n_f, dtype=np.int32)
        self.frame_coalesced = np.zeros(n_f, dtype=bool)
        # Free frames: min-heap with lazy deletion + membership set, so we get
        # deterministic low-address-first frame selection (helps contiguity)
        # *and* O(log n) removal of a specific frame (needed by the baseline
        # MMU, which allocates pages without frame awareness).
        self._free_heap: List[int] = list(range(n_f))
        heapq.heapify(self._free_heap)
        self._free_set: Set[int] = set(range(n_f))
        # Statistics (read by benchmarks / EXPERIMENTS.md tables).
        self.stats = {
            "frames_allocated": 0,
            "frames_released": 0,
            "pages_allocated": 0,
            "pages_freed": 0,
            "coalesce_ops": 0,
            "splinter_ops": 0,
            "compaction_copies": 0,
        }

    # -- frame-granularity ops ------------------------------------------------

    @property
    def num_free_frames(self) -> int:
        return len(self._free_set)

    def free_frame_ids(self) -> Set[int]:
        return set(self._free_set)

    def take_free_frame(self, owner: int) -> Optional[int]:
        """Pop the lowest-addressed free frame for ``owner``; None if full."""
        while self._free_heap:
            f = heapq.heappop(self._free_heap)
            if f in self._free_set:  # skip lazily-deleted entries
                self._free_set.discard(f)
                self.frame_owner[f] = owner
                self.stats["frames_allocated"] += 1
                return f
        return None

    def take_specific_frame(self, f: int, owner: int) -> int:
        """Claim a specific free frame (baseline MMU path; lazy heap delete)."""
        assert f in self._free_set, f"frame {f} is not free"
        self._free_set.discard(f)
        self.frame_owner[f] = owner
        self.stats["frames_allocated"] += 1
        return f

    def take_free_frames(self, owner: int, n: int) -> Optional[List[int]]:
        """Pop ``n`` free frames at once (en-masse allocation path)."""
        if len(self._free_set) < n:
            return None
        return [self.take_free_frame(owner) for _ in range(n)]

    def release_frame(self, f: int) -> None:
        assert self.frame_used[f] == 0, f"releasing non-empty frame {f}"
        self.frame_owner[f] = FREE
        self.frame_coalesced[f] = False
        self._free_set.add(f)
        heapq.heappush(self._free_heap, f)
        self.stats["frames_released"] += 1

    # -- page-granularity ops --------------------------------------------------

    def page_of(self, frame: int, slot: int) -> int:
        return frame * self.config.frame_pages + slot

    def frame_of(self, ppn: int) -> int:
        return ppn // self.config.frame_pages

    def slot_of(self, ppn: int) -> int:
        return ppn % self.config.frame_pages

    def alloc_page(self, frame: int, slot: int) -> int:
        ppn = self.page_of(frame, slot)
        assert not self.page_allocated[ppn], f"double alloc of page {ppn}"
        assert self.frame_owner[frame] != FREE, f"alloc in unowned frame {frame}"
        self.page_allocated[ppn] = True
        self.frame_used[frame] += 1
        self.stats["pages_allocated"] += 1
        return ppn

    def free_page(self, ppn: int) -> None:
        assert self.page_allocated[ppn], f"double free of page {ppn}"
        f = self.frame_of(ppn)
        self.page_allocated[ppn] = False
        self.frame_used[f] -= 1
        self.stats["pages_freed"] += 1
        if self.frame_used[f] == 0:
            self.release_frame(f)

    def free_slots(self, frame: int) -> List[int]:
        base = frame * self.config.frame_pages
        return [
            s
            for s in range(self.config.frame_pages)
            if not self.page_allocated[base + s]
        ]

    # -- fragmentation metrics (paper §4.4 / Fig. 8 analysis) -------------------

    def frame_frag(self, f: int) -> float:
        """Unallocated fraction of an *owned* frame (internal fragmentation)."""
        return 1.0 - self.frame_used[f] / self.config.frame_pages

    def memory_bloat(self) -> float:
        """Paper's 'memory bloat': frames reserved / pages actually used."""
        owned = int((self.frame_owner != FREE).sum())
        used_pages = int(self.page_allocated.sum())
        if used_pages == 0:
            return 1.0
        return owned * self.config.frame_pages / used_pages

    def occupancy(self) -> float:
        return float(self.page_allocated.mean())

    def coalesced_fraction(self) -> float:
        """Fraction of *allocated* pages that live in coalesced frames."""
        total = int(self.page_allocated.sum())
        if total == 0:
            return 0.0
        coalesced_pages = int(
            (self.frame_used * self.frame_coalesced).sum()
        )
        return coalesced_pages / total

    # -- invariant checking (used by hypothesis tests) ---------------------------

    def check_invariants(self) -> None:
        cfg = self.config
        used = self.page_allocated.reshape(cfg.num_frames, cfg.frame_pages)
        per_frame = used.sum(axis=1).astype(np.int32)
        # I2
        assert (per_frame == self.frame_used).all(), "I2: frame_used mismatch"
        # I1: pages allocated only in owned frames
        owned = self.frame_owner != FREE
        assert not (per_frame[~owned] > 0).any(), "I1: pages in unowned frame"
        # I3: empty owned frames are not allowed to linger
        assert not ((per_frame == 0) & owned).any(), "I3: empty owned frame"
        # I4
        assert (
            per_frame[self.frame_coalesced] == cfg.frame_pages
        ).all(), "I4: coalesced frame not full"
        # I5
        for f in range(cfg.num_frames):
            assert (f in self._free_set) != bool(owned[f]), "I5: free xor owned"
