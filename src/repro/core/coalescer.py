"""In-Place Coalescer: metadata-only page-size promotion/demotion.

Paper §2 — the second of Mosaic's three mechanisms, and the one that
realizes the headline "application-transparent large pages without
migration" claim.  After :class:`~repro.core.cocoa.CoCoA` finishes an
allocation it hands the coalescer the list of touched large-page frames.
For each, the *runtime* part checks that (1) every base page in the frame
is allocated and (2) the base pages are contiguous in both virtual and
physical memory (and aligned).  If so, the *hardware* part updates the
page table so the frame is addressed as one large page — **no data
migration**.  Because CoCoA conserved contiguity at allocation time, the
check almost always passes and promotion is O(frame_pages) metadata.

The split mirrors the paper exactly:

* *runtime half* → :meth:`InPlaceCoalescer.maybe_coalesce` — the
  promotion-condition check (`PageTable.vframe_contiguous_aligned`);
* *hardware half* → the ``coalesced`` bit arrays on the page table and
  pool.  In the TLB-timing simulator (:mod:`repro.core.tlb_sim`) a set
  bit makes translation use the large-page TLB arrays (Fig. 1's reach
  benefit); on the model side the packed frame-table arrays the Pallas
  paged-attention kernel scalar-prefetches
  (:func:`repro.core.page_table.pack_batch_tables`) flip the kernel onto
  its contiguous-frame fast path — one index per frame, long DMAs
  (DESIGN.md §4).

Demotion (``splinter``) is the same operation in reverse and is what CAC
(:mod:`repro.core.compaction`) uses before migrating pages out of
fragmented frames: flipping the bit back re-enables base-page addressing
with, again, zero copies.
"""

from __future__ import annotations

from typing import Iterable

from repro.core.page_table import PageTable
from repro.core.pagepool import PagePool


class InPlaceCoalescer:
    def __init__(self, pool: PagePool):
        self.pool = pool

    def maybe_coalesce(self, table: PageTable, vf: int) -> bool:
        """Promote virtual frame ``vf`` to a large page if conditions hold."""
        if vf < len(table.coalesced) and table.coalesced[vf]:
            return True  # already large
        ok, pf = table.vframe_contiguous_aligned(vf)
        if not ok:
            return False
        table.coalesced[vf] = True
        self.pool.frame_coalesced[pf] = True
        self.pool.stats["coalesce_ops"] += 1
        return True

    def coalesce_all(self, table: PageTable, vframes: Iterable[int]) -> int:
        return sum(self.maybe_coalesce(table, vf) for vf in set(vframes))

    def splinter(self, table: PageTable, vf: int) -> bool:
        """Demote a large page back to base pages (metadata-only).

        Needed before any base page of the frame can be individually
        unmapped or migrated (paper §2, memory deallocation walkthrough).
        """
        if vf >= len(table.coalesced) or not table.coalesced[vf]:
            return False
        ok, pf = table.vframe_contiguous_aligned(vf)
        assert ok, "coalesced bit was set on a non-contiguous vframe"
        table.coalesced[vf] = False
        self.pool.frame_coalesced[pf] = False
        self.pool.stats["splinter_ops"] += 1
        return True
