"""Radix page-table walker + contiguity-coalesced TLB entries (DESIGN.md §15).

The flat walker in :mod:`repro.core.tlb_sim` charges every TLB miss a
constant ``walk_levels × dram_latency`` — the paper's contiguity ⇒
cheap-translation chain is asserted, not measured.  This module makes it
measurable:

* :class:`RadixWalker` — a multi-level radix walk (x86-64-style: ``bits``
  index bits per level) with **per-level page-walk caches** (PWCs): a walk
  probes the PWCs deepest-intermediate-level first and skips every level
  already cached, so only the uncached tail issues serialized DRAM
  accesses.  ``walker_slots`` concurrent walks share the walker (FIFO
  overflow, exactly the flat walker's queueing mechanics), an MSHR merges
  duplicate in-flight walks, and per-level DRAM accesses plus per-app
  latency/queue-interference are accounted (MASK's cross-app walker
  interference, arxiv 1708.04911).

* :class:`CoalescedTLB` — subregion-coalesced entries (Large-Reach TLBs
  via subregion contiguity, arxiv 2110.08613): one entry covers the run
  of contiguously-mapped base pages inside a ``span``-page subregion.
  Coverage is **derived from the actual frame map** the allocator
  produced (``ppn[v] == base + (v - base_vpn)``), not from an oracle
  bit — CoCoA's contiguity-preserving allocation widens every entry's
  reach, the baseline's interleaved frames collapse it to one page.
  Splintering a page invalidates only the touched subregion's entry.

* :class:`TranslationMeter` — the serving-side adapter: one L1/L2
  coalesced TLB + radix walker per engine, fed the KV page tables each
  decode step touches.  Purely observational for decode timing (tokens
  are byte-identical with it on or off), but its walker backlog is the
  optional translation-interference term
  :meth:`repro.serving.router.RequestRouter.engine_cost_us` charges.

Bitwise compatibility: with PWCs disabled (``pwc_entries=0``) and
``span=1`` the radix walker performs full-depth walks of exactly
``levels × dram_latency`` cycles with the flat walker's slot mechanics
and MSHR rule — the parity the ``translation`` bench and
``tests/test_ptw.py`` pin against ``translation="flat"``.
"""

from __future__ import annotations

import heapq
import math
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


# ----------------------------------------------------------------- subregions


def subregion_entry(ppn_map: Sequence[int], vpn: int, span: int
                    ) -> Tuple[int, int]:
    """Build a coalesced TLB entry for the subregion containing ``vpn``.

    Returns ``(delta, mask)``: ``delta = ppn - vpn`` for the walked page,
    and ``mask`` has bit ``o`` set when page ``base + o`` of the
    ``span``-aligned subregion is mapped with the *same* delta — i.e. its
    translation is derivable from the entry (``ppn = vpn + delta``).
    Coverage comes from the frame map itself, never from an oracle bit.
    """
    delta = int(ppn_map[vpn]) - vpn
    base = (vpn // span) * span
    mask = 0
    n = len(ppn_map)
    for o in range(span):
        v = base + o
        if v < n and int(ppn_map[v]) >= 0 and int(ppn_map[v]) - v == delta:
            mask |= 1 << o
    return delta, mask


class CoalescedTLB:
    """Fully-associative LRU of subregion-coalesced entries.

    Keyed by subregion tag (``vpn // span``, plus whatever address-space
    discriminator the caller folds into the key); the stored entry is the
    ``(delta, mask)`` pair of :func:`subregion_entry`.  A lookup hits only
    when the tag is present *and* the entry's coverage mask includes the
    page — a present-but-uncovered page (a delta conflict inside the
    subregion, or a splintered page) is a miss that re-walks.
    """

    __slots__ = ("cap", "span", "d", "hits", "misses")

    def __init__(self, cap: int, span: int = 1):
        assert span >= 1
        self.cap = cap
        self.span = span
        self.d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, tag, off: int) -> Optional[Tuple[int, int]]:
        e = self.d.get(tag)
        if e is not None and (e[1] >> off) & 1:
            self.d.move_to_end(tag)
            self.hits += 1
            return e
        self.misses += 1
        return None

    def insert(self, tag, entry: Tuple[int, int]) -> None:
        if tag in self.d:
            self.d[tag] = entry
            self.d.move_to_end(tag)
            return
        if len(self.d) >= self.cap and self.cap > 0:
            self.d.popitem(last=False)
        if self.cap > 0:
            self.d[tag] = entry

    def invalidate(self, tag) -> bool:
        """Drop the entry for one subregion (CoCoA splintered a page in
        it).  Entries for every other subregion are untouched — the
        selective invalidation the ``ptw`` property tests pin."""
        return self.d.pop(tag, None) is not None

    @property
    def rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else float("nan")

    def reach_pages(self) -> int:
        """Base pages currently covered across all resident entries —
        the TLB-reach figure coalescing widens."""
        return sum(bin(e[1]).count("1") for e in self.d.values())


# --------------------------------------------------------------- radix walker


class RadixWalker:
    """Multi-level radix page-table walker with per-level walk caches.

    A walk is ``levels`` serialized memory accesses (root → leaf PTE).
    PWC ``i`` caches the intermediate entry fetched by access ``i + 1``
    (the leaf PTE itself goes to the TLB, not a PWC), keyed by
    ``(app, vpn >> bits·(levels - level))``.  The walk probes deepest
    intermediate level first; a hit at level ``ℓ`` leaves only the
    ``levels - ℓ`` tail accesses to DRAM.  ``slots`` concurrent walks
    share the walker with FIFO overflow — the same mechanics (and, with
    PWCs disabled, the same timings to the cycle) as the flat walker.
    An MSHR merges duplicate in-flight walks under the flat path's rule.
    """

    def __init__(self, slots: int, levels: int, dram_latency: int, *,
                 pwc_entries: int = 64, pwc_latency: int = 2,
                 bits: int = 9, n_apps: int = 1):
        assert levels >= 1
        self.slots = slots
        self.levels = levels
        self.dram_latency = dram_latency
        self.pwc_latency = pwc_latency
        self.bits = bits
        # pwcs[i] caches level i+1 entries, i in [0, levels-2].
        self.pwcs = [_TagLRU(pwc_entries) for _ in range(levels - 1)]
        self._busy: List[float] = []       # heap of walk finish times
        self.walks = 0
        self.merged = 0                    # MSHR-merged duplicate misses
        self.stall_cycles = 0.0            # slot-queue wait, all apps
        self.peak_inflight = 0
        self.level_accesses = [0] * levels     # DRAM accesses per level
        self.app_walks = [0] * n_apps
        self.app_walk_cycles = [0.0] * n_apps  # latency past the L2 miss
        self.app_queue_cycles = [0.0] * n_apps  # slot-wait (interference)
        self.mshr: Dict[object, float] = {}

    # -- caches ------------------------------------------------------------

    def _pwc_tag(self, vpn: int, level: int) -> int:
        return vpn >> (self.bits * (self.levels - level))

    def pwc_hit_rate(self) -> float:
        h = sum(p.hits for p in self.pwcs)
        m = sum(p.misses for p in self.pwcs)
        n = h + m
        return h / n if n else float("nan")

    # -- the walk ----------------------------------------------------------

    def walk(self, now: float, t0: float, app: int, vpn: int,
             key) -> float:
        """Resolve a TLB miss requested at ``now`` whose walk may begin
        at ``t0`` (after the L1+L2 probe latencies).  Returns the cycle
        the translation resolves.  Duplicate in-flight misses on ``key``
        merge into the existing walk (the flat path's MSHR rule)."""
        got = self.mshr.get(key)
        if got is not None and got > now:
            self.merged += 1
            return got
        # Deepest already-cached intermediate level: those accesses skip.
        skip = 0
        for lvl in range(self.levels - 1, 0, -1):
            if self.pwcs[lvl - 1].lookup((app, self._pwc_tag(vpn, lvl))):
                skip = lvl
                break
        accesses = self.levels - skip
        duration = accesses * self.dram_latency \
            + (self.pwc_latency if skip else 0)
        # Slot queue: identical mechanics to the flat walker.
        while self._busy and self._busy[0] <= t0:
            heapq.heappop(self._busy)
        if len(self._busy) < self.slots:
            begin = t0
        else:
            begin = heapq.heappop(self._busy)      # wait for a slot
            self.stall_cycles += begin - t0
            if app < len(self.app_queue_cycles):
                self.app_queue_cycles[app] += begin - t0
        finish = begin + duration
        heapq.heappush(self._busy, finish)
        self.peak_inflight = max(self.peak_inflight, len(self._busy))
        self.walks += 1
        for lvl in range(skip + 1, self.levels + 1):
            self.level_accesses[lvl - 1] += 1
        # The walk fetched every uncached intermediate entry: cache them.
        for lvl in range(skip + 1, self.levels):
            self.pwcs[lvl - 1].insert((app, self._pwc_tag(vpn, lvl)))
        if app < len(self.app_walks):
            self.app_walks[app] += 1
            self.app_walk_cycles[app] += finish - t0
        self.mshr[key] = finish
        return finish

    # -- occupancy (router / cost-model parity hook) -----------------------

    def backlog(self, now: float) -> float:
        """Booked walker time beyond ``now`` (cycles): the queueing a
        newly-missing translation would experience.  Monotone in booked
        walks — the serving router's translation-interference term."""
        return sum(max(0.0, t - now) for t in self._busy)

    def dram_accesses(self) -> int:
        return sum(self.level_accesses)


class _TagLRU:
    """Tag-only LRU (the flat sim's LRU, minus the never-touched-rate
    wart): capacity 0 never hits and never stores."""

    __slots__ = ("cap", "d", "hits", "misses")

    def __init__(self, cap: int):
        self.cap = cap
        self.d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, tag) -> bool:
        if tag in self.d:
            self.d.move_to_end(tag)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert(self, tag) -> None:
        if tag in self.d:
            self.d.move_to_end(tag)
            return
        if len(self.d) >= self.cap and self.cap > 0:
            self.d.popitem(last=False)
        if self.cap > 0:
            self.d[tag] = True

    @property
    def rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else float("nan")


# ------------------------------------------------------------ serving meter


class TranslationMeter:
    """Per-engine translation model for the serving tier (DESIGN.md §15).

    Each decode step the engine feeds it the KV page tables the step's
    packed batch reads; the meter runs every page through an L1/L2
    coalesced-TLB + radix-walker pipeline on the engine's modeled µs
    clock (converted to cycles at ``clock_ghz``).  It is observational —
    decode timing and tokens are untouched — but it exports:

    * per-app (tenant) translation cycles and walk counts,
    * PWC / TLB hit rates,
    * walker slot-queue interference, and
    * :meth:`backlog_us` — the walker's booked-time-beyond-now, the
      optional translation-interference term the request router charges.

    ``mode="flat"`` degrades to the flat model (span-1 entries, PWCs
    off, every walk full depth) so flat/radix can be A/B'd per engine.
    """

    def __init__(self, mode: str = "radix", *, span: int = 4,
                 l1_entries: int = 64, l2_entries: int = 256,
                 levels: int = 4, dram_latency: int = 160,
                 pwc_entries: int = 16, pwc_latency: int = 2,
                 walker_slots: int = 8, l1_latency: int = 1,
                 l2_latency: int = 10, clock_ghz: float = 1.02):
        if mode not in ("flat", "radix"):
            raise ValueError(
                f"translation mode must be 'flat' or 'radix', got {mode!r}")
        self.mode = mode
        if mode == "flat":
            span, pwc_entries = 1, 0
        self.span = span
        self.l1_latency = l1_latency
        self.l2_latency = l2_latency
        self.cycles_per_us = clock_ghz * 1e3
        self.l1 = CoalescedTLB(l1_entries, span)
        self.l2 = CoalescedTLB(l2_entries, span)
        self.walker = RadixWalker(walker_slots, levels, dram_latency,
                                  pwc_entries=pwc_entries,
                                  pwc_latency=pwc_latency)
        self.lookups = 0
        self.cycles_by_app: Dict[object, float] = {}
        self.walks_by_app: Dict[object, int] = {}

    # -- per-step driving --------------------------------------------------

    def step_access(self, now_us: float,
                    tables: Iterable[Tuple[object, object, Sequence[int]]]
                    ) -> Dict[str, float]:
        """Translate one decode step's page touches.

        ``tables`` yields ``(space, app, ppn_map)``: a distinct address
        space (seq/shard), the app label its latency is charged to, and
        the space's vpn→ppn map (the actual frame map the allocator
        produced — contiguity coverage is derived from it).  Returns the
        step's deltas for the engine's stats counters.
        """
        now = now_us * self.cycles_per_us
        d = {"lookups": 0, "tlb_hits": 0, "walks": 0, "walk_cycles": 0.0,
             "queue_cycles": 0.0, "latency_cycles": 0.0}
        w = self.walker
        walks0, stall0 = w.walks, w.stall_cycles
        merged0, wcyc0 = w.merged, w.app_walk_cycles[0]
        for space, app, ppn_map in tables:
            app_cycles = 0.0
            app_walks0 = w.walks
            for vpn in range(len(ppn_map)):
                if int(ppn_map[vpn]) < 0:
                    continue                      # unmapped hole
                done = self._translate(now, space, app, vpn, ppn_map)
                d["lookups"] += 1
                app_cycles += done - now
            d["latency_cycles"] += app_cycles
            self.cycles_by_app[app] = \
                self.cycles_by_app.get(app, 0.0) + app_cycles
            self.walks_by_app[app] = \
                self.walks_by_app.get(app, 0) + (w.walks - app_walks0)
        d["walks"] = w.walks - walks0
        d["queue_cycles"] = w.stall_cycles - stall0
        d["tlb_hits"] = d["lookups"] - d["walks"] - (w.merged - merged0)
        d["walk_cycles"] = w.app_walk_cycles[0] - wcyc0
        self.lookups += d["lookups"]
        return d

    def _translate(self, now: float, space, app, vpn: int,
                   ppn_map) -> float:
        sreg, off = divmod(vpn, self.span)
        tag = (space, sreg)
        if self.l1.lookup(tag, off) is not None:
            return now + self.l1_latency
        e = self.l2.lookup(tag, off)
        if e is not None:
            self.l1.insert(tag, e)
            return now + self.l1_latency + self.l2_latency
        t0 = now + self.l1_latency + self.l2_latency
        # App index for the walker's per-app arrays is unused here (the
        # meter keeps its own dicts); charge everything to slot 0.
        done = self.walker.walk(now, t0, 0, vpn, (space, sreg))
        entry = subregion_entry(ppn_map, vpn, self.span)
        self.l2.insert(tag, entry)
        self.l1.insert(tag, entry)
        return done

    # -- invalidation ------------------------------------------------------

    def splinter(self, space, vpn: int) -> None:
        """A page of ``space`` was remapped (CAC compaction / splinter):
        invalidate only the touched subregion's entries."""
        tag = (space, vpn // self.span)
        self.l1.invalidate(tag)
        self.l2.invalidate(tag)

    def drop_space(self, space) -> None:
        """The address space retired: drop its entries wholesale."""
        for tlb in (self.l1, self.l2):
            for tag in [t for t in tlb.d if t[0] == space]:
                del tlb.d[tag]
        for key in [k for k in self.walker.mshr if k[0] == space]:
            del self.walker.mshr[key]

    # -- export ------------------------------------------------------------

    def backlog_us(self, now_us: float) -> float:
        return self.walker.backlog(now_us * self.cycles_per_us) \
            / self.cycles_per_us

    def cycles_us(self, cycles: float) -> float:
        return cycles / self.cycles_per_us

    def summary(self) -> str:
        per_app = " | ".join(
            f"app{a}: {c:.0f} cyc / {self.walks_by_app.get(a, 0)} walks"
            for a, c in sorted(self.cycles_by_app.items()))
        l1r, pwcr = self.l1.rate, self.walker.pwc_hit_rate()
        return (f"translation[{self.mode}] span={self.span}: "
                f"{per_app or 'no lookups'} | "
                f"l1 {0.0 if math.isnan(l1r) else l1r:.1%} | "
                f"pwc {0.0 if math.isnan(pwcr) else pwcr:.1%} | "
                f"queue {self.walker.stall_cycles:.0f} cyc | "
                f"dram {self.walker.dram_accesses()}")
